//! Quickstart: simulate one benchmark under the baseline and under full
//! AMOEBA (warp regrouping), and print the speedup.
//!
//! Run: `cargo run --release --example quickstart [BENCH]`

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::errors::{err, Result};
use amoeba_gpu::sim::gpu::run_benchmark;
use amoeba_gpu::workload::bench;

fn main() -> Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "SM".to_string());
    let profile =
        bench(&name).ok_or_else(|| err(format!("unknown benchmark '{name}' (try: amoeba list)")))?;
    let cfg = SystemConfig::gtx480();

    println!("simulating {name} on the Table-1 machine ({} SMs)...", cfg.num_sms);
    let base = run_benchmark(&cfg, &profile, Scheme::Baseline)?;
    println!("  baseline        : IPC {:.2} ({} cycles)", base.ipc(), base.cycles);

    let amoeba = run_benchmark(&cfg, &profile, Scheme::WarpRegroup)?;
    println!("  AMOEBA(regroup) : IPC {:.2} ({} cycles)", amoeba.ipc(), amoeba.cycles);
    for (i, d) in amoeba.decisions.iter().enumerate() {
        println!(
            "    kernel {i}: P(scale-up)={:.3} -> {}",
            d.probability,
            if d.scale_up { "FUSE" } else { "stay scaled out" }
        );
    }
    println!("  speedup         : {:.2}x", amoeba.ipc() / base.ipc().max(1e-9));
    Ok(())
}
