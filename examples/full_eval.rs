//! Full evaluation: the Fig 12 headline experiment — every benchmark of
//! the paper's main suite under every scheme, with speedups over the
//! scale-out baseline and the geometric mean.
//!
//! Run: `cargo run --release --example full_eval [--quick]`

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::sim::gpu::run_benchmark_seeded;
use amoeba_gpu::stats::Table;
use amoeba_gpu::workload::{bench, FIG12_SET};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = SystemConfig::gtx480();
    if quick {
        cfg.num_sms = 8;
        cfg.num_mcs = 4;
    }
    let mut t = Table::new(
        "Fig 12 — IPC speedup over scale-out baseline",
        &["bench", "scale_up", "static_fuse", "direct_split", "warp_regrouping", "dws"],
    );
    for name in FIG12_SET {
        let mut p = bench(name).unwrap();
        if quick {
            p.num_ctas = p.num_ctas.min(12);
            p.insns_per_thread = p.insns_per_thread.min(100);
            p.num_kernels = 1;
        }
        let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 0xF16).ipc().max(1e-9);
        let row: Vec<f64> = [
            Scheme::ScaleUp,
            Scheme::StaticFuse,
            Scheme::DirectSplit,
            Scheme::WarpRegroup,
            Scheme::Dws,
        ]
        .iter()
        .map(|s| run_benchmark_seeded(&cfg, &p, *s, 0xF16).ipc() / base)
        .collect();
        eprintln!("{name:6}: {row:.2?}");
        t.row(name, row);
    }
    let g = t.geomean_row();
    t.row("GEOMEAN", g);
    println!("\n{}", t.render());
    Ok(())
}
