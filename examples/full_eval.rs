//! Full evaluation: the Fig 12 headline experiment — every benchmark of
//! the paper's main suite under every scheme, with speedups over the
//! scale-out baseline and the geometric mean. The whole grid fans out
//! across cores through the sweep executor (`AMOEBA_JOBS` sets the
//! worker count).
//!
//! Run: `cargo run --release --example full_eval [--quick]`

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::harness::{SimJob, SweepExec};
use amoeba_gpu::stats::Table;
use amoeba_gpu::workload::{bench, FIG12_SET};

fn main() -> amoeba_gpu::errors::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = SystemConfig::gtx480();
    if quick {
        cfg.num_sms = 8;
        cfg.num_mcs = 4;
    }
    let schemes = [
        Scheme::Baseline,
        Scheme::ScaleUp,
        Scheme::StaticFuse,
        Scheme::DirectSplit,
        Scheme::WarpRegroup,
        Scheme::Dws,
    ];

    let mut jobs = Vec::new();
    for name in FIG12_SET {
        let mut p = bench(name).unwrap();
        if quick {
            p.num_ctas = p.num_ctas.min(12);
            p.insns_per_thread = p.insns_per_thread.min(100);
            p.num_kernels = 1;
        }
        for s in schemes {
            jobs.push(SimJob::new(cfg.clone(), p.clone(), s, 0xF16));
        }
    }

    let exec = SweepExec::from_env();
    eprintln!("[full_eval] {} simulations on {} threads...", jobs.len(), exec.threads());
    let reports = exec.run_batch(jobs);

    let mut t = Table::new(
        "Fig 12 — IPC speedup over scale-out baseline",
        &["bench", "scale_up", "static_fuse", "direct_split", "warp_regrouping", "dws"],
    );
    for (bi, name) in FIG12_SET.iter().enumerate() {
        let r = &reports[bi * schemes.len()..(bi + 1) * schemes.len()];
        let base = r[0].ipc().max(1e-9);
        let row: Vec<f64> = r[1..].iter().map(|rep| rep.ipc() / base).collect();
        eprintln!("{name:6}: {row:.2?}");
        t.row(*name, row);
    }
    let g = t.geomean_row();
    t.row("GEOMEAN", g);
    println!("\n{}", t.render());
    Ok(())
}
