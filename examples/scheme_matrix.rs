//! Scheme matrix: every scheme on selected benchmarks with split/fuse
//! event counts — the quick way to eyeball the Fig 12/21 shape.
//!
//! Run: `cargo run --release --example scheme_matrix SM RAY BFS`

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::sim::gpu::run_benchmark_seeded;
use amoeba_gpu::workload::bench;

fn main() {
    let cfg = SystemConfig::gtx480();
    for name in std::env::args().skip(1) {
        let p = bench(&name).unwrap();
        let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 9).unwrap().ipc();
        print!("{name:5} base={base:6.1} |");
        for s in [
            Scheme::ScaleUp,
            Scheme::StaticFuse,
            Scheme::DirectSplit,
            Scheme::WarpRegroup,
            Scheme::Hetero,
            Scheme::Dws,
        ] {
            let r = run_benchmark_seeded(&cfg, &p, s, 9).unwrap();
            print!(" {s}={:.2}({}sp/{}fu)", r.ipc() / base, r.sm.split_events, r.sm.fuse_events);
        }
        println!();
    }
}
