//! End-to-end driver: the full offline-training + online-inference loop
//! of the AMOEBA scalability predictor, exercising all three layers.
//!
//! 1. **Data generation (L3)**: run every benchmark in the suite under
//!    both the scale-out baseline and the fused scale-up machine, collect
//!    profiling-window metric samples, and label them with which machine
//!    actually won (measured IPC). Two datasets come out of this:
//!    *chip-wide* windows (a `StaticFuse` probe — what `DEFAULT_COEFFS`
//!    is fitted on) and *per-cluster* windows (a `Scheme::Hetero` probe,
//!    one sample per cluster per kernel — what `HETERO_COEFFS` is fitted
//!    on; its feature scaling differs, see §4.4).
//! 2. **Training**: by default SGD through the AOT-compiled
//!    `predictor_train.hlo.txt` (JAX train step wrapping the Pallas
//!    gradient kernel) driven from rust via PJRT. With `--native`, a
//!    dependency-free full-batch gradient-descent fit runs instead — so
//!    retraining works on hosts without the `xla` feature or artifacts.
//!    The per-cluster set always fits natively (the compiled train step
//!    is specialised to the chip-wide batch).
//! 3. **Evaluation**: report training accuracy for both sets and print
//!    paste-ready `Coefficients` blocks for `predictor.rs`
//!    (`DEFAULT_COEFFS` / `HETERO_COEFFS`).
//!
//! Run: `cargo run --release --example train_predictor -- --native --quick`
//! (or without `--native` after `make artifacts` for the PJRT path).
//! The headline numbers are recorded in EXPERIMENTS.md.

use amoeba_gpu::amoeba::{
    Controller, MetricsSample, NativePredictor, ScalePredictor, Coefficients, NUM_FEATURES,
};
use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::runtime::{HloPredictor, Runtime};
use amoeba_gpu::sim::gpu::{run_benchmark_seeded, run_benchmark_with_controller};
use amoeba_gpu::workload::all_benchmarks;

/// Full-batch logistic-regression fit (deterministic, no dependencies):
/// minimises BCE with plain gradient descent. Returns (weights,
/// intercept, final loss).
fn fit_logistic(
    xs: &[[f32; NUM_FEATURES]],
    ys: &[f32],
    epochs: usize,
    lr: f64,
) -> ([f64; NUM_FEATURES], f64, f64) {
    let n = xs.len().max(1) as f64;
    let mut w = [0f64; NUM_FEATURES];
    let mut b = 0f64;
    let mut loss = f64::NAN;
    for _ in 0..epochs {
        let mut gw = [0f64; NUM_FEATURES];
        let mut gb = 0f64;
        loss = 0.0;
        for (x, &y) in xs.iter().zip(ys) {
            let mut z = b;
            for (wi, &xi) in w.iter().zip(x) {
                z += wi * xi as f64;
            }
            let p = amoeba_gpu::amoeba::sigmoid(z);
            let y = y as f64;
            // BCE with the usual clamp against log(0).
            let pc = p.clamp(1e-12, 1.0 - 1e-12);
            loss -= y * pc.ln() + (1.0 - y) * (1.0 - pc).ln();
            let err = p - y;
            for (g, &xi) in gw.iter_mut().zip(x) {
                *g += err * xi as f64;
            }
            gb += err;
        }
        loss /= n;
        for (wi, g) in w.iter_mut().zip(gw) {
            *wi -= lr * g / n;
        }
        b -= lr * gb / n;
    }
    (w, b, loss)
}

/// Training accuracy of a coefficient set on a dataset.
fn accuracy(coeffs: Coefficients, xs: &[[f32; NUM_FEATURES]], ys: &[f32]) -> f64 {
    let mut p = NativePredictor::with_coeffs(coeffs);
    let mut correct = 0usize;
    for (x, &y) in xs.iter().zip(ys) {
        let mut f = [0f64; NUM_FEATURES];
        for (o, &v) in f.iter_mut().zip(x) {
            *o = v as f64;
        }
        let s = MetricsSample { features: f };
        correct += (p.scale_up(&s) == (y > 0.5)) as usize;
    }
    correct as f64 / xs.len().max(1) as f64
}

/// Print a paste-ready `Coefficients` block for `amoeba/predictor.rs`.
fn print_coeffs_block(name: &str, w: &[f64; NUM_FEATURES], b: f64) {
    println!("pub const {name}: Coefficients = Coefficients {{");
    println!("    weights: [");
    for (wi, feat) in w.iter().zip(amoeba_gpu::amoeba::FEATURES) {
        println!("        {wi:.9}, // {feat}");
    }
    println!("    ],");
    println!("    intercept: {b:.9},");
    println!("}};");
}

fn main() -> amoeba_gpu::errors::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let native = std::env::args().any(|a| a == "--native");
    let mut cfg = SystemConfig::gtx480();
    if quick {
        cfg.num_sms = 8;
        cfg.num_mcs = 4;
    }

    // ---------------- Phase 1: generate labelled samples -----------------
    println!("== phase 1: generating training data from simulations ==");
    let mut xs: Vec<[f32; NUM_FEATURES]> = Vec::new();
    let mut ys: Vec<f32> = Vec::new();
    // Per-cluster windows (§4.4): one sample per cluster per kernel from
    // the heterogeneous probe, labelled with the same measured outcome.
    let mut xs_cluster: Vec<[f32; NUM_FEATURES]> = Vec::new();
    let mut ys_cluster: Vec<f32> = Vec::new();
    let seeds: &[u64] = if quick { &[1] } else { &[1, 2, 3] };
    for profile in all_benchmarks() {
        let mut p = profile.clone();
        if quick {
            p.num_ctas = p.num_ctas.min(12);
            p.insns_per_thread = p.insns_per_thread.min(100);
            p.num_kernels = 1;
        }
        for &seed in seeds {
            // The chip-wide profiling sample comes from a StaticFuse run
            // (it always profiles in scale-out mode first).
            let probe = run_benchmark_seeded(&cfg, &p, Scheme::StaticFuse, seed)?;
            let hetero_probe = run_benchmark_seeded(&cfg, &p, Scheme::Hetero, seed)?;
            let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, seed)?;
            let fused = run_benchmark_seeded(&cfg, &p, Scheme::ScaleUp, seed)?;
            let label = (fused.ipc() > base.ipc()) as u8 as f32;
            for s in &probe.samples {
                xs.push(s.as_f32());
                ys.push(label);
            }
            for s in &hetero_probe.samples {
                xs_cluster.push(s.as_f32());
                ys_cluster.push(label);
            }
            println!(
                "  {:6} seed={seed}: base={:.2} fused={:.2} -> label={}",
                p.name,
                base.ipc(),
                fused.ipc(),
                if label > 0.5 { "scale-up" } else { "scale-out" }
            );
        }
    }
    println!(
        "  collected {} chip-wide + {} per-cluster samples",
        xs.len(),
        xs_cluster.len()
    );

    // ---------------- Phase 2: train the chip-wide set -------------------
    let epochs = if quick { 200 } else { 800 };
    // Kept alive past training so phase 3 can evaluate through the
    // compiled `predictor_infer` path (None on the --native route).
    let mut rt: Option<Runtime> = None;
    let (w_default, b_default) = if native {
        println!("\n== phase 2: native full-batch logistic fit (chip-wide windows) ==");
        let (w, b, loss) = fit_logistic(&xs, &ys, epochs, 0.8);
        println!("  final BCE: {loss:.4}");
        (w, b)
    } else {
        println!("\n== phase 2: SGD through predictor_train.hlo.txt (PJRT) ==");
        use amoeba_gpu::runtime::HloTrainer;
        let runtime = Runtime::new()?;
        println!("  PJRT platform: {}", runtime.platform());
        let mut trainer = HloTrainer::new(&runtime)?;
        let batch = trainer.batch;
        // Tile the dataset up to the fixed batch (with replication).
        let mut x_flat = vec![0f32; batch * NUM_FEATURES];
        let mut y_flat = vec![0f32; batch];
        for i in 0..batch {
            let j = i % xs.len();
            x_flat[i * NUM_FEATURES..(i + 1) * NUM_FEATURES].copy_from_slice(&xs[j]);
            y_flat[i] = ys[j];
        }
        let mut first_loss = None;
        let mut last_loss = 0.0;
        for e in 0..epochs {
            last_loss = trainer.step(&x_flat, &y_flat, 0.8)?;
            first_loss.get_or_insert(last_loss);
            if e % (epochs / 8).max(1) == 0 {
                println!("  epoch {e:4}: loss {last_loss:.4}");
            }
        }
        println!("  loss: {:.4} -> {last_loss:.4}", first_loss.unwrap_or(0.0));
        let mut w = [0f64; NUM_FEATURES];
        for (o, v) in w.iter_mut().zip(&trainer.weights) {
            *o = *v as f64;
        }
        rt = Some(runtime);
        (w, trainer.intercept as f64)
    };

    // ---------------- Phase 2b: train the per-cluster set ----------------
    println!("\n== phase 2b: native fit on per-cluster (Hetero) windows ==");
    let (w_hetero, b_hetero, loss_h) = fit_logistic(&xs_cluster, &ys_cluster, epochs, 0.8);
    println!("  final BCE: {loss_h:.4}");

    // ---------------- Phase 3: evaluate ----------------------------------
    println!("\n== phase 3: evaluation ==");
    let default_fit = Coefficients { weights: w_default, intercept: b_default };
    let hetero_fit = Coefficients { weights: w_hetero, intercept: b_hetero };
    println!(
        "  chip-wide   : fitted {:.1}% | shipped DEFAULT_COEFFS {:.1}%",
        accuracy(default_fit, &xs, &ys) * 100.0,
        accuracy(amoeba_gpu::amoeba::DEFAULT_COEFFS, &xs, &ys) * 100.0
    );
    println!(
        "  per-cluster : fitted {:.1}% | shipped HETERO_COEFFS  {:.1}%",
        accuracy(hetero_fit, &xs_cluster, &ys_cluster) * 100.0,
        accuracy(amoeba_gpu::amoeba::HETERO_COEFFS, &xs_cluster, &ys_cluster) * 100.0
    );

    // On the PJRT route, additionally validate the compiled inference
    // path end to end: the same fitted weights through `predictor_infer`
    // must reproduce the accuracy (modulo f32 quantization) — this is
    // the "training accuracy (HLO inference path)" number EXPERIMENTS.md
    // records.
    let mut w32 = [0f32; NUM_FEATURES];
    for (o, v) in w32.iter_mut().zip(&w_default) {
        *o = *v as f32;
    }
    if let Some(rt) = &rt {
        let mut hlo = HloPredictor::new(rt, w32, b_default as f32)?;
        let mut correct = 0usize;
        for (x, y) in xs.iter().zip(&ys) {
            let mut f = [0f64; NUM_FEATURES];
            for (o, v) in f.iter_mut().zip(x) {
                *o = *v as f64;
            }
            let pred = hlo.scale_up(&MetricsSample { features: f });
            correct += (pred == (*y > 0.5)) as usize;
        }
        println!(
            "  chip-wide   : {:.1}% through the compiled HLO inference path",
            correct as f64 / xs.len().max(1) as f64 * 100.0
        );
    }

    println!("\n-- paste into rust/src/amoeba/predictor.rs --");
    print_coeffs_block("DEFAULT_COEFFS", &w_default, b_default);
    print_coeffs_block("HETERO_COEFFS", &w_hetero, b_hetero);

    // Full AMOEBA run with the fitted chip-wide model on a benchmark with
    // a strong fuse signal — through PJRT when it trained the model, so
    // the compiled path also drives a whole simulation.
    let mut p = all_benchmarks().into_iter().find(|b| b.name == "SM").unwrap();
    if quick {
        p.num_ctas = 12;
        p.insns_per_thread = 100;
        p.num_kernels = 1;
    }
    let predictor: Box<dyn ScalePredictor> = match &rt {
        Some(rt) => Box::new(HloPredictor::new(rt, w32, b_default as f32)?),
        None => Box::new(NativePredictor::with_coeffs(default_fit)),
    };
    let controller = Controller::with_predictor(predictor);
    let amoeba = run_benchmark_with_controller(&cfg, &p, Scheme::WarpRegroup, controller, 7)?;
    let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 7)?;
    println!(
        "\n  SM with the fitted predictor: {:.2}x over baseline",
        amoeba.ipc() / base.ipc().max(1e-9)
    );
    for (i, d) in amoeba.decisions.iter().enumerate() {
        println!(
            "    kernel {i}: P={:.3} -> {}",
            d.probability,
            if d.scale_up { "FUSE" } else { "out" }
        );
    }
    Ok(())
}
