//! End-to-end driver: the full offline-training + online-inference loop
//! of the AMOEBA scalability predictor, exercising all three layers.
//!
//! 1. **Data generation (L3)**: run every benchmark in the suite under
//!    both the scale-out baseline and the fused scale-up machine, collect
//!    the profiling-window metric sample, and label it with which machine
//!    actually won (measured IPC).
//! 2. **Training (L2+L1 via PJRT)**: drive the AOT-compiled
//!    `predictor_train.hlo.txt` (JAX train step wrapping the Pallas
//!    gradient kernel) from rust — SGD epochs entirely through PJRT.
//! 3. **Evaluation**: report training accuracy, compare against the
//!    native-rust predictor, and run a full AMOEBA simulation using the
//!    *learned* model through the compiled `predictor_infer` path.
//!
//! Run: `make artifacts && cargo run --release --example train_predictor`
//! The headline numbers are recorded in EXPERIMENTS.md.

use amoeba_gpu::amoeba::{Controller, MetricsSample, ScalePredictor, NUM_FEATURES};
use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::runtime::{HloPredictor, HloTrainer, Runtime};
use amoeba_gpu::sim::gpu::{run_benchmark_seeded, run_benchmark_with_controller};
use amoeba_gpu::workload::all_benchmarks;

fn main() -> amoeba_gpu::errors::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = SystemConfig::gtx480();
    if quick {
        cfg.num_sms = 8;
        cfg.num_mcs = 4;
    }

    // ---------------- Phase 1: generate labelled samples -----------------
    println!("== phase 1: generating training data from simulations ==");
    let mut xs: Vec<[f32; NUM_FEATURES]> = Vec::new();
    let mut ys: Vec<f32> = Vec::new();
    let seeds: &[u64] = if quick { &[1] } else { &[1, 2, 3] };
    for profile in all_benchmarks() {
        let mut p = profile.clone();
        if quick {
            p.num_ctas = p.num_ctas.min(12);
            p.insns_per_thread = p.insns_per_thread.min(100);
            p.num_kernels = 1;
        }
        for &seed in seeds {
            // The profiling sample comes from a StaticFuse run (it always
            // profiles in scale-out mode first).
            let probe = run_benchmark_seeded(&cfg, &p, Scheme::StaticFuse, seed);
            let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, seed);
            let fused = run_benchmark_seeded(&cfg, &p, Scheme::ScaleUp, seed);
            let label = (fused.ipc() > base.ipc()) as u8 as f32;
            for s in &probe.samples {
                xs.push(s.as_f32());
                ys.push(label);
            }
            println!(
                "  {:6} seed={seed}: base={:.2} fused={:.2} -> label={}",
                p.name,
                base.ipc(),
                fused.ipc(),
                if label > 0.5 { "scale-up" } else { "scale-out" }
            );
        }
    }
    println!("  collected {} samples", xs.len());

    // ---------------- Phase 2: train via the compiled HLO ----------------
    println!("\n== phase 2: SGD through predictor_train.hlo.txt (PJRT) ==");
    let rt = Runtime::new()?;
    println!("  PJRT platform: {}", rt.platform());
    let mut trainer = HloTrainer::new(&rt)?;
    let batch = trainer.batch;
    // Tile the dataset up to the fixed batch (with replication).
    let mut x_flat = vec![0f32; batch * NUM_FEATURES];
    let mut y_flat = vec![0f32; batch];
    for i in 0..batch {
        let j = i % xs.len();
        x_flat[i * NUM_FEATURES..(i + 1) * NUM_FEATURES].copy_from_slice(&xs[j]);
        y_flat[i] = ys[j];
    }
    let epochs = if quick { 200 } else { 800 };
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for e in 0..epochs {
        last_loss = trainer.step(&x_flat, &y_flat, 0.8)?;
        first_loss.get_or_insert(last_loss);
        if e % (epochs / 8).max(1) == 0 {
            println!("  epoch {e:4}: loss {last_loss:.4}");
        }
    }
    println!("  loss: {:.4} -> {last_loss:.4}", first_loss.unwrap_or(0.0));
    println!("  learned weights: {:?}", trainer.weights);
    println!("  learned intercept: {:.4}", trainer.intercept);

    // ---------------- Phase 3: evaluate ----------------------------------
    println!("\n== phase 3: evaluation ==");
    let mut w = [0f32; NUM_FEATURES];
    w.copy_from_slice(&trainer.weights);
    let mut hlo = HloPredictor::new(&rt, w, trainer.intercept)?;
    let mut correct = 0;
    for (x, y) in xs.iter().zip(&ys) {
        let mut f = [0f64; NUM_FEATURES];
        for (o, v) in f.iter_mut().zip(x) {
            *o = *v as f64;
        }
        let s = MetricsSample { features: f };
        let pred = hlo.scale_up(&s);
        correct += (pred == (*y > 0.5)) as u32;
    }
    let acc = correct as f64 / xs.len().max(1) as f64;
    println!("  training accuracy (HLO inference path): {:.1}%", acc * 100.0);

    // Full AMOEBA run with the learned model through PJRT on a benchmark
    // with a strong fuse signal.
    let mut p = all_benchmarks().into_iter().find(|b| b.name == "SM").unwrap();
    if quick {
        p.num_ctas = 12;
        p.insns_per_thread = 100;
        p.num_kernels = 1;
    }
    let predictor = HloPredictor::new(&rt, w, trainer.intercept)?;
    let controller = Controller::with_predictor(Box::new(predictor));
    let amoeba = run_benchmark_with_controller(&cfg, &p, Scheme::WarpRegroup, controller, 7);
    let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 7);
    println!(
        "  SM with learned predictor through PJRT: {:.2}x over baseline",
        amoeba.ipc() / base.ipc().max(1e-9)
    );
    for (i, d) in amoeba.decisions.iter().enumerate() {
        println!("    kernel {i}: P={:.3} -> {}", d.probability, if d.scale_up { "FUSE" } else { "out" });
    }
    Ok(())
}
