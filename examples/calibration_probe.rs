//! Calibration probe: run one benchmark baseline-vs-fused with ad-hoc
//! profile-knob overrides (`key=val` args: shared, stream, scatter, bcast,
//! ld, ws, div, regs, region, ctas). The tool used to fit the workload
//! profiles to the paper's characterisation — see DESIGN.md "Calibration".
//!
//! Run: `cargo run --release --example calibration_probe SM ws=244 ld=0.42`

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::sim::gpu::run_benchmark_seeded;
use amoeba_gpu::workload::bench;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().cloned().unwrap_or("SM".into());
    let cfg = SystemConfig::gtx480();
    let mut p = bench(&name).unwrap();
    // knob overrides: key=val pairs
    for kv in &args[1..] {
        let (k, v) = kv.split_once('=').unwrap();
        let f: f64 = v.parse().unwrap();
        match k {
            "shared" => p.shared_frac = f,
            "stream" => p.stream_frac = f,
            "scatter" => p.scatter_frac = f,
            "bcast" => p.broadcast_frac = f,
            "ld" => p.frac_ld = f,
            "ws" => p.working_set_lines = f as u32,
            "div" => p.div_prob = f,
            "regs" => p.regs_per_thread = f as u32,
            "region" => p.div_region = f as u16,
            "ctas" => p.num_ctas = f as u32,
            _ => panic!("unknown knob {k}"),
        }
    }
    for scheme in [Scheme::Baseline, Scheme::ScaleUp] {
        let t0 = std::time::Instant::now();
        let r = run_benchmark_seeded(&cfg, &p, scheme, 9).unwrap();
        println!("{scheme:12}: cycles={} ipc={:.2} l1d_miss={:.3} noc_lat={:.0} mc_stall={:.3} ctrl={:.3} mem_stall={} wall={:.1}s",
            r.cycles, r.ipc(), r.sm.l1d_miss_rate(), r.sm.avg_noc_latency(),
            r.chip.mc_inject_stall_rate(), r.sm.control_stall_rate(), r.sm.stall_memory, t0.elapsed().as_secs_f32());
    }
}
