//! Label check: measure baseline-vs-fused IPC for every benchmark and
//! compare the measured winner against the paper's ground-truth label
//! (`scale_up_expected`). The suite-level calibration acceptance test.
//!
//! Run: `cargo run --release --example label_check`

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::sim::gpu::run_benchmark_seeded;
use amoeba_gpu::workload::all_benchmarks;

fn main() {
    let cfg = SystemConfig::gtx480();
    println!("{:6} {:>8} {:>8} {:>7} {:>9} {:>6}", "bench", "base", "fused", "ratio", "expected", "match");
    let mut ok = 0;
    let mut n = 0;
    for p in all_benchmarks() {
        let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 9).unwrap().ipc();
        let fused = run_benchmark_seeded(&cfg, &p, Scheme::ScaleUp, 9).unwrap().ipc();
        let ratio = fused / base;
        let measured_up = ratio > 1.02;
        let m = measured_up == p.scale_up_expected;
        ok += m as u32;
        n += 1;
        println!("{:6} {:8.1} {:8.1} {:7.2} {:>9} {:>6}", p.name, base, fused, ratio,
            if p.scale_up_expected { "up" } else { "out" }, if m { "OK" } else { "MISS" });
    }
    println!("label match: {ok}/{n}");
}
