//! NoC scaling explorer: reproduce the Fig 3 methodology interactively —
//! sweep SM counts under mesh vs perfect NoC and print normalised IPC.
//!
//! Run: `cargo run --release --example noc_explorer [BENCH...]`

use amoeba_gpu::config::{NocMode, Scheme, SystemConfig};
use amoeba_gpu::sim::gpu::run_benchmark;
use amoeba_gpu::stats::Table;
use amoeba_gpu::workload::bench;

fn main() -> amoeba_gpu::errors::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let names: Vec<String> = if args.is_empty() {
        ["CP", "RAY", "MUM", "SC"].iter().map(|s| s.to_string()).collect()
    } else {
        args
    };
    let sm_counts = [16usize, 24, 36, 64];

    for mode in [NocMode::Mesh, NocMode::Perfect] {
        let mut t = Table::new(
            format!("IPC vs SM count ({mode} NoC), normalised to 16 SMs"),
            &["bench", "16", "24", "36", "64"],
        );
        for name in &names {
            let profile = bench(name)
                .ok_or_else(|| amoeba_gpu::errors::err(format!("unknown benchmark '{name}'")))?;
            let mut row = Vec::new();
            let mut base = None;
            for n in sm_counts {
                let mut cfg = SystemConfig::gtx480().with_sm_count(n);
                cfg.noc_mode = mode;
                let ipc = run_benchmark(&cfg, &profile, Scheme::Baseline)?.ipc();
                let b = *base.get_or_insert(ipc);
                row.push(ipc / b);
            }
            t.row(name.clone(), row);
            eprint!(".");
        }
        eprintln!();
        println!("{}", t.render());
    }
    Ok(())
}
