//! Fuse/split dynamics trace (the Fig 19 experiment): run RAY under
//! warp-regrouping and render each cluster's fuse/split phases over time
//! as an ASCII timeline.
//!
//! Run: `cargo run --release --example dynamics_trace [BENCH]`

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::sim::core::ClusterMode;
use amoeba_gpu::sim::gpu::run_benchmark;
use amoeba_gpu::workload::bench;

fn main() -> amoeba_gpu::errors::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "RAY".to_string());
    let profile =
        bench(&name).ok_or_else(|| amoeba_gpu::errors::err(format!("unknown benchmark '{name}'")))?;
    let cfg = SystemConfig::gtx480();
    println!("tracing {name} under warp_regrouping ({} clusters)...", cfg.num_sms / 2);
    let r = run_benchmark(&cfg, &profile, Scheme::WarpRegroup)?;

    // Render the first 5 clusters (as the paper's Fig 19 does).
    let shown = 5.min(cfg.num_sms / 2);
    println!("\nlegend: F=fused  s=split  .=private/baseline   (one column per sample)\n");
    for sm in 0..shown {
        let line: String = r
            .phases
            .iter()
            .map(|p| match p.modes.get(sm) {
                Some(ClusterMode::Fused) => 'F',
                Some(ClusterMode::FusedSplit) => 's',
                _ => '.',
            })
            .collect();
        println!("SM{sm:02} |{line}|");
    }
    let splits = r.sm.split_events;
    let fuses = r.sm.fuse_events;
    println!("\nsplit events: {splits}, re-fuse events: {fuses}");
    println!("fused cycles: {}, split cycles: {}", r.sm.fused_cycles, r.sm.split_cycles);
    println!("IPC: {:.2}", r.ipc());
    Ok(())
}
