#!/usr/bin/env bash
# CI entry point: build, test, smoke-run the figure harness, and record
# the sweep-executor speedup in BENCH_sweep.json (the perf trajectory is
# tracked from PR 1 onward — keep the file committed after each run).
#
# Usage: ./ci.sh            # full pipeline
#        AMOEBA_JOBS=8 ./ci.sh
set -euo pipefail
cd "$(dirname "$0")"

echo "== build (release) =="
cargo build --release

echo "== build benches + examples =="
cargo build --release --benches --examples

echo "== tests =="
cargo test -q

echo "== figures smoke (quick mode, parallel + memoized) =="
./target/release/figures --all --quick > /dev/null

echo "== sweep speedup benchmark (writes BENCH_sweep.json) =="
cargo bench --bench bench_sweep

echo "== BENCH_sweep.json =="
cat BENCH_sweep.json

echo "CI OK"
