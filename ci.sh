#!/usr/bin/env bash
# CI entry point: build, test (with per-binary timings), run the golden
# suite under BOTH execution modes, smoke the figure harness, and record
# the sweep/skip/server speedups in BENCH_sweep.json (the perf trajectory
# is tracked from PR 1 onward — keep the file committed after each run).
#
# Usage: ./ci.sh            # full pipeline
#        AMOEBA_JOBS=8 ./ci.sh
set -euo pipefail
cd "$(dirname "$0")"

# A missing toolchain used to silently skip everything (PR 1-3's build
# containers had no cargo and the stale BENCH_sweep.json went unnoticed).
# Fail loudly instead: CI without a compiler is not CI.
if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: cargo not found on PATH — install the rust toolchain." >&2
    echo "       (BENCH_sweep.json still carries stale/pending numbers;" >&2
    echo "        rust/tests/goldens/ cannot be generated without it.)" >&2
    exit 1
fi

TIMING_SUMMARY=""
run_timed() { # run_timed <label> <cmd...>
    local label="$1"; shift
    local start end
    start=$(date +%s)
    "$@"
    end=$(date +%s)
    TIMING_SUMMARY+=$(printf '%-38s %4ds' "$label" "$((end - start))")$'\n'
}

echo "== build (release) =="
run_timed "build release" cargo build --release

echo "== build benches + examples =="
run_timed "build benches+examples" cargo build --release --benches --examples

echo "== tests (per-binary timings recorded) =="
run_timed "unit tests (lib+bins)" cargo test -q --lib --bins
# Every integration-test file gets its own timed run — derived from the
# directory so a future suite can never be silently skipped.
for f in rust/tests/*.rs; do
    t=$(basename "$f" .rs)
    run_timed "test $t" cargo test -q --test "$t"
done
run_timed "doc tests" cargo test -q --doc

echo "== golden suite (AMOEBA_DENSE=1: dense reference loop) =="
# The goldens are mode-independent by the skip==dense contract; running
# the suite again under the dense loop proves the committed fingerprints
# hold in both execution modes.
run_timed "golden_reports (dense)" env AMOEBA_DENSE=1 cargo test -q --test golden_reports

echo "== determinism suite (AMOEBA_DENSE=1) =="
# The determinism suite compares skip vs dense in-process regardless of
# the env; this pass additionally proves the whole suite holds when the
# escape hatch pins every env-driven run (figures, sweeps) to dense.
run_timed "exec_determinism (dense)" env AMOEBA_DENSE=1 cargo test -q --test exec_determinism

echo "== active-set determinism pass (default scheduler, dense cross-check) =="
# The per-component active-set scheduler is the default execution mode;
# this pass pins goldens + determinism explicitly under it (AMOEBA_DENSE
# unset/0) so the cross-check against the AMOEBA_DENSE=1 passes above is
# recorded as its own timed CI step, not an accident of the default env.
run_timed "golden_reports (active-set)" env AMOEBA_DENSE=0 cargo test -q --test golden_reports
run_timed "exec_determinism (active-set)" env AMOEBA_DENSE=0 cargo test -q --test exec_determinism
run_timed "prop_invariants (active-set)" env AMOEBA_DENSE=0 cargo test -q --test prop_invariants

echo "== fault-mode determinism pass (AMOEBA_DENSE=0/1) =="
# The fault-injection paths (half-SM retirement, cluster retirement, NoC
# degrade, MC stalls) must hold the skip==dense contract too: run the
# faulted determinism tests and the fault property tests explicitly
# under both execution modes.
run_timed "fault determinism (active-set)" env AMOEBA_DENSE=0 \
    cargo test -q --test exec_determinism faulted
run_timed "fault determinism (dense)" env AMOEBA_DENSE=1 \
    cargo test -q --test exec_determinism faulted
run_timed "fault invariants (active-set)" env AMOEBA_DENSE=0 \
    cargo test -q --test prop_invariants fault retired_cluster
run_timed "fault invariants (dense)" env AMOEBA_DENSE=1 \
    cargo test -q --test prop_invariants fault retired_cluster

echo "== checkpoint round-trip pass (AMOEBA_DENSE=0/1) =="
# Capture/restore must be bit-identical in both execution modes — the
# checkpoint tests compare the resumed run against the uninterrupted one
# and the two modes' checkpoint bytes against each other.
run_timed "checkpoint restore (active-set)" env AMOEBA_DENSE=0 \
    cargo test -q --test exec_determinism checkpoint
run_timed "checkpoint restore (dense)" env AMOEBA_DENSE=1 \
    cargo test -q --test exec_determinism checkpoint
run_timed "checkpoint fuzz" env AMOEBA_DENSE=0 \
    cargo test -q --test prop_invariants checkpoint memo_truncation

echo "== intra-sim parallel determinism pass (AMOEBA_TICK_JOBS=4, DENSE=0/1) =="
# Fanning one simulation's live cluster set across worker threads must be
# bit-identical to the serial walk for every thread count — in-process
# the tick_jobs tests compare jobs 1 vs {2,4} directly, and this pass
# additionally pins the whole determinism + property suites with the
# env-driven fan-out engaged, under both execution modes (the dense loop
# ignores tick jobs by design; that, too, is asserted).
run_timed "tick-jobs determinism (active-set)" env AMOEBA_DENSE=0 AMOEBA_TICK_JOBS=4 \
    cargo test -q --test exec_determinism tick_jobs
run_timed "tick-jobs determinism (dense)" env AMOEBA_DENSE=1 AMOEBA_TICK_JOBS=4 \
    cargo test -q --test exec_determinism tick_jobs
run_timed "tick-jobs invariants (active-set)" env AMOEBA_DENSE=0 AMOEBA_TICK_JOBS=4 \
    cargo test -q --test prop_invariants tick_jobs

echo "== adaptive tick-jobs pass (AMOEBA_TICK_JOBS=auto, DENSE=0/1) =="
# The auto sizer picks the worker count from the live-cluster census each
# cycle; bit-identity vs the 1-worker walk must hold for every census it
# can produce, so the same tick_jobs suite runs again with the env knob
# set to auto (the dense loop ignores tick jobs either way — asserted).
run_timed "tick-jobs auto (active-set)" env AMOEBA_DENSE=0 AMOEBA_TICK_JOBS=auto \
    cargo test -q --test exec_determinism tick_jobs
run_timed "tick-jobs auto (dense)" env AMOEBA_DENSE=1 AMOEBA_TICK_JOBS=auto \
    cargo test -q --test exec_determinism tick_jobs

echo "== fleet determinism pass (serial vs parallel chips, DENSE=0/1) =="
# The pool scheduler fans per-chip shards across the SweepExec; the fleet
# tests compare 1-thread vs N-thread executors in-process, and this pass
# pins the comparison under both execution modes, plus the conservation
# property (every launch served exactly once, or honestly rejected or
# dropped — never double-served, never silently lost).
run_timed "fleet determinism (active-set)" env AMOEBA_DENSE=0 \
    cargo test -q --test exec_determinism fleet
run_timed "fleet determinism (dense)" env AMOEBA_DENSE=1 \
    cargo test -q --test exec_determinism fleet
run_timed "fleet conservation (active-set)" env AMOEBA_DENSE=0 \
    cargo test -q --test prop_invariants fleet

echo "== bisect smoke (artificial divergence must localize) =="
# A clean run vs the same run with a cluster killed at cycle 200: the
# bisector must report a divergence (at a cycle after the injection).
run_timed "amoeba bisect smoke" bash -c \
    './target/release/amoeba bisect CP --quick --faults-b cluster0@200 | grep -q "diverged at cycle"'
run_timed "amoeba bisect identical" bash -c \
    './target/release/amoeba bisect CP --quick | grep -q "identical"'

# `status --porcelain` reports both modified tracked goldens and brand-new
# (untracked) ones.
if [ -n "$(git status --porcelain -- rust/tests/goldens 2>/dev/null)" ]; then
    echo "NOTE: rust/tests/goldens/ changed (first blessing or re-bless) — commit it."
fi

echo "== figures smoke (quick mode, parallel + memoized, incl. srv) =="
run_timed "figures --all --quick" ./target/release/figures --all --quick > /dev/null

echo "== qos figure (quick mode: priority mix x load, partition-scoped drain) =="
run_timed "figures --fig qos --quick" ./target/release/figures --fig qos --quick > /dev/null

echo "== fleet figure (quick mode: chips x tenants pool sweep + chip loss) =="
run_timed "figures --fig fleet --quick" ./target/release/figures --fig fleet --quick > /dev/null

echo "== serve-sim smoke =="
run_timed "amoeba serve-sim --quick" ./target/release/amoeba serve-sim --quick > /dev/null
run_timed "serve-sim qos smoke" ./target/release/amoeba serve-sim --quick \
    --policy adaptive --bursty \
    --tenants SM:hetero:high@400_000,BFS:warp_regrouping,CP:baseline:low > /dev/null

echo "== serve-fleet smoke (healthy pool + chip-loss migration) =="
run_timed "serve-fleet smoke" ./target/release/amoeba serve-fleet --quick > /dev/null
# Chip 0 loses all four clusters at cycle 10 (the quick pool chip is
# 8 SMs = 4 clusters): its tenants must migrate to a healthy peer or be
# dropped honestly — the summary line always reports the migration count.
run_timed "serve-fleet chip-loss smoke" bash -c \
    "./target/release/amoeba serve-fleet --quick --chips 3 \
     --faults '0:cluster0@10,cluster1@10,cluster2@10,cluster3@10' \
     | grep -q 'migrations'"

echo "== sweep + cycle-skip + server benchmark (writes BENCH_sweep.json) =="
run_timed "bench_sweep" cargo bench --bench bench_sweep

echo "== BENCH_sweep.json =="
cat BENCH_sweep.json

# Acceptance bars on the measured numbers (open item since PR 1): the
# event-horizon engine must be >= 2x on at least one memory-bound
# profile, and the server sweep must have been recorded.
best=$(sed -n 's/.*"cycle_skip_best": \([0-9.]*\).*/\1/p' BENCH_sweep.json | head -1)
if [ -z "$best" ]; then
    echo "ERROR: BENCH_sweep.json has no measured cycle_skip_best" >&2
    exit 1
fi
awk -v b="$best" 'BEGIN { exit !(b >= 2.0) }' || {
    echo "ERROR: cycle_skip_best = ${best}x, below the 2x acceptance bar" >&2
    exit 1
}
# An actual record, not the stale `"server_sweep": null` marker.
grep -q '"server_sweep": {' BENCH_sweep.json || {
    echo "ERROR: BENCH_sweep.json has no measured server_sweep record" >&2
    exit 1
}
# Fault plumbing must be measured and free when unused: the bench
# asserts bit-identity of no-trace vs empty-trace in-process, and the
# record proves the assertion actually ran.
grep -q '"fault_sweep": {' BENCH_sweep.json || {
    echo "ERROR: BENCH_sweep.json has no measured fault_sweep record" >&2
    exit 1
}
grep -q '"identical": true' BENCH_sweep.json || {
    echo "ERROR: fault_sweep record did not confirm empty-trace identity" >&2
    exit 1
}
# The QoS scenario (partition-scoped drain + priority preemption) must be
# measured with skip==dense identity confirmed on its bursty mixed-
# priority trace.
grep -q '"qos_sweep": {' BENCH_sweep.json || {
    echo "ERROR: BENCH_sweep.json has no measured qos_sweep record" >&2
    exit 1
}
# Active-set acceptance: the one-hot-tenant (partial-quiescence) profile
# must be >= 1.5x over the dense loop — the regime the whole-chip
# cycle-skip bar cannot measure.
da=$(sed -n 's/.*"dense_active_speedup": \([0-9.]*\).*/\1/p' BENCH_sweep.json | head -1)
if [ -z "$da" ]; then
    echo "ERROR: BENCH_sweep.json has no measured dense_active_speedup" >&2
    exit 1
fi
awk -v d="$da" 'BEGIN { exit !(d >= 1.5) }' || {
    echo "ERROR: dense_active_speedup = ${da}x, below the 1.5x acceptance bar" >&2
    exit 1
}
# Intra-simulation parallel ticking must be measured (hot 64-SM chip,
# jobs 1 vs N, bit-identity asserted in-process by the bench).
grep -q '"intra_sim_speedup":' BENCH_sweep.json || {
    echo "ERROR: BENCH_sweep.json has no measured intra_sim_speedup" >&2
    exit 1
}
# Fleet serving must be measured (chips-vs-tenants pool sweep; the bench
# asserts serial-vs-parallel FleetReport bit-identity in-process).
grep -q '"fleet_sweep": {' BENCH_sweep.json || {
    echo "ERROR: BENCH_sweep.json has no measured fleet_sweep record" >&2
    exit 1
}
echo "acceptance: cycle_skip_best ${best}x >= 2x, dense_active ${da}x >= 1.5x, server_sweep + intra_sim + fleet_sweep recorded"

echo "== per-step timing summary =="
printf '%s' "$TIMING_SUMMARY"

echo "CI OK"
