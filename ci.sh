#!/usr/bin/env bash
# CI entry point: build, test, smoke-run the figure harness, and record
# the sweep-executor + event-horizon speedups in BENCH_sweep.json (the
# perf trajectory is tracked from PR 1 onward — keep the file committed
# after each run).
#
# Usage: ./ci.sh            # full pipeline
#        AMOEBA_JOBS=8 ./ci.sh
set -euo pipefail
cd "$(dirname "$0")"

echo "== build (release) =="
cargo build --release

echo "== build benches + examples =="
cargo build --release --benches --examples

echo "== tests =="
cargo test -q

echo "== tests (AMOEBA_DENSE=1: dense reference loop) =="
# The determinism suite compares skip vs dense in-process regardless of
# the env; this pass additionally proves the whole suite holds when the
# escape hatch pins every env-driven run (figures, sweeps) to dense.
AMOEBA_DENSE=1 cargo test -q --test exec_determinism

echo "== figures smoke (quick mode, parallel + memoized) =="
./target/release/figures --all --quick > /dev/null

echo "== sweep + cycle-skip speedup benchmark (writes BENCH_sweep.json) =="
cargo bench --bench bench_sweep

echo "== BENCH_sweep.json =="
cat BENCH_sweep.json

echo "CI OK"
