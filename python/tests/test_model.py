"""Layer-2 model shape/semantics tests + AOT artifact smoke checks."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def test_infer_shapes():
    x = np.zeros((1, model.NUM_FEATURES), np.float32)
    w = np.zeros((model.NUM_FEATURES,), np.float32)
    (p,) = model.infer(x, w, np.float32(0.0))
    assert p.shape == (1,)
    np.testing.assert_allclose(p, [0.5], atol=1e-6)  # zero logit => 0.5


def test_infer_batch_shapes():
    x = np.random.default_rng(0).normal(size=(model.INFER_BATCH, model.NUM_FEATURES)).astype(np.float32)
    w = np.ones((model.NUM_FEATURES,), np.float32)
    (p,) = model.infer_batch(x, w, np.float32(0.1))
    assert p.shape == (model.INFER_BATCH,)
    want = ref.logistic_forward(jnp.asarray(x), jnp.ones(model.NUM_FEATURES), jnp.float32(0.1))
    np.testing.assert_allclose(p, want, rtol=1e-5, atol=1e-6)


def test_train_step_learns_synthetic_rule():
    """Driving train_step must fit a linearly-separable synthetic ruleset."""
    rng = np.random.default_rng(42)
    true_w = rng.normal(size=(model.NUM_FEATURES,)).astype(np.float32) * 2
    x = rng.normal(size=(model.TRAIN_BATCH, model.NUM_FEATURES)).astype(np.float32)
    y = (x @ true_w > 0).astype(np.float32)
    w = jnp.zeros(model.NUM_FEATURES, jnp.float32)
    b = jnp.float32(0.0)
    losses = []
    step = jax.jit(model.train_step)
    for _ in range(200):
        w, b, loss = step(x, y, w, b, jnp.float32(1.0))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.2
    pred = np.asarray(ref.logistic_forward(jnp.asarray(x), w, b)) > 0.5
    acc = float(np.mean(pred == (y > 0.5)))
    assert acc > 0.95


def test_feature_order_matches_design():
    """Pin the feature count + ordering contract shared with rust."""
    assert model.NUM_FEATURES == 10
    names = [s[0] for s in model.specs()]
    assert names == ["predictor_infer", "predictor_batch", "predictor_train"]


def test_artifacts_exist_and_are_hlo_text():
    """make artifacts output must be parseable-looking HLO text modules."""
    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(adir):
        import pytest

        pytest.skip("artifacts/ not built")
    for name in ("predictor_infer", "predictor_batch", "predictor_train"):
        path = os.path.join(adir, f"{name}.hlo.txt")
        assert os.path.exists(path), f"missing {path} (run make artifacts)"
        head = open(path).read(200)
        assert "HloModule" in head
