"""Pallas predictor kernels vs the pure-jnp oracle (ref.py).

This is the CORE Layer-1 correctness signal: hypothesis sweeps shapes and
value ranges; every case asserts allclose against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import predictor as K
from compile.kernels import ref

RTOL, ATOL = 1e-5, 1e-6


def _case(rng, n, f, scale=1.0):
    x = rng.normal(size=(n, f)).astype(np.float32) * scale
    w = rng.normal(size=(f,)).astype(np.float32) * scale
    b = np.float32(rng.normal() * scale)
    y = (rng.random(size=(n,)) > 0.5).astype(np.float32)
    return x, w, b, y


# ---------------------------------------------------------------- forward

@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 300),
    f=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
    block=st.sampled_from([8, 32, 64, 128]),
)
def test_forward_matches_ref(n, f, seed, block):
    rng = np.random.default_rng(seed)
    x, w, b, _ = _case(rng, n, f)
    got = K.logistic_forward(x, w, b, block_b=block)
    want = ref.logistic_forward(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    assert got.shape == (n,)
    assert got.dtype == jnp.float32


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([1e-3, 1.0, 30.0]))
def test_forward_value_ranges(seed, scale):
    """Probabilities stay in [0,1] even for large |logit| (no NaN/Inf)."""
    rng = np.random.default_rng(seed)
    x, w, b, _ = _case(rng, 50, 10, scale=scale)
    p = np.asarray(K.logistic_forward(x, w, b))
    assert np.all(np.isfinite(p))
    assert np.all((p >= 0.0) & (p <= 1.0))


def test_forward_bf16_inputs():
    """Kernel accumulates in f32 even when fed bfloat16 metric rows."""
    rng = np.random.default_rng(0)
    x, w, b, _ = _case(rng, 17, 10)
    got = K.logistic_forward(jnp.asarray(x, jnp.bfloat16), w, b)
    want = ref.logistic_forward(
        jnp.asarray(x, jnp.bfloat16).astype(jnp.float32), jnp.asarray(w), jnp.asarray(b)
    )
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_forward_decision_sign_equivalence():
    """P > 0.5 iff logit > 0 — the rust fast path relies on this."""
    rng = np.random.default_rng(7)
    x, w, b, _ = _case(rng, 200, 10)
    p = np.asarray(K.logistic_forward(x, w, b))
    z = np.asarray(ref.logistic_logits(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)))
    np.testing.assert_array_equal(p > 0.5, z > 0)


# ---------------------------------------------------------------- backward

@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 200),
    f=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
    block=st.sampled_from([16, 64, 128]),
)
def test_grads_match_ref(n, f, seed, block):
    rng = np.random.default_rng(seed)
    x, w, b, y = _case(rng, n, f)
    gw, gb, loss = K.bce_grads(x, w, b, y, block_b=block)
    rgw, rgb = ref.bce_grads(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), jnp.asarray(y))
    rloss = ref.bce_loss(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), jnp.asarray(y))
    np.testing.assert_allclose(gw, rgw, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gb, rgb, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(loss, rloss, rtol=1e-4, atol=1e-5)


def test_grads_zero_at_perfect_fit():
    """If the model already separates the labels with huge margin, grads ~ 0."""
    x = np.array([[10.0], [-10.0]], np.float32)
    w = np.array([10.0], np.float32)
    b = np.float32(0.0)
    y = np.array([1.0, 0.0], np.float32)
    gw, gb, loss = K.bce_grads(x, w, b, y, block_b=16)
    assert abs(float(gw[0])) < 1e-6 and abs(float(gb)) < 1e-6
    assert float(loss) < 1e-6


def test_grad_descent_reduces_loss():
    """A few SGD steps with the Pallas grads must reduce the ref loss."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(128, 10)).astype(np.float32)
    true_w = rng.normal(size=(10,)).astype(np.float32)
    y = (x @ true_w > 0).astype(np.float32)
    w = np.zeros(10, np.float32)
    b = np.float32(0.0)
    l0 = float(ref.bce_loss(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), jnp.asarray(y)))
    for _ in range(50):
        gw, gb, _ = K.bce_grads(x, w, b, y)
        w = w - 0.5 * np.asarray(gw)
        b = np.float32(b - 0.5 * float(gb))
    l1 = float(ref.bce_loss(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), jnp.asarray(y)))
    assert l1 < l0 * 0.5


# ---------------------------------------------------------------- misc

def test_vmem_footprint_within_budget():
    """Forward tile must fit comfortably in a 16 MiB VMEM core budget."""
    assert K.vmem_footprint_bytes(K.DEFAULT_BLOCK_B, 10) < 1 << 20
    assert K.vmem_footprint_bytes(128, 128) < 1 << 20


@pytest.mark.parametrize("n", [1, 7, 8, 9, 127, 128, 129])
def test_padding_boundaries(n):
    """Batch sizes straddling the tile boundary are exact (masking works)."""
    rng = np.random.default_rng(n)
    x, w, b, y = _case(rng, n, 10)
    got = K.logistic_forward(x, w, b, block_b=8)
    want = ref.logistic_forward(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    gw, gb, loss = K.bce_grads(x, w, b, y, block_b=8)
    rgw, rgb = ref.bce_grads(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), jnp.asarray(y))
    np.testing.assert_allclose(gw, rgw, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gb, rgb, rtol=1e-4, atol=1e-5)
