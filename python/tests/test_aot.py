"""AOT pipeline tests: the lowered HLO text must be stable, parseable and
re-generable, and the lowering must preserve numerics vs direct execution."""

import os
import subprocess
import sys
import tempfile

import numpy as np
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

import jax


def test_to_hlo_text_shape():
    lowered = jax.jit(model.infer).lower(*model.specs()[0][2])
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: the root is a tuple.
    assert "tuple(" in text or "(f32[1]" in text


def test_aot_cli_writes_all_artifacts():
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", d],
            check=True,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            env=env,
        )
        names = sorted(os.listdir(d))
        assert names == [
            "predictor_batch.hlo.txt",
            "predictor_infer.hlo.txt",
            "predictor_train.hlo.txt",
        ]
        for n in names:
            assert os.path.getsize(os.path.join(d, n)) > 1000


def test_lowered_infer_matches_direct_call():
    """jit-lowered+compiled output == direct (unlowered) model call."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(1, model.NUM_FEATURES)).astype(np.float32)
    w = rng.normal(size=(model.NUM_FEATURES,)).astype(np.float32)
    b = np.float32(0.3)
    direct = model.infer(x, w, b)[0]
    compiled = jax.jit(model.infer).lower(x, w, b).compile()(x, w, b)[0]
    np.testing.assert_allclose(direct, compiled, rtol=1e-6, atol=1e-7)
    want = ref.logistic_forward(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(compiled, want, rtol=1e-5, atol=1e-6)


def test_train_step_lowering_roundtrip():
    rng = np.random.default_rng(6)
    args = (
        rng.normal(size=(model.TRAIN_BATCH, model.NUM_FEATURES)).astype(np.float32),
        (rng.random(model.TRAIN_BATCH) > 0.5).astype(np.float32),
        rng.normal(size=(model.NUM_FEATURES,)).astype(np.float32),
        np.float32(0.1),
        np.float32(0.5),
    )
    direct = model.train_step(*args)
    compiled = jax.jit(model.train_step).lower(*args).compile()(*args)
    for d, c in zip(direct, compiled):
        np.testing.assert_allclose(d, c, rtol=1e-5, atol=1e-6)
