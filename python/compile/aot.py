"""AOT: lower the Layer-2 model to HLO *text* artifacts for the rust runtime.

HLO text — NOT ``HloModuleProto.serialize()`` — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/): ``python -m compile.aot --out-dir ../artifacts``
Invoked by ``make artifacts``; a no-op when outputs are newer than inputs
(handled by make). Python never runs on the rust request path.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, fn, example_args in model.specs():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name}: {len(text)} chars -> {path}")


if __name__ == "__main__":
    main()
