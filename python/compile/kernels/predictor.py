"""Layer-1 Pallas kernels for the AMOEBA scalability predictor.

The paper (§5.5) evaluates its binary-logistic predictor in a pipelined
Booth-Wallace MAC IP block fed by per-SM performance counters. On a
TPU-class target the natural re-expression (DESIGN.md §Hardware-Adaptation)
is a *batched* fused MAC + sigmoid: one MXU-shaped pass evaluates a whole
batch of pending per-kernel decisions (and, offline, the whole training
set). The batch dimension is tiled with BlockSpec so the HBM->VMEM schedule
streams metric rows through VMEM exactly like the paper's counter buffer
streamed into the MAC.

Kernels (all checked against ``ref.py`` by pytest/hypothesis):

* ``mac_sigmoid_kernel``  — P = sigmoid(X @ w + b) over a (block_b, F) tile.
* ``bce_grad_kernel``     — per-tile contribution to (dw, db, loss) of the
                            batch-mean binary cross entropy, accumulated
                            across sequential grid steps.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU lowering is treated as compile-only
(DESIGN.md). Numerics are identical either way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch tile. 128 matches the MXU systolic dimension; the feature
# dimension (10 metrics, padded by the caller if desired) always stays
# resident in VMEM.
DEFAULT_BLOCK_B = 128


def _pad_batch(a: jnp.ndarray, block_b: int) -> jnp.ndarray:
    """Pad the leading (batch) dim of ``a`` up to a multiple of block_b."""
    n = a.shape[0]
    rem = (-n) % block_b
    if rem == 0:
        return a
    pad = [(0, rem)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad)


# ---------------------------------------------------------------------------
# Forward: P = sigmoid(X @ w + b)
# ---------------------------------------------------------------------------

def mac_sigmoid_kernel(x_ref, w_ref, b_ref, o_ref):
    """One batch tile of the fused MAC + sigmoid.

    x_ref: (block_b, F) metric rows      (VMEM tile of the batch)
    w_ref: (F, 1)       coefficients     (fully VMEM-resident)
    b_ref: (1, 1)       intercept
    o_ref: (block_b, 1) probabilities
    """
    # MXU-shaped matmul; accumulate in f32 regardless of input dtype.
    logit = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) + b_ref[0, 0].astype(jnp.float32)
    o_ref[...] = (1.0 / (1.0 + jnp.exp(-logit))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b",))
def logistic_forward(x, w, b, *, block_b: int = DEFAULT_BLOCK_B):
    """P = sigmoid(x @ w + b) via the Pallas MAC kernel.

    x: (batch, F) float; w: (F,) or (F,1); b: scalar or (1,1).
    Returns (batch,) float32 probabilities.
    """
    n, f = x.shape
    w2 = jnp.asarray(w, jnp.float32).reshape(f, 1)
    b2 = jnp.asarray(b, jnp.float32).reshape(1, 1)
    xp = _pad_batch(jnp.asarray(x), block_b)
    grid = (xp.shape[0] // block_b,)
    out = pl.pallas_call(
        mac_sigmoid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((f, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
        interpret=True,
    )(xp, w2, b2)
    return out[:n, 0]


# ---------------------------------------------------------------------------
# Backward: batch-mean BCE gradient, tile-accumulated
# ---------------------------------------------------------------------------

def bce_grad_kernel(x_ref, w_ref, b_ref, y_ref, nvalid_ref,
                    gw_ref, gb_ref, loss_ref):
    """Accumulate one batch tile's contribution to (dw, db, loss).

    The grid walks batch tiles sequentially (Pallas guarantees sequential
    grid execution on TPU/interpret), so accumulation into the output refs
    is safe: tile 0 initialises, later tiles add. Padded rows are masked
    with a global-row iota against ``nvalid``.
    """
    i = pl.program_id(0)
    block_b = x_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    nvalid = nvalid_ref[0, 0]

    z = jnp.dot(x, w_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b_ref[0, 0]
    row = jax.lax.broadcasted_iota(jnp.float32, (block_b, 1), 0) + i * block_b
    valid = (row < nvalid).astype(jnp.float32)

    p = 1.0 / (1.0 + jnp.exp(-z))
    dz = valid * (p - y) / nvalid
    # Stable BCE: max(z,0) - z*y + log1p(exp(-|z|)), masked then tile-summed.
    bce = valid * (jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))

    gw_tile = jnp.dot(x.T, dz, preferred_element_type=jnp.float32)
    gb_tile = jnp.sum(dz, keepdims=True).reshape(1, 1)
    loss_tile = (jnp.sum(bce, keepdims=True) / nvalid).reshape(1, 1)

    @pl.when(i == 0)
    def _init():
        gw_ref[...] = gw_tile
        gb_ref[...] = gb_tile
        loss_ref[...] = loss_tile

    @pl.when(i > 0)
    def _acc():
        gw_ref[...] += gw_tile
        gb_ref[...] += gb_tile
        loss_ref[...] += loss_tile


@functools.partial(jax.jit, static_argnames=("block_b",))
def bce_grads(x, w, b, y, *, block_b: int = DEFAULT_BLOCK_B):
    """(dw, db, loss) of mean-BCE via the Pallas gradient kernel.

    x: (batch, F); w: (F,)/(F,1); b: scalar; y: (batch,)/(batch,1) in {0,1}.
    Returns dw (F,), db scalar, loss scalar — all float32.
    """
    n, f = x.shape
    w2 = jnp.asarray(w, jnp.float32).reshape(f, 1)
    b2 = jnp.asarray(b, jnp.float32).reshape(1, 1)
    y2 = jnp.asarray(y, jnp.float32).reshape(n, 1)
    xp = _pad_batch(jnp.asarray(x), block_b)
    yp = _pad_batch(y2, block_b)
    nvalid = jnp.full((1, 1), float(n), jnp.float32)
    grid = (xp.shape[0] // block_b,)
    gw, gb, loss = pl.pallas_call(
        bce_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((f, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((f, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((f, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(xp, w2, b2, yp, nvalid)
    return gw[:, 0], gb[0, 0], loss[0, 0]


def vmem_footprint_bytes(block_b: int, f: int) -> int:
    """Analytic VMEM footprint of one forward tile (DESIGN.md §Perf L1).

    x tile + w + b + out tile, all f32. Used by the perf report, and by
    tests asserting we stay far under the ~16 MiB/core VMEM budget.
    """
    return 4 * (block_b * f + f * 1 + 1 + block_b * 1)
