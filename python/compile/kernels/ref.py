"""Pure-jnp reference oracles for the Pallas predictor kernels.

These are the *correctness ground truth* for the Layer-1 kernels in
``predictor.py``. They implement the paper's binary-logistic scalability
predictor (AMOEBA §4.1.3) in straight-line jax.numpy with no Pallas:

    logit  = X @ w + b                     (the Booth-Wallace MAC IP, §5.5)
    P      = sigmoid(logit)                (eq. 2/5)
    decide = P > 0.5  <=>  logit > 0       (fuse / don't-fuse)

plus the training-step math (gradient of the batch-mean binary cross
entropy over eq.-5 logits, fitted by SGD).

Everything here is deliberately trivial jnp so that pytest/hypothesis can
assert_allclose the Pallas kernels against it across shapes and dtypes.
"""

from __future__ import annotations

import jax.numpy as jnp


def logistic_logits(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Raw logits (log-odds, paper eq. 1). Sign(logit) is the fuse decision.

    x: (batch, features) profiled metric vectors (one row per kernel sample)
    w: (features,)       trained coefficients (paper Table 2)
    b: ()                intercept
    """
    return x @ w + b


def logistic_forward(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """P = sigmoid(x @ w + b), shape (batch,) — probability to scale up."""
    return 1.0 / (1.0 + jnp.exp(-logistic_logits(x, w, b)))


def bce_loss(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean binary cross-entropy of the predictor on labelled samples.

    Numerically stable: BCE(z, y) = max(z,0) - z*y + log1p(exp(-|z|)).
    """
    z = logistic_logits(x, w, b)
    return jnp.mean(jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def bce_grads(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, y: jnp.ndarray):
    """Analytic gradients of ``bce_loss`` w.r.t. (w, b).

    dL/dz = (sigmoid(z) - y) / batch;  dL/dw = x^T dL/dz;  dL/db = sum dL/dz.
    """
    p = logistic_forward(x, w, b)
    dz = (p - y) / x.shape[0]
    return x.T @ dz, jnp.sum(dz)


def sgd_train_step(x, w, b, y, lr):
    """One SGD step on (w, b); returns (w', b', loss)."""
    gw, gb = bce_grads(x, w, b, y)
    loss = bce_loss(x, w, b, y)
    return w - lr * gw, b - lr * gb, loss
