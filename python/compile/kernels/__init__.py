from . import predictor, ref  # noqa: F401
