"""Layer-2 JAX model: the AMOEBA scalability predictor (paper §4.1.3).

This module is the build-time compute-graph definition. It composes the
Layer-1 Pallas kernels (``kernels.predictor``) into the three functions the
rust coordinator executes through PJRT:

* ``infer``       — one decision: P(scale-up) for a single 10-metric row.
* ``infer_batch`` — a batch of decisions (offline sweeps, Fig 20 analysis).
* ``train_step``  — one SGD step of the offline training pipeline
                    (examples/train_predictor.rs drives the epoch loop from
                    rust; weight buffers are donated so XLA updates them
                    in place).

Feature order — MUST match ``rust/src/amoeba/metrics.rs::FEATURES``:

    0 control_divergent   inactive-thread rate from control divergence
    1 coalescing          coalescing rate (actual/requested accesses)
    2 l1d_miss            L1 data cache miss rate
    3 l1i_miss            L1 instruction cache miss rate
    4 l1c_miss            L1 constant cache miss rate
    5 mshr                MSHR merge rate
    6 load_inst_rate      fraction of load instructions
    7 store_inst_rate     fraction of store instructions
    8 noc                 NoC intensity (latency-weighted throughput)
    9 concurrent_cta      concurrently resident CTAs (normalised)

Paper Table 2 ships the authors' trained coefficients in this order; they
are the default weights in rust (``predictor::PAPER_COEFFS``) and the
regression target of the parity tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import predictor as K

NUM_FEATURES = 10
TRAIN_BATCH = 256
INFER_BATCH = 64


def infer(x, w, b):
    """P(scale-up) for a single metrics row. x: (1, F) -> (1,) f32."""
    return (K.logistic_forward(x, w, b, block_b=8),)


def infer_batch(x, w, b):
    """P(scale-up) for a batch of metric rows. x: (B, F) -> (B,) f32."""
    return (K.logistic_forward(x, w, b, block_b=64),)


def train_step(x, y, w, b, lr):
    """One SGD step on (w, b); returns (w', b', loss).

    x: (TRAIN_BATCH, F); y: (TRAIN_BATCH,); lr: scalar (1,1).
    The gradient is the Pallas ``bce_grad_kernel``; the update is plain jnp
    so XLA fuses the whole step into one executable.
    """
    gw, gb, loss = K.bce_grads(x, w, b, y, block_b=64)
    lr_s = jnp.asarray(lr, jnp.float32).reshape(())
    w2 = jnp.asarray(w, jnp.float32).reshape(-1)
    b2 = jnp.asarray(b, jnp.float32).reshape(())
    return w2 - lr_s * gw, b2 - lr_s * gb, loss


def specs():
    """(name, fn, example-arg ShapeDtypeStructs, donate) for every artifact."""
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return [
        (
            "predictor_infer",
            infer,
            (s((1, NUM_FEATURES), f32), s((NUM_FEATURES,), f32), s((), f32)),
        ),
        (
            "predictor_batch",
            infer_batch,
            (s((INFER_BATCH, NUM_FEATURES), f32), s((NUM_FEATURES,), f32), s((), f32)),
        ),
        (
            "predictor_train",
            train_step,
            (
                s((TRAIN_BATCH, NUM_FEATURES), f32),
                s((TRAIN_BATCH,), f32),
                s((NUM_FEATURES,), f32),
                s((), f32),
                s((), f32),
            ),
        ),
    ]
