//! Predictor latency — native logistic vs the PJRT-compiled HLO path.
//! The controller runs once per kernel launch; the paper claims a
//! negligible decision overhead (§5.5), which this verifies.
//! Run: `make artifacts && cargo bench --bench bench_predictor`

use amoeba_gpu::amoeba::{MetricsSample, NativePredictor, ScalePredictor, NUM_FEATURES};
use amoeba_gpu::harness::Bencher;
use amoeba_gpu::runtime::{HloPredictor, Runtime};

fn main() {
    let sample = MetricsSample { features: [0.25; NUM_FEATURES] };
    let mut b = Bencher::new("predictor");
    b.iters = 100;

    let mut native = NativePredictor::new();
    b.bench("native", || native.probability(std::hint::black_box(&sample)));

    match Runtime::new().and_then(|rt| HloPredictor::new(&rt, [0.5; NUM_FEATURES], -1.0)) {
        Ok(mut hlo) => {
            b.bench("hlo_pjrt", || hlo.probability(std::hint::black_box(&sample)));
        }
        Err(e) => eprintln!("skipping hlo_pjrt (artifacts missing?): {e}"),
    }
}
