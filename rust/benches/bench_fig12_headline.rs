//! End-to-end simulation throughput for the Fig 12 headline
//! configurations (shrunken workloads — this measures *simulator* speed;
//! the per-scheme IPC tables come from `figures --fig 12`).
//! Run: `cargo bench --bench bench_fig12_headline`

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::harness::Bencher;
use amoeba_gpu::sim::gpu::run_benchmark_seeded;
use amoeba_gpu::workload::bench;

fn main() {
    let mut cfg = SystemConfig::gtx480();
    cfg.num_sms = 16;
    cfg.num_mcs = 4;
    let mut b = Bencher::new("fig12_headline");
    b.iters = 5;
    b.warmup = 1;
    for scheme in [Scheme::Baseline, Scheme::ScaleUp, Scheme::WarpRegroup] {
        for name in ["SM", "RAY"] {
            let mut p = bench(name).unwrap();
            p.num_ctas = 24;
            p.insns_per_thread = 100;
            p.num_kernels = 1;
            let label = format!("{name}_{scheme}");
            let r = b.bench(&label, || run_benchmark_seeded(&cfg, &p, scheme, 0xBE7C).unwrap());
            // Report simulated-cycles/sec as the throughput figure.
            let report = run_benchmark_seeded(&cfg, &p, scheme, 0xBE7C).unwrap();
            let cps = report.cycles as f64 / r.median.as_secs_f64();
            println!("    -> {:.2} Mcycles/s simulated ({} cycles)", cps / 1e6, report.cycles);
        }
    }
}
