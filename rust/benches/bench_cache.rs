//! Cache + MSHR hot path (L1 access mix under reuse/streaming).
//! Run: `cargo bench --bench bench_cache`

use amoeba_gpu::harness::Bencher;
use amoeba_gpu::sim::mem::{Access, Cache};

fn main() {
    let b = Bencher::new("cache");

    b.bench_batched(
        "l1_reuse_hits_512acc",
        || {
            let mut cache = Cache::new(16 << 10, 4, 128, 1, 64);
            for i in 0..64u64 {
                cache.access(i * 128);
                cache.fill(i * 128);
            }
            cache
        },
        |mut cache| {
            for r in 0..8u64 {
                for i in 0..64u64 {
                    let _ = cache.access(((i * 7 + r) % 64) * 128);
                }
            }
            cache
        },
    );

    b.bench_batched(
        "l1_streaming_misses_512acc",
        || Cache::new(16 << 10, 4, 128, 1, 64),
        |mut cache| {
            let mut addr = 0u64;
            for _ in 0..512 {
                match cache.access(addr) {
                    Access::MissNew => {
                        cache.fill(addr);
                    }
                    Access::MshrFull => {
                        cache.fill(addr - 128);
                    }
                    _ => {}
                }
                addr += 128;
            }
            cache
        },
    );
}
