//! Mesh NoC routing throughput under load (Fig 17/18 substrate).
//! Run: `cargo bench --bench bench_noc`

use amoeba_gpu::config::SystemConfig;
use amoeba_gpu::harness::Bencher;
use amoeba_gpu::sim::noc::{Noc, Packet, Payload, Subnet};

fn main() {
    let cfg = SystemConfig::gtx480();
    let b = Bencher::new("noc");
    for (label, nodes) in [("mesh56_baseline_256cyc", 56usize), ("mesh32_fused_256cyc", 32)] {
        b.bench_batched(
            label,
            || Noc::with_nodes(&cfg, nodes),
            |mut noc| {
                let mcs = 8;
                for t in 0..256u64 {
                    for src in 0..nodes - mcs {
                        let dst = nodes - mcs + (src % mcs);
                        let _ = noc.inject(
                            Subnet::Request,
                            Packet {
                                src,
                                dst,
                                flits: 1,
                                born: t,
                                payload: Payload::MemRequest {
                                    line: src as u64 * 128,
                                    requester: src as u32,
                                    is_write: false,
                                },
                            },
                        );
                    }
                    noc.tick(t);
                    for n in nodes - mcs..nodes {
                        while noc.eject(Subnet::Request, n).is_some() {}
                    }
                }
                noc
            },
        );
    }
}
