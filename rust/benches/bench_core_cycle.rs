//! SM-cluster cycle-loop throughput (the L3 hot path).
//! Run: `cargo bench --bench bench_core_cycle`

use amoeba_gpu::config::SystemConfig;
use amoeba_gpu::harness::Bencher;
use amoeba_gpu::sim::core::{ClusterMode, SmCluster};
use amoeba_gpu::sim::noc::Noc;
use amoeba_gpu::workload::{bench, kernel_launches, TraceGen};

fn main() {
    let cfg = SystemConfig::tiny();
    let profile = bench("CP").unwrap();
    let k = kernel_launches(&profile, 1)[0].clone();
    let gen = TraceGen::new(&profile, &k);
    let b = Bencher::new("core_cycle");

    for (label, mode) in [
        ("private_pair_512cyc", ClusterMode::PrivatePair),
        ("fused_512cyc", ClusterMode::Fused),
        ("fused_split_512cyc", ClusterMode::FusedSplit),
    ] {
        b.bench_batched(
            label,
            || {
                let mut cl = SmCluster::new(0, &cfg, mode);
                cl.dispatch_cta(&k, 0, &gen);
                (cl, Noc::with_nodes(&cfg, 6))
            },
            |(mut cl, mut noc)| {
                for now in 0..512u64 {
                    cl.tick(now, &mut noc, [0, 1], &gen);
                }
                (cl, noc)
            },
        );
    }
}
