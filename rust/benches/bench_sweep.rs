//! Full-suite sweep wall-clock: the old serial per-figure replay vs the
//! memoized parallel executor — the headline number for the sweep
//! subsystem. Writes `BENCH_sweep.json` (consumed by ci.sh to track the
//! perf trajectory across PRs).
//!
//! The job list reproduces what quick-mode figure regeneration used to
//! simulate before the executor existed: the seven per-scheme sweep
//! figures (12/13/14/15/16/17/18) each re-ran the full bench x scheme
//! grid, and Fig 21 re-ran DWS + warp-regrouping — duplicates included.
//! "serial" replays that list one simulation at a time (the old
//! behaviour); "parallel+memo" hands the same list to [`SweepExec`].
//!
//! Run: `cargo bench --bench bench_sweep`  (threads via AMOEBA_JOBS)

use std::time::Instant;

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::harness::{SimJob, SweepExec};
use amoeba_gpu::sim::gpu::run_benchmark_seeded;
use amoeba_gpu::workload::{bench, BenchProfile, FIG12_SET};

/// Mirror of the harness quick-mode shrink + base config (kept in sync
/// with `harness::figures`).
fn quick_cfg() -> SystemConfig {
    let mut c = SystemConfig::gtx480();
    c.num_sms = 8;
    c.num_mcs = 4;
    c.max_cycles = 2_000_000;
    c.profile_window = 1_000;
    c
}

fn quick_profile(name: &str) -> BenchProfile {
    let mut p = bench(name).unwrap();
    p.num_ctas = p.num_ctas.min(16);
    p.insns_per_thread = p.insns_per_thread.min(120);
    p.num_kernels = 1;
    p
}

const SEED: u64 = 0xA30EBA;
/// Per-scheme sweep figures that each replayed the full grid (Figs
/// 12/13/14/15/16/17/18).
const SWEEP_FIGURES: usize = 7;

fn main() {
    let cfg = quick_cfg();
    let benches: &[&str] = &FIG12_SET[..4];

    // The duplicate-laden instance list the pre-executor harness ran.
    let mut jobs: Vec<SimJob> = Vec::new();
    for _fig in 0..SWEEP_FIGURES {
        for name in benches {
            for s in Scheme::FIG12 {
                jobs.push(SimJob::new(cfg.clone(), quick_profile(name), s, SEED));
            }
        }
    }
    for name in benches {
        for s in [Scheme::Dws, Scheme::WarpRegroup] {
            jobs.push(SimJob::new(cfg.clone(), quick_profile(name), s, SEED));
        }
    }

    let exec = SweepExec::from_env();
    let threads = exec.threads();
    eprintln!(
        "[bench_sweep] {} job instances (quick figure replay), {} threads",
        jobs.len(),
        threads
    );

    // -------- Before: serial replay, no memoization (old behaviour).
    let t0 = Instant::now();
    for job in &jobs {
        std::hint::black_box(run_benchmark_seeded(&job.cfg, &job.profile, job.scheme, job.seed));
    }
    let serial = t0.elapsed();
    eprintln!("[bench_sweep] serial replay      : {:.2} s", serial.as_secs_f64());

    // -------- After: one batch through the parallel memoized executor.
    let t1 = Instant::now();
    let reports = exec.run_batch(jobs.clone());
    let parallel = t1.elapsed();
    std::hint::black_box(&reports);
    let (hits, misses) = exec.cache_stats();
    eprintln!(
        "[bench_sweep] parallel + memoized: {:.2} s ({} unique sims, {} cache hits)",
        parallel.as_secs_f64(),
        misses,
        hits
    );

    // -------- Memo-only contribution: a fresh 1-thread executor.
    let ser_exec = SweepExec::serial();
    let t2 = Instant::now();
    std::hint::black_box(ser_exec.run_batch(jobs.clone()));
    let memo_only = t2.elapsed();
    eprintln!("[bench_sweep] serial + memoized  : {:.2} s", memo_only.as_secs_f64());

    let speedup = serial.as_secs_f64() / parallel.as_secs_f64().max(1e-9);
    let memo_speedup = serial.as_secs_f64() / memo_only.as_secs_f64().max(1e-9);
    eprintln!("[bench_sweep] speedup: {speedup:.2}x total ({memo_speedup:.2}x from memoization alone)");

    let json = format!(
        "{{\n  \"benchmark\": \"figures_quick_sweep_replay\",\n  \"job_instances\": {},\n  \"unique_jobs\": {},\n  \"threads\": {},\n  \"serial_replay_s\": {:.3},\n  \"parallel_memo_s\": {:.3},\n  \"serial_memo_s\": {:.3},\n  \"speedup\": {:.3},\n  \"memo_only_speedup\": {:.3}\n}}\n",
        jobs.len(),
        misses,
        threads,
        serial.as_secs_f64(),
        parallel.as_secs_f64(),
        memo_only.as_secs_f64(),
        speedup,
        memo_speedup,
    );
    match std::fs::write("BENCH_sweep.json", &json) {
        Ok(()) => eprintln!("[bench_sweep] wrote BENCH_sweep.json"),
        Err(e) => eprintln!("[bench_sweep] could not write BENCH_sweep.json: {e}"),
    }
    print!("{json}");
}
