//! Full-suite sweep wall-clock: the old serial per-figure replay vs the
//! memoized parallel executor — the headline number for the sweep
//! subsystem — plus the event-horizon skip engine vs the dense cycle
//! loop on the memory-divergent profiles. Writes `BENCH_sweep.json`
//! (consumed by ci.sh to track the perf trajectory across PRs).
//!
//! The job list reproduces what quick-mode figure regeneration used to
//! simulate before the executor existed: the seven per-scheme sweep
//! figures (12/13/14/15/16/17/18) each re-ran the full bench x scheme
//! grid, and Fig 21 re-ran DWS + warp-regrouping — duplicates included.
//! "serial" replays that list one simulation at a time (the old
//! behaviour); "parallel+memo" hands the same list to [`SweepExec`].
//!
//! Run: `cargo bench --bench bench_sweep`  (threads via AMOEBA_JOBS)

use std::time::Instant;

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::harness::{SimJob, SweepExec};
use amoeba_gpu::runtime::fleet::{serve_fleet, FleetConfig};
use amoeba_gpu::runtime::serve;
use amoeba_gpu::sim::fault::FaultTrace;
use amoeba_gpu::sim::gpu::{
    run_benchmark_faulted, run_benchmark_seeded, run_benchmark_seeded_dense,
    run_benchmark_seeded_jobs, serve_streams_dense, PartitionPolicy,
};
use amoeba_gpu::workload::{
    bench, shrink_streams, traffic_trace, traffic_trace_qos, BenchProfile, KernelStream, Priority,
    TenantQosSpec, TrafficPattern, FIG12_SET,
};

/// Mirror of the harness quick-mode shrink + base config (kept in sync
/// with `harness::figures`).
fn quick_cfg() -> SystemConfig {
    let mut c = SystemConfig::gtx480();
    c.num_sms = 8;
    c.num_mcs = 4;
    c.max_cycles = 2_000_000;
    c.profile_window = 1_000;
    c
}

fn quick_profile(name: &str) -> BenchProfile {
    let mut p = bench(name).unwrap();
    p.num_ctas = p.num_ctas.min(16);
    p.insns_per_thread = p.insns_per_thread.min(120);
    p.num_kernels = 1;
    p
}

const SEED: u64 = 0xA30EBA;
/// Per-scheme sweep figures that each replayed the full grid (Figs
/// 12/13/14/15/16/17/18).
const SWEEP_FIGURES: usize = 7;

fn main() {
    let cfg = quick_cfg();
    let benches: &[&str] = &FIG12_SET[..4];

    // The duplicate-laden instance list the pre-executor harness ran.
    let mut jobs: Vec<SimJob> = Vec::new();
    for _fig in 0..SWEEP_FIGURES {
        for name in benches {
            for s in Scheme::FIG12 {
                jobs.push(SimJob::new(cfg.clone(), quick_profile(name), s, SEED));
            }
        }
    }
    for name in benches {
        for s in [Scheme::Dws, Scheme::WarpRegroup] {
            jobs.push(SimJob::new(cfg.clone(), quick_profile(name), s, SEED));
        }
    }

    let exec = SweepExec::from_env();
    let threads = exec.threads();
    eprintln!(
        "[bench_sweep] {} job instances (quick figure replay), {} threads",
        jobs.len(),
        threads
    );

    // -------- Before: serial replay, no memoization (old behaviour).
    let t0 = Instant::now();
    for job in &jobs {
        std::hint::black_box(
            run_benchmark_seeded(&job.cfg, &job.profile, job.scheme, job.seed).unwrap(),
        );
    }
    let serial = t0.elapsed();
    eprintln!("[bench_sweep] serial replay      : {:.2} s", serial.as_secs_f64());

    // -------- After: one batch through the parallel memoized executor.
    let t1 = Instant::now();
    let reports = exec.run_batch(jobs.clone());
    let parallel = t1.elapsed();
    std::hint::black_box(&reports);
    let (hits, misses) = exec.cache_stats();
    eprintln!(
        "[bench_sweep] parallel + memoized: {:.2} s ({} unique sims, {} cache hits)",
        parallel.as_secs_f64(),
        misses,
        hits
    );

    // -------- Memo-only contribution: a fresh 1-thread executor.
    let ser_exec = SweepExec::serial();
    let t2 = Instant::now();
    std::hint::black_box(ser_exec.run_batch(jobs.clone()));
    let memo_only = t2.elapsed();
    eprintln!("[bench_sweep] serial + memoized  : {:.2} s", memo_only.as_secs_f64());

    let speedup = serial.as_secs_f64() / parallel.as_secs_f64().max(1e-9);
    let memo_speedup = serial.as_secs_f64() / memo_only.as_secs_f64().max(1e-9);
    eprintln!("[bench_sweep] speedup: {speedup:.2}x total ({memo_speedup:.2}x from memoization alone)");

    // -------- Event-horizon cycle skipping: dense vs skip wall-clock on
    // the memory-divergent profiles (the §5/Fig 12 set the paper cares
    // most about). Low occupancy keeps the chip quiescent between DRAM
    // releases, which is exactly the regime the skip engine targets.
    // CP is the control: compute-bound, so its ratio measures the pure
    // overhead of the quiescence probe on live cycles (expected ~1.0 —
    // a value well below 1 flags a dense-path regression). Bit-identity
    // of the two reports is asserted on every pair.
    eprintln!("[bench_sweep] event-horizon skip vs dense (single-thread, no memo):");
    let mut skip_rows = String::new();
    let mut best_skip = (0.0f64, "");
    for name in ["BFS", "MUM", "SM", "CP"] {
        let mut p = quick_profile(name);
        p.num_ctas = 6; // low occupancy: long quiescent windows
        let t_dense = Instant::now();
        let dense = run_benchmark_seeded_dense(&cfg, &p, Scheme::Baseline, SEED, true).unwrap();
        let dense_s = t_dense.elapsed().as_secs_f64();
        let t_skip = Instant::now();
        let skipped = run_benchmark_seeded_dense(&cfg, &p, Scheme::Baseline, SEED, false).unwrap();
        let skip_s = t_skip.elapsed().as_secs_f64();
        assert_eq!(dense, skipped, "{name}: skip must be bit-identical to dense");
        let ratio = dense_s / skip_s.max(1e-9);
        eprintln!(
            "[bench_sweep]   {name:4}: dense {dense_s:.3} s, skip {skip_s:.3} s -> {ratio:.2}x (cycles={})",
            dense.cycles
        );
        if ratio > best_skip.0 {
            best_skip = (ratio, name);
        }
        if !skip_rows.is_empty() {
            skip_rows.push_str(",\n");
        }
        skip_rows.push_str(&format!(
            "    {{ \"bench\": \"{name}\", \"dense_s\": {dense_s:.3}, \"skip_s\": {skip_s:.3}, \"speedup\": {ratio:.3} }}"
        ));
    }
    eprintln!(
        "[bench_sweep] best skip speedup: {:.2}x on {} (target >= 2x on a memory-bound profile)",
        best_skip.0, best_skip.1
    );

    // -------- Active-set on a PARTIALLY busy chip: one hot tenant on a
    // wide machine whose other tenants finished immediately. This is the
    // regime `cycle_skip*` cannot measure — the hot tenant keeps the
    // chip from ever being *fully* quiescent for long, so the old
    // whole-chip skip degenerates toward dense ticking, while the
    // active-set engine parks every idle cluster/partition/router
    // individually and the cycle cost tracks the live work. Dense vs
    // active wall-clock, bit-identity asserted.
    eprintln!("[bench_sweep] active-set vs dense on a one-hot-tenant chip:");
    let mut da_cfg = quick_cfg();
    da_cfg.num_sms = 16; // 8 clusters: 7 of them idle once the CP tenants drain
    da_cfg.num_mcs = 8;
    let mut hot = bench("BFS").unwrap();
    hot.num_ctas = 12;
    hot.insns_per_thread = 120;
    hot.num_kernels = 4;
    let mut da_streams =
        vec![KernelStream::back_to_back("hot:BFS", hot, Scheme::Baseline, SEED)];
    let mut idle = bench("CP").unwrap();
    idle.num_ctas = 2;
    idle.insns_per_thread = 24;
    idle.num_kernels = 1;
    for i in 0..3 {
        da_streams.push(KernelStream::back_to_back(
            format!("idle{i}:CP"),
            idle.clone(),
            Scheme::Baseline,
            SEED + 1 + i as u64,
        ));
    }
    let t_dd = Instant::now();
    let da_dense =
        serve_streams_dense(&da_cfg, &da_streams, PartitionPolicy::Static, true).unwrap();
    let da_dense_s = t_dd.elapsed().as_secs_f64();
    let t_da = Instant::now();
    let da_active =
        serve_streams_dense(&da_cfg, &da_streams, PartitionPolicy::Static, false).unwrap();
    let da_active_s = t_da.elapsed().as_secs_f64();
    assert_eq!(da_dense, da_active, "one-hot-tenant: active-set must be bit-identical to dense");
    let dense_active_speedup = da_dense_s / da_active_s.max(1e-9);
    eprintln!(
        "[bench_sweep]   dense {da_dense_s:.3} s, active {da_active_s:.3} s -> \
         {dense_active_speedup:.2}x on {} tenants / {} clusters (cycles={})",
        da_streams.len(),
        da_cfg.num_sms / 2,
        da_dense.cycles
    );

    // -------- Server sweep: the concurrent multi-tenant stream scenario
    // (the "srv" figure's workload). One shared run per policy plus each
    // tenant alone, fanned through the stream memo; skip-vs-dense
    // bit-identity is asserted on the static-policy shared run, and its
    // wall-clock ratio is recorded alongside the single-app numbers.
    eprintln!("[bench_sweep] server sweep (concurrent streams):");
    let mut streams = traffic_trace(&serve::default_tenants(), 2, 20_000, SEED);
    shrink_streams(&mut streams, 8, 80);
    let t_sd = Instant::now();
    let sdense = serve_streams_dense(&cfg, &streams, PartitionPolicy::Static, true).unwrap();
    let sdense_s = t_sd.elapsed().as_secs_f64();
    let t_ss = Instant::now();
    let sskip = serve_streams_dense(&cfg, &streams, PartitionPolicy::Static, false).unwrap();
    let sskip_s = t_ss.elapsed().as_secs_f64();
    assert_eq!(sdense, sskip, "server run: skip must be bit-identical to dense");
    let stream_skip_ratio = sdense_s / sskip_s.max(1e-9);
    let t_batch = Instant::now();
    let shared = [PartitionPolicy::Static, PartitionPolicy::Adaptive];
    let sout = exec.run_stream_batch(serve::server_jobs(&cfg, &streams, &shared));
    let batch_s = t_batch.elapsed().as_secs_f64();
    let antt_worst = (0..streams.len())
        .map(|ti| serve::antt_slowdown(&sout[0], &sout[shared.len() + ti], ti))
        .fold(0.0f64, f64::max);
    eprintln!(
        "[bench_sweep]   dense {sdense_s:.3} s, skip {sskip_s:.3} s -> {stream_skip_ratio:.2}x; \
         {}-job batch {batch_s:.3} s; worst tenant ANTT {antt_worst:.2}",
        shared.len() + streams.len()
    );

    // -------- Fault-injection plumbing must be free when unused: the
    // faulted entry point threads the trace through both cycle loops
    // (fast-forward caps clamp to the next fault cycle), so this pins
    // the zero-event case to the plain path — bit-identical report, and
    // the wall-clock ratio records that the clamp costs nothing when
    // `next_fault_cycle()` is never finite.
    eprintln!("[bench_sweep] fault plumbing overhead (empty trace):");
    let fp = quick_profile("BFS");
    let t_nf = Instant::now();
    let no_trace = run_benchmark_seeded(&cfg, &fp, Scheme::Baseline, SEED).unwrap();
    let no_trace_s = t_nf.elapsed().as_secs_f64();
    let t_ef = Instant::now();
    let empty_trace =
        run_benchmark_faulted(&cfg, &fp, Scheme::Baseline, SEED, &FaultTrace::default()).unwrap();
    let empty_trace_s = t_ef.elapsed().as_secs_f64();
    assert_eq!(no_trace, empty_trace, "empty fault trace must be bit-identical to no trace");
    let fault_overhead = empty_trace_s / no_trace_s.max(1e-9);
    eprintln!(
        "[bench_sweep]   no-trace {no_trace_s:.3} s, empty-trace {empty_trace_s:.3} s -> \
         {fault_overhead:.2}x (reports identical)"
    );

    // -------- QoS sweep: the mixed-priority bursty scenario (the "qos"
    // figure's workload) under the Adaptive policy — the path that
    // exercises partition-scoped drain, the quiesce gate, and
    // CTA-boundary preemption all at once. Skip-vs-dense bit-identity is
    // asserted (the active-set contract must survive preemption), and
    // the run's preemption count is recorded.
    eprintln!("[bench_sweep] qos sweep (mixed-priority bursty streams):");
    let prios = [Priority::High, Priority::Normal, Priority::Low];
    let qspecs: Vec<TenantQosSpec> = serve::default_tenants()
        .into_iter()
        .zip(prios)
        .map(|((profile, scheme), priority)| TenantQosSpec {
            profile,
            scheme,
            priority,
            slo_turnaround: (priority == Priority::High).then_some(400_000),
        })
        .collect();
    let mut qstreams = traffic_trace_qos(
        &qspecs,
        2,
        2_000,
        SEED,
        TrafficPattern::Bursty { burst_len: 4, dilation: 8 },
    );
    shrink_streams(&mut qstreams, 8, 80);
    let t_qd = Instant::now();
    let qdense = serve_streams_dense(&cfg, &qstreams, PartitionPolicy::Adaptive, true).unwrap();
    let qdense_s = t_qd.elapsed().as_secs_f64();
    let t_qs = Instant::now();
    let qskip = serve_streams_dense(&cfg, &qstreams, PartitionPolicy::Adaptive, false).unwrap();
    let qskip_s = t_qs.elapsed().as_secs_f64();
    assert_eq!(qdense, qskip, "qos run: skip must be bit-identical to dense under preemption");
    let qos_skip_ratio = qdense_s / qskip_s.max(1e-9);
    eprintln!(
        "[bench_sweep]   dense {qdense_s:.3} s, skip {qskip_s:.3} s -> {qos_skip_ratio:.2}x; \
         {} preemptions, {} CTAs preempted (reports identical)",
        qdense.chip.preemptions, qdense.chip.ctas_preempted
    );

    // -------- Checkpoint save/load on a 16-SM chip: serialization and
    // parse wall-clock plus the byte size of a mid-run capture, and the
    // zero-cost contract — a snapshot armed past the end never fires
    // and the run stays bit-identical to one that never armed at all.
    eprintln!("[bench_sweep] checkpoint save/load (16-SM chip):");
    let mut snap_cfg = quick_cfg();
    snap_cfg.num_sms = 16;
    snap_cfg.num_mcs = 8;
    let snap_p = quick_profile("BFS");
    let baseline =
        run_benchmark_seeded(&snap_cfg, &snap_p, Scheme::Baseline, SEED).unwrap();
    let (armed_unfired, no_cp) = amoeba_gpu::sim::gpu::run_benchmark_snapshot(
        &snap_cfg, &snap_p, Scheme::Baseline, SEED, false, u64::MAX, None,
    )
    .unwrap();
    assert!(no_cp.is_none(), "armed-past-the-end snapshot must not fire");
    assert_eq!(baseline, armed_unfired, "an unfired snapshot arm must cost nothing");
    let mid = baseline.cycles / 2;
    let (_, cp) = amoeba_gpu::sim::gpu::run_benchmark_snapshot(
        &snap_cfg, &snap_p, Scheme::Baseline, SEED, false, mid, None,
    )
    .unwrap();
    let cp = cp.expect("mid-run snapshot must fire");
    let t_save = Instant::now();
    let cp_bytes = std::hint::black_box(cp.to_bytes());
    let save_s = t_save.elapsed().as_secs_f64();
    let snapshot_bytes = cp_bytes.len();
    let t_load = Instant::now();
    let reloaded =
        std::hint::black_box(amoeba_gpu::sim::Checkpoint::from_bytes(&cp_bytes).unwrap());
    let load_s = t_load.elapsed().as_secs_f64();
    let resumed = amoeba_gpu::sim::gpu::run_benchmark_resume(
        &snap_cfg, &snap_p, Scheme::Baseline, SEED, false, &reloaded,
    )
    .unwrap();
    assert_eq!(baseline, resumed, "restore-then-continue must be bit-identical");
    eprintln!(
        "[bench_sweep]   capture@{mid}: {snapshot_bytes} bytes, save {:.1} us, load {:.1} us \
         (resume bit-identical)",
        save_s * 1e6,
        load_s * 1e6
    );

    // -------- Intra-simulation parallelism: fan the live cluster set of
    // ONE simulation across worker threads. A hot 64-SM chip (32
    // clusters, enough CTAs to keep them all live) is the regime the
    // per-cluster outbox targets — cluster ticks dominate the cycle and
    // the fixed-index merge is cheap against them. Bit-identity against
    // the single-worker walk is asserted; the reported speedup is the
    // whole-run wall-clock ratio, so merge overhead and the serial NoC /
    // MC phases are all priced in.
    eprintln!("[bench_sweep] intra-simulation parallel ticking (hot 64-SM chip):");
    let mut is_cfg = quick_cfg();
    is_cfg.num_sms = 64; // 32 clusters
    is_cfg.num_mcs = 16;
    let mut is_p = bench("BFS").unwrap();
    is_p.num_ctas = 128; // 4 CTAs per cluster: every cluster stays hot
    is_p.insns_per_thread = 120;
    is_p.num_kernels = 1;
    let tick_jobs = std::thread::available_parallelism().map_or(2, |n| n.get()).clamp(2, 8);
    let t_i1 = Instant::now();
    let is_serial = run_benchmark_seeded_jobs(&is_cfg, &is_p, Scheme::Baseline, SEED, false, 1)
        .unwrap();
    let is_serial_s = t_i1.elapsed().as_secs_f64();
    let t_in = Instant::now();
    let is_fanned =
        run_benchmark_seeded_jobs(&is_cfg, &is_p, Scheme::Baseline, SEED, false, tick_jobs)
            .unwrap();
    let is_fanned_s = t_in.elapsed().as_secs_f64();
    assert_eq!(is_serial, is_fanned, "intra-sim fan-out must be bit-identical to 1 worker");
    let intra_sim_speedup = is_serial_s / is_fanned_s.max(1e-9);
    eprintln!(
        "[bench_sweep]   1 job {is_serial_s:.3} s, {tick_jobs} jobs {is_fanned_s:.3} s -> \
         {intra_sim_speedup:.2}x on {} clusters (cycles={}, reports identical)",
        is_cfg.num_sms / 2,
        is_serial.cycles
    );

    // -------- Fleet serving: chips-vs-tenants pool throughput, plus the
    // determinism contract that makes the pool testable — the same
    // FleetReport bit-for-bit whether the chip shards are served on a
    // 1-thread executor or a multi-thread one. Fresh executors on both
    // sides so the memo cache cannot mask a scheduling divergence; the
    // per-pool row records how many of the 6 tenants a pool that size
    // actually serves (capacity rejections are honest, so served counts
    // climb with the chip count).
    eprintln!("[bench_sweep] fleet serving (tiny-chip pool, 6 tenants):");
    let mut fleet_chip = SystemConfig::tiny();
    fleet_chip.max_cycles = 300_000;
    let fleet_tenants: Vec<_> = serve::default_tenants().into_iter().cycle().take(6).collect();
    let mut fleet_streams = traffic_trace(&fleet_tenants, 2, 5_000, SEED);
    shrink_streams(&mut fleet_streams, 4, 40);
    let fleet_faults = vec![FaultTrace::default(); 4];
    let mut fleet_rows = String::new();
    for pool in [1usize, 2, 4] {
        let fc = FleetConfig::pool(fleet_chip.clone(), pool);
        let f1_exec = SweepExec::serial();
        let t_f1 = Instant::now();
        let f1 = serve_fleet(&f1_exec, &fc, &fleet_streams, &fleet_faults[..pool]).unwrap();
        let f1_s = t_f1.elapsed().as_secs_f64();
        let fn_exec = SweepExec::new(threads.max(2));
        let t_fn = Instant::now();
        let fnn = serve_fleet(&fn_exec, &fc, &fleet_streams, &fleet_faults[..pool]).unwrap();
        let fn_s = t_fn.elapsed().as_secs_f64();
        assert_eq!(
            f1, fnn,
            "fleet({pool} chips): parallel chip serving must be bit-identical to serial"
        );
        let active = f1.chips.iter().filter(|c| c.activated).count();
        eprintln!(
            "[bench_sweep]   {pool} chips ({active} active): served {} dropped {} rejected {} \
             tenants; serial {f1_s:.3} s, parallel {fn_s:.3} s (reports identical)",
            f1.served, f1.dropped, f1.rejections
        );
        if !fleet_rows.is_empty() {
            fleet_rows.push_str(",\n");
        }
        fleet_rows.push_str(&format!(
            "    {{ \"chips\": {pool}, \"active\": {active}, \"served\": {}, \"dropped\": {}, \
             \"rejected_tenants\": {}, \"makespan_kcyc\": {:.1}, \"serial_s\": {f1_s:.3}, \
             \"parallel_s\": {fn_s:.3} }}",
            f1.served,
            f1.dropped,
            f1.rejections,
            f1.makespan as f64 / 1e3
        ));
    }

    let json = format!(
        "{{\n  \"benchmark\": \"figures_quick_sweep_replay\",\n  \"job_instances\": {},\n  \"unique_jobs\": {},\n  \"threads\": {},\n  \"serial_replay_s\": {:.3},\n  \"parallel_memo_s\": {:.3},\n  \"serial_memo_s\": {:.3},\n  \"speedup\": {:.3},\n  \"memo_only_speedup\": {:.3},\n  \"cycle_skip\": [\n{}\n  ],\n  \"cycle_skip_best\": {:.3},\n  \"cycle_skip_best_bench\": \"{}\",\n  \"dense_active\": {{ \"hot\": \"BFS\", \"tenants\": {}, \"clusters\": {}, \"dense_s\": {:.3}, \"active_s\": {:.3}, \"speedup\": {:.3} }},\n  \"dense_active_speedup\": {:.3},\n  \"server_sweep\": {{ \"tenants\": {}, \"dense_s\": {:.3}, \"skip_s\": {:.3}, \"skip_speedup\": {:.3}, \"batch_s\": {:.3}, \"worst_antt\": {:.3} }},\n  \"fault_sweep\": {{ \"no_trace_s\": {:.3}, \"empty_trace_s\": {:.3}, \"overhead\": {:.3}, \"identical\": true }},\n  \"qos_sweep\": {{ \"tenants\": {}, \"dense_s\": {:.3}, \"skip_s\": {:.3}, \"skip_speedup\": {:.3}, \"preemptions\": {}, \"ctas_preempted\": {}, \"identical\": true }},\n  \"snapshot_sweep\": {{ \"sms\": {}, \"capture_cycle\": {}, \"bytes\": {}, \"save_s\": {:.6}, \"load_s\": {:.6}, \"unfired_arm_identical\": true, \"resume_identical\": true }},\n  \"intra_sim\": {{ \"sms\": {}, \"clusters\": {}, \"tick_jobs\": {}, \"serial_s\": {:.3}, \"fanned_s\": {:.3}, \"identical\": true }},\n  \"intra_sim_speedup\": {:.3},\n  \"fleet_sweep\": {{ \"tenants\": {}, \"pools\": [1, 2, 4], \"rows\": [\n{}\n  ], \"identical\": true }}\n}}\n",
        jobs.len(),
        misses,
        threads,
        serial.as_secs_f64(),
        parallel.as_secs_f64(),
        memo_only.as_secs_f64(),
        speedup,
        memo_speedup,
        skip_rows,
        best_skip.0,
        best_skip.1,
        da_streams.len(),
        da_cfg.num_sms / 2,
        da_dense_s,
        da_active_s,
        dense_active_speedup,
        dense_active_speedup,
        streams.len(),
        sdense_s,
        sskip_s,
        stream_skip_ratio,
        batch_s,
        antt_worst,
        no_trace_s,
        empty_trace_s,
        fault_overhead,
        qstreams.len(),
        qdense_s,
        qskip_s,
        qos_skip_ratio,
        qdense.chip.preemptions,
        qdense.chip.ctas_preempted,
        snap_cfg.num_sms,
        mid,
        snapshot_bytes,
        save_s,
        load_s,
        is_cfg.num_sms,
        is_cfg.num_sms / 2,
        tick_jobs,
        is_serial_s,
        is_fanned_s,
        intra_sim_speedup,
        fleet_streams.len(),
        fleet_rows,
    );
    match std::fs::write("BENCH_sweep.json", &json) {
        Ok(()) => eprintln!("[bench_sweep] wrote BENCH_sweep.json"),
        Err(e) => eprintln!("[bench_sweep] could not write BENCH_sweep.json: {e}"),
    }
    print!("{json}");
}
