//! Per-benchmark workload profiles.
//!
//! The paper evaluates CUDA benchmarks from Ispass, Rodinia, Polybench and
//! Mars on GPGPU-Sim. We do not have the CUDA sources or a PTX frontend, so
//! each benchmark is modelled as a *profile*: a parameter vector describing
//! its instruction mix, control divergence, memory locality, coalescing,
//! inter-CTA sharing and NoC intensity. The parameters are set from the
//! paper's own characterisation (Figs 3-6, 8, 12-20) so the reconfiguration
//! controller observes the same metric signatures the authors measured —
//! see DESIGN.md "Substitutions".
//!
//! `scale_up_expected` records the paper's ground truth (which configuration
//! won in their experiments); it is used as the *label* when training the
//! scalability predictor and as the oracle in accuracy tests — never as an
//! input to the simulated controller.

use super::Suite;

/// A complete workload model for one benchmark application.
#[derive(Debug, Clone)]
pub struct BenchProfile {
    /// Benchmark name as the paper's figures label it.
    pub name: &'static str,
    /// Originating suite (documentation only).
    pub suite: Suite,

    // ---- Shape --------------------------------------------------------
    /// Kernels launched per run (kernels re-trigger the AMOEBA controller).
    pub num_kernels: u32,
    /// CTAs per kernel grid.
    pub num_ctas: u32,
    /// Threads per CTA.
    pub cta_threads: u32,
    /// Dynamic instructions per thread per kernel.
    pub insns_per_thread: u32,
    /// Registers per thread (occupancy limiter).
    pub regs_per_thread: u32,
    /// Shared memory per CTA in bytes (occupancy limiter).
    pub smem_per_cta: u32,

    // ---- Instruction mix (fractions of the dynamic stream) -------------
    /// Global/const/texture loads.
    pub frac_ld: f64,
    /// Global stores.
    pub frac_st: f64,
    /// Shared-memory accesses.
    pub frac_smem: f64,
    /// SFU (transcendental) ops.
    pub frac_sfu: f64,
    /// Conditional branches.
    pub frac_branch: f64,

    // ---- Control divergence --------------------------------------------
    /// P(a branch diverges) for one 32-thread sub-warp.
    pub div_prob: f64,
    /// Instructions per divergent-path region (serialised twice).
    pub div_region: u16,
    /// Mean fraction of threads taking the slow path when diverging.
    pub div_taken_frac: f64,

    // ---- Memory behaviour ------------------------------------------------
    /// Hot working-set size in cache lines per CTA *pair* (locality knob:
    /// larger than baseline L1 but smaller than a fused L1 => fusion wins).
    pub working_set_lines: u32,
    /// Fraction of loads that stream (unique lines, never reused).
    pub stream_frac: f64,
    /// Fraction of accesses that broadcast within the warp (coalesce to 1).
    pub broadcast_frac: f64,
    /// Fraction of accesses hitting the CTA-pair shared region (Fig 5's
    /// neighbouring-SM sharing; dedups in a fused L1).
    pub shared_frac: f64,
    /// Fraction of accesses scattering to random lines (uncoalescable).
    pub scatter_frac: f64,
    /// Element stride in bytes for strided accesses (4 = fully coalesced).
    pub stride: u32,

    // ---- Ground truth -----------------------------------------------------
    /// Paper's observed preference: true = scale-up (fused) wins.
    pub scale_up_expected: bool,
}

impl BenchProfile {
    /// Fraction of plain ALU ops (the remainder of the mix).
    pub fn frac_alu(&self) -> f64 {
        1.0 - self.frac_ld - self.frac_st - self.frac_smem - self.frac_sfu - self.frac_branch
    }

    /// Sanity-check the profile parameters.
    pub fn validate(&self) -> Result<(), String> {
        let frac_sum =
            self.frac_ld + self.frac_st + self.frac_smem + self.frac_sfu + self.frac_branch;
        if !(0.0..=1.0).contains(&frac_sum) {
            return Err(format!("{}: instruction mix sums to {frac_sum}", self.name));
        }
        let pat = self.broadcast_frac + self.shared_frac + self.scatter_frac + self.stream_frac;
        if pat > 1.0 + 1e-9 {
            return Err(format!("{}: access-pattern fractions sum to {pat}", self.name));
        }
        for (label, v) in [
            ("div_prob", self.div_prob),
            ("div_taken_frac", self.div_taken_frac),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{}: {label}={v} out of range", self.name));
            }
        }
        if self.num_ctas == 0 || self.cta_threads == 0 || self.insns_per_thread == 0 {
            return Err(format!("{}: degenerate shape", self.name));
        }
        Ok(())
    }
}

/// Baseline profile all benchmarks derive from (moderate everything).
fn base(name: &'static str, suite: Suite) -> BenchProfile {
    BenchProfile {
        name,
        suite,
        num_kernels: 2,
        num_ctas: 96,
        cta_threads: 256,
        insns_per_thread: 300,
        regs_per_thread: 16,
        smem_per_cta: 4 << 10,
        frac_ld: 0.16,
        frac_st: 0.05,
        frac_smem: 0.05,
        frac_sfu: 0.02,
        frac_branch: 0.08,
        div_prob: 0.08,
        div_region: 14,
        div_taken_frac: 0.4,
        working_set_lines: 96,
        stream_frac: 0.25,
        broadcast_frac: 0.10,
        shared_frac: 0.05,
        scatter_frac: 0.05,
        stride: 4,
        scale_up_expected: false,
    }
}

/// The full benchmark suite: every application named in the paper's
/// evaluation figures, with parameters chosen to reproduce its measured
/// characterisation. Comments cite the figure that pins each behaviour.
pub fn all_benchmarks() -> Vec<BenchProfile> {
    vec![
        // ---- Ispass ------------------------------------------------------
        // CP: compute-dense, well coalesced, tiny working set; its modest
        // divergence is amplified by the wider fused pipeline => scale-out
        // (Fig 3a; Fig 20 negative sum).
        BenchProfile {
            frac_ld: 0.10,
            frac_st: 0.02,
            frac_branch: 0.08,
            div_prob: 0.08,
            div_region: 14,
            working_set_lines: 24,
            stream_frac: 0.10,
            scatter_frac: 0.0,
            broadcast_frac: 0.30,
            insns_per_thread: 400,
            ..base("CP", Suite::Ispass)
        },
        // MUM: DNA alignment; suffix-tree hot nodes thrash one L1 but fit
        // the fused L1; NoC-hungry => strong scale-up (Fig 12: 2.11x).
        BenchProfile {
            frac_ld: 0.40,
            frac_st: 0.03,
            frac_branch: 0.08,
            div_prob: 0.08,
            div_region: 8,
            working_set_lines: 235,
            stream_frac: 0.02,
            scatter_frac: 0.0,
            shared_frac: 0.55,
            broadcast_frac: 0.08,
            scale_up_expected: true,
            regs_per_thread: 32,
            ..base("MUM", Suite::Ispass)
        },
        // RAY: ray tracing; BVH hot set => scale-up trend (Fig 3a/8) but
        // heavy control divergence => the dynamic split/fuse showcase
        // (Fig 19): static fuse is mediocre, regrouping shines.
        BenchProfile {
            frac_ld: 0.34,
            frac_sfu: 0.06,
            frac_branch: 0.12,
            div_prob: 0.16,
            div_region: 14,
            div_taken_frac: 0.35,
            working_set_lines: 230,
            stream_frac: 0.02,
            shared_frac: 0.52,
            broadcast_frac: 0.10,
            scatter_frac: 0.02,
            scale_up_expected: true,
            regs_per_thread: 32,
            ..base("RAY", Suite::Ispass)
        },
        // LIB: Monte-Carlo libor; register-fat, path-divergent, small hot
        // set => scale-out (Fig 8).
        BenchProfile {
            frac_ld: 0.12,
            frac_sfu: 0.06,
            frac_branch: 0.08,
            div_prob: 0.08,
            div_region: 14,
            regs_per_thread: 32,
            working_set_lines: 40,
            stream_frac: 0.30,
            broadcast_frac: 0.10,
            shared_frac: 0.0,
            scatter_frac: 0.02,
            ..base("LIB", Suite::Ispass)
        },
        // LPS: Laplace 3D; stencil with moderate traffic and divergence at
        // halo boundaries. Mesh-NoC relief roughly offsets the divergence
        // cost (Fig 3a ~flat); the perfect NoC flips it to scale-out
        // (Fig 3b).
        BenchProfile {
            frac_ld: 0.20,
            frac_st: 0.06,
            frac_smem: 0.12,
            frac_branch: 0.08,
            div_prob: 0.07,
            working_set_lines: 90,
            stream_frac: 0.30,
            shared_frac: 0.12,
            div_region: 12,
            ..base("LPS", Suite::Ispass)
        },
        // AES: crypto; T-table lookups (const cache) + streaming state,
        // byte-dependent branches. Same mesh-vs-perfect story as LPS.
        BenchProfile {
            frac_ld: 0.22,
            frac_st: 0.06,
            frac_branch: 0.06,
            div_prob: 0.06,
            working_set_lines: 64,
            stream_frac: 0.32,
            broadcast_frac: 0.22,
            scatter_frac: 0.04,
            div_region: 12,
            ..base("AES", Suite::Ispass)
        },
        // STO: store-heavy hashing; streaming writes, mild divergence =>
        // slight scale-out.
        BenchProfile {
            frac_ld: 0.08,
            frac_st: 0.16,
            frac_branch: 0.06,
            div_prob: 0.05,
            working_set_lines: 32,
            stream_frac: 0.45,
            div_region: 12,
            ..base("STO", Suite::Ispass)
        },
        // NN: neural net inference; weight tables shared by every CTA fit
        // only the fused L1 => scale-up.
        BenchProfile {
            frac_ld: 0.36,
            frac_sfu: 0.05,
            frac_branch: 0.04,
            div_prob: 0.01,
            broadcast_frac: 0.10,
            shared_frac: 0.55,
            working_set_lines: 228,
            stream_frac: 0.02,
            scale_up_expected: true,
            regs_per_thread: 32,
            scatter_frac: 0.0,
            ..base("NN", Suite::Ispass)
        },
        // ---- Rodinia ------------------------------------------------------
        // BFS: graph traversal; hot frontier + visited bitmaps fit the
        // fused L1, high MSHR merging; divergent => splitting helps too
        // (Fig 20 positive sum).
        BenchProfile {
            frac_ld: 0.36,
            frac_st: 0.05,
            frac_branch: 0.12,
            div_prob: 0.15,
            div_region: 8,
            div_taken_frac: 0.25,
            working_set_lines: 235,
            stream_frac: 0.02,
            scatter_frac: 0.0,
            shared_frac: 0.55,
            broadcast_frac: 0.06,
            num_kernels: 2,
            scale_up_expected: true,
            regs_per_thread: 32,
            ..base("BFS", Suite::Rodinia)
        },
        // HW (heartwall): template tables shared across neighbouring SMs
        // (~10% sharing in Fig 5) => scale-up.
        BenchProfile {
            frac_ld: 0.36,
            frac_smem: 0.08,
            frac_branch: 0.05,
            div_prob: 0.03,
            shared_frac: 0.52,
            broadcast_frac: 0.08,
            working_set_lines: 222,
            stream_frac: 0.03,
            scale_up_expected: true,
            regs_per_thread: 32,
            scatter_frac: 0.01,
            ..base("HW", Suite::Rodinia)
        },
        // SC (streamcluster): distance kernel with branchy center updates,
        // small hot set => scale-out (Fig 3a).
        BenchProfile {
            frac_ld: 0.14,
            frac_st: 0.03,
            frac_branch: 0.09,
            div_prob: 0.10,
            div_region: 14,
            working_set_lines: 28,
            stream_frac: 0.30,
            broadcast_frac: 0.10,
            shared_frac: 0.0,
            ..base("SC", Suite::Rodinia)
        },
        // KM (kmeans): bandwidth-streaming both ways, tiny divergence =>
        // insensitive to scaling (Fig 12).
        BenchProfile {
            frac_ld: 0.18,
            frac_st: 0.04,
            frac_branch: 0.04,
            working_set_lines: 48,
            stream_frac: 0.50,
            broadcast_frac: 0.12,
            shared_frac: 0.0,
            scatter_frac: 0.0,
            div_prob: 0.01,
            ..base("KM", Suite::Rodinia)
        },
        // ---- Polybench ----------------------------------------------------
        // 3MM: tiled matrix chains; smem-blocked with per-tile edge
        // branches => prefers scale-out by ~10% (Fig 12).
        BenchProfile {
            frac_ld: 0.16,
            frac_smem: 0.20,
            frac_branch: 0.07,
            div_prob: 0.06,
            div_region: 14,
            working_set_lines: 56,
            stream_frac: 0.20,
            broadcast_frac: 0.18,
            num_kernels: 3,
            ..base("3MM", Suite::Polybench)
        },
        // ATAX: matrix-vector; broadcast-heavy with short divergent tails
        // => scale-out (Fig 12).
        BenchProfile {
            frac_ld: 0.20,
            frac_st: 0.03,
            frac_branch: 0.07,
            div_prob: 0.06,
            div_region: 14,
            broadcast_frac: 0.30,
            working_set_lines: 44,
            stream_frac: 0.25,
            num_kernels: 2,
            ..base("ATAX", Suite::Polybench)
        },
        // CORR / COVR: correlation/covariance; the symmetric-matrix hot
        // band fits only the fused L1 and their reply traffic saturates
        // the MC injection queues (Fig 17: AMOEBA removes the ICNT
        // stalls) => scale-up.
        BenchProfile {
            frac_ld: 0.38,
            frac_st: 0.05,
            frac_branch: 0.04,
            div_prob: 0.02,
            working_set_lines: 238,
            stream_frac: 0.04,
            shared_frac: 0.52,
            broadcast_frac: 0.08,
            scatter_frac: 0.02,
            scale_up_expected: true,
            regs_per_thread: 32,
            ..base("CORR", Suite::Polybench)
        },
        BenchProfile {
            frac_ld: 0.38,
            frac_st: 0.05,
            frac_branch: 0.04,
            div_prob: 0.02,
            working_set_lines: 230,
            stream_frac: 0.03,
            shared_frac: 0.55,
            broadcast_frac: 0.08,
            scatter_frac: 0.02,
            scale_up_expected: true,
            regs_per_thread: 32,
            ..base("COVR", Suite::Polybench)
        },
        // FWT: butterfly transform; latency-tolerant smem shuffles,
        // insensitive to scaling (Fig 12).
        BenchProfile {
            frac_ld: 0.12,
            frac_st: 0.08,
            frac_smem: 0.16,
            frac_branch: 0.04,
            working_set_lines: 64,
            stream_frac: 0.25,
            div_prob: 0.015,
            ..base("FWT", Suite::Polybench)
        },
        // ---- Mars -----------------------------------------------------------
        // SM (StringMatch): the headline (Fig 12: 4.25x; Fig 15: L1D miss
        // -70%). The keyword/pattern tables (every CTA walks them) thrash
        // one 16KB L1 but sit entirely inside the fused 32KB L1.
        BenchProfile {
            frac_ld: 0.42,
            frac_st: 0.03,
            frac_branch: 0.08,
            div_prob: 0.04,
            div_region: 6,
            working_set_lines: 244,
            stream_frac: 0.01,
            shared_frac: 0.60,
            broadcast_frac: 0.12,
            scatter_frac: 0.0,
            num_kernels: 2,
            scale_up_expected: true,
            regs_per_thread: 32,
            ..base("SM", Suite::Mars)
        },
        // WP (WordCount): divergent string scanning over streamed text;
        // fusion backfires (Fig 12 shows degradation under static fuse).
        BenchProfile {
            frac_ld: 0.20,
            frac_st: 0.08,
            frac_branch: 0.14,
            div_prob: 0.12,
            div_region: 14,
            working_set_lines: 70,
            stream_frac: 0.40,
            scatter_frac: 0.06,
            shared_frac: 0.02,
            num_kernels: 3,
            ..base("WP", Suite::Mars)
        },
        // PR (PageRank-style): scattered neighbour reads with tiny reuse
        // and ranking branches => scale-out (Fig 20 negative sum).
        BenchProfile {
            frac_ld: 0.22,
            frac_branch: 0.10,
            div_prob: 0.10,
            div_region: 12,
            working_set_lines: 36,
            stream_frac: 0.30,
            scatter_frac: 0.15,
            shared_frac: 0.02,
            broadcast_frac: 0.06,
            ..base("PR", Suite::Mars)
        },
        // 3DCV (3D stencil/convolution): filter planes shared by all CTAs
        // (Fig 5 neighbour sharing) => scale-up.
        BenchProfile {
            frac_ld: 0.38,
            frac_smem: 0.08,
            frac_branch: 0.04,
            div_prob: 0.02,
            shared_frac: 0.55,
            working_set_lines: 232,
            stream_frac: 0.02,
            scale_up_expected: true,
            regs_per_thread: 32,
            broadcast_frac: 0.06,
            scatter_frac: 0.0,
            ..base("3DCV", Suite::Polybench)
        },
    ]
}

/// Benchmarks plotted in the paper's Fig 12/13/21 main evaluation.
pub const FIG12_SET: [&str; 12] = [
    "BFS", "MUM", "RAY", "SM", "LIB", "WP", "FWT", "KM", "3MM", "ATAX", "CORR", "COVR",
];

/// Benchmarks of the Fig 3 scaling characterisation.
pub const FIG3_SET: [&str; 8] = ["CP", "SC", "MUM", "RAY", "LPS", "AES", "LIB", "STO"];

/// Benchmarks of the Fig 5 L1-sharing characterisation.
pub const FIG5_SET: [&str; 6] = ["HW", "3DCV", "SM", "RAY", "LPS", "KM"];

/// Benchmarks of the Fig 20 predictor-weight analysis.
pub const FIG20_SET: [&str; 4] = ["BFS", "RAY", "CP", "PR"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_profiles_validate() {
        let benches = all_benchmarks();
        assert!(benches.len() >= 20, "suite has {} benchmarks", benches.len());
        for b in &benches {
            b.validate().unwrap_or_else(|e| panic!("{e}"));
            assert!(b.frac_alu() >= 0.0, "{}: negative ALU fraction", b.name);
        }
    }

    #[test]
    fn names_unique() {
        let benches = all_benchmarks();
        let mut names: Vec<_> = benches.iter().map(|b| b.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), benches.len());
    }

    #[test]
    fn figure_sets_resolve() {
        let benches = all_benchmarks();
        let has = |n: &str| benches.iter().any(|b| b.name == n);
        for n in FIG12_SET.iter().chain(&FIG3_SET).chain(&FIG5_SET).chain(&FIG20_SET) {
            assert!(has(n), "figure set references unknown benchmark {n}");
        }
    }

    #[test]
    fn headline_benchmarks_have_expected_labels() {
        let benches = all_benchmarks();
        let find = |n: &str| benches.iter().find(|b| b.name == n).unwrap();
        // Paper Fig 3/12 ground truth.
        assert!(find("SM").scale_up_expected);
        assert!(find("MUM").scale_up_expected);
        assert!(find("RAY").scale_up_expected);
        assert!(!find("CP").scale_up_expected);
        assert!(!find("SC").scale_up_expected);
        assert!(!find("3MM").scale_up_expected);
        assert!(!find("ATAX").scale_up_expected);
    }
}
