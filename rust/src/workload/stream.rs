//! Multi-tenant kernel streams: the workload side of the server-style
//! simulation mode (`Gpu::run_streams`).
//!
//! A [`KernelStream`] is one tenant's ordered sequence of kernel launches
//! — the unit a shared GPU serves when several applications are resident
//! simultaneously. Each launch carries an *arrival cycle* so a stream can
//! model bursty service traffic rather than back-to-back batch work; a
//! seeded [`traffic_trace`] builds an interleaved multi-tenant trace whose
//! arrivals, grid shapes and per-kernel instruction seeds are all pure
//! functions of the trace seed (the determinism contract every other
//! workload generator in this crate obeys).

use crate::config::Scheme;
use crate::isa::KernelLaunch;

use super::profiles::BenchProfile;
use super::rng::{hash_combine, Pcg32};

/// One timed kernel launch inside a stream.
#[derive(Debug, Clone)]
pub struct StreamLaunch {
    /// Earliest cycle the launch may start (service-queue arrival time).
    pub arrival: u64,
    /// The launch itself (grid shape + per-warp trace seed).
    pub kernel: KernelLaunch,
}

/// One tenant's ordered kernel launches plus the AMOEBA scheme its
/// partition of the chip runs under.
#[derive(Debug, Clone)]
pub struct KernelStream {
    /// Tenant label (reports and tables key on it).
    pub name: String,
    /// Workload profile every launch of this tenant draws from.
    pub profile: BenchProfile,
    /// Reconfiguration scheme applied to this tenant's clusters.
    pub scheme: Scheme,
    /// Launches in arrival order (arrivals are nondecreasing).
    pub launches: Vec<StreamLaunch>,
}

impl KernelStream {
    /// A stream that launches `profile`'s kernels back to back (arrival 0
    /// for every kernel — the batch special case).
    pub fn back_to_back(name: impl Into<String>, profile: BenchProfile, scheme: Scheme, seed: u64) -> Self {
        let launches = super::kernel_launches(&profile, seed)
            .into_iter()
            .map(|kernel| StreamLaunch { arrival: 0, kernel })
            .collect();
        KernelStream { name: name.into(), profile, scheme, launches }
    }

    /// Total CTAs across every launch of the stream.
    pub fn total_ctas(&self) -> u64 {
        self.launches.iter().map(|l| l.kernel.num_ctas as u64).sum()
    }

    /// Sanity-check the stream: a validated profile, at least one launch,
    /// nondecreasing arrivals.
    pub fn validate(&self) -> Result<(), String> {
        self.profile.validate()?;
        if self.launches.is_empty() {
            return Err(format!("stream '{}' has no launches", self.name));
        }
        if self.launches.windows(2).any(|w| w[0].arrival > w[1].arrival) {
            return Err(format!("stream '{}' arrivals not sorted", self.name));
        }
        Ok(())
    }
}

/// Build a seeded multi-tenant traffic trace: tenant `i` runs
/// `tenants[i].0` under scheme `tenants[i].1`, launching `kernels_each`
/// kernels with pseudo-random inter-arrival gaps drawn uniformly from
/// `[0, 2 * mean_gap]` (mean `mean_gap`). Every quantity — arrival
/// cycles and per-kernel instruction seeds — derives from `seed`, so the
/// same call always produces the identical trace (the stream sweeps are
/// memoized and compared bit-for-bit across executors on that basis).
pub fn traffic_trace(
    tenants: &[(BenchProfile, Scheme)],
    kernels_each: u32,
    mean_gap: u64,
    seed: u64,
) -> Vec<KernelStream> {
    tenants
        .iter()
        .enumerate()
        .map(|(ti, (profile, scheme))| {
            let mut rng = Pcg32::new(hash_combine(&[seed, ti as u64, 0x7EA2]), ti as u64);
            let mut arrival = 0u64;
            let launches = (0..kernels_each)
                .map(|k| {
                    if k > 0 && mean_gap > 0 {
                        arrival += rng.next_u64() % (2 * mean_gap + 1);
                    }
                    StreamLaunch {
                        arrival,
                        kernel: KernelLaunch {
                            id: k,
                            num_ctas: profile.num_ctas,
                            cta_threads: profile.cta_threads,
                            insns_per_thread: profile.insns_per_thread,
                            regs_per_thread: profile.regs_per_thread,
                            smem_per_cta: profile.smem_per_cta,
                            seed: hash_combine(&[seed, ti as u64, k as u64, 0x5EE7]),
                        },
                    }
                })
                .collect();
            KernelStream {
                name: format!("t{ti}:{}", profile.name),
                profile: profile.clone(),
                scheme: *scheme,
                launches,
            }
        })
        .collect()
}

/// Shrink every launch of `streams` for quick/CI runs (same knobs the
/// figure harness applies to single-application sweeps).
pub fn shrink_streams(streams: &mut [KernelStream], max_ctas: u32, max_insns: u32) {
    for s in streams {
        s.profile.num_ctas = s.profile.num_ctas.min(max_ctas);
        s.profile.insns_per_thread = s.profile.insns_per_thread.min(max_insns);
        for l in &mut s.launches {
            l.kernel.num_ctas = l.kernel.num_ctas.min(max_ctas);
            l.kernel.insns_per_thread = l.kernel.insns_per_thread.min(max_insns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::bench;

    #[test]
    fn back_to_back_matches_kernel_launches() {
        let p = bench("BFS").unwrap();
        let s = KernelStream::back_to_back("t0", p.clone(), Scheme::Baseline, 9);
        assert_eq!(s.launches.len(), p.num_kernels as usize);
        assert!(s.launches.iter().all(|l| l.arrival == 0));
        s.validate().unwrap();
        let ks = crate::workload::kernel_launches(&p, 9);
        assert_eq!(s.launches[0].kernel.seed, ks[0].seed, "same derived kernel seeds");
    }

    #[test]
    fn traffic_trace_is_deterministic_and_sorted() {
        let tenants = vec![
            (bench("BFS").unwrap(), Scheme::Hetero),
            (bench("CP").unwrap(), Scheme::Baseline),
        ];
        let a = traffic_trace(&tenants, 4, 1000, 7);
        let b = traffic_trace(&tenants, 4, 1000, 7);
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            x.validate().unwrap();
            assert_eq!(x.launches.len(), 4);
            for (lx, ly) in x.launches.iter().zip(&y.launches) {
                assert_eq!(lx.arrival, ly.arrival, "same seed, same arrivals");
                assert_eq!(lx.kernel.seed, ly.kernel.seed);
            }
        }
        // A different trace seed moves the arrivals and kernel seeds.
        let c = traffic_trace(&tenants, 4, 1000, 8);
        assert_ne!(c[0].launches[0].kernel.seed, a[0].launches[0].kernel.seed);
        // Tenants draw independent gap sequences.
        let gaps = |s: &KernelStream| {
            s.launches.windows(2).map(|w| w[1].arrival - w[0].arrival).collect::<Vec<_>>()
        };
        assert_ne!(gaps(&a[0]), gaps(&a[1]), "independent per-tenant arrival processes");
    }

    #[test]
    fn shrink_bounds_every_launch() {
        let tenants = vec![(bench("RAY").unwrap(), Scheme::WarpRegroup)];
        let mut tr = traffic_trace(&tenants, 3, 0, 1);
        shrink_streams(&mut tr, 8, 80);
        assert!(tr[0].launches.iter().all(|l| l.kernel.num_ctas <= 8));
        assert!(tr[0].launches.iter().all(|l| l.kernel.insns_per_thread <= 80));
        assert_eq!(tr[0].profile.num_ctas, 8);
    }
}
