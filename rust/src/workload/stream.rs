//! Multi-tenant kernel streams: the workload side of the server-style
//! simulation mode (`Gpu::run_streams`).
//!
//! A [`KernelStream`] is one tenant's ordered sequence of kernel launches
//! — the unit a shared GPU serves when several applications are resident
//! simultaneously. Each launch carries an *arrival cycle* so a stream can
//! model bursty service traffic rather than back-to-back batch work; a
//! seeded [`traffic_trace`] builds an interleaved multi-tenant trace whose
//! arrivals, grid shapes and per-kernel instruction seeds are all pure
//! functions of the trace seed (the determinism contract every other
//! workload generator in this crate obeys).

use std::str::FromStr;

use crate::config::Scheme;
use crate::isa::KernelLaunch;

use super::profiles::BenchProfile;
use super::rng::{hash_combine, Pcg32};

/// Tenant priority class. Ordering is meaningful: `Low < Normal < High`,
/// and the preemption path only ever takes clusters from a *strictly*
/// lower class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Best-effort: may be preempted at CTA boundaries and drained last.
    Low,
    /// The default class (every pre-QoS trace is all-Normal).
    #[default]
    Normal,
    /// Latency-sensitive: fair-share shortfalls are made up by stealing
    /// clusters from strictly lower classes at launch boundaries.
    High,
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        })
    }
}

impl FromStr for Priority {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => Err(format!("unknown priority '{other}' (low|normal|high)")),
        }
    }
}

/// One tenant's full QoS description: what it runs, how its clusters
/// reconfigure, its priority class, and an optional per-launch turnaround
/// SLO in cycles (arrival -> finish; `None` = best effort).
#[derive(Debug, Clone)]
pub struct TenantQosSpec {
    /// Workload profile the tenant launches.
    pub profile: BenchProfile,
    /// Reconfiguration scheme for the tenant's clusters.
    pub scheme: Scheme,
    /// Priority class (drives preemption and the SLO objective weights).
    pub priority: Priority,
    /// Turnaround SLO per launch in cycles, if any.
    pub slo_turnaround: Option<u64>,
}

impl TenantQosSpec {
    /// A Normal-priority, no-SLO spec — the pre-QoS tenant shape.
    pub fn best_effort(profile: BenchProfile, scheme: Scheme) -> Self {
        TenantQosSpec { profile, scheme, priority: Priority::Normal, slo_turnaround: None }
    }
}

/// Arrival-process shape for [`traffic_trace_qos`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficPattern {
    /// Independent uniform gaps in `[0, 2*mean_gap]` — byte-identical to
    /// the original [`traffic_trace`] arrivals for the same seed.
    Uniform,
    /// Noisy-neighbour bursts: launches arrive in back-to-back clumps of
    /// `burst_len` (intra-burst gaps divided by `dilation`) separated by
    /// long idle periods (every `burst_len`-th gap multiplied by
    /// `dilation`). Draws the *same* RNG sequence as `Uniform`, so the
    /// kernel seeds — and therefore the work — are identical; only the
    /// arrival timing changes.
    Bursty {
        /// Launches per burst (>= 1).
        burst_len: u32,
        /// Idle-period stretch / intra-burst compression factor (>= 1).
        dilation: u64,
    },
}

/// One timed kernel launch inside a stream.
#[derive(Debug, Clone)]
pub struct StreamLaunch {
    /// Earliest cycle the launch may start (service-queue arrival time).
    pub arrival: u64,
    /// The launch itself (grid shape + per-warp trace seed).
    pub kernel: KernelLaunch,
}

/// One tenant's ordered kernel launches plus the AMOEBA scheme its
/// partition of the chip runs under.
#[derive(Debug, Clone)]
pub struct KernelStream {
    /// Tenant label (reports and tables key on it).
    pub name: String,
    /// Workload profile every launch of this tenant draws from.
    pub profile: BenchProfile,
    /// Reconfiguration scheme applied to this tenant's clusters.
    pub scheme: Scheme,
    /// Priority class (Normal for every pre-QoS constructor).
    pub priority: Priority,
    /// Per-launch turnaround SLO in cycles (arrival -> finish), if any.
    pub slo_turnaround: Option<u64>,
    /// Launches in arrival order (arrivals are nondecreasing).
    pub launches: Vec<StreamLaunch>,
}

impl KernelStream {
    /// A stream that launches `profile`'s kernels back to back (arrival 0
    /// for every kernel — the batch special case).
    pub fn back_to_back(name: impl Into<String>, profile: BenchProfile, scheme: Scheme, seed: u64) -> Self {
        let launches = super::kernel_launches(&profile, seed)
            .into_iter()
            .map(|kernel| StreamLaunch { arrival: 0, kernel })
            .collect();
        KernelStream {
            name: name.into(),
            profile,
            scheme,
            priority: Priority::Normal,
            slo_turnaround: None,
            launches,
        }
    }

    /// Total CTAs across every launch of the stream.
    pub fn total_ctas(&self) -> u64 {
        self.launches.iter().map(|l| l.kernel.num_ctas as u64).sum()
    }

    /// Sanity-check the stream: a validated profile, at least one launch,
    /// nondecreasing arrivals.
    pub fn validate(&self) -> Result<(), String> {
        self.profile.validate()?;
        if self.launches.is_empty() {
            return Err(format!("stream '{}' has no launches", self.name));
        }
        if self.launches.windows(2).any(|w| w[0].arrival > w[1].arrival) {
            return Err(format!("stream '{}' arrivals not sorted", self.name));
        }
        Ok(())
    }
}

/// Build a seeded multi-tenant traffic trace: tenant `i` runs
/// `tenants[i].0` under scheme `tenants[i].1`, launching `kernels_each`
/// kernels with pseudo-random inter-arrival gaps drawn uniformly from
/// `[0, 2 * mean_gap]` (mean `mean_gap`). Every quantity — arrival
/// cycles and per-kernel instruction seeds — derives from `seed`, so the
/// same call always produces the identical trace (the stream sweeps are
/// memoized and compared bit-for-bit across executors on that basis).
pub fn traffic_trace(
    tenants: &[(BenchProfile, Scheme)],
    kernels_each: u32,
    mean_gap: u64,
    seed: u64,
) -> Vec<KernelStream> {
    let specs: Vec<TenantQosSpec> = tenants
        .iter()
        .map(|(p, s)| TenantQosSpec::best_effort(p.clone(), *s))
        .collect();
    traffic_trace_qos(&specs, kernels_each, mean_gap, seed, TrafficPattern::Uniform)
}

/// QoS-aware trace generator: like [`traffic_trace`] but each tenant
/// carries its full [`TenantQosSpec`] (priority + SLO land on the
/// produced [`KernelStream`]s) and the arrival process is selectable via
/// [`TrafficPattern`]. `Uniform` is byte-identical to the original
/// generator — same RNG streams, same gap draws, same kernel seeds — so
/// every pre-QoS golden and memo key is untouched; `Bursty` reshapes the
/// *same* draws into clump-and-idle noisy-neighbour timing without
/// changing the work.
pub fn traffic_trace_qos(
    tenants: &[TenantQosSpec],
    kernels_each: u32,
    mean_gap: u64,
    seed: u64,
    pattern: TrafficPattern,
) -> Vec<KernelStream> {
    tenants
        .iter()
        .enumerate()
        .map(|(ti, spec)| {
            let profile = &spec.profile;
            let mut rng = Pcg32::new(hash_combine(&[seed, ti as u64, 0x7EA2]), ti as u64);
            let mut arrival = 0u64;
            let launches = (0..kernels_each)
                .map(|k| {
                    if k > 0 && mean_gap > 0 {
                        let gap = rng.next_u64() % (2 * mean_gap + 1);
                        arrival += match pattern {
                            TrafficPattern::Uniform => gap,
                            TrafficPattern::Bursty { burst_len, dilation } => {
                                let burst_len = burst_len.max(1);
                                let dilation = dilation.max(1);
                                if k % burst_len == 0 {
                                    gap.saturating_mul(dilation)
                                } else {
                                    gap / dilation
                                }
                            }
                        };
                    }
                    StreamLaunch {
                        arrival,
                        kernel: KernelLaunch {
                            id: k,
                            num_ctas: profile.num_ctas,
                            cta_threads: profile.cta_threads,
                            insns_per_thread: profile.insns_per_thread,
                            regs_per_thread: profile.regs_per_thread,
                            smem_per_cta: profile.smem_per_cta,
                            seed: hash_combine(&[seed, ti as u64, k as u64, 0x5EE7]),
                        },
                    }
                })
                .collect();
            KernelStream {
                name: format!("t{ti}:{}", profile.name),
                profile: profile.clone(),
                scheme: spec.scheme,
                priority: spec.priority,
                slo_turnaround: spec.slo_turnaround,
                launches,
            }
        })
        .collect()
}

/// Shrink every launch of `streams` for quick/CI runs (same knobs the
/// figure harness applies to single-application sweeps).
///
/// **Invariant:** shrinking only caps grid size and instruction counts.
/// It never reorders or drops tenants or launches, and never touches the
/// QoS fields (`priority`, `slo_turnaround`) — so the quick trace
/// presents exactly the same tenant order and priority-class mix as the
/// full trace, and priority-sensitive behaviour (preemption, the SLO
/// objective) is exercised identically in CI quick mode. Pinned by
/// `shrink_preserves_priority_order_and_class_mix` below.
pub fn shrink_streams(streams: &mut [KernelStream], max_ctas: u32, max_insns: u32) {
    for s in streams {
        s.profile.num_ctas = s.profile.num_ctas.min(max_ctas);
        s.profile.insns_per_thread = s.profile.insns_per_thread.min(max_insns);
        for l in &mut s.launches {
            l.kernel.num_ctas = l.kernel.num_ctas.min(max_ctas);
            l.kernel.insns_per_thread = l.kernel.insns_per_thread.min(max_insns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::bench;

    #[test]
    fn back_to_back_matches_kernel_launches() {
        let p = bench("BFS").unwrap();
        let s = KernelStream::back_to_back("t0", p.clone(), Scheme::Baseline, 9);
        assert_eq!(s.launches.len(), p.num_kernels as usize);
        assert!(s.launches.iter().all(|l| l.arrival == 0));
        s.validate().unwrap();
        let ks = crate::workload::kernel_launches(&p, 9);
        assert_eq!(s.launches[0].kernel.seed, ks[0].seed, "same derived kernel seeds");
    }

    #[test]
    fn traffic_trace_is_deterministic_and_sorted() {
        let tenants = vec![
            (bench("BFS").unwrap(), Scheme::Hetero),
            (bench("CP").unwrap(), Scheme::Baseline),
        ];
        let a = traffic_trace(&tenants, 4, 1000, 7);
        let b = traffic_trace(&tenants, 4, 1000, 7);
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            x.validate().unwrap();
            assert_eq!(x.launches.len(), 4);
            for (lx, ly) in x.launches.iter().zip(&y.launches) {
                assert_eq!(lx.arrival, ly.arrival, "same seed, same arrivals");
                assert_eq!(lx.kernel.seed, ly.kernel.seed);
            }
        }
        // A different trace seed moves the arrivals and kernel seeds.
        let c = traffic_trace(&tenants, 4, 1000, 8);
        assert_ne!(c[0].launches[0].kernel.seed, a[0].launches[0].kernel.seed);
        // Tenants draw independent gap sequences.
        let gaps = |s: &KernelStream| {
            s.launches.windows(2).map(|w| w[1].arrival - w[0].arrival).collect::<Vec<_>>()
        };
        assert_ne!(gaps(&a[0]), gaps(&a[1]), "independent per-tenant arrival processes");
    }

    #[test]
    fn shrink_bounds_every_launch() {
        let tenants = vec![(bench("RAY").unwrap(), Scheme::WarpRegroup)];
        let mut tr = traffic_trace(&tenants, 3, 0, 1);
        shrink_streams(&mut tr, 8, 80);
        assert!(tr[0].launches.iter().all(|l| l.kernel.num_ctas <= 8));
        assert!(tr[0].launches.iter().all(|l| l.kernel.insns_per_thread <= 80));
        assert_eq!(tr[0].profile.num_ctas, 8);
    }

    fn qos_specs() -> Vec<TenantQosSpec> {
        vec![
            TenantQosSpec {
                profile: bench("BFS").unwrap(),
                scheme: Scheme::Hetero,
                priority: Priority::High,
                slo_turnaround: Some(50_000),
            },
            TenantQosSpec::best_effort(bench("CP").unwrap(), Scheme::Baseline),
            TenantQosSpec {
                profile: bench("RAY").unwrap(),
                scheme: Scheme::WarpRegroup,
                priority: Priority::Low,
                slo_turnaround: None,
            },
        ]
    }

    #[test]
    fn qos_uniform_trace_matches_legacy_generator_exactly() {
        // The Uniform pattern must be byte-identical to the pre-QoS
        // generator: same arrivals, same kernel seeds, same names.
        let specs = qos_specs();
        let legacy_tenants: Vec<_> =
            specs.iter().map(|s| (s.profile.clone(), s.scheme)).collect();
        let legacy = traffic_trace(&legacy_tenants, 4, 1_000, 7);
        let qos = traffic_trace_qos(&specs, 4, 1_000, 7, TrafficPattern::Uniform);
        assert_eq!(legacy.len(), qos.len());
        for (l, q) in legacy.iter().zip(&qos) {
            assert_eq!(l.name, q.name);
            for (ll, ql) in l.launches.iter().zip(&q.launches) {
                assert_eq!(ll.arrival, ql.arrival);
                assert_eq!(ll.kernel.seed, ql.kernel.seed);
            }
        }
        // The QoS fields rode along.
        assert_eq!(qos[0].priority, Priority::High);
        assert_eq!(qos[0].slo_turnaround, Some(50_000));
        assert_eq!(qos[1].priority, Priority::Normal);
        assert_eq!(qos[2].priority, Priority::Low);
        // Legacy trace defaults to all-Normal, no SLO.
        assert!(legacy.iter().all(|s| s.priority == Priority::Normal));
        assert!(legacy.iter().all(|s| s.slo_turnaround.is_none()));
    }

    #[test]
    fn bursty_pattern_clumps_arrivals_without_changing_work() {
        let specs = qos_specs();
        let uniform = traffic_trace_qos(&specs, 8, 2_000, 11, TrafficPattern::Uniform);
        let bursty = traffic_trace_qos(
            &specs,
            8,
            2_000,
            11,
            TrafficPattern::Bursty { burst_len: 4, dilation: 8 },
        );
        for (u, b) in uniform.iter().zip(&bursty) {
            b.validate().unwrap();
            // Identical work: kernel seeds and grids untouched.
            for (ul, bl) in u.launches.iter().zip(&b.launches) {
                assert_eq!(ul.kernel.seed, bl.kernel.seed);
                assert_eq!(ul.kernel.num_ctas, bl.kernel.num_ctas);
            }
            // Bursty timing is the exact per-gap transform of the SAME
            // uniform draws: gap before launch k is multiplied by the
            // dilation at burst boundaries (k % burst_len == 0) and
            // integer-divided by it inside a burst.
            let gap_of = |s: &KernelStream| -> Vec<u64> {
                s.launches.windows(2).map(|w| w[1].arrival - w[0].arrival).collect()
            };
            let (ug, bg) = (gap_of(u), gap_of(b));
            for (i, (&raw, &got)) in ug.iter().zip(&bg).enumerate() {
                let k = i as u32 + 1;
                let want = if k % 4 == 0 { raw * 8 } else { raw / 8 };
                assert_eq!(got, want, "gap before launch {k}");
            }
        }
        // Determinism: the same call reproduces the same trace.
        let again = traffic_trace_qos(
            &specs,
            8,
            2_000,
            11,
            TrafficPattern::Bursty { burst_len: 4, dilation: 8 },
        );
        for (a, b) in bursty.iter().zip(&again) {
            for (al, bl) in a.launches.iter().zip(&b.launches) {
                assert_eq!(al.arrival, bl.arrival);
            }
        }
    }

    #[test]
    fn shrink_preserves_priority_order_and_class_mix() {
        let specs = qos_specs();
        let full = traffic_trace_qos(&specs, 4, 5_000, 3, TrafficPattern::Uniform);
        let mut quick = full.clone();
        shrink_streams(&mut quick, 4, 40);
        let mix = |streams: &[KernelStream]| -> Vec<(String, Priority, Option<u64>)> {
            streams
                .iter()
                .map(|s| (s.name.clone(), s.priority, s.slo_turnaround))
                .collect()
        };
        assert_eq!(mix(&full), mix(&quick), "shrink must not disturb tenant order or QoS class mix");
        assert_eq!(full.len(), quick.len());
        for (f, q) in full.iter().zip(&quick) {
            assert_eq!(f.launches.len(), q.launches.len(), "no launches dropped");
        }
    }

    #[test]
    fn priority_parses_and_orders() {
        assert_eq!("high".parse::<Priority>().unwrap(), Priority::High);
        assert_eq!("normal".parse::<Priority>().unwrap(), Priority::Normal);
        assert_eq!("low".parse::<Priority>().unwrap(), Priority::Low);
        assert!("urgent".parse::<Priority>().is_err());
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
        assert_eq!(Priority::High.to_string(), "high");
    }
}
