//! Deterministic PCG32 random number generator (no external deps).
//!
//! Every stochastic choice in the workload model flows through this RNG so
//! that simulations are exactly reproducible from a seed. The generator is
//! the standard PCG-XSH-RR 64/32 construction.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seeded constructor; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next 32 random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) << 32 | self.next_u32() as u64
    }

    /// Uniform float in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u32() >> 8) as f64 / (1u32 << 24) as f64
    }

    /// Uniform integer in [0, bound) (Lemire-style rejection-free approx).
    pub fn next_bounded(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

/// Stateless splitmix64 hash — used to derive per-warp/per-pc seeds so
/// instruction streams can be generated at random access (no stored trace).
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Combine hash inputs into one seed.
pub fn hash_combine(parts: &[u64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &p in parts {
        h = splitmix64(h ^ p);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::new(42, 2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniformity_rough() {
        let mut rng = Pcg32::new(7, 0);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        let ones = (0..n).filter(|_| rng.chance(0.25)).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn bounded_in_range() {
        let mut rng = Pcg32::new(1, 3);
        for _ in 0..10_000 {
            assert!(rng.next_bounded(7) < 7);
        }
    }

    #[test]
    fn hash_combine_sensitivity() {
        let a = hash_combine(&[1, 2, 3]);
        assert_eq!(a, hash_combine(&[1, 2, 3]));
        assert_ne!(a, hash_combine(&[1, 2, 4]));
        assert_ne!(a, hash_combine(&[3, 2, 1]));
    }
}
