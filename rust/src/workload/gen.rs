//! Procedural instruction-trace generation.
//!
//! A benchmark's dynamic instruction stream is never stored: the op *kind*
//! at a PC is a pure function of `(kernel seed, pc)` — all CTAs execute the
//! same static code, as in SIMT — while per-sub-warp dynamics (branch
//! outcomes, concrete addresses) are pure functions of
//! `(kernel seed, cta, sub-warp, pc)`. This gives O(1) memory, exact
//! reproducibility, and random access (a fused 64-wide warp resolves both
//! of its 32-wide sub-warps at the same PC and co-executes them).

use crate::isa::{AccessPattern, KernelLaunch, MemSpace, Op};

use super::profiles::BenchProfile;
use super::rng::{hash_combine, splitmix64};

/// Modelled per-kernel code footprint ceiling (bytes) for L1I behaviour.
pub const CODE_FOOTPRINT_BYTES: u64 = 16 << 10;

/// Bytes of one modelled instruction (I-cache line pressure).
const INSN_BYTES: u64 = 8;

/// Address-space region bases (disjoint by construction).
const PAIR_REGION: u64 = 0x1_0000_0000;
const PRIVATE_REGION: u64 = 0x2_0000_0000;
const STREAM_REGION: u64 = 0x4_0000_0000;
const CODE_REGION: u64 = 0x8_0000_0000;
/// Span reserved per CTA pair / per CTA inside their regions.
const REGION_SPAN: u64 = 1 << 22;

/// Static classification of the op at a PC (pattern category included,
/// since the access type is a property of the code location).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PcClass {
    Alu,
    Falu,
    Sfu,
    Smem,
    Branch,
    Store { cat: AccessCat },
    Load { cat: AccessCat },
}

/// Which address-generation category a memory PC belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum AccessCat {
    /// Hot-set strided access in the CTA's private region.
    PrivateReuse,
    /// Streaming: unique lines, never reused.
    Stream,
    /// Warp-wide broadcast of a line (constant tables etc.).
    Broadcast,
    /// CTA-pair shared region (neighbouring-SM sharing, Fig 5).
    Shared,
    /// Per-lane random scatter (uncoalescable).
    Scatter,
}

/// Trace generator for one kernel launch of one benchmark.
#[derive(Debug, Clone)]
pub struct TraceGen {
    profile: BenchProfile,
    seed: u64,
    insns_per_thread: u32,
    code_bytes: u64,
}

impl TraceGen {
    /// Build the generator for `kernel` of `profile`.
    pub fn new(profile: &BenchProfile, kernel: &KernelLaunch) -> Self {
        let code_bytes =
            (kernel.insns_per_thread as u64 * INSN_BYTES).clamp(256, CODE_FOOTPRINT_BYTES);
        TraceGen {
            profile: profile.clone(),
            seed: kernel.seed,
            insns_per_thread: kernel.insns_per_thread,
            code_bytes,
        }
    }

    /// Per-thread trace length of this kernel.
    pub fn trace_len(&self) -> u32 {
        self.insns_per_thread
    }

    /// Modelled code footprint in bytes (drives L1I pressure).
    pub fn code_bytes(&self) -> u64 {
        self.code_bytes
    }

    /// Instruction-fetch address for a PC (loops inside the code footprint,
    /// modelling the hot loop bodies real kernels execute).
    pub fn code_addr(&self, pc: u32) -> u64 {
        CODE_REGION + (pc as u64 * INSN_BYTES) % self.code_bytes
    }

    /// Uniform hash in [0,1) from mixed identifiers.
    fn unit(&self, parts: &[u64]) -> f64 {
        (hash_combine(parts) >> 40) as f64 / (1u64 << 24) as f64
    }

    /// Static op class at `pc` (same for every warp: SIMT code).
    fn classify(&self, pc: u32) -> PcClass {
        let p = &self.profile;
        let u = self.unit(&[self.seed, pc as u64, 0xC1A5]);
        let mut acc = p.frac_ld;
        if u < acc {
            return PcClass::Load { cat: self.access_cat(pc) };
        }
        acc += p.frac_st;
        if u < acc {
            // Stores never broadcast; fold broadcast share into streaming.
            let cat = match self.access_cat(pc) {
                AccessCat::Broadcast => AccessCat::Stream,
                c => c,
            };
            return PcClass::Store { cat };
        }
        acc += p.frac_smem;
        if u < acc {
            return PcClass::Smem;
        }
        acc += p.frac_sfu;
        if u < acc {
            return PcClass::Sfu;
        }
        acc += p.frac_branch;
        if u < acc {
            return PcClass::Branch;
        }
        // Split remaining ALU work 50/50 int/float.
        if hash_combine(&[self.seed, pc as u64, 0xF10A]) & 1 == 0 {
            PcClass::Alu
        } else {
            PcClass::Falu
        }
    }

    /// Access category for a memory PC (static property of the code line).
    fn access_cat(&self, pc: u32) -> AccessCat {
        let p = &self.profile;
        let u = self.unit(&[self.seed, pc as u64, 0xACCE55]);
        let mut acc = p.broadcast_frac;
        if u < acc {
            return AccessCat::Broadcast;
        }
        acc += p.shared_frac;
        if u < acc {
            return AccessCat::Shared;
        }
        acc += p.scatter_frac;
        if u < acc {
            return AccessCat::Scatter;
        }
        acc += p.stream_frac;
        if u < acc {
            return AccessCat::Stream;
        }
        AccessCat::PrivateReuse
    }

    /// Concrete address pattern for `(cta, sub-warp, pc)` in `cat`.
    fn pattern(&self, cat: AccessCat, cta: u32, warp: u32, pc: u32) -> (MemSpace, AccessPattern) {
        let p = &self.profile;
        let line = 128u64; // address math only; caches re-derive their own
        let h = hash_combine(&[self.seed, cta as u64, warp as u64, pc as u64, 0xADD2]);
        match cat {
            AccessCat::PrivateReuse => {
                // Strided walk within the CTA's (small) private hot set.
                let ws = (p.working_set_lines / 16).max(8) as u64;
                let base = PRIVATE_REGION
                    + cta as u64 * REGION_SPAN
                    + (h % ws) * line;
                (MemSpace::Global, AccessPattern::Strided { base, stride: p.stride })
            }
            AccessCat::Stream => {
                // Unique line per (cta, warp, pc): never reused.
                let base = STREAM_REGION + (splitmix64(h) % (1 << 30)) * line;
                (MemSpace::Global, AccessPattern::Strided { base, stride: p.stride })
            }
            AccessCat::Broadcast => {
                // Constant-table line shared warp-wide; half of these live
                // in the constant space (L1C), half in global.
                let ws = (p.working_set_lines.max(4) / 4) as u64;
                let base = PAIR_REGION + (h % ws) * line;
                let space = if h & 1 == 0 { MemSpace::Const } else { MemSpace::Global };
                (space, AccessPattern::Broadcast { base })
            }
            AccessCat::Shared => {
                // Kernel-global hot table (`working_set_lines` wide): every
                // CTA walks the same lines (e.g. StringMatch's pattern
                // tables). This is THE capacity-crossover driver: a table
                // that thrashes one baseline L1 but fits the fused
                // (doubled) L1 reproduces the paper's SM/Fig-15 behaviour,
                // and duplicated copies in neighbouring SMs' L1s dedup on
                // fusion (Fig 5).
                let ws = p.working_set_lines.max(1) as u64;
                let base = PAIR_REGION + (h % ws) * line;
                (MemSpace::Global, AccessPattern::Strided { base, stride: p.stride })
            }
            AccessCat::Scatter => {
                (MemSpace::Global, AccessPattern::Scatter { base: PRIVATE_REGION, seed: h })
            }
        }
    }

    /// Resolve the dynamic instruction a 32-wide sub-warp executes at `pc`.
    pub fn resolve(&self, cta: u32, subwarp: u32, pc: u32) -> Op {
        match self.classify(pc) {
            PcClass::Alu => Op::IAlu,
            PcClass::Falu => Op::FAlu,
            PcClass::Sfu => Op::Sfu,
            PcClass::Smem => {
                let base = (pc as u64 % 64) * 128;
                Op::Ld { space: MemSpace::Shared, pattern: AccessPattern::Strided { base, stride: 4 } }
            }
            PcClass::Branch => {
                let p = &self.profile;
                let u = self.unit(&[self.seed, cta as u64, subwarp as u64, pc as u64, 0xD1FF]);
                Op::Branch { diverges: u < p.div_prob, region_len: p.div_region }
            }
            PcClass::Load { cat } => {
                let (space, pattern) = self.pattern(cat, cta, subwarp, pc);
                Op::Ld { space, pattern }
            }
            PcClass::Store { cat } => {
                let (space, pattern) = self.pattern(cat, cta, subwarp, pc);
                Op::St { space, pattern }
            }
        }
    }

    /// Fraction of threads taking the slow path when a branch diverges,
    /// drawn around the profile's mean.
    pub fn divergence_split(&self, cta: u32, subwarp: u32, pc: u32) -> f64 {
        let u = self.unit(&[self.seed, cta as u64, subwarp as u64, pc as u64, 0x5711]);
        (self.profile.div_taken_frac * (0.5 + u)).clamp(0.05, 0.95)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{bench, kernel_launches};

    fn gen_for(name: &str) -> TraceGen {
        let p = bench(name).unwrap();
        let ks = kernel_launches(&p, 7);
        TraceGen::new(&p, &ks[0])
    }

    #[test]
    fn same_pc_same_static_op_across_warps() {
        let g = gen_for("RAY");
        for pc in 0..200 {
            let a = g.resolve(0, 0, pc);
            let b = g.resolve(5, 3, pc);
            // Kind must match (SIMT: same code); operands may differ.
            assert_eq!(std::mem::discriminant(&a), std::mem::discriminant(&b), "pc={pc}");
        }
    }

    #[test]
    fn resolve_is_deterministic() {
        let g1 = gen_for("BFS");
        let g2 = gen_for("BFS");
        for pc in 0..300 {
            assert_eq!(g1.resolve(3, 1, pc), g2.resolve(3, 1, pc));
        }
    }

    #[test]
    fn mix_roughly_matches_profile() {
        let p = bench("MUM").unwrap();
        let g = gen_for("MUM");
        let n = 20_000u32;
        let mut loads = 0;
        let mut branches = 0;
        for pc in 0..n {
            match g.resolve(0, 0, pc) {
                Op::Ld { space, .. } if space != MemSpace::Shared => loads += 1,
                Op::Branch { .. } => branches += 1,
                _ => {}
            }
        }
        let lf = loads as f64 / n as f64;
        let bf = branches as f64 / n as f64;
        assert!((lf - p.frac_ld).abs() < 0.02, "load frac {lf} vs {}", p.frac_ld);
        assert!((bf - p.frac_branch).abs() < 0.02, "branch frac {bf} vs {}", p.frac_branch);
    }

    #[test]
    fn divergence_rate_roughly_matches() {
        let p = bench("RAY").unwrap();
        let g = gen_for("RAY");
        let mut total = 0u32;
        let mut div = 0u32;
        for pc in 0..40_000 {
            for w in 0..2 {
                if let Op::Branch { diverges, .. } = g.resolve(1, w, pc) {
                    total += 1;
                    div += diverges as u32;
                }
            }
        }
        let rate = div as f64 / total as f64;
        assert!((rate - p.div_prob).abs() < 0.03, "div rate {rate} vs {}", p.div_prob);
    }

    #[test]
    fn shared_table_is_common_across_ctas() {
        // All CTAs draw Shared addresses from the same bounded global
        // table, so different CTAs produce colliding lines (the dedup /
        // capacity effect fusion exploits).
        let g = gen_for("SM");
        let p = bench("SM").unwrap();
        let span = p.working_set_lines as u64 * 128;
        let mut lines_cta0 = std::collections::HashSet::new();
        let mut overlap = false;
        for pc in 0..4000 {
            if let Op::Ld { pattern: AccessPattern::Strided { base, .. }, .. } =
                g.resolve(0, 0, pc)
            {
                if (PAIR_REGION..PAIR_REGION + span).contains(&base) {
                    lines_cta0.insert(base);
                }
            }
        }
        for pc in 0..4000 {
            if let Op::Ld { pattern: AccessPattern::Strided { base, .. }, .. } =
                g.resolve(7, 2, pc)
            {
                if lines_cta0.contains(&base) {
                    overlap = true;
                    break;
                }
            }
        }
        assert!(!lines_cta0.is_empty(), "SM draws from the shared table");
        assert!(overlap, "distinct CTAs hit common table lines");
    }

    #[test]
    fn code_addrs_stay_in_footprint() {
        let g = gen_for("CP");
        for pc in 0..10_000 {
            let a = g.code_addr(pc);
            assert!(a >= CODE_REGION && a < CODE_REGION + g.code_bytes());
        }
    }

    #[test]
    fn divergence_split_bounded() {
        let g = gen_for("BFS");
        for pc in 0..1000 {
            let f = g.divergence_split(0, 0, pc);
            assert!((0.05..=0.95).contains(&f));
        }
    }
}
