//! Workload models: deterministic RNG, per-benchmark profiles, the
//! procedural trace generator that turns a profile into per-warp
//! instruction streams, and multi-tenant kernel streams (arrival-timed
//! launch sequences for the server simulation mode).

mod gen;
mod profiles;
mod rng;
mod stream;

pub use gen::{TraceGen, CODE_FOOTPRINT_BYTES};
pub use profiles::{all_benchmarks, BenchProfile, FIG12_SET, FIG20_SET, FIG3_SET, FIG5_SET};
pub use rng::{hash_combine, splitmix64, Pcg32};
pub use stream::{
    shrink_streams, traffic_trace, traffic_trace_qos, KernelStream, Priority, StreamLaunch,
    TenantQosSpec, TrafficPattern,
};

use crate::isa::KernelLaunch;

/// Benchmark suite of origin (documentation / reporting only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Suite {
    Ispass,
    Rodinia,
    Polybench,
    Mars,
}

impl std::fmt::Display for Suite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Suite::Ispass => "ispass",
            Suite::Rodinia => "rodinia",
            Suite::Polybench => "polybench",
            Suite::Mars => "mars",
        })
    }
}

/// Look up a benchmark profile by (case-insensitive) name.
pub fn bench(name: &str) -> Option<BenchProfile> {
    all_benchmarks()
        .into_iter()
        .find(|b| b.name.eq_ignore_ascii_case(name))
}

/// The kernel launches of one application run of `profile`, seeded by
/// `run_seed` (each kernel gets a distinct derived seed).
pub fn kernel_launches(profile: &BenchProfile, run_seed: u64) -> Vec<KernelLaunch> {
    (0..profile.num_kernels)
        .map(|k| KernelLaunch {
            id: k,
            num_ctas: profile.num_ctas,
            cta_threads: profile.cta_threads,
            insns_per_thread: profile.insns_per_thread,
            regs_per_thread: profile.regs_per_thread,
            smem_per_cta: profile.smem_per_cta,
            seed: hash_combine(&[run_seed, k as u64, 0xA110C]),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_lookup_case_insensitive() {
        assert!(bench("ray").is_some());
        assert!(bench("RAY").is_some());
        assert!(bench("nope").is_none());
    }

    #[test]
    fn kernel_launches_are_seed_distinct() {
        let p = bench("BFS").unwrap();
        let ks = kernel_launches(&p, 1);
        assert_eq!(ks.len(), p.num_kernels as usize);
        let mut seeds: Vec<_> = ks.iter().map(|k| k.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), ks.len());
        // Different run seed => different kernel seeds.
        assert_ne!(kernel_launches(&p, 2)[0].seed, ks[0].seed);
    }
}
