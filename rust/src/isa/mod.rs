//! SIMT execution-model types: kernels, CTAs, warps, instructions, masks.
//!
//! The simulator executes *procedurally generated* instruction traces: a
//! warp's instruction at a given PC is produced deterministically by the
//! workload model ([`crate::workload`]) from `(kernel seed, cta, warp, pc)`.
//! This keeps memory bounded (no stored traces) while remaining exactly
//! reproducible.

mod mask;

pub use mask::ActiveMask;

/// Memory space an access targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemSpace {
    /// Global memory: L1D -> NoC -> L2/DRAM.
    Global,
    /// Shared (scratchpad) memory: on-SM, fixed latency, no NoC.
    Shared,
    /// Constant memory: L1C, read-only.
    Const,
    /// Texture memory: L1T, read-only.
    Texture,
}

/// One warp-level dynamic instruction (the unit the pipeline issues).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// Integer ALU operation.
    IAlu,
    /// Floating-point ALU operation.
    FAlu,
    /// Special-function unit op (transcendental, rsqrt, ...).
    Sfu,
    /// Memory load. `pattern` drives per-thread address generation.
    Ld { space: MemSpace, pattern: AccessPattern },
    /// Memory store.
    St { space: MemSpace, pattern: AccessPattern },
    /// Conditional branch. `diverges` is resolved by the workload model;
    /// a divergent branch serialises `region_len` instructions per path.
    Branch { diverges: bool, region_len: u16 },
    /// CTA-wide barrier.
    Bar,
    /// Thread-block exit (the warp is done when every instr is consumed).
    Exit,
}

impl Op {
    /// Is this op a global/texture/const load or store (i.e. may miss L1)?
    pub fn is_cached_mem(&self) -> bool {
        matches!(
            self,
            Op::Ld { space: MemSpace::Global | MemSpace::Const | MemSpace::Texture, .. }
                | Op::St { space: MemSpace::Global, .. }
        )
    }

    /// Is this op any kind of load?
    pub fn is_load(&self) -> bool {
        matches!(self, Op::Ld { .. })
    }

    /// Is this op any kind of store?
    pub fn is_store(&self) -> bool {
        matches!(self, Op::St { .. })
    }
}

/// Per-thread address-generation pattern for one memory instruction.
///
/// `base` is a byte address inside the benchmark's modelled footprint; the
/// pattern determines each active lane's address, which the coalescer then
/// folds into cache-line transactions. The patterns are chosen to span the
/// paper's characterisation space (Fig 4: coalescing; Fig 5: inter-SM
/// sharing; §3.1(2) locality).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AccessPattern {
    /// `addr(lane) = base + lane * stride` — coalesces into few lines when
    /// `stride` is small (the classic "nice" GPU access).
    Strided { base: u64, stride: u32 },
    /// All lanes read the same line (broadcast; coalesces to 1 transaction).
    Broadcast { base: u64 },
    /// Each lane hits an independent pseudo-random line (worst case:
    /// one transaction per lane). `seed` makes it deterministic.
    Scatter { base: u64, seed: u64 },
}

impl AccessPattern {
    /// Byte address accessed by `lane` under this pattern.
    pub fn lane_addr(&self, lane: usize) -> u64 {
        match *self {
            AccessPattern::Strided { base, stride } => base + lane as u64 * stride as u64,
            AccessPattern::Broadcast { base } => base,
            AccessPattern::Scatter { base, seed } => {
                // splitmix64 on (seed, lane): deterministic scatter.
                let mut z = seed ^ (lane as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
                base + (z ^ (z >> 31)) % (64 << 20) // within a 64 MiB window
            }
        }
    }
}

/// Static identity of a warp within the launched grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WarpId {
    /// Kernel launch ordinal.
    pub kernel: u32,
    /// CTA index within the grid.
    pub cta: u32,
    /// Warp index within the CTA.
    pub warp: u32,
}

/// A kernel launch: how much work and under which workload profile.
#[derive(Debug, Clone)]
pub struct KernelLaunch {
    /// Kernel ordinal within the application (keys the trace generator).
    pub id: u32,
    /// Number of CTAs in the grid.
    pub num_ctas: u32,
    /// Threads per CTA.
    pub cta_threads: u32,
    /// Dynamic instructions each thread executes (trace length).
    pub insns_per_thread: u32,
    /// Registers per thread (occupancy limiter).
    pub regs_per_thread: u32,
    /// Shared memory per CTA in bytes (occupancy limiter).
    pub smem_per_cta: u32,
    /// Seed deriving every per-warp instruction stream of this kernel.
    pub seed: u64,
}

impl KernelLaunch {
    /// Warps per CTA for a machine with `warp_size`-wide warps.
    pub fn warps_per_cta(&self, warp_size: usize) -> u32 {
        (self.cta_threads as usize).div_ceil(warp_size) as u32
    }

    /// Total dynamic warp-instructions this kernel will execute (used for
    /// IPC bookkeeping and progress checks).
    pub fn total_warp_insns(&self, warp_size: usize) -> u64 {
        self.num_ctas as u64
            * self.warps_per_cta(warp_size) as u64
            * self.insns_per_thread as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_addresses_are_deterministic() {
        let p = AccessPattern::Scatter { base: 0x1000, seed: 42 };
        let a = p.lane_addr(5);
        assert_eq!(a, p.lane_addr(5));
        assert_ne!(a, p.lane_addr(6));
        let s = AccessPattern::Strided { base: 0x100, stride: 4 };
        assert_eq!(s.lane_addr(0), 0x100);
        assert_eq!(s.lane_addr(3), 0x10C);
        let b = AccessPattern::Broadcast { base: 0x80 };
        assert_eq!(b.lane_addr(0), b.lane_addr(31));
    }

    #[test]
    fn kernel_warp_math() {
        let k = KernelLaunch {
            id: 0,
            num_ctas: 10,
            cta_threads: 256,
            insns_per_thread: 100,
            regs_per_thread: 16,
            smem_per_cta: 0,
            seed: 1,
        };
        assert_eq!(k.warps_per_cta(32), 8);
        assert_eq!(k.warps_per_cta(64), 4);
        assert_eq!(k.total_warp_insns(32), 10 * 8 * 100);
        // Non-multiple thread count rounds up.
        let k2 = KernelLaunch { cta_threads: 100, ..k };
        assert_eq!(k2.warps_per_cta(32), 4);
    }

    #[test]
    fn op_classification() {
        let ld = Op::Ld { space: MemSpace::Global, pattern: AccessPattern::Broadcast { base: 0 } };
        assert!(ld.is_cached_mem() && ld.is_load() && !ld.is_store());
        let sm = Op::Ld { space: MemSpace::Shared, pattern: AccessPattern::Broadcast { base: 0 } };
        assert!(!sm.is_cached_mem());
        let st = Op::St { space: MemSpace::Global, pattern: AccessPattern::Broadcast { base: 0 } };
        assert!(st.is_cached_mem() && st.is_store());
        assert!(!Op::Bar.is_cached_mem());
    }
}
