//! Active-thread masks for warps up to 64 lanes (fused warp width).

/// A per-lane activity bitmask. Bit `i` set means lane `i` executes.
///
/// Baseline warps use the low 32 bits; fused (64-wide) warps use all 64.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ActiveMask(pub u64);

impl ActiveMask {
    /// All lanes of a `width`-wide warp active.
    pub fn full(width: usize) -> Self {
        debug_assert!(width <= 64 && width > 0);
        if width == 64 {
            ActiveMask(u64::MAX)
        } else {
            ActiveMask((1u64 << width) - 1)
        }
    }

    /// No lanes active.
    pub fn empty() -> Self {
        ActiveMask(0)
    }

    /// Number of active lanes.
    pub fn count(&self) -> u32 {
        self.0.count_ones()
    }

    /// Is lane `i` active?
    pub fn lane(&self, i: usize) -> bool {
        debug_assert!(i < 64);
        self.0 >> i & 1 == 1
    }

    /// Set lane `i`.
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < 64);
        self.0 |= 1 << i;
    }

    /// Clear lane `i`.
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < 64);
        self.0 &= !(1 << i);
    }

    /// Iterator over active lane indices, ascending.
    pub fn lanes(&self) -> impl Iterator<Item = usize> + '_ {
        let m = self.0;
        (0..64usize).filter(move |i| m >> i & 1 == 1)
    }

    /// Lower half (lanes [0, width/2)) of a `width`-wide warp's mask.
    pub fn low_half(&self, width: usize) -> ActiveMask {
        let half = width / 2;
        ActiveMask(self.0 & (if half == 64 { u64::MAX } else { (1u64 << half) - 1 }))
    }

    /// Upper half, shifted down so it becomes a `width/2`-wide mask.
    pub fn high_half(&self, width: usize) -> ActiveMask {
        let half = width / 2;
        ActiveMask(self.0 >> half & (if half == 64 { u64::MAX } else { (1u64 << half) - 1 }))
    }

    /// Fraction of a `width`-wide warp that is active.
    pub fn occupancy(&self, width: usize) -> f64 {
        self.count() as f64 / width as f64
    }
}

impl std::ops::BitAnd for ActiveMask {
    type Output = ActiveMask;
    fn bitand(self, rhs: Self) -> Self {
        ActiveMask(self.0 & rhs.0)
    }
}

impl std::ops::BitOr for ActiveMask {
    type Output = ActiveMask;
    fn bitor(self, rhs: Self) -> Self {
        ActiveMask(self.0 | rhs.0)
    }
}

impl std::ops::Not for ActiveMask {
    type Output = ActiveMask;
    fn not(self) -> Self {
        ActiveMask(!self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_masks() {
        assert_eq!(ActiveMask::full(32).count(), 32);
        assert_eq!(ActiveMask::full(64).count(), 64);
        assert_eq!(ActiveMask::full(8).0, 0xFF);
        assert_eq!(ActiveMask::empty().count(), 0);
    }

    #[test]
    fn lane_ops() {
        let mut m = ActiveMask::empty();
        m.set(0);
        m.set(33);
        assert!(m.lane(0) && m.lane(33) && !m.lane(1));
        assert_eq!(m.lanes().collect::<Vec<_>>(), vec![0, 33]);
        m.clear(0);
        assert_eq!(m.count(), 1);
    }

    #[test]
    fn halves() {
        let m = ActiveMask::full(64);
        assert_eq!(m.low_half(64).count(), 32);
        assert_eq!(m.high_half(64).count(), 32);
        let mut m = ActiveMask::empty();
        m.set(0);
        m.set(40);
        assert_eq!(m.low_half(64).lanes().collect::<Vec<_>>(), vec![0]);
        assert_eq!(m.high_half(64).lanes().collect::<Vec<_>>(), vec![8]); // 40-32
    }

    #[test]
    fn occupancy_and_bitops() {
        let m = ActiveMask::full(32);
        assert!((m.occupancy(32) - 1.0).abs() < 1e-12);
        assert_eq!((m & ActiveMask::empty()).count(), 0);
        assert_eq!((m | ActiveMask::empty()).count(), 32);
        assert_eq!((!ActiveMask(0)).count(), 64);
    }
}
