//! Dynamic SM splitting and re-fusing (paper §4.3, Figs 10/11).
//!
//! Each fused cluster is watched independently: when the divergent-warp
//! ratio exceeds the configured threshold, the cluster splits — divergent
//! work moves to the second half per the active policy (direct split or
//! warp regrouping) while fast warps keep the first half busy. When the
//! second half drains, the cluster re-fuses. A periodic rebalance donates
//! fast warps to an under-utilised slow half so its issue slots are not
//! wasted while slow warps stall (§4.3 last paragraph).
//!
//! "Watched independently" is structural: the GPU owns **one `DynSplit`
//! instance per cluster**, so one cluster's rebalance can never consume
//! another cluster's rebalance period (a single shared instance used to
//! do exactly that), and the rebalance timer restarts whenever a cluster
//! enters split mode.

use crate::config::{SplitPolicy, SystemConfig};
use crate::sim::core::{ClusterMode, SmCluster};

/// The per-cluster split/fuse state machine driver.
#[derive(Debug, Clone)]
pub struct DynSplit {
    threshold: f32,
    rebalance_period: u64,
    last_rebalance: u64,
}

impl DynSplit {
    /// Build from the system config knobs.
    pub fn new(cfg: &SystemConfig) -> Self {
        DynSplit {
            threshold: cfg.split_threshold,
            rebalance_period: cfg.rebalance_period,
            last_rebalance: 0,
        }
    }

    /// Serialize the mutable state (checkpoint format): only the rebalance
    /// timer — threshold and period are config, rebuilt by the constructor.
    pub fn save_state(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        w.u64(self.last_rebalance);
    }

    /// Inverse of [`DynSplit::save_state`].
    pub fn load_state(
        &mut self,
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<()> {
        self.last_rebalance = r.u64()?;
        Ok(())
    }

    /// Evaluate one cluster: split, re-fuse, or rebalance as needed.
    /// Called periodically (every `split_check_period` cycles) by the GPU.
    pub fn check(&mut self, now: u64, cluster: &mut SmCluster) {
        match cluster.mode() {
            ClusterMode::Fused => {
                if cluster.split_policy.is_some()
                    && cluster.divergent_ratio() > self.threshold
                    && cluster.live_warps() > 1
                {
                    self.split(now, cluster);
                    cluster.stats.split_events += 1;
                    // Split/rebalance move warp `home`s behind the
                    // scheduler's back: refile the ready-warp index.
                    cluster.rebuild_sched();
                }
            }
            ClusterMode::FusedSplit => {
                self.restore_reconverged(cluster);
                if self.slow_half_drained(cluster) {
                    self.refuse(cluster);
                    cluster.stats.fuse_events += 1;
                } else if now.saturating_sub(self.last_rebalance) >= self.rebalance_period {
                    self.last_rebalance = now;
                    self.rebalance(cluster);
                }
                cluster.rebuild_sched();
            }
            ClusterMode::PrivatePair => {}
        }
    }

    /// Enter split mode and distribute currently-divergent warps per the
    /// policy. New divergences are handled at issue time by the cluster
    /// (see `SmCluster::handle_divergence`).
    fn split(&mut self, now: u64, cluster: &mut SmCluster) {
        // Entering split starts a fresh rebalance period: a stale
        // `last_rebalance` from a previous split would otherwise donate a
        // fast warp on the very first check after splitting.
        self.last_rebalance = now;
        let policy = cluster.split_policy.expect("split checked only with a policy");
        cluster.set_mode(ClusterMode::FusedSplit);
        match policy {
            SplitPolicy::Direct => {
                // Move every divergent warp wholesale to the slow half.
                for w in cluster.warps.iter_mut().filter(|w| !w.finished && w.divergent) {
                    w.home = 1;
                }
            }
            SplitPolicy::Regroup => {
                // Divergent warps stay on the fast half; their slow passes
                // become shadows on half 1 as they are (re-)issued. Warps
                // already in a serial second pass migrate like direct
                // split (their fast threads are already done).
                for w in cluster.warps.iter_mut().filter(|w| !w.finished && w.divergent) {
                    if w.replay.map(|r| r.in_second_pass).unwrap_or(false) {
                        w.home = 1;
                    }
                }
            }
        }
    }

    /// Move reconverged warps back to the fast half.
    fn restore_reconverged(&self, cluster: &mut SmCluster) {
        for w in cluster.warps.iter_mut() {
            if w.home == 1 && !w.divergent && !w.finished {
                w.home = 0;
            }
        }
    }

    /// Slow half fully drained (no divergent residents, no live shadows)?
    fn slow_half_drained(&self, cluster: &SmCluster) -> bool {
        let resident =
            cluster.warps.iter().any(|w| !w.finished && (w.home == 1 || w.divergent));
        !resident && !cluster.shadows_active()
    }

    /// Re-fuse the cluster (keeps merged caches warm).
    fn refuse(&self, cluster: &mut SmCluster) {
        cluster.reap_shadows();
        for w in cluster.warps.iter_mut() {
            w.home = 0;
        }
        cluster.set_mode(ClusterMode::Fused);
    }

    /// Donate one fast warp to the slow half if it is starving (§4.3:
    /// "periodically move some fast warps to them so that the resources
    /// are not wasted when the slow warps cause stalls").
    fn rebalance(&self, cluster: &mut SmCluster) {
        let slow_issuable = cluster
            .warps
            .iter()
            .filter(|w| w.home == 1 && w.issuable())
            .count()
            + cluster.shadows.iter().filter(|s| s.issuable()).count();
        if slow_issuable > 0 {
            return; // slow half has work
        }
        let fast_live: Vec<usize> = cluster
            .warps
            .iter()
            .enumerate()
            .filter(|(_, w)| w.home == 0 && !w.finished && !w.divergent)
            .map(|(i, _)| i)
            .collect();
        if fast_live.len() > 1 {
            let donate = fast_live[fast_live.len() / 2];
            cluster.warps[donate].home = 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{bench, kernel_launches, TraceGen};

    fn fused_cluster_with_cta(policy: SplitPolicy) -> (SmCluster, TraceGen) {
        let cfg = SystemConfig::tiny();
        let mut c = SmCluster::new(0, &cfg, ClusterMode::Fused);
        c.split_policy = Some(policy);
        let p = bench("RAY").unwrap();
        let k = kernel_launches(&p, 5)[0].clone();
        let gen = TraceGen::new(&p, &k);
        c.dispatch_cta(&k, 0, &gen);
        (c, gen)
    }

    #[test]
    fn split_triggers_on_divergence_ratio() {
        let cfg = SystemConfig::tiny();
        let mut ds = DynSplit::new(&cfg);
        let (mut c, _) = fused_cluster_with_cta(SplitPolicy::Direct);
        // Below threshold: stays fused.
        ds.check(0, &mut c);
        assert_eq!(c.mode(), ClusterMode::Fused);
        // Push most warps divergent.
        let n = c.warps.len();
        for w in c.warps.iter_mut().take(n / 2 + 1) {
            w.divergent = true;
        }
        ds.check(1, &mut c);
        assert_eq!(c.mode(), ClusterMode::FusedSplit);
        assert_eq!(c.stats.split_events, 1);
        // Direct policy: divergent warps moved to half 1.
        assert!(c.warps.iter().filter(|w| w.divergent).all(|w| w.home == 1));
    }

    #[test]
    fn refuse_when_slow_half_drains() {
        let cfg = SystemConfig::tiny();
        let mut ds = DynSplit::new(&cfg);
        let (mut c, _) = fused_cluster_with_cta(SplitPolicy::Direct);
        for w in c.warps.iter_mut() {
            w.divergent = true;
        }
        ds.check(0, &mut c);
        assert_eq!(c.mode(), ClusterMode::FusedSplit);
        // Divergence resolves.
        for w in c.warps.iter_mut() {
            w.divergent = false;
        }
        ds.check(1, &mut c);
        assert_eq!(c.mode(), ClusterMode::Fused, "re-fused after drain");
        assert_eq!(c.stats.fuse_events, 1);
        assert!(c.warps.iter().all(|w| w.home == 0));
    }

    #[test]
    fn regroup_keeps_first_pass_warps_on_fast_half() {
        let cfg = SystemConfig::tiny();
        let mut ds = DynSplit::new(&cfg);
        let (mut c, _) = fused_cluster_with_cta(SplitPolicy::Regroup);
        for w in c.warps.iter_mut() {
            w.divergent = true; // divergent but not yet in second pass
        }
        ds.check(0, &mut c);
        assert_eq!(c.mode(), ClusterMode::FusedSplit);
        assert!(c.warps.iter().all(|w| w.home == 0), "fast passes stay");
    }

    #[test]
    fn split_entry_resets_rebalance_timer() {
        let cfg = SystemConfig::tiny();
        let mut ds = DynSplit::new(&cfg);
        let (mut c, _) = fused_cluster_with_cta(SplitPolicy::Direct);
        // Two of four warps divergent: over the 0.25 threshold, with two
        // fast warps left so a rebalance donation is possible.
        c.warps[0].divergent = true;
        c.warps[1].divergent = true;
        // Stale timer: the last rebalance happened "long ago" at cycle 0.
        assert_eq!(ds.last_rebalance, 0);
        ds.check(10_000, &mut c);
        assert_eq!(c.mode(), ClusterMode::FusedSplit);
        // Stall the slow half so a due rebalance would donate.
        for w in c.warps.iter_mut().filter(|w| w.home == 1) {
            w.outstanding_loads = 5;
        }
        let on_slow = |c: &SmCluster| c.warps.iter().filter(|w| w.home == 1).count();
        assert_eq!(on_slow(&c), 2);
        // One cycle after the split: the period restarted at split entry,
        // so no donation (the unfixed code donated here).
        ds.check(10_001, &mut c);
        assert_eq!(on_slow(&c), 2, "fresh split must not rebalance immediately");
        // A full period after the split: now the donation happens.
        ds.check(10_000 + cfg.rebalance_period, &mut c);
        assert_eq!(on_slow(&c), 3, "due rebalance donates one fast warp");
    }

    /// Regression for the cross-cluster state-sharing bug: the GPU wires
    /// one `DynSplit` per cluster, so two clusters both due for rebalance
    /// in the same check pass both get one. (With the old single shared
    /// instance, the first cluster's rebalance reset the timer and starved
    /// every other cluster — the shared-instance half of this test pins
    /// that failure mode as the reason for the per-cluster structure.)
    #[test]
    fn rebalance_state_is_per_cluster() {
        let cfg = SystemConfig::tiny();
        let stalled_split_cluster = || {
            let (mut c, _) = fused_cluster_with_cta(SplitPolicy::Direct);
            c.warps[0].divergent = true;
            c.set_mode(ClusterMode::FusedSplit);
            c.warps[0].home = 1;
            c.warps[0].outstanding_loads = 5;
            c
        };
        let on_slow = |c: &SmCluster| c.warps.iter().filter(|w| w.home == 1).count();
        let t = cfg.rebalance_period * 2;

        // Per-cluster instances (what `Gpu::new` builds): both rebalance.
        let mut ds: Vec<DynSplit> = (0..2).map(|_| DynSplit::new(&cfg)).collect();
        let mut a = stalled_split_cluster();
        let mut b = stalled_split_cluster();
        ds[0].check(t, &mut a);
        ds[1].check(t, &mut b);
        assert_eq!(on_slow(&a), 2, "cluster A rebalanced");
        assert_eq!(on_slow(&b), 2, "cluster B rebalanced in the same pass");

        // Counterexample: one shared instance starves the second cluster.
        let mut shared = DynSplit::new(&cfg);
        let mut c = stalled_split_cluster();
        let mut d = stalled_split_cluster();
        shared.check(t, &mut c);
        shared.check(t, &mut d);
        assert_eq!(on_slow(&c), 2);
        assert_eq!(on_slow(&d), 1, "shared timer suppresses the second cluster");
    }

    #[test]
    fn rebalance_donates_a_fast_warp() {
        let cfg = SystemConfig::tiny();
        let mut ds = DynSplit::new(&cfg);
        let (mut c, _) = fused_cluster_with_cta(SplitPolicy::Direct);
        // Enter split with one divergent warp that then blocks on memory.
        c.warps[0].divergent = true;
        for w in c.warps.iter_mut().skip(1) {
            w.divergent = false;
        }
        c.set_mode(ClusterMode::FusedSplit);
        c.warps[0].home = 1;
        c.warps[0].outstanding_loads = 5; // slow half stalled
        ds.last_rebalance = 0;
        ds.rebalance(&mut c);
        let on_slow = c.warps.iter().filter(|w| w.home == 1).count();
        assert_eq!(on_slow, 2, "one fast warp donated");
    }
}
