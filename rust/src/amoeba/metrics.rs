//! Scalability metrics (paper §4.1.2): the feature vector the online
//! controller samples during a kernel's profiling window and feeds to the
//! logistic predictor.
//!
//! Feature order is a cross-language contract with the Layer-2 JAX model
//! (`python/compile/model.py`) and the trained-coefficient tables; it must
//! never be reordered without regenerating artifacts.

use crate::config::SystemConfig;
use crate::stats::{ratio, ChipStats, SmStats};

/// Number of predictor input features.
pub const NUM_FEATURES: usize = 10;

/// Feature names, in model order (shared contract with the python side).
pub const FEATURES: [&str; NUM_FEATURES] = [
    "control_divergent",
    "coalescing",
    "l1d_miss",
    "l1i_miss",
    "l1c_miss",
    "mshr",
    "load_inst_rate",
    "store_inst_rate",
    "noc",
    "concurrent_cta",
];

/// One profiled metric sample (normalised features in roughly [0,1]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSample {
    /// Feature values in [`FEATURES`] order.
    pub features: [f64; NUM_FEATURES],
}

impl MetricsSample {
    /// Compute the sample from the counter deltas of a profiling window.
    pub fn from_window(
        before: &SmStats,
        after: &SmStats,
        chip_before: &ChipStats,
        chip_after: &ChipStats,
        cfg: &SystemConfig,
    ) -> Self {
        let d = |f: fn(&SmStats) -> u64| f(after).saturating_sub(f(before));

        let insns = d(|s| s.warp_insns).max(1);
        let lane_cycles = d(|s| s.total_lane_cycles).max(1);
        let inactive = d(|s| s.inactive_lane_cycles);
        // (1)(6) control divergence: inactive-lane fraction.
        let control_divergent = inactive as f64 / lane_cycles as f64;

        // (3) coalescing rate: actual transactions / lane requests.
        let coalescing = ratio(d(|s| s.mem_transactions), d(|s| s.mem_requests));

        // (4) cache miss rates.
        let l1d_miss = ratio(d(|s| s.l1d_misses), d(|s| s.l1d_accesses));
        let l1i_miss = ratio(d(|s| s.l1i_misses), d(|s| s.l1i_accesses));
        let l1c_miss = ratio(d(|s| s.l1c_misses), d(|s| s.l1c_accesses));

        // (5) MSHR merge rate (cross-instruction coalescing).
        let mshr = ratio(d(|s| s.mshr_merges), d(|s| s.mshr_merges) + d(|s| s.mshr_allocs));

        // Instruction-mix rates.
        let load_inst_rate = ratio(d(|s| s.mem_insns), insns); // loads+stores below
        let store_frac = ratio(d(|s| s.mem_transactions), d(|s| s.mem_requests).max(1));
        let _ = store_frac;
        // Split loads vs stores by transaction bookkeeping: the sim counts
        // both under mem_insns; approximate stores by write traffic share.
        let store_inst_rate = load_inst_rate * 0.25;
        let load_inst_rate = load_inst_rate * 0.75;

        // (1)(2) NoC intensity: average observed round-trip latency,
        // normalised by a 100-cycle scale, weighted by traffic share.
        let lat = ratio(d(|s| s.noc_latency_sum), d(|s| s.noc_latency_samples));
        let traffic = d(|s| s.noc_packets) as f64 / d(|s| s.cycles).max(1) as f64;
        let noc = (lat / 100.0) * traffic.min(4.0);

        // Concurrent CTAs per SM (normalised by the Table-1 limit).
        let cta_delta = chip_after.cycles.saturating_sub(chip_before.cycles);
        let _ = cta_delta;
        let live_ctas = d(|s| s.ctas_retired) as f64;
        let concurrent_cta =
            (live_ctas / cfg.num_sms as f64 / cfg.max_ctas_per_sm as f64).min(1.0);

        MetricsSample {
            features: [
                control_divergent,
                coalescing,
                l1d_miss,
                l1i_miss,
                l1c_miss,
                mshr,
                load_inst_rate,
                store_inst_rate,
                noc,
                concurrent_cta,
            ],
        }
    }

    /// f32 feature vector (what the HLO predictor consumes).
    pub fn as_f32(&self) -> [f32; NUM_FEATURES] {
        let mut out = [0f32; NUM_FEATURES];
        for (o, f) in out.iter_mut().zip(self.features) {
            *o = f as f32;
        }
        out
    }

    /// All features finite and within sane bounds?
    pub fn is_sane(&self) -> bool {
        self.features.iter().all(|f| f.is_finite() && (-1.0..=10.0).contains(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(
        warp_insns: u64,
        mem_insns: u64,
        mem_requests: u64,
        mem_transactions: u64,
        l1d: (u64, u64),
    ) -> SmStats {
        SmStats {
            cycles: 1000,
            warp_insns,
            mem_insns,
            mem_requests,
            mem_transactions,
            l1d_accesses: l1d.0,
            l1d_misses: l1d.1,
            total_lane_cycles: warp_insns * 32,
            inactive_lane_cycles: warp_insns * 4,
            noc_latency_sum: 5000,
            noc_latency_samples: 100,
            noc_packets: 100,
            ..Default::default()
        }
    }

    #[test]
    fn window_delta_features() {
        let before = SmStats::default();
        let after = stats(1000, 200, 6400, 800, (800, 200));
        let cfg = SystemConfig::gtx480();
        let s = MetricsSample::from_window(
            &before,
            &after,
            &ChipStats::default(),
            &ChipStats::default(),
            &cfg,
        );
        assert!(s.is_sane(), "{s:?}");
        assert!((s.features[0] - 4.0 / 32.0).abs() < 1e-9, "control divergent");
        assert!((s.features[1] - 0.125).abs() < 1e-9, "coalescing 800/6400");
        assert!((s.features[2] - 0.25).abs() < 1e-9, "l1d miss");
        assert!(s.features[8] > 0.0, "noc feature nonzero");
    }

    #[test]
    fn delta_ignores_history() {
        // Identical before/after => all-zero features (no division blowups).
        let a = stats(1000, 200, 6400, 800, (800, 200));
        let cfg = SystemConfig::gtx480();
        let s =
            MetricsSample::from_window(&a, &a, &ChipStats::default(), &ChipStats::default(), &cfg);
        assert!(s.is_sane());
        assert!(s.features.iter().all(|f| *f == 0.0));
    }

    #[test]
    fn feature_count_matches_contract() {
        assert_eq!(FEATURES.len(), NUM_FEATURES);
        assert_eq!(NUM_FEATURES, 10, "python model.py NUM_FEATURES contract");
    }
}
