//! Scalability metrics (paper §4.1.2): the feature vector the online
//! controller samples during a kernel's profiling window and feeds to the
//! logistic predictor.
//!
//! Feature order is a cross-language contract with the Layer-2 JAX model
//! (`python/compile/model.py`) and the trained-coefficient tables; it must
//! never be reordered without regenerating artifacts.

use crate::config::SystemConfig;
use crate::stats::{ratio, SmStats};

/// Number of predictor input features.
pub const NUM_FEATURES: usize = 10;

/// Feature names, in model order (shared contract with the python side).
pub const FEATURES: [&str; NUM_FEATURES] = [
    "control_divergent",
    "coalescing",
    "l1d_miss",
    "l1i_miss",
    "l1c_miss",
    "mshr",
    "load_inst_rate",
    "store_inst_rate",
    "noc",
    "concurrent_cta",
];

/// One profiled metric sample (normalised features in roughly [0,1]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSample {
    /// Feature values in [`FEATURES`] order.
    pub features: [f64; NUM_FEATURES],
}

impl MetricsSample {
    /// Serialize the feature vector (checkpoint format): each feature as
    /// its IEEE bit pattern — exact round trip.
    pub fn write_to(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        for f in &self.features {
            w.f64(*f);
        }
    }

    /// Inverse of [`MetricsSample::write_to`].
    pub fn read_from(
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<MetricsSample> {
        let mut features = [0.0; NUM_FEATURES];
        for f in &mut features {
            *f = r.f64()?;
        }
        Ok(MetricsSample { features })
    }

    /// Compute the sample from the counter deltas of a chip-wide
    /// profiling window (normalised over all `cfg.num_sms` SMs).
    pub fn from_window(before: &SmStats, after: &SmStats, cfg: &SystemConfig) -> Self {
        Self::from_window_scaled(before, after, cfg, cfg.num_sms)
    }

    /// Compute the sample from the counter deltas of a profiling window
    /// covering `sm_count` SMs — `cfg.num_sms` for a chip-wide window,
    /// `2` for one cluster's window (the §4.4 per-cluster decision path).
    pub fn from_window_scaled(
        before: &SmStats,
        after: &SmStats,
        cfg: &SystemConfig,
        sm_count: usize,
    ) -> Self {
        let d = |f: fn(&SmStats) -> u64| f(after).saturating_sub(f(before));

        let insns = d(|s| s.warp_insns).max(1);
        let lane_cycles = d(|s| s.total_lane_cycles).max(1);
        let inactive = d(|s| s.inactive_lane_cycles);
        // (1)(6) control divergence: inactive-lane fraction.
        let control_divergent = inactive as f64 / lane_cycles as f64;

        // (3) coalescing rate: actual transactions / lane requests.
        let coalescing = ratio(d(|s| s.mem_transactions), d(|s| s.mem_requests));

        // (4) cache miss rates.
        let l1d_miss = ratio(d(|s| s.l1d_misses), d(|s| s.l1d_accesses));
        let l1i_miss = ratio(d(|s| s.l1i_misses), d(|s| s.l1i_accesses));
        let l1c_miss = ratio(d(|s| s.l1c_misses), d(|s| s.l1c_accesses));

        // (5) MSHR merge rate (cross-instruction coalescing).
        let mshr = ratio(d(|s| s.mshr_merges), d(|s| s.mshr_merges) + d(|s| s.mshr_allocs));

        // (7)(8) instruction-mix rates from the real load/store split:
        // stores are counted separately (`st_insns`), loads are the rest.
        let mem_rate = ratio(d(|s| s.mem_insns), insns);
        let st_share = ratio(d(|s| s.st_insns), d(|s| s.mem_insns));
        let store_inst_rate = mem_rate * st_share;
        let load_inst_rate = mem_rate * (1.0 - st_share);

        // (1)(2) NoC intensity: average observed round-trip latency,
        // normalised by a 100-cycle scale, weighted by traffic share.
        let lat = ratio(d(|s| s.noc_latency_sum), d(|s| s.noc_latency_samples));
        let traffic = d(|s| s.noc_packets) as f64 / d(|s| s.cycles).max(1) as f64;
        let noc = (lat / 100.0) * traffic.min(4.0);

        // Concurrent CTAs per SM (normalised by the Table-1 limit over the
        // SMs the window covers).
        let live_ctas = d(|s| s.ctas_retired) as f64;
        let concurrent_cta =
            (live_ctas / sm_count.max(1) as f64 / cfg.max_ctas_per_sm as f64).min(1.0);

        MetricsSample {
            features: [
                control_divergent,
                coalescing,
                l1d_miss,
                l1i_miss,
                l1c_miss,
                mshr,
                load_inst_rate,
                store_inst_rate,
                noc,
                concurrent_cta,
            ],
        }
    }

    /// f32 feature vector (what the HLO predictor consumes).
    pub fn as_f32(&self) -> [f32; NUM_FEATURES] {
        let mut out = [0f32; NUM_FEATURES];
        for (o, f) in out.iter_mut().zip(self.features) {
            *o = f as f32;
        }
        out
    }

    /// All features finite and within sane bounds?
    pub fn is_sane(&self) -> bool {
        self.features.iter().all(|f| f.is_finite() && (-1.0..=10.0).contains(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(
        warp_insns: u64,
        mem_insns: u64,
        mem_requests: u64,
        mem_transactions: u64,
        l1d: (u64, u64),
    ) -> SmStats {
        SmStats {
            cycles: 1000,
            warp_insns,
            mem_insns,
            mem_requests,
            mem_transactions,
            l1d_accesses: l1d.0,
            l1d_misses: l1d.1,
            total_lane_cycles: warp_insns * 32,
            inactive_lane_cycles: warp_insns * 4,
            noc_latency_sum: 5000,
            noc_latency_samples: 100,
            noc_packets: 100,
            ..Default::default()
        }
    }

    #[test]
    fn window_delta_features() {
        let before = SmStats::default();
        let after = stats(1000, 200, 6400, 800, (800, 200));
        let cfg = SystemConfig::gtx480();
        let s = MetricsSample::from_window(&before, &after, &cfg);
        assert!(s.is_sane(), "{s:?}");
        assert!((s.features[0] - 4.0 / 32.0).abs() < 1e-9, "control divergent");
        assert!((s.features[1] - 0.125).abs() < 1e-9, "coalescing 800/6400");
        assert!((s.features[2] - 0.25).abs() < 1e-9, "l1d miss");
        assert!(s.features[8] > 0.0, "noc feature nonzero");
    }

    #[test]
    fn delta_ignores_history() {
        // Identical before/after => all-zero features (no division blowups).
        let a = stats(1000, 200, 6400, 800, (800, 200));
        let cfg = SystemConfig::gtx480();
        let s = MetricsSample::from_window(&a, &a, &cfg);
        assert!(s.is_sane());
        assert!(s.features.iter().all(|f| *f == 0.0));
    }

    #[test]
    fn load_store_split_uses_real_store_counter() {
        // Features (7)/(8) on a synthetic window: 1000 warp insns, 200
        // memory insns of which 70 are stores => mem rate 0.2, store
        // share 0.35 => load_inst_rate 0.13, store_inst_rate 0.07.
        let before = SmStats::default();
        let mut after = stats(1000, 200, 6400, 800, (800, 200));
        after.st_insns = 70;
        let cfg = SystemConfig::gtx480();
        let s = MetricsSample::from_window(&before, &after, &cfg);
        assert!((s.features[6] - 0.13).abs() < 1e-9, "load rate {}", s.features[6]);
        assert!((s.features[7] - 0.07).abs() < 1e-9, "store rate {}", s.features[7]);
        // No stores at all => the store feature is exactly zero (the old
        // hardcoded 25% split reported phantom stores here).
        let s0 =
            MetricsSample::from_window(&before, &stats(1000, 200, 6400, 800, (800, 200)), &cfg);
        assert_eq!(s0.features[7], 0.0);
        assert!((s0.features[6] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn per_cluster_window_scales_cta_feature() {
        let before = SmStats::default();
        let mut after = stats(1000, 200, 6400, 800, (800, 200));
        after.ctas_retired = 4;
        let cfg = SystemConfig::gtx480();
        let whole = MetricsSample::from_window(&before, &after, &cfg);
        let cluster = MetricsSample::from_window_scaled(&before, &after, &cfg, 2);
        // Same counters over 2 SMs instead of 48 => 24x the density.
        assert!((cluster.features[9] - whole.features[9] * 24.0).abs() < 1e-9);
        assert!(cluster.is_sane());
    }

    #[test]
    fn feature_count_matches_contract() {
        assert_eq!(FEATURES.len(), NUM_FEATURES);
        assert_eq!(NUM_FEATURES, 10, "python model.py NUM_FEATURES contract");
    }
}
