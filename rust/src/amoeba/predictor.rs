//! The binary-logistic scalability predictor (paper §4.1.3, Table 2).
//!
//! Two interchangeable backends implement [`ScalePredictor`]:
//!
//! * [`NativePredictor`] — the logistic evaluated directly in rust. Always
//!   available; used as the default and as the parity oracle.
//! * `runtime::HloPredictor` — the AOT-compiled JAX/Pallas model executed
//!   through the PJRT CPU client (the reproduction of the paper's MAC IP
//!   block). Numerical parity with the native path is asserted by
//!   integration tests.
//!
//! The decision rule is `P(scale-up) > 0.5`, equivalently `logit > 0`.

use super::metrics::{MetricsSample, NUM_FEATURES};

/// A scalability predictor: metrics in, fuse decision out.
pub trait ScalePredictor {
    /// Probability in [0,1] that scale-up (fusing) wins for this sample.
    fn probability(&mut self, sample: &MetricsSample) -> f64;

    /// Fuse decision (P > 0.5).
    fn scale_up(&mut self, sample: &MetricsSample) -> bool {
        self.probability(sample) > 0.5
    }

    /// How many times this predictor failed and substituted a default
    /// probability instead of a measured one. Infallible backends (the
    /// native logistic) always report 0; the PJRT-backed predictor counts
    /// its fallbacks so a dead backend cannot masquerade as measured
    /// decisions.
    fn fallback_count(&self) -> u64 {
        0
    }
}

/// Trained logistic coefficients: weights (feature order of
/// [`super::metrics::FEATURES`]) plus the intercept.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Coefficients {
    /// Per-feature weights.
    pub weights: [f64; NUM_FEATURES],
    /// Intercept (bias).
    pub intercept: f64,
}

/// The paper's Table 2 coefficients, in our feature order. These were
/// fitted to the authors' GPGPU-Sim feature scaling and are shipped for
/// the Fig 20 / Table 2 reproductions; the *default decision weights* are
/// [`DEFAULT_COEFFS`], trained on this simulator's own profiling windows
/// (see `examples/train_predictor.rs`).
pub const PAPER_COEFFS: Coefficients = Coefficients {
    weights: [
        444.628,   // control divergent
        2057.050,  // coalescing
        -313.838,  // L1D miss rate
        1674.513,  // L1I miss rate
        -67.277,   // L1C miss rate
        -102.971,  // MSHR
        -680.786,  // load inst rate
        -804.7,    // store inst rate
        -8.301,    // NoC
        1.414,     // concurrent cta
    ],
    intercept: -73.635,
};

/// Default coefficients for this simulator's feature scaling, fitted by
/// `examples/train_predictor.rs`: 132 profiling-window samples from the
/// full 21-benchmark suite x 3 seeds, labelled with measured
/// baseline-vs-scale-up IPC, trained by SGD *through the compiled PJRT
/// train step* (800 epochs, lr 0.8, final BCE 0.565, training accuracy
/// 70.5% via the HLO inference path — see EXPERIMENTS.md §Table 2).
///
/// The dominant learned signal is memory pressure (load-instruction rate
/// + MSHR/coalescing structure): on this substrate the capacity-crossover
/// benchmarks are exactly the load-heavy shared-table ones, matching the
/// paper's observation that memory-locality metrics drive the fuse
/// decision, while divergence and streaming push toward scale-out.
///
/// Known staleness (retrain on the next toolchain-equipped run — see
/// ROADMAP open items): these weights were fitted on *chip-wide* windows
/// under the old fixed 75/25 load/store split. Features (7)/(8) now use
/// the measured split (small shifts for every predictor scheme), and the
/// §4.4 heterogeneous path feeds *per-cluster* windows, where the
/// concurrent-CTA feature is scaled over 2 SMs instead of the chip —
/// benign today only because its weight is 0.0.
pub const DEFAULT_COEFFS: Coefficients = Coefficients {
    weights: [
        -0.226_396_83, // control divergent
        -2.285_68,     // coalescing (actual-access rate)
        -0.349_336_8,  // L1D miss (cold-dominated in the probe window)
        -0.762_929_7,  // L1I miss
        -0.132_789_63, // L1C miss
        -1.056_968_2,  // MSHR merge rate
        6.160_763_3,   // load-instruction rate
        2.053_589_3,   // store-instruction rate
        -0.065_658_96, // NoC latency-weighted throughput
        0.0,           // concurrent CTAs (constant in probe windows)
    ],
    intercept: -0.697_3,
};

/// Coefficient set for **per-cluster** profiling windows — the §4.4
/// heterogeneous decision path (`Controller::decide_cluster`). A
/// 2-SM window differs from a chip-wide one in feature scaling: the
/// concurrent-CTA feature (9) is normalised over 2 SMs instead of the
/// chip, and a single probe CTA's counters make the rate features
/// noisier, so the set is fitted separately on per-cluster windows
/// (`examples/train_predictor.rs --native` collects them from
/// `Scheme::Hetero` probe runs and prints a paste-ready block).
///
/// Bootstrap values: numerically identical to [`DEFAULT_COEFFS`] until
/// the first toolchain-equipped retraining run replaces them (ROADMAP
/// open item) — shipping untrained *different* numbers would silently
/// change every Hetero figure, so the bootstrap is deliberately a
/// behaviour-preserving alias with its own identity and plumbing.
pub const HETERO_COEFFS: Coefficients = Coefficients {
    weights: [
        -0.226_396_83, // control divergent
        -2.285_68,     // coalescing (actual-access rate)
        -0.349_336_8,  // L1D miss (cold-dominated in the probe window)
        -0.762_929_7,  // L1I miss
        -0.132_789_63, // L1C miss
        -1.056_968_2,  // MSHR merge rate
        6.160_763_3,   // load-instruction rate
        2.053_589_3,   // store-instruction rate
        -0.065_658_96, // NoC latency-weighted throughput
        0.0,           // concurrent CTAs (2-SM scaling; weight pending fit)
    ],
    intercept: -0.697_3,
};

/// Native rust logistic predictor.
#[derive(Debug, Clone)]
pub struct NativePredictor {
    coeffs: Coefficients,
}

impl NativePredictor {
    /// Predictor with the repo-trained default coefficients.
    pub fn new() -> Self {
        NativePredictor { coeffs: DEFAULT_COEFFS }
    }

    /// Predictor with explicit coefficients (tests, training loops).
    pub fn with_coeffs(coeffs: Coefficients) -> Self {
        NativePredictor { coeffs }
    }

    /// Predictor with the per-cluster-window coefficient set
    /// ([`HETERO_COEFFS`]) used by the §4.4 heterogeneous decision path.
    pub fn hetero() -> Self {
        NativePredictor { coeffs: HETERO_COEFFS }
    }

    /// Raw logit (log-odds, paper eq. 1).
    pub fn logit(&self, sample: &MetricsSample) -> f64 {
        let mut z = self.coeffs.intercept;
        for (w, x) in self.coeffs.weights.iter().zip(sample.features) {
            z += w * x;
        }
        z
    }

    /// Per-feature impact magnitudes (coefficient x measured value) — the
    /// Fig 20 decomposition.
    pub fn impacts(&self, sample: &MetricsSample) -> [f64; NUM_FEATURES] {
        let mut out = [0.0; NUM_FEATURES];
        for (o, (w, x)) in out.iter_mut().zip(self.coeffs.weights.iter().zip(sample.features)) {
            *o = w * x;
        }
        out
    }

    /// The active coefficient set.
    pub fn coeffs(&self) -> &Coefficients {
        &self.coeffs
    }
}

impl Default for NativePredictor {
    fn default() -> Self {
        Self::new()
    }
}

impl ScalePredictor for NativePredictor {
    fn probability(&mut self, sample: &MetricsSample) -> f64 {
        sigmoid(self.logit(sample))
    }
}

/// Numerically-stable logistic function.
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(features: [f64; NUM_FEATURES]) -> MetricsSample {
        MetricsSample { features }
    }

    #[test]
    fn sigmoid_properties() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(50.0) > 0.999999);
        assert!(sigmoid(-50.0) < 1e-6);
        assert!((sigmoid(2.0) + sigmoid(-2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn decision_sign_equivalence() {
        let mut p = NativePredictor::new();
        for i in 0..NUM_FEATURES {
            let mut f = [0.1; NUM_FEATURES];
            f[i] = 0.9;
            let s = sample(f);
            assert_eq!(p.scale_up(&s), p.logit(&s) > 0.0);
        }
    }

    #[test]
    fn shared_table_signature_fuses() {
        // The SM/MUM signature the trained model keys on: load-heavy,
        // well-coalesced table walking with L1 pressure.
        let mut f = [0.0; NUM_FEATURES];
        f[6] = 0.32; // load instruction rate
        f[7] = 0.10; // store rate
        f[1] = 0.10; // well coalesced (low actual-access rate)
        f[2] = 0.45; // l1d miss
        f[5] = 0.40; // mshr merges
        let mut p = NativePredictor::new();
        assert!(p.scale_up(&sample(f)), "logit={}", p.logit(&sample(f)));
    }

    #[test]
    fn compute_divergent_signature_scales_out() {
        // CP/WP-like: light memory traffic, divergence, streaming.
        let mut f = [0.0; NUM_FEATURES];
        f[0] = 0.30; // control divergence
        f[1] = 0.50; // poor coalescing (high actual-access rate)
        f[6] = 0.08; // few loads
        f[2] = 0.25;
        let mut p = NativePredictor::new();
        assert!(!p.scale_up(&sample(f)), "logit={}", p.logit(&sample(f)));
    }

    #[test]
    fn impacts_decompose_logit() {
        let s = sample([0.3; NUM_FEATURES]);
        let p = NativePredictor::new();
        let total: f64 = p.impacts(&s).iter().sum::<f64>() + p.coeffs().intercept;
        assert!((total - p.logit(&s)).abs() < 1e-12);
    }

    #[test]
    fn paper_coefficients_are_table2() {
        assert_eq!(PAPER_COEFFS.intercept, -73.635);
        assert_eq!(PAPER_COEFFS.weights[1], 2057.050);
        assert_eq!(PAPER_COEFFS.weights[9], 1.414);
    }
}
