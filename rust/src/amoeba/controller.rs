//! The online reconfiguration controller (paper §4.1, Fig 7).
//!
//! Per kernel launch: sample the scalability metrics over a short
//! profiling window (CTAs track their kernel's scaling behaviour, §4.1.1),
//! run the logistic predictor, and reconfigure the SM fabric accordingly.
//! The GPU cycle loop in [`crate::sim::gpu`] drives the phases; this type
//! owns the predictor and records decisions.

use crate::config::SystemConfig;

use super::metrics::MetricsSample;
use super::predictor::{NativePredictor, ScalePredictor};

/// One per-kernel decision record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelDecision {
    /// Predictor probability of scale-up winning.
    pub probability: f64,
    /// The decision taken (P > 0.5).
    pub scale_up: bool,
}

/// The reconfiguration controller: predictor + decision log.
pub struct Controller {
    predictor: Box<dyn ScalePredictor>,
    /// Decision history (one entry per kernel).
    pub history: Vec<KernelDecision>,
    /// Force a fixed decision (ablations / ScaleUp scheme plumbing).
    pub force: Option<bool>,
}

impl Controller {
    /// Controller backed by the native rust logistic predictor.
    pub fn native(_cfg: &SystemConfig) -> Self {
        Controller { predictor: Box::new(NativePredictor::new()), history: Vec::new(), force: None }
    }

    /// Controller backed by an arbitrary predictor (e.g. the PJRT HLO
    /// predictor from [`crate::runtime`]).
    pub fn with_predictor(predictor: Box<dyn ScalePredictor>) -> Self {
        Controller { predictor, history: Vec::new(), force: None }
    }

    /// Controller that always answers `fuse` (ablation baseline).
    pub fn forced(fuse: bool) -> Self {
        Controller {
            predictor: Box::new(NativePredictor::new()),
            history: Vec::new(),
            force: Some(fuse),
        }
    }

    /// Decide whether the current kernel should run on fused SMs.
    pub fn decide(&mut self, sample: &MetricsSample) -> KernelDecision {
        let d = match self.force {
            Some(f) => KernelDecision { probability: if f { 1.0 } else { 0.0 }, scale_up: f },
            None => {
                let p = self.predictor.probability(sample);
                KernelDecision { probability: p, scale_up: p > 0.5 }
            }
        };
        self.history.push(d);
        d
    }
}

impl std::fmt::Debug for Controller {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Controller")
            .field("history", &self.history)
            .field("force", &self.force)
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amoeba::metrics::NUM_FEATURES;

    #[test]
    fn decisions_are_logged() {
        let cfg = SystemConfig::tiny();
        let mut c = Controller::native(&cfg);
        let s = MetricsSample { features: [0.0; NUM_FEATURES] };
        let d = c.decide(&s);
        assert_eq!(c.history.len(), 1);
        assert_eq!(c.history[0], d);
        assert_eq!(d.scale_up, d.probability > 0.5);
    }

    #[test]
    fn forced_controller_ignores_metrics() {
        let mut c = Controller::forced(true);
        let mut f = [0.0; NUM_FEATURES];
        f[0] = 1.0; // heavy divergence would normally say "scale out"
        assert!(c.decide(&MetricsSample { features: f }).scale_up);
        let mut c = Controller::forced(false);
        let mut f = [0.0; NUM_FEATURES];
        f[2] = 1.0;
        assert!(!c.decide(&MetricsSample { features: f }).scale_up);
    }
}
