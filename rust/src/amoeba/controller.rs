//! The online reconfiguration controller (paper §4.1, Fig 7).
//!
//! Per kernel launch: sample the scalability metrics over a short
//! profiling window (CTAs track their kernel's scaling behaviour, §4.1.1),
//! run the logistic predictor, and reconfigure the SM fabric accordingly.
//! The GPU cycle loop in [`crate::sim::gpu`] drives the phases; this type
//! owns the predictor and records decisions.

use crate::config::SystemConfig;

use super::metrics::MetricsSample;
use super::predictor::{NativePredictor, ScalePredictor};

/// One decision record: chip-global (`cluster == None`, one per kernel)
/// or per-cluster (§4.4 heterogeneous path, one per cluster per kernel).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelDecision {
    /// Predictor probability of scale-up winning.
    pub probability: f64,
    /// The decision taken (P > 0.5).
    pub scale_up: bool,
    /// Cluster the decision applies to (None = every cluster).
    pub cluster: Option<u32>,
}

/// The reconfiguration controller: predictor + decision log.
pub struct Controller {
    predictor: Box<dyn ScalePredictor>,
    /// Predictor for per-cluster profiling windows (§4.4). A 2-SM window
    /// has different feature scaling than a chip-wide one, so the
    /// heterogeneous path gets its own coefficient set
    /// ([`crate::amoeba::predictor::HETERO_COEFFS`]). `None` routes
    /// per-cluster decisions through the main predictor (custom backends
    /// supply one model for all windows).
    cluster_predictor: Option<Box<dyn ScalePredictor>>,
    /// Decision history (one entry per `decide`/`decide_cluster` call).
    pub history: Vec<KernelDecision>,
    /// Force a fixed decision (ablations / ScaleUp scheme plumbing).
    pub force: Option<bool>,
}

impl Controller {
    /// Controller backed by the native rust logistic predictor (chip-wide
    /// coefficients for chip-global decisions, the per-cluster-window set
    /// for `decide_cluster`).
    pub fn native(_cfg: &SystemConfig) -> Self {
        Controller {
            predictor: Box::new(NativePredictor::new()),
            cluster_predictor: Some(Box::new(NativePredictor::hetero())),
            history: Vec::new(),
            force: None,
        }
    }

    /// Controller backed by an arbitrary predictor (e.g. the PJRT HLO
    /// predictor from [`crate::runtime`]); it serves both chip-global and
    /// per-cluster decisions.
    pub fn with_predictor(predictor: Box<dyn ScalePredictor>) -> Self {
        Controller { predictor, cluster_predictor: None, history: Vec::new(), force: None }
    }

    /// Controller that always answers `fuse` (ablation baseline).
    pub fn forced(fuse: bool) -> Self {
        Controller {
            predictor: Box::new(NativePredictor::new()),
            cluster_predictor: None,
            history: Vec::new(),
            force: Some(fuse),
        }
    }

    /// Decide whether the current kernel should run on fused SMs
    /// (chip-global: the decision applies to every cluster).
    pub fn decide(&mut self, sample: &MetricsSample) -> KernelDecision {
        self.record(sample, None)
    }

    /// Decide for one cluster from that cluster's own profiling window —
    /// the §4.4 heterogeneous path runs this once per cluster per kernel.
    pub fn decide_cluster(&mut self, cluster: usize, sample: &MetricsSample) -> KernelDecision {
        self.record(sample, Some(cluster as u32))
    }

    fn record(&mut self, sample: &MetricsSample, cluster: Option<u32>) -> KernelDecision {
        let d = match self.force {
            Some(f) => {
                KernelDecision { probability: if f { 1.0 } else { 0.0 }, scale_up: f, cluster }
            }
            None => {
                let predictor = match (&mut self.cluster_predictor, cluster) {
                    (Some(cp), Some(_)) => cp,
                    _ => &mut self.predictor,
                };
                let p = predictor.probability(sample);
                KernelDecision { probability: p, scale_up: p > 0.5, cluster }
            }
        };
        self.history.push(d);
        d
    }

    /// Fallback substitutions made by the underlying predictor backends
    /// (see [`ScalePredictor::fallback_count`]); 0 for the native path.
    pub fn fallback_count(&self) -> u64 {
        self.predictor.fallback_count()
            + self.cluster_predictor.as_ref().map_or(0, |p| p.fallback_count())
    }
}

impl std::fmt::Debug for Controller {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Controller")
            .field("history", &self.history)
            .field("force", &self.force)
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amoeba::metrics::NUM_FEATURES;

    #[test]
    fn decisions_are_logged() {
        let cfg = SystemConfig::tiny();
        let mut c = Controller::native(&cfg);
        let s = MetricsSample { features: [0.0; NUM_FEATURES] };
        let d = c.decide(&s);
        assert_eq!(c.history.len(), 1);
        assert_eq!(c.history[0], d);
        assert_eq!(d.scale_up, d.probability > 0.5);
        assert_eq!(d.cluster, None, "chip-global decisions carry no cluster");
        assert_eq!(c.fallback_count(), 0, "native predictor never falls back");
    }

    #[test]
    fn per_cluster_decisions_carry_cluster_ids() {
        let cfg = SystemConfig::tiny();
        let mut c = Controller::native(&cfg);
        let s = MetricsSample { features: [0.0; NUM_FEATURES] };
        for ci in 0..3 {
            let d = c.decide_cluster(ci, &s);
            assert_eq!(d.cluster, Some(ci as u32));
        }
        assert_eq!(c.history.len(), 3);
        // Identical samples give identical probabilities per cluster.
        assert_eq!(c.history[0].probability, c.history[2].probability);
    }

    #[test]
    fn per_cluster_decisions_use_the_hetero_coefficient_set() {
        use crate::amoeba::predictor::{NativePredictor, HETERO_COEFFS};
        let cfg = SystemConfig::tiny();
        let mut c = Controller::native(&cfg);
        let mut f = [0.0; NUM_FEATURES];
        f[6] = 0.3; // load-heavy window
        let s = MetricsSample { features: f };
        let d = c.decide_cluster(0, &s);
        let mut reference = NativePredictor::hetero();
        assert_eq!(
            d.probability.to_bits(),
            reference.probability(&s).to_bits(),
            "per-cluster path must evaluate HETERO_COEFFS"
        );
        // The bootstrap set is numerically DEFAULT_COEFFS (behaviour-
        // preserving until the first toolchain retrain); pin that so a
        // future retrain is a conscious, test-visible change.
        assert_eq!(HETERO_COEFFS, crate::amoeba::predictor::DEFAULT_COEFFS);
    }

    #[test]
    fn forced_controller_ignores_metrics() {
        let mut c = Controller::forced(true);
        let mut f = [0.0; NUM_FEATURES];
        f[0] = 1.0; // heavy divergence would normally say "scale out"
        assert!(c.decide(&MetricsSample { features: f }).scale_up);
        let mut c = Controller::forced(false);
        let mut f = [0.0; NUM_FEATURES];
        f[2] = 1.0;
        assert!(!c.decide(&MetricsSample { features: f }).scale_up);
    }
}
