//! AMOEBA: the paper's contribution — the online reconfiguration
//! controller, scalability metrics, the logistic predictor, and the
//! dynamic split/fuse machinery that creates heterogeneous SM populations
//! at runtime.
//!
//! The SM-fusion *mechanism* itself (merged L1s, single scheduler over
//! both datapaths, shared coalescer, NoC router bypass) lives in
//! [`crate::sim::core::cluster`] since it is part of the reconfigurable
//! hardware model; this module holds the *policy* layers on top.

pub mod controller;
pub mod dynsplit;
pub mod metrics;
pub mod predictor;

pub use controller::{Controller, KernelDecision};
pub use dynsplit::DynSplit;
pub use metrics::{MetricsSample, FEATURES, NUM_FEATURES};
pub use predictor::{
    sigmoid, Coefficients, NativePredictor, ScalePredictor, DEFAULT_COEFFS, HETERO_COEFFS,
    PAPER_COEFFS,
};
