//! Execution schemes (the bars of Fig 12/13/21) and interconnect modes.

use std::fmt;
use std::str::FromStr;

/// Which machine / reconfiguration scheme a simulation runs under.
///
/// These correspond one-to-one to the configurations the paper evaluates:
/// the scale-out `Baseline`, a statically fused `ScaleUp` machine, AMOEBA's
/// predictor-driven `StaticFuse`, the two dynamic heterogeneous schemes
/// (`DirectSplit`, `WarpRegroup`), the per-cluster `Hetero` machine
/// (§4.4's independently fused/split SM populations) and the `Dws`
/// comparator of Fig 21.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Paper baseline: 48 scale-out SMs, no reconfiguration.
    Baseline,
    /// All neighboring SM pairs fused for the whole run (direct scale_up).
    ScaleUp,
    /// AMOEBA static fuse: profile + predict once per kernel, then fuse
    /// every pair (or none) for the kernel's lifetime (§4.1).
    StaticFuse,
    /// StaticFuse + dynamic splitting with the *direct split* policy (§4.3):
    /// a divergent fused warp is cut in the middle into two halves.
    DirectSplit,
    /// StaticFuse + dynamic splitting with the *warp regrouping* policy:
    /// thread groups are sorted into a fast warp and a slow warp.
    WarpRegroup,
    /// Per-cluster heterogeneous reconfiguration (§4.4): every SM pair is
    /// profiled and decided *independently*, so one kernel can run on a
    /// mixed population of fused and private clusters. Fused clusters
    /// additionally run the warp-regrouping dynamic split.
    Hetero,
    /// Dynamic Warp Subdivision (Meng et al.) — intra-SM baseline of Fig 21.
    Dws,
}

impl Scheme {
    /// All schemes in the order the paper's figures plot them.
    pub const ALL: [Scheme; 7] = [
        Scheme::Baseline,
        Scheme::ScaleUp,
        Scheme::StaticFuse,
        Scheme::DirectSplit,
        Scheme::WarpRegroup,
        Scheme::Hetero,
        Scheme::Dws,
    ];

    /// The four AMOEBA-vs-baseline bars of Fig 12.
    pub const FIG12: [Scheme; 5] = [
        Scheme::Baseline,
        Scheme::ScaleUp,
        Scheme::StaticFuse,
        Scheme::DirectSplit,
        Scheme::WarpRegroup,
    ];

    /// Does this scheme ever fuse SM pairs?
    pub fn can_fuse(&self) -> bool {
        !matches!(self, Scheme::Baseline | Scheme::Dws)
    }

    /// Does this scheme dynamically split fused SMs?
    pub fn splits(&self) -> Option<SplitPolicy> {
        match self {
            Scheme::DirectSplit => Some(SplitPolicy::Direct),
            Scheme::WarpRegroup | Scheme::Hetero => Some(SplitPolicy::Regroup),
            _ => None,
        }
    }

    /// Does the scheme consult the scalability predictor per kernel?
    pub fn uses_predictor(&self) -> bool {
        matches!(
            self,
            Scheme::StaticFuse | Scheme::DirectSplit | Scheme::WarpRegroup | Scheme::Hetero
        )
    }

    /// Does the scheme profile and decide each cluster independently
    /// (heterogeneous SM populations, §4.4)? Chip-global schemes take one
    /// aggregate decision per kernel instead.
    pub fn per_cluster(&self) -> bool {
        matches!(self, Scheme::Hetero)
    }

    /// Can the scheme keep serving on the healthy half of a cluster whose
    /// other half-SM has faulted? Every scheme that can run a cluster in
    /// split (private-pair) mode can route around a dead half; the rigid
    /// `ScaleUp` machine is permanently fused and loses the whole cluster
    /// — the asymmetry AMOEBA's graceful-degradation figure plots.
    pub fn tolerates_half_fault(&self) -> bool {
        !matches!(self, Scheme::ScaleUp)
    }
}

impl fmt::Display for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Scheme::Baseline => "baseline",
            Scheme::ScaleUp => "scale_up",
            Scheme::StaticFuse => "static_fuse",
            Scheme::DirectSplit => "direct_split",
            Scheme::WarpRegroup => "warp_regrouping",
            Scheme::Hetero => "hetero",
            Scheme::Dws => "dws",
        };
        f.write_str(s)
    }
}

impl FromStr for Scheme {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "baseline" | "scale_out" => Ok(Scheme::Baseline),
            "scale_up" | "scaleup" => Ok(Scheme::ScaleUp),
            "static_fuse" | "staticfuse" | "fuse" => Ok(Scheme::StaticFuse),
            "direct_split" | "directsplit" => Ok(Scheme::DirectSplit),
            "warp_regrouping" | "warp_regroup" | "regroup" => Ok(Scheme::WarpRegroup),
            "hetero" | "heterogeneous" => Ok(Scheme::Hetero),
            "dws" => Ok(Scheme::Dws),
            other => Err(format!("unknown scheme '{other}'")),
        }
    }
}

/// How a fused SM distributes warps when it dynamically splits (§4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SplitPolicy {
    /// Cut the divergent 64-wide warp in the middle; both halves move to
    /// the second SM. Cheap, but fast and slow threads may stay mixed.
    Direct,
    /// Sort `regroup_granularity`-sized thread groups by divergence into a
    /// fast warp (stays) and a slow warp (moves). The paper's best scheme.
    Regroup,
}

/// Interconnect model selector (Fig 3a vs Fig 3b).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NocMode {
    /// Cycle-modelled 2D mesh with 2-stage routers and bounded queues.
    Mesh,
    /// Ideal interconnect: zero latency, infinite bandwidth.
    Perfect,
}

impl fmt::Display for NocMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            NocMode::Mesh => "mesh",
            NocMode::Perfect => "perfect",
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_roundtrip() {
        for s in Scheme::ALL {
            assert_eq!(s.to_string().parse::<Scheme>().unwrap(), s);
        }
    }

    #[test]
    fn scheme_properties() {
        assert!(!Scheme::Baseline.can_fuse());
        assert!(!Scheme::Dws.can_fuse());
        assert!(Scheme::ScaleUp.can_fuse());
        assert!(!Scheme::ScaleUp.uses_predictor());
        assert!(Scheme::StaticFuse.uses_predictor());
        assert_eq!(Scheme::DirectSplit.splits(), Some(SplitPolicy::Direct));
        assert_eq!(Scheme::WarpRegroup.splits(), Some(SplitPolicy::Regroup));
        assert_eq!(Scheme::StaticFuse.splits(), None);
        assert!(Scheme::Hetero.can_fuse());
        assert!(Scheme::Hetero.uses_predictor());
        assert_eq!(Scheme::Hetero.splits(), Some(SplitPolicy::Regroup));
        assert!(Scheme::Hetero.per_cluster());
        assert!(Scheme::ALL.iter().filter(|s| s.per_cluster()).count() == 1);
        // Only the permanently fused machine is rigid under a half-SM fault.
        assert!(!Scheme::ScaleUp.tolerates_half_fault());
        assert!(Scheme::ALL.iter().filter(|s| !s.tolerates_half_fault()).count() == 1);
    }

    #[test]
    fn unknown_scheme_rejected() {
        assert!("bogus".parse::<Scheme>().is_err());
    }
}
