//! System configuration (paper Table 1) and experiment knobs.
//!
//! The defaults reproduce the GPGPU-Sim v3.2.2 GTX480-style setup the paper
//! simulates: 48 scale-out SMs (warp size 32, SIMD pipeline width 8), 8
//! memory controllers, a 2-stage-router 128-bit mesh NoC with separate
//! request/reply subnets, GTO warp scheduling and FR-FCFS memory scheduling.

mod scheme;

pub use scheme::{NocMode, Scheme, SplitPolicy};

/// Full system configuration. One instance describes one simulated machine.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    // ---- SM fabric ----------------------------------------------------
    /// Number of baseline (scale-out) SMs on the chip.
    pub num_sms: usize,
    /// Threads per warp in a baseline SM (paper: 32; fused SMs run 64).
    pub warp_size: usize,
    /// SIMD pipeline width (lanes issued per cycle; paper: 8).
    pub simd_width: usize,
    /// Maximum resident threads per SM (paper: 1024).
    pub max_threads_per_sm: usize,
    /// Maximum resident CTAs per SM (paper: 8).
    pub max_ctas_per_sm: usize,
    /// Registers per SM (paper: 16384).
    pub registers_per_sm: usize,
    /// Shared memory per SM in bytes (paper: 48 KB).
    pub shared_mem_bytes: usize,
    /// Warp schedulers per SM (GTO policy).
    pub schedulers_per_sm: usize,

    // ---- Caches -------------------------------------------------------
    /// L1 data cache size per SM in bytes (paper: 16 KB).
    pub l1d_bytes: usize,
    /// L1 instruction cache size per SM in bytes.
    pub l1i_bytes: usize,
    /// L1 constant cache size per SM in bytes (paper: 8 KB).
    pub l1c_bytes: usize,
    /// L1 texture cache size per SM in bytes (paper: 8 KB).
    pub l1t_bytes: usize,
    /// Cache line size in bytes (all levels).
    pub line_bytes: usize,
    /// L1 associativity (baseline; fusion doubles it).
    pub l1_assoc: usize,
    /// L1 hit latency in cycles.
    pub l1_hit_latency: u32,
    /// Extra L1 hit latency when two SMs' L1s are fused (paper: +1).
    pub fused_l1_extra_latency: u32,
    /// MSHR entries per SM (paper: 64).
    pub mshr_per_sm: usize,
    /// L2 cache size per memory controller slice (paper: 128 KB/core-slice).
    pub l2_slice_bytes: usize,
    /// L2 associativity.
    pub l2_assoc: usize,
    /// L2 hit latency in cycles (includes slice pipeline).
    pub l2_hit_latency: u32,

    // ---- Memory system -------------------------------------------------
    /// Number of memory controllers (paper: 8).
    pub num_mcs: usize,
    /// DRAM banks per memory controller.
    pub dram_banks_per_mc: usize,
    /// Row-hit access latency (GPU cycles).
    pub dram_row_hit_latency: u32,
    /// Row-miss (activate+precharge) access latency (GPU cycles).
    pub dram_row_miss_latency: u32,
    /// DRAM row size in bytes (for FR-FCFS row-hit detection).
    pub dram_row_bytes: usize,
    /// Memory-controller request queue depth.
    pub mc_queue_depth: usize,

    // ---- NoC ------------------------------------------------------------
    /// Channel width in bits (paper: 128).
    pub noc_channel_bits: usize,
    /// Router pipeline stages (paper: 2).
    pub noc_router_stages: u32,
    /// Per-port input queue depth in flits.
    pub noc_queue_depth: usize,
    /// Injection queue depth (SM/MC -> network; Fig 17's stall source).
    pub noc_inject_depth: usize,
    /// Mesh vs. ideal interconnect (Fig 3a vs 3b).
    pub noc_mode: NocMode,

    // ---- Pipeline latencies ----------------------------------------------
    /// Integer ALU latency in cycles.
    pub ialu_latency: u32,
    /// FP ALU latency in cycles.
    pub falu_latency: u32,
    /// SFU (transcendental) latency in cycles.
    pub sfu_latency: u32,
    /// Shared-memory access latency in cycles.
    pub smem_latency: u32,

    // ---- AMOEBA ----------------------------------------------------------
    /// Cycles of the online profiling window at kernel start (§4.1.1).
    pub profile_window: u64,
    /// Pipeline-drain + reconfiguration cost in cycles when fusing/unfusing.
    pub reconfig_cost: u64,
    /// Divergent-warp ratio threshold that triggers a dynamic split (§4.3).
    pub split_threshold: f32,
    /// Cycles between divergence-ratio evaluations on a fused SM.
    pub split_check_period: u64,
    /// Thread-group granularity for warp regrouping (threads per group).
    pub regroup_granularity: usize,
    /// Periodic fast-warp rebalance interval for split SMs (cycles).
    pub rebalance_period: u64,
    /// Minimum cycles between *policy-driven* reconfigurations (0 = no
    /// cooldown, the historical behaviour). Fault-forced splits bypass
    /// the cooldown — routing around a dead half-SM cannot wait.
    pub reconfig_cooldown: u64,
    /// Cycles a cluster stolen by CTA-boundary preemption stays frozen
    /// before the claimant may dispatch onto it (checkpoint/requeue of
    /// the victim's CTA occupancy — no mid-warp state is saved).
    pub preempt_cost: u64,

    // ---- Simulation -------------------------------------------------------
    /// Hard cycle limit per kernel (safety net; 0 = unlimited).
    pub max_cycles: u64,
}

impl SystemConfig {
    /// Paper Table 1: the GTX480-style 48-SM baseline.
    pub fn gtx480() -> Self {
        SystemConfig {
            num_sms: 48,
            warp_size: 32,
            simd_width: 8,
            max_threads_per_sm: 1024,
            max_ctas_per_sm: 8,
            registers_per_sm: 16384,
            shared_mem_bytes: 48 << 10,
            schedulers_per_sm: 1,

            l1d_bytes: 16 << 10,
            l1i_bytes: 4 << 10,
            l1c_bytes: 8 << 10,
            l1t_bytes: 8 << 10,
            line_bytes: 128,
            l1_assoc: 4,
            l1_hit_latency: 1,
            fused_l1_extra_latency: 1,
            mshr_per_sm: 64,
            l2_slice_bytes: 128 << 10,
            l2_assoc: 8,
            l2_hit_latency: 8,

            num_mcs: 8,
            dram_banks_per_mc: 8,
            dram_row_hit_latency: 40,
            dram_row_miss_latency: 110,
            dram_row_bytes: 2048,
            mc_queue_depth: 32,

            noc_channel_bits: 128,
            noc_router_stages: 2,
            noc_queue_depth: 8,
            noc_inject_depth: 8,
            noc_mode: NocMode::Mesh,

            ialu_latency: 4,
            falu_latency: 4,
            sfu_latency: 16,
            smem_latency: 3,

            profile_window: 2_000,
            reconfig_cost: 500,
            split_threshold: 0.25,
            split_check_period: 512,
            regroup_granularity: 4,
            rebalance_period: 2_048,
            reconfig_cooldown: 0,
            preempt_cost: 200,

            max_cycles: 3_000_000,
        }
    }

    /// A small configuration for fast unit tests (4 SMs, 2 MCs).
    pub fn tiny() -> Self {
        let mut c = Self::gtx480();
        c.num_sms = 4;
        c.num_mcs = 2;
        c.max_cycles = 400_000;
        c
    }

    /// Resource-fixed rescale used by the Fig 3/4 scaling sweeps: keep the
    /// total number of lanes, registers, L1 capacity and thread slots on the
    /// chip constant while varying the SM count (`n`). This mirrors the
    /// paper's "fit the total amount of chip resources but vary the size and
    /// the number of SMs" methodology.
    pub fn with_sm_count(&self, n: usize) -> Self {
        assert!(n > 0, "need at least one SM");
        let total_lanes = self.num_sms * self.simd_width;
        let total_threads = self.num_sms * self.max_threads_per_sm;
        let total_regs = self.num_sms * self.registers_per_sm;
        let total_l1d = self.num_sms * self.l1d_bytes;
        let total_smem = self.num_sms * self.shared_mem_bytes;
        let mut c = self.clone();
        c.num_sms = n;
        // SIMD width: largest power of two not exceeding the fair lane
        // share (power-of-two keeps warp_size % simd_width == 0; lane
        // totals are preserved up to that rounding, like the paper's
        // 16/25/36/64 grid which cannot split resources exactly either).
        let fair_lanes = (total_lanes / n).max(1);
        c.simd_width = if fair_lanes.is_power_of_two() {
            fair_lanes
        } else {
            (fair_lanes.next_power_of_two() / 2).max(1)
        };
        // Warp size tracks SM width at the baseline 4:1 ratio (what fusion
        // does too: 8 lanes/32-wide -> 16 lanes/64-wide).
        c.warp_size = (c.simd_width * (self.warp_size / self.simd_width)).clamp(8, 64);
        c.max_threads_per_sm = (total_threads / n).max(c.warp_size);
        c.registers_per_sm = (total_regs / n).max(1024);
        c.l1d_bytes = (total_l1d / n).max(self.line_bytes * self.l1_assoc);
        c.shared_mem_bytes = (total_smem / n).max(1 << 10);
        c
    }

    /// Number of scale-up SMs when every neighboring pair is fused.
    pub fn fused_sm_count(&self) -> usize {
        self.num_sms / 2
    }

    /// Flits needed for a payload of `bytes` on this NoC. The 128-bit
    /// channel is double-pumped (router clock = 2x core clock, as in
    /// GPGPU-Sim's GTX480 interconnect config), so one core-cycle flit
    /// carries 32 bytes.
    pub fn flits_for(&self, bytes: usize) -> usize {
        let flit_bytes = self.noc_channel_bits / 8 * 2;
        bytes.div_ceil(flit_bytes).max(1)
    }

    /// Validate internal consistency; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if !self.warp_size.is_power_of_two() {
            return Err(format!("warp_size {} must be a power of two", self.warp_size));
        }
        if self.warp_size > 64 {
            return Err("warp_size > 64 unsupported (mask is u64)".into());
        }
        if self.simd_width == 0 || self.warp_size % self.simd_width != 0 {
            return Err(format!(
                "simd_width {} must divide warp_size {}",
                self.simd_width, self.warp_size
            ));
        }
        if self.num_sms == 0 || self.num_mcs == 0 {
            return Err("need at least one SM and one MC".into());
        }
        if self.line_bytes == 0 || !self.line_bytes.is_power_of_two() {
            return Err("line_bytes must be a power of two".into());
        }
        if self.l1d_bytes < self.line_bytes * self.l1_assoc {
            return Err("L1D smaller than one set".into());
        }
        if !(0.0..=1.0).contains(&self.split_threshold) {
            return Err("split_threshold must be in [0,1]".into());
        }
        Ok(())
    }
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self::gtx480()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gtx480_matches_table1() {
        let c = SystemConfig::gtx480();
        assert_eq!(c.num_sms, 48);
        assert_eq!(c.num_mcs, 8);
        assert_eq!(c.warp_size, 32);
        assert_eq!(c.simd_width, 8);
        assert_eq!(c.max_threads_per_sm, 1024);
        assert_eq!(c.max_ctas_per_sm, 8);
        assert_eq!(c.registers_per_sm, 16384);
        assert_eq!(c.mshr_per_sm, 64);
        assert_eq!(c.l1d_bytes, 16 << 10);
        assert_eq!(c.l2_slice_bytes, 128 << 10);
        assert_eq!(c.shared_mem_bytes, 48 << 10);
        assert_eq!(c.noc_channel_bits, 128);
        assert_eq!(c.noc_router_stages, 2);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn rescale_preserves_total_resources() {
        let base = SystemConfig::gtx480();
        let base_lanes = base.num_sms * base.simd_width;
        for n in [16usize, 24, 36, 48, 64] {
            let c = base.with_sm_count(n);
            // Lanes preserved up to power-of-two rounding of the SIMD
            // width (exact when n divides the total).
            let lanes = c.num_sms * c.simd_width;
            assert!(
                lanes <= base_lanes && lanes * 2 > base_lanes,
                "lanes at n={n}: {lanes} vs {base_lanes}"
            );
            // L1 capacity preserved up to integer division (< 1 line/SM).
            let l1_total = c.num_sms * c.l1d_bytes;
            let base_l1 = base.num_sms * base.l1d_bytes;
            assert!(
                base_l1 - l1_total < n * base.line_bytes,
                "l1 at n={n}: {l1_total} vs {base_l1}"
            );
            assert!(c.validate().is_ok(), "valid at n={n}: {:?}", c.validate());
        }
        // Exact-divisor case is exactly preserved.
        let c = base.with_sm_count(24);
        assert_eq!(c.num_sms * c.simd_width, base_lanes);
    }

    #[test]
    fn rescale_adjusts_warp_size() {
        let base = SystemConfig::gtx480();
        let up = base.with_sm_count(24); // scale-up: half the SMs
        assert_eq!(up.warp_size, 64);
        assert_eq!(up.simd_width, 16);
        let same = base.with_sm_count(48);
        assert_eq!(same.warp_size, 32);
        assert_eq!(same.simd_width, 8);
    }

    #[test]
    fn flit_math() {
        let c = SystemConfig::gtx480();
        assert_eq!(c.flits_for(8), 1); // 32-byte flits (double-pumped)
        assert_eq!(c.flits_for(32), 1);
        assert_eq!(c.flits_for(33), 2);
        assert_eq!(c.flits_for(128 + 16), 5); // data reply: line + header
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = SystemConfig::gtx480();
        c.warp_size = 48;
        assert!(c.validate().is_err());
        let mut c = SystemConfig::gtx480();
        c.simd_width = 7;
        assert!(c.validate().is_err());
        let mut c = SystemConfig::gtx480();
        c.split_threshold = 1.5;
        assert!(c.validate().is_err());
    }
}
