//! `amoeba` CLI — simulate benchmarks under any scheme, sweep the suite,
//! or inspect the machine configuration.
//!
//! Argument parsing is hand-rolled and errors are plain strings (the
//! offline build has no CLI or error crates); see `usage()` for the
//! grammar. Sweeps fan out across cores through the
//! [`amoeba_gpu::harness::SweepExec`] executor — set `AMOEBA_JOBS` to
//! control the thread count.

use std::str::FromStr;

use amoeba_gpu::config::{NocMode, Scheme, SystemConfig};
use amoeba_gpu::errors::{err, Result};
use amoeba_gpu::harness::{SimJob, SweepExec};
use amoeba_gpu::runtime::serve;
use amoeba_gpu::sim::bisect::{bisect_benchmark, BisectOutcome, BisectSide};
use amoeba_gpu::sim::fault::{FaultEvent, FaultKind, FaultTrace};
use amoeba_gpu::sim::gpu::{run_benchmark_seeded, run_benchmark_with_controller, PartitionPolicy};
use amoeba_gpu::stats::Table;
use amoeba_gpu::workload::{
    all_benchmarks, bench, shrink_streams, traffic_trace_qos, TenantQosSpec, TrafficPattern,
};

fn usage() -> &'static str {
    "amoeba — AMOEBA reconfigurable-GPU simulator (paper reproduction)

USAGE:
  amoeba run <BENCH> [--scheme S] [--sms N] [--perfect-noc] [--seed N]
                     [--hlo-predictor]
  amoeba sweep [--quick] [--jobs N]
  amoeba serve-sim [--tenants SPEC] [--policy static|adaptive]
                   [--kernels N] [--gap CYCLES] [--seed N] [--sms N]
                   [--bursty] [--quick] [--jobs N]
  amoeba serve-fleet [--chips N] [--tenants N] [--policy static|adaptive]
                     [--kernels N] [--gap CYCLES] [--seed N] [--sms N]
                     [--tenants-per-chip N] [--cooldown CYCLES]
                     [--faults 'CHIP:SPEC[;CHIP:SPEC...]']
                     [--bursty] [--quick] [--jobs N]
  amoeba bisect <BENCH> [--scheme S] [--seed N] [--sms N] [--quick]
                [--dense-a] [--dense-b] [--faults-a SPEC] [--faults-b SPEC]
  amoeba list
  amoeba config

SCHEMES: baseline | scale_up | static_fuse | direct_split |
         warp_regrouping | hetero | dws

bisect runs the same workload twice (side A vs side B — each side an
execution mode plus an optional fault schedule) and, if the runs
disagree, binary-searches the FIRST main-loop cycle whose serialized
machine state differs, naming the differing checkpoint sections
(cluster.3, noc, mc.0, ...). Fault SPEC is comma-separated events:
clusterN@CYC kills cluster N, halfN.H@CYC kills half H of cluster N,
noc+P@CYC adds P cycles per hop, mcN.D@CYC stalls MC N for D cycles.

serve-sim replays a seeded traffic trace of interleaved tenant kernel
launches on ONE chip (spatially partitioned clusters, shared NoC and
memory) and reports per-tenant throughput, ANTT-style slowdown against
each tenant running alone, and QoS service quality (SLO attainment,
p95 queueing delay). SPEC is comma-separated
BENCH[:SCHEME[:PRIORITY[@SLO]]] entries, e.g.
'SM:hetero:high@400_000,BFS:warp_regrouping:low,CP' — scheme defaults
to hetero, priority (low|normal|high) to normal, and the SLO (a
per-launch turnaround target in cycles, underscores ignored) to none.
High-priority tenants below their fair cluster share preempt
lower-priority tenants at CTA boundaries. --bursty clumps each
tenant's arrivals into noisy-neighbour bursts.

serve-fleet serves a seeded multi-tenant trace across a POOL of chips:
tenants are admitted to the least-loaded chip (SLO-gated, honest
rejection), the active chip count scales elastically with live tenant
load, per-chip fault schedules drive a health/quarantine ledger, and
tenants stranded on a dead chip checkpoint-migrate onto a healthy
peer. --faults assigns one fault SPEC (grammar above) per chip as
semicolon-separated 'CHIP_INDEX:SPEC' entries, e.g.
'0:cluster0@10,cluster1@10;2:noc+3@5_000'. Fully deterministic: the
fleet report is bit-identical for any --jobs value.

Sweeps run in parallel; --jobs (or the AMOEBA_JOBS env var) sets the
worker count, defaulting to the machine's available parallelism."
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        println!("{}", usage());
        return Ok(());
    };
    match cmd.as_str() {
        "run" => cmd_run(&args[1..]),
        "sweep" => cmd_sweep(&args[1..]),
        "serve-sim" => cmd_serve_sim(&args[1..]),
        "serve-fleet" => cmd_serve_fleet(&args[1..]),
        "bisect" => cmd_bisect(&args[1..]),
        "list" => cmd_list(),
        "config" => {
            println!("{}", amoeba_gpu::harness::figure("t1", true).unwrap().render());
            Ok(())
        }
        "-h" | "--help" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(err(format!("unknown command '{other}'\n\n{}", usage()))),
    }
}

/// Fetch the value following a `--flag`.
fn opt_value<'a>(args: &'a [String], flag: &str) -> Result<Option<&'a str>> {
    match args.iter().position(|a| a == flag) {
        None => Ok(None),
        Some(i) => args
            .get(i + 1)
            .map(|s| Some(s.as_str()))
            .ok_or_else(|| err(format!("{flag} needs a value"))),
    }
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn cmd_run(args: &[String]) -> Result<()> {
    let name = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .ok_or_else(|| err(format!("run needs a benchmark name\n\n{}", usage())))?;
    let profile =
        bench(name).ok_or_else(|| err(format!("unknown benchmark '{name}' (try `amoeba list`)")))?;
    let scheme = match opt_value(args, "--scheme")? {
        Some(s) => Scheme::from_str(s).map_err(err)?,
        None => Scheme::WarpRegroup,
    };
    let mut cfg = SystemConfig::gtx480();
    if let Some(n) = opt_value(args, "--sms")? {
        cfg = cfg.with_sm_count(n.parse()?);
    }
    if has_flag(args, "--perfect-noc") {
        cfg.noc_mode = NocMode::Perfect;
    }
    let seed: u64 = match opt_value(args, "--seed")? {
        Some(s) => s.parse()?,
        None => 0xAB0EBA,
    };

    let report = if has_flag(args, "--hlo-predictor") {
        let rt = amoeba_gpu::runtime::Runtime::new()?;
        let coeffs = amoeba_gpu::amoeba::DEFAULT_COEFFS;
        let mut w = [0f32; amoeba_gpu::amoeba::NUM_FEATURES];
        for (o, c) in w.iter_mut().zip(coeffs.weights) {
            *o = c as f32;
        }
        let predictor = amoeba_gpu::runtime::HloPredictor::new(&rt, w, coeffs.intercept as f32)?;
        let controller = amoeba_gpu::amoeba::Controller::with_predictor(Box::new(predictor));
        run_benchmark_with_controller(&cfg, &profile, scheme, controller, seed)?
    } else {
        run_benchmark_seeded(&cfg, &profile, scheme, seed)?
    };

    println!("benchmark       : {}", report.bench);
    println!("scheme          : {}", report.scheme);
    println!("cycles          : {}", report.cycles);
    println!("thread insns    : {}", report.sm.thread_insns);
    println!("IPC             : {:.3}", report.ipc());
    println!("L1D miss rate   : {:.4}", report.sm.l1d_miss_rate());
    println!("L1I miss rate   : {:.4}", report.sm.l1i_miss_rate());
    println!("actual mem rate : {:.4}", report.sm.actual_access_rate());
    println!("MSHR merge rate : {:.4}", report.sm.mshr_rate());
    println!("control stalls  : {:.4}", report.sm.control_stall_rate());
    println!("inactive threads: {:.4}", report.sm.inactive_thread_rate());
    println!("avg NoC latency : {:.1}", report.sm.avg_noc_latency());
    println!("MC inject stall : {:.4}", report.chip.mc_inject_stall_rate());
    println!("L2 miss rate    : {:.4}", report.chip.l2_miss_rate());
    println!("DRAM row hits   : {:.4}", report.chip.dram_row_hit_rate());
    println!("fuse/split evts : {}/{}", report.sm.fuse_events, report.sm.split_events);
    for (i, d) in report.decisions.iter().enumerate() {
        let scope = match d.cluster {
            Some(c) => format!("cluster {c}"),
            None => "all clusters".to_string(),
        };
        println!(
            "decision {i} ({scope}): P(scale-up)={:.3} -> {}",
            d.probability,
            if d.scale_up { "FUSE" } else { "scale-out" }
        );
    }
    if report.chip.predictor_fallbacks > 0 {
        eprintln!(
            "WARNING: {} predictor inference(s) fell back to the default \
             probability — the backend was dead for those decisions",
            report.chip.predictor_fallbacks
        );
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let quick = has_flag(args, "--quick");
    let exec = match opt_value(args, "--jobs")? {
        Some(n) => SweepExec::new(n.parse()?),
        None => SweepExec::from_env(),
    };
    let mut cfg = SystemConfig::gtx480();
    if quick {
        cfg.num_sms = 8;
        cfg.num_mcs = 4;
    }

    // Fan the whole (bench x scheme) grid out across the executor at once
    // instead of simulating cell by cell.
    let mut jobs = Vec::new();
    let mut profiles = Vec::new();
    for mut p in all_benchmarks() {
        if quick {
            p.num_ctas = p.num_ctas.min(12);
            p.insns_per_thread = p.insns_per_thread.min(100);
            p.num_kernels = 1;
        }
        for s in Scheme::ALL {
            jobs.push(SimJob::new(cfg.clone(), p.clone(), s, 0xAB0EBA));
        }
        profiles.push(p);
    }
    eprintln!(
        "[sweep] {} simulations on {} threads...",
        jobs.len(),
        exec.threads()
    );
    let reports = exec.run_batch(jobs);

    let mut t = Table::new(
        "IPC by scheme",
        &[
            "bench",
            "baseline",
            "scale_up",
            "static_fuse",
            "direct_split",
            "warp_regrouping",
            "hetero",
            "dws",
        ],
    );
    for (bi, p) in profiles.iter().enumerate() {
        let row: Vec<f64> = (0..Scheme::ALL.len())
            .map(|si| reports[bi * Scheme::ALL.len() + si].ipc())
            .collect();
        t.row(p.name, row);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_serve_sim(args: &[String]) -> Result<()> {
    let quick = has_flag(args, "--quick");
    let policy: PartitionPolicy = match opt_value(args, "--policy")? {
        Some(s) => s.parse().map_err(err)?,
        None => PartitionPolicy::Static,
    };
    let seed: u64 = match opt_value(args, "--seed")? {
        Some(s) => s.parse()?,
        None => 0xA30EBA,
    };
    let kernels_each: u32 = match opt_value(args, "--kernels")? {
        Some(s) => s.parse()?,
        None => {
            if quick {
                2
            } else {
                4
            }
        }
    };
    let mean_gap: u64 = match opt_value(args, "--gap")? {
        Some(s) => s.parse()?,
        None => {
            if quick {
                20_000
            } else {
                100_000
            }
        }
    };
    let tenants: Vec<TenantQosSpec> = match opt_value(args, "--tenants")? {
        Some(spec) => serve::parse_tenant_spec_qos(spec).map_err(err)?,
        None => serve::default_tenants()
            .into_iter()
            .map(|(p, s)| TenantQosSpec::best_effort(p, s))
            .collect(),
    };
    let pattern = if has_flag(args, "--bursty") {
        TrafficPattern::Bursty { burst_len: 4, dilation: 8 }
    } else {
        TrafficPattern::Uniform
    };
    let exec = match opt_value(args, "--jobs")? {
        Some(n) => SweepExec::new(n.parse()?),
        None => SweepExec::from_env(),
    };
    let mut cfg = SystemConfig::gtx480();
    if quick {
        cfg.num_sms = 8;
        cfg.num_mcs = 4;
        cfg.profile_window = 1_000;
    }
    if let Some(n) = opt_value(args, "--sms")? {
        cfg = cfg.with_sm_count(n.parse()?);
    }
    let n_clusters = cfg.num_sms / 2;
    if tenants.len() > n_clusters {
        return Err(err(format!(
            "{} tenants need at least {} SMs (one cluster each); this config has {} SMs \
             ({n_clusters} clusters) — drop tenants or raise --sms",
            tenants.len(),
            tenants.len() * 2,
            cfg.num_sms
        )));
    }

    let mut streams = traffic_trace_qos(&tenants, kernels_each, mean_gap, seed, pattern);
    if quick {
        shrink_streams(&mut streams, 8, 80);
    }
    eprintln!(
        "[serve-sim] {} tenants x {} kernels, policy {policy}, {} threads...",
        streams.len(),
        kernels_each,
        exec.threads()
    );

    // The shared run plus each tenant alone (the interference-free
    // reference), batched through the stream memo.
    let out = exec.run_stream_batch(serve::server_jobs(&cfg, &streams, &[policy]));
    let shared = &out[0];

    let mut t = Table::new(
        format!("serve-sim — {policy} partition, seed {seed:#x}"),
        &["tenant", "kernels", "finish_kcyc", "tput_ipc", "antt", "slowdown"],
    );
    for (ti, s) in streams.iter().enumerate() {
        let alone = &out[1 + ti];
        t.row(
            s.name.as_str(),
            vec![
                shared.tenants[ti].chip.kernels_completed as f64,
                shared.tenants[ti].cycles as f64 / 1000.0,
                shared.tenant_throughput(ti),
                serve::antt_slowdown(shared, alone, ti),
                serve::stream_slowdown(shared, alone, ti),
            ],
        );
    }
    println!("{}", t.render());
    println!(
        "chip: {} cycles, {} kernels, {} reconfigurations, {} preemptions \
         ({} CTAs requeued), L2 miss {:.4}",
        shared.cycles,
        shared.chip.kernels_completed,
        shared.chip.reconfig_events,
        shared.chip.preemptions,
        shared.chip.ctas_preempted,
        shared.chip.l2_miss_rate()
    );
    for q in serve::qos_summary(shared, &streams) {
        let slo = match q.slo_turnaround {
            Some(c) => format!("{c} cyc"),
            None => "best-effort".to_string(),
        };
        println!(
            "qos tenant {} ({}, {}): SLO {} -> attainment {:.2} ({}/{} served), \
             queue delay mean {:.0} / p95 {} cyc, slowdown {:.2}x",
            q.tenant,
            streams[q.tenant].name,
            q.priority,
            slo,
            q.slo_attainment(),
            q.slo_met,
            q.served,
            q.mean_queue_delay,
            q.p95_queue_delay,
            q.mean_slowdown_milli as f64 / 1000.0
        );
    }
    for (ti, rep) in shared.tenants.iter().enumerate() {
        let scale_ups = rep.decisions.iter().filter(|d| d.scale_up).count();
        println!(
            "tenant {ti} ({}): {} decisions ({} scale-up), {} reconfigs, partition {:?}",
            rep.bench,
            rep.decisions.len(),
            scale_ups,
            rep.chip.reconfig_events,
            shared.partitions[ti]
        );
    }
    Ok(())
}

fn cmd_serve_fleet(args: &[String]) -> Result<()> {
    use amoeba_gpu::runtime::fleet::{serve_fleet, FleetConfig, RejectReason};
    let quick = has_flag(args, "--quick");
    let n_chips: usize = match opt_value(args, "--chips")? {
        Some(s) => s.parse()?,
        None => 2,
    };
    if n_chips == 0 {
        return Err(err("--chips must be >= 1"));
    }
    let n_tenants: usize = match opt_value(args, "--tenants")? {
        Some(s) => s.parse()?,
        None => 4,
    };
    let policy: PartitionPolicy = match opt_value(args, "--policy")? {
        Some(s) => s.parse().map_err(err)?,
        None => PartitionPolicy::Static,
    };
    let seed: u64 = match opt_value(args, "--seed")? {
        Some(s) => s.parse()?,
        None => 0xA30EBA,
    };
    let kernels_each: u32 = match opt_value(args, "--kernels")? {
        Some(s) => s.parse()?,
        None => 2,
    };
    let mean_gap: u64 = match opt_value(args, "--gap")? {
        Some(s) => s.parse()?,
        None => {
            if quick {
                5_000
            } else {
                50_000
            }
        }
    };
    let tenants_per_chip: usize = match opt_value(args, "--tenants-per-chip")? {
        Some(s) => s.parse()?,
        None => 2,
    };
    let cooldown: u64 = match opt_value(args, "--cooldown")? {
        Some(s) => s.trim().replace('_', "").parse()?,
        None => 0,
    };
    let pattern = if has_flag(args, "--bursty") {
        TrafficPattern::Bursty { burst_len: 4, dilation: 8 }
    } else {
        TrafficPattern::Uniform
    };
    let exec = match opt_value(args, "--jobs")? {
        Some(n) => SweepExec::new(n.parse()?),
        None => SweepExec::from_env(),
    };
    let mut cfg = SystemConfig::gtx480();
    if quick {
        cfg.num_sms = 8;
        cfg.num_mcs = 4;
        cfg.max_cycles = 2_000_000;
        cfg.profile_window = 1_000;
    }
    if let Some(n) = opt_value(args, "--sms")? {
        cfg = cfg.with_sm_count(n.parse()?);
    }

    // Per-chip fault schedules: 'CHIP_INDEX:SPEC' entries, ';'-separated
    // (the SPEC grammar itself is parse_fault_spec's, colon-free).
    let mut faults = vec![FaultTrace::default(); n_chips];
    if let Some(spec) = opt_value(args, "--faults")? {
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (chip_s, fault_s) = entry
                .split_once(':')
                .ok_or_else(|| err(format!("fleet fault '{entry}' needs 'CHIP_INDEX:SPEC'")))?;
            let chip: usize = chip_s
                .trim()
                .parse()
                .map_err(|e| err(format!("bad chip index '{chip_s}': {e}")))?;
            if chip >= n_chips {
                return Err(err(format!("fault chip index {chip} >= pool size {n_chips}")));
            }
            faults[chip] = parse_fault_spec(fault_s)?;
        }
    }

    let specs: Vec<TenantQosSpec> = {
        let mix = serve::default_tenants();
        (0..n_tenants)
            .map(|i| {
                let (p, s) = mix[i % mix.len()].clone();
                TenantQosSpec::best_effort(p, s)
            })
            .collect()
    };
    let mut streams = traffic_trace_qos(&specs, kernels_each, mean_gap, seed, pattern);
    if quick {
        shrink_streams(&mut streams, 4, 40);
    }

    let mut fc = FleetConfig::pool(cfg, n_chips);
    fc.policy = policy;
    fc.tenants_per_chip = tenants_per_chip;
    fc.scale_cooldown = cooldown;

    eprintln!(
        "[serve-fleet] {} tenants across a {}-chip pool, policy {policy}, {} threads...",
        streams.len(),
        n_chips,
        exec.threads()
    );
    let rep = serve_fleet(&exec, &fc, &streams, &faults)?;

    let mut t = Table::new(
        format!("serve-fleet — {n_chips}-chip pool, {policy} partitions, seed {seed:#x}"),
        &["chip", "tenants", "migr_in", "failures", "ipc", "cycles_kcyc"],
    );
    for c in &rep.chips {
        let cycles = c.report.as_ref().map_or(0, |r| r.cycles);
        t.row(
            format!("chip{} ({}{})", c.chip, c.health, if c.quarantined { ", quarantined" } else { "" }),
            vec![
                c.tenants.len() as f64,
                c.migrated_in.len() as f64,
                c.failures as f64,
                c.ipc,
                cycles as f64 / 1000.0,
            ],
        );
    }
    println!("{}", t.render());
    println!(
        "fleet: {} served, {} dropped, {} rejected tenants ({} launches), {} migrations, \
         ANTT {:.2}, queue delay mean {:.0} / p95 {} cyc, makespan {} cyc",
        rep.served,
        rep.dropped,
        rep.rejections,
        rep.rejected_launches,
        rep.migrations,
        rep.antt,
        rep.mean_queue_delay,
        rep.p95_queue_delay,
        rep.makespan
    );
    for e in &rep.scaling {
        println!("scale @{}: {} -> {} chips ({} live tenants)", e.cycle, e.from, e.to, e.live);
    }
    for ft in &rep.tenants {
        let outcome = match (ft.rejected, ft.chip) {
            (Some(RejectReason::Capacity), _) => "REJECTED (capacity)".to_string(),
            (Some(RejectReason::Slo), _) => "REJECTED (slo)".to_string(),
            (None, Some(c)) => match ft.migrated_to {
                Some(d) => format!("chip {c} -> migrated to chip {d}"),
                None => format!("chip {c}"),
            },
            (None, None) => "unplaced".to_string(),
        };
        println!(
            "tenant {} ({}): {} — {} served, {} dropped",
            ft.tenant, streams[ft.tenant].name, outcome, ft.served, ft.dropped
        );
    }
    Ok(())
}

/// Parse a fault-schedule spec: comma-separated events, each
/// `clusterN@CYC`, `halfN.H@CYC`, `noc+P@CYC`, or `mcN.D@CYC`.
fn parse_fault_spec(spec: &str) -> Result<FaultTrace> {
    let mut events = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (kind_s, cyc_s) = entry
            .split_once('@')
            .ok_or_else(|| err(format!("fault '{entry}' needs '@CYCLE'")))?;
        let cycle: u64 = cyc_s
            .trim()
            .replace('_', "")
            .parse()
            .map_err(|e| err(format!("bad fault cycle '{cyc_s}': {e}")))?;
        let kind_s = kind_s.trim();
        let kind = if let Some(rest) = kind_s.strip_prefix("cluster") {
            FaultKind::Cluster { cluster: rest.parse().map_err(|e| err(format!("bad cluster id in '{entry}': {e}")))? }
        } else if let Some(rest) = kind_s.strip_prefix("half") {
            let (c, h) = rest
                .split_once('.')
                .ok_or_else(|| err(format!("half fault '{entry}' needs 'halfN.H'")))?;
            FaultKind::HalfSm {
                cluster: c.parse().map_err(|e| err(format!("bad cluster id in '{entry}': {e}")))?,
                half: h.parse().map_err(|e| err(format!("bad half in '{entry}': {e}")))?,
            }
        } else if let Some(rest) = kind_s.strip_prefix("noc+") {
            FaultKind::NocDegrade { penalty: rest.parse().map_err(|e| err(format!("bad NoC penalty in '{entry}': {e}")))? }
        } else if let Some(rest) = kind_s.strip_prefix("mc") {
            let (m, d) = rest
                .split_once('.')
                .ok_or_else(|| err(format!("MC fault '{entry}' needs 'mcN.D'")))?;
            FaultKind::McStall {
                mc: m.parse().map_err(|e| err(format!("bad MC id in '{entry}': {e}")))?,
                cycles: d.parse().map_err(|e| err(format!("bad stall length in '{entry}': {e}")))?,
            }
        } else {
            return Err(err(format!(
                "unknown fault kind in '{entry}' (want clusterN / halfN.H / noc+P / mcN.D)"
            )));
        };
        events.push(FaultEvent { cycle, kind });
    }
    Ok(FaultTrace::new(events))
}

fn cmd_bisect(args: &[String]) -> Result<()> {
    let name = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .ok_or_else(|| err(format!("bisect needs a benchmark name\n\n{}", usage())))?;
    let mut profile =
        bench(name).ok_or_else(|| err(format!("unknown benchmark '{name}' (try `amoeba list`)")))?;
    let scheme = match opt_value(args, "--scheme")? {
        Some(s) => Scheme::from_str(s).map_err(err)?,
        None => Scheme::Baseline,
    };
    let seed: u64 = match opt_value(args, "--seed")? {
        Some(s) => s.parse()?,
        None => 0xAB0EBA,
    };
    let mut cfg = SystemConfig::gtx480();
    if has_flag(args, "--quick") {
        cfg.num_sms = 8;
        cfg.num_mcs = 4;
        profile.num_ctas = profile.num_ctas.min(12);
        profile.insns_per_thread = profile.insns_per_thread.min(100);
        profile.num_kernels = 1;
    }
    if let Some(n) = opt_value(args, "--sms")? {
        cfg = cfg.with_sm_count(n.parse()?);
    }
    let side = |dense_flag: &str, faults_flag: &str| -> Result<BisectSide> {
        Ok(BisectSide {
            dense: has_flag(args, dense_flag),
            faults: match opt_value(args, faults_flag)? {
                Some(spec) => Some(parse_fault_spec(spec)?),
                None => None,
            },
        })
    };
    let a = side("--dense-a", "--faults-a")?;
    let b = side("--dense-b", "--faults-b")?;
    eprintln!(
        "[bisect] {} under {scheme}: A({}, {} faults) vs B({}, {} faults)...",
        profile.name,
        if a.dense { "dense" } else { "skip" },
        a.faults.as_ref().map_or(0, |f| f.events.len()),
        if b.dense { "dense" } else { "skip" },
        b.faults.as_ref().map_or(0, |f| f.events.len()),
    );
    match bisect_benchmark(&cfg, &profile, scheme, seed, &a, &b)? {
        BisectOutcome::Identical => println!("identical: the two runs agree byte-for-byte"),
        BisectOutcome::Diverged { cycle, sections } => {
            println!("diverged at cycle {cycle}");
            println!("differing sections: {}", sections.join(", "));
        }
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    for b in all_benchmarks() {
        println!(
            "{:6} [{}] ctas={} insns/thread={} expected={}",
            b.name,
            b.suite,
            b.num_ctas,
            b.insns_per_thread,
            if b.scale_up_expected { "scale-up" } else { "scale-out" }
        );
    }
    Ok(())
}
