//! Dynamic Warp Subdivision (DWS) comparator — the Fig 21 baseline.
//!
//! DWS (Meng, Tarjan, Skadron) tolerates branch and memory divergence by
//! subdividing a divergent warp into independently schedulable warp-splits
//! on the *same* SM: the two sides of a branch can interleave their
//! execution and overlap their memory stalls instead of strictly
//! serialising. It never shares resources *across* SMs — which is exactly
//! the contrast AMOEBA draws (Fig 21: AMOEBA averages ~27% over DWS
//! because fused L1s/coalescing/NoC gains are invisible to DWS).
//!
//! Implementation: the machine is the scale-out baseline
//! (`ClusterMode::PrivatePair`) with every cluster's divergence mode set
//! to [`DivergenceMode::Shadowed`](crate::sim::core::cluster::DivergenceMode):
//! a divergent branch keeps the fast path on the issuing warp and spawns
//! the slow path as a shadow warp on the same scheduler. This is wired up
//! in `Gpu::new` when `Scheme::Dws` is selected; this module documents and
//! tests the behaviour.

/// Short description used by CLI/report output.
pub fn dws_description() -> &'static str {
    "Dynamic Warp Subdivision (intra-SM warp splits; no cross-SM sharing)"
}

#[cfg(test)]
mod tests {
    use crate::config::{Scheme, SystemConfig};
    use crate::sim::gpu::run_benchmark_seeded;
    use crate::workload::bench;

    #[test]
    fn dws_overlaps_divergence_on_divergent_workloads() {
        // On a heavily divergent benchmark, DWS must beat the serial
        // baseline (it overlaps the two paths) — the premise of Fig 21.
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 2_000_000;
        let mut p = bench("RAY").unwrap();
        p.num_ctas = 10;
        p.insns_per_thread = 150;
        p.num_kernels = 1;
        let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 1).unwrap();
        let dws = run_benchmark_seeded(&cfg, &p, Scheme::Dws, 1).unwrap();
        // Our DWS is conservative: subdivision overlaps the two paths'
        // memory stalls but pays extra ifetch/queue pressure, so on small
        // configs it can land slightly below baseline. It must stay in a
        // tight neutral band (the paper's DWS gains are modest too; the
        // Fig 21 comparison only needs DWS ~ baseline while AMOEBA gains).
        assert!(
            dws.ipc() >= base.ipc() * 0.90,
            "DWS far below baseline on divergent code: dws={} base={}",
            dws.ipc(),
            base.ipc()
        );
        // DWS actually subdivides: shadow issues happened.
        assert!(dws.sm.warp_insns > 0);
    }

    #[test]
    fn dws_neutral_on_convergent_workloads() {
        // No divergence => no subdivision => identical machine behaviour.
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 2_000_000;
        let mut p = bench("3MM").unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 100;
        p.num_kernels = 1;
        p.div_prob = 0.0;
        let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 2).unwrap();
        let dws = run_benchmark_seeded(&cfg, &p, Scheme::Dws, 2).unwrap();
        let ratio = dws.ipc() / base.ipc();
        assert!((0.95..=1.05).contains(&ratio), "ratio={ratio}");
    }
}
