//! Comparator schemes the paper evaluates against.
//!
//! * **Scale-out baseline** and **direct scale-up** are machine layouts:
//!   `Scheme::Baseline` / `Scheme::ScaleUp` (see [`crate::config`]).
//! * **DWS** — Dynamic Warp Subdivision (Meng et al., Fig 21) — is the
//!   intra-SM divergence-tolerance baseline, implemented here.

pub mod dws;

pub use dws::dws_description;
