//! `figures` — regenerate every table and figure of the paper's
//! evaluation section (DESIGN.md §3 maps ids to experiments).
//!
//! All simulation-backed figures run through one shared
//! [`amoeba_gpu::harness::SweepExec`]: jobs fan out across cores and every
//! unique `(bench, scheme, config, seed)` simulation runs exactly once per
//! invocation, no matter how many figures consume it.
//!
//! Usage:
//!   figures --fig 12            # one figure (full workloads)
//!   figures --all --quick       # everything, shrunken workloads
//!   figures --fig 12 --tsv      # machine-readable output
//!   figures --all --jobs 8      # explicit worker count (else AMOEBA_JOBS)

use amoeba_gpu::errors::{err, Result};
use amoeba_gpu::harness::{figure_with, SweepExec, ALL_FIGURES};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let tsv = args.iter().any(|a| a == "--tsv");
    let all = args.iter().any(|a| a == "--all");
    let fig = args
        .iter()
        .position(|a| a == "--fig")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let exec = match args.iter().position(|a| a == "--jobs") {
        Some(i) => {
            let n = args.get(i + 1).ok_or_else(|| err("--jobs needs a value"))?;
            SweepExec::new(n.parse()?)
        }
        None => SweepExec::from_env(),
    };

    let ids: Vec<String> = if all {
        ALL_FIGURES.iter().map(|s| s.to_string()).collect()
    } else if let Some(f) = fig {
        vec![f]
    } else {
        return Err(err(format!(
            "usage: figures --fig <id> [--quick] [--tsv] [--jobs N] | figures --all [--quick]\nids: {}",
            ALL_FIGURES.join(", ")
        )));
    };
    for id in ids {
        eprintln!("[figures] generating {id}...");
        let t = figure_with(&exec, &id, quick).ok_or_else(|| {
            err(format!("unknown figure id '{id}' (ids: {})", ALL_FIGURES.join(", ")))
        })?;
        if tsv {
            println!("# {id}");
            print!("{}", t.to_tsv());
        } else {
            println!("{}", t.render());
        }
    }
    let (hits, misses) = exec.cache_stats();
    eprintln!(
        "[figures] done: {misses} unique simulations on {} threads, {hits} served from cache",
        exec.threads()
    );
    Ok(())
}
