//! `figures` — regenerate every table and figure of the paper's
//! evaluation section (DESIGN.md §3 maps ids to experiments).
//!
//! Usage:
//!   figures --fig 12            # one figure (full workloads)
//!   figures --all --quick       # everything, shrunken workloads
//!   figures --fig 12 --tsv      # machine-readable output

use anyhow::{anyhow, Result};

use amoeba_gpu::harness::{figure, ALL_FIGURES};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let tsv = args.iter().any(|a| a == "--tsv");
    let all = args.iter().any(|a| a == "--all");
    let fig = args
        .iter()
        .position(|a| a == "--fig")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let ids: Vec<String> = if all {
        ALL_FIGURES.iter().map(|s| s.to_string()).collect()
    } else if let Some(f) = fig {
        vec![f]
    } else {
        return Err(anyhow!(
            "usage: figures --fig <id> [--quick] [--tsv] | figures --all [--quick]\nids: {}",
            ALL_FIGURES.join(", ")
        ));
    };
    for id in ids {
        eprintln!("[figures] generating {id}...");
        let t = figure(&id, quick)
            .ok_or_else(|| anyhow!("unknown figure id '{id}' (ids: {})", ALL_FIGURES.join(", ")))?;
        if tsv {
            println!("# {id}");
            print!("{}", t.to_tsv());
        } else {
            println!("{}", t.render());
        }
    }
    Ok(())
}
