//! PJRT runtime: loads the AOT-compiled JAX/Pallas predictor artifacts and
//! executes them from the rust request path. The [`serve`] submodule is
//! the server-simulation front-end (tenant specs, service traces, ANTT
//! math) shared by `amoeba serve-sim` and the harness's server sweep;
//! [`fleet`] scales it out to a health-monitored pool of chips with
//! admission control, elastic scaling, and chip-to-chip migration
//! (`amoeba serve-fleet`, `figures --fig fleet`).
//!
//! Interchange format is HLO **text** (`artifacts/*.hlo.txt`), produced by
//! `python/compile/aot.py`. Text is used instead of a serialized
//! `HloModuleProto` because jax >= 0.5 emits 64-bit instruction ids that
//! the crate's bundled XLA rejects; the text parser reassigns ids and
//! round-trips cleanly.
//!
//! Python never runs here: the artifacts are built once by
//! `make artifacts` and the rust binary is self-contained afterwards.
//!
//! ## The `xla` feature
//!
//! The PJRT backend needs the vendored `xla` crate, which the offline
//! default build does not ship. The real implementation is gated behind
//! `--features xla`; without it this module compiles a **stub** with the
//! same public surface whose `load` always fails with a descriptive
//! error. Probing consumers (the parity tests, `bench_predictor`) treat
//! the failed load as "skip"; consumers that *require* the backend
//! (`amoeba run --hlo-predictor`, `examples/train_predictor.rs`) exit
//! with that error and point at the `xla` feature. Either way the
//! default build compiles and the simulator itself always runs on the
//! native predictor.

pub mod fleet;
pub mod serve;

use std::fmt;
use std::path::PathBuf;

use crate::amoeba::metrics::{MetricsSample, NUM_FEATURES};
use crate::amoeba::predictor::ScalePredictor;

/// Default artifact directory relative to the repo root.
pub const ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$AMOEBA_ARTIFACTS`, else `artifacts/`
/// relative to the working directory, else relative to the crate root.
pub fn artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("AMOEBA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from(ARTIFACT_DIR);
    if cwd.is_dir() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(ARTIFACT_DIR)
}

/// Runtime-layer error (dep-free; the crate builds without `anyhow`).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Runtime result alias.
pub type Result<T> = std::result::Result<T, RuntimeError>;

fn eyre(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

// ---------------------------------------------------------------------
// Real backend (requires the vendored `xla` crate)
// ---------------------------------------------------------------------

#[cfg(feature = "xla")]
mod backend {
    use std::path::{Path, PathBuf};

    use super::{artifact_dir, eyre, Result};

    /// A compiled HLO executable on the PJRT CPU client.
    pub struct HloExecutable {
        pub(super) exe: xla::PjRtLoadedExecutable,
        /// Artifact path (diagnostics).
        pub path: PathBuf,
    }

    /// The PJRT runtime: one CPU client, executables loaded on demand.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at the default artifact dir.
        pub fn new() -> Result<Self> {
            Self::with_dir(artifact_dir())
        }

        /// Create a CPU PJRT client rooted at `dir`.
        pub fn with_dir(dir: impl Into<PathBuf>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| eyre(format!("PJRT cpu client: {e:?}")))?;
            Ok(Runtime { client, dir: dir.into() })
        }

        /// Platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile `name` (e.g. "predictor_infer") from the
        /// artifact directory.
        pub fn load(&self, name: &str) -> Result<HloExecutable> {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            self.load_path(&path)
        }

        /// Load and compile an HLO-text file.
        pub fn load_path(&self, path: &Path) -> Result<HloExecutable> {
            if !path.exists() {
                return Err(eyre(format!(
                    "artifact {} missing — run `make artifacts` first",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| eyre("non-utf8 path"))?,
            )
            .map_err(|e| eyre(format!("parse HLO text {}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| eyre(format!("compile {}: {e:?}", path.display())))?;
            Ok(HloExecutable { exe, path: path.to_path_buf() })
        }
    }

    impl HloExecutable {
        /// Execute with literal inputs; returns the elements of the output
        /// tuple (aot.py lowers with `return_tuple=True`).
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| eyre(format!("execute {}: {e:?}", self.path.display())))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| eyre(format!("fetch result: {e:?}")))?;
            decompose_tuple(out)
        }
    }

    /// Split a (possibly 1-ary) tuple literal into its elements.
    fn decompose_tuple(mut lit: xla::Literal) -> Result<Vec<xla::Literal>> {
        match lit.decompose_tuple() {
            Ok(parts) if !parts.is_empty() => Ok(parts),
            _ => Ok(vec![lit]),
        }
    }
}

// ---------------------------------------------------------------------
// Stub backend (default offline build)
// ---------------------------------------------------------------------

#[cfg(not(feature = "xla"))]
mod backend {
    use std::path::{Path, PathBuf};

    use super::{artifact_dir, eyre, Result};

    /// Stub handle; never constructed (loading always fails without the
    /// `xla` feature).
    pub struct HloExecutable {
        /// Artifact path (diagnostics).
        pub path: PathBuf,
    }

    /// Stub runtime: construction succeeds so callers can probe for
    /// artifacts and report a precise reason for skipping, but `load`
    /// always fails.
    pub struct Runtime {
        dir: PathBuf,
    }

    impl Runtime {
        /// Stub client rooted at the default artifact dir.
        pub fn new() -> Result<Self> {
            Self::with_dir(artifact_dir())
        }

        /// Stub client rooted at `dir`.
        pub fn with_dir(dir: impl Into<PathBuf>) -> Result<Self> {
            Ok(Runtime { dir: dir.into() })
        }

        /// Platform name (diagnostics).
        pub fn platform(&self) -> String {
            "stub (xla feature disabled)".to_string()
        }

        /// Always fails: either the artifact is missing (same message as
        /// the real backend) or the backend itself is unavailable.
        pub fn load(&self, name: &str) -> Result<HloExecutable> {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            self.load_path(&path)
        }

        /// See [`Runtime::load`].
        pub fn load_path(&self, path: &Path) -> Result<HloExecutable> {
            if !path.exists() {
                return Err(eyre(format!(
                    "artifact {} missing — run `make artifacts` first",
                    path.display()
                )));
            }
            Err(eyre(format!(
                "artifact {} present, but this build has no PJRT backend \
                 (rebuild with `--features xla`)",
                path.display()
            )))
        }
    }
}

pub use backend::{HloExecutable, Runtime};

// ---------------------------------------------------------------------
// Predictor backend
// ---------------------------------------------------------------------

/// The scalability predictor executed through the compiled HLO — the
/// reproduction of the paper's MAC-IP decision block, running the same
/// numerics as the Pallas kernel (verified against `NativePredictor`).
/// Without the `xla` feature, construction fails (callers fall back to
/// the native predictor).
pub struct HloPredictor {
    #[cfg(feature = "xla")]
    exe: HloExecutable,
    weights: Vec<f32>,
    intercept: f32,
    /// Inferences that failed and fell back to the default probability.
    fallbacks: u64,
    /// First-failure warning already emitted?
    warned: bool,
}

impl HloPredictor {
    /// Load `predictor_infer.hlo.txt` with the given coefficients.
    pub fn new(rt: &Runtime, weights: [f32; NUM_FEATURES], intercept: f32) -> Result<Self> {
        #[cfg(feature = "xla")]
        {
            let exe = rt.load("predictor_infer")?;
            Ok(HloPredictor { exe, weights: weights.to_vec(), intercept, fallbacks: 0, warned: false })
        }
        #[cfg(not(feature = "xla"))]
        {
            rt.load("predictor_infer")?;
            // `load` always errs in the stub; keep the constructor total.
            Ok(HloPredictor { weights: weights.to_vec(), intercept, fallbacks: 0, warned: false })
        }
    }

    /// Inferences that failed and substituted the 0.5 default.
    pub fn fallbacks(&self) -> u64 {
        self.fallbacks
    }

    /// Run one inference; returns P(scale-up).
    #[cfg(feature = "xla")]
    pub fn infer(&self, features: &[f32; NUM_FEATURES]) -> Result<f64> {
        let x = xla::Literal::vec1(&features[..])
            .reshape(&[1, NUM_FEATURES as i64])
            .map_err(|e| eyre(format!("reshape input: {e:?}")))?;
        let w = xla::Literal::vec1(&self.weights[..]);
        let b = xla::Literal::scalar(self.intercept);
        let out = self.exe.run(&[x, w, b])?;
        let p: Vec<f32> = out[0].to_vec().map_err(|e| eyre(format!("fetch output: {e:?}")))?;
        Ok(p[0] as f64)
    }

    /// Run one inference; returns P(scale-up). Stub: always errs.
    #[cfg(not(feature = "xla"))]
    pub fn infer(&self, _features: &[f32; NUM_FEATURES]) -> Result<f64> {
        let _ = (&self.weights, self.intercept);
        Err(eyre("PJRT backend unavailable (build with `--features xla`)"))
    }
}

impl ScalePredictor for HloPredictor {
    fn probability(&mut self, sample: &MetricsSample) -> f64 {
        // A failed PJRT execution is a deployment error; fall back to 0.5
        // (P > 0.5 is false => scale-out) rather than crashing the
        // simulation loop — but count it and warn once, so a dead backend
        // cannot silently masquerade as a stream of measured decisions.
        match self.infer(&sample.as_f32()) {
            Ok(p) => p,
            Err(e) => {
                self.fallbacks += 1;
                if !self.warned {
                    self.warned = true;
                    eprintln!(
                        "[amoeba] HLO predictor failed ({e}); substituting P=0.5 \
                         (scale-out). Further fallbacks are counted in the SimReport."
                    );
                }
                0.5
            }
        }
    }

    fn fallback_count(&self) -> u64 {
        self.fallbacks
    }
}

/// A batched trainer driving `predictor_train.hlo.txt` (one SGD step per
/// call; the epoch loop lives in `examples/train_predictor.rs`).
pub struct HloTrainer {
    #[cfg(feature = "xla")]
    exe: HloExecutable,
    /// Current weights.
    pub weights: Vec<f32>,
    /// Current intercept.
    pub intercept: f32,
    /// Training batch size baked into the artifact.
    pub batch: usize,
}

impl HloTrainer {
    /// Expected batch size of the compiled train step (matches
    /// `python/compile/model.py::TRAIN_BATCH`).
    pub const TRAIN_BATCH: usize = 256;

    /// Load the train-step artifact with zero-initialised parameters.
    pub fn new(rt: &Runtime) -> Result<Self> {
        #[cfg(feature = "xla")]
        {
            let exe = rt.load("predictor_train")?;
            Ok(HloTrainer {
                exe,
                weights: vec![0.0; NUM_FEATURES],
                intercept: 0.0,
                batch: Self::TRAIN_BATCH,
            })
        }
        #[cfg(not(feature = "xla"))]
        {
            rt.load("predictor_train")?;
            Ok(HloTrainer {
                weights: vec![0.0; NUM_FEATURES],
                intercept: 0.0,
                batch: Self::TRAIN_BATCH,
            })
        }
    }

    /// One SGD step over a fixed-size batch; returns the loss.
    /// `x` is row-major `[batch][NUM_FEATURES]`, `y` in {0,1}.
    #[cfg(feature = "xla")]
    pub fn step(&mut self, x: &[f32], y: &[f32], lr: f32) -> Result<f32> {
        if x.len() != self.batch * NUM_FEATURES || y.len() != self.batch {
            return Err(eyre(format!(
                "train step needs exactly {} samples (got x={} y={})",
                self.batch,
                x.len() / NUM_FEATURES,
                y.len()
            )));
        }
        let xl = xla::Literal::vec1(x)
            .reshape(&[self.batch as i64, NUM_FEATURES as i64])
            .map_err(|e| eyre(format!("reshape batch: {e:?}")))?;
        let yl = xla::Literal::vec1(y);
        let wl = xla::Literal::vec1(&self.weights[..]);
        let bl = xla::Literal::scalar(self.intercept);
        let lrl = xla::Literal::scalar(lr);
        let out = self.exe.run(&[xl, yl, wl, bl, lrl])?;
        if out.len() != 3 {
            return Err(eyre(format!("train step returned {} outputs, want 3", out.len())));
        }
        self.weights = out[0].to_vec::<f32>().map_err(|e| eyre(format!("weights out: {e:?}")))?;
        let b: Vec<f32> = out[1].to_vec().map_err(|e| eyre(format!("bias out: {e:?}")))?;
        let loss: Vec<f32> = out[2].to_vec().map_err(|e| eyre(format!("loss out: {e:?}")))?;
        self.intercept = b[0];
        Ok(loss[0])
    }

    /// One SGD step. Stub: always errs.
    #[cfg(not(feature = "xla"))]
    pub fn step(&mut self, _x: &[f32], _y: &[f32], _lr: f32) -> Result<f32> {
        Err(eyre("PJRT backend unavailable (build with `--features xla`)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let rt = Runtime::new().ok()?;
        if rt.load("predictor_infer").is_ok() {
            Some(rt)
        } else {
            None
        }
    }

    #[test]
    fn hlo_infer_matches_native_sigmoid() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let weights = [0.5f32; NUM_FEATURES];
        let p = HloPredictor::new(&rt, weights, -1.0).unwrap();
        let features = [0.2f32; NUM_FEATURES];
        let got = p.infer(&features).unwrap();
        // logit = 10 * 0.5 * 0.2 - 1.0 = 0.0 => P = 0.5.
        assert!((got - 0.5).abs() < 1e-6, "got {got}");
    }

    #[test]
    fn hlo_train_reduces_loss() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut t = HloTrainer::new(&rt).unwrap();
        // Learnable rule: label = (feature0 > 0.5).
        let n = t.batch;
        let mut x = vec![0f32; n * NUM_FEATURES];
        let mut y = vec![0f32; n];
        for i in 0..n {
            let v = (i % 100) as f32 / 100.0;
            x[i * NUM_FEATURES] = v;
            y[i] = (v > 0.5) as u8 as f32;
        }
        let first = t.step(&x, &y, 1.0).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = t.step(&x, &y, 1.0).unwrap();
        }
        assert!(last < first * 0.6, "loss {first} -> {last}");
        assert!(t.weights[0] > 0.0, "learned positive weight on feature0");
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let rt = Runtime::with_dir("/nonexistent-dir-for-test").unwrap();
        let err = match rt.load("predictor_infer") {
            Err(e) => e,
            Ok(_) => panic!("load from a nonexistent dir must fail"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_backend_reports_itself() {
        let rt = Runtime::new().unwrap();
        assert!(rt.platform().contains("stub"));
        let sample = MetricsSample { features: [0.2; NUM_FEATURES] };
        // An un-loadable predictor cannot exist; but the fallback path of
        // `probability` is exercised through a hand-built instance.
        let mut p = HloPredictor {
            weights: vec![0.5; NUM_FEATURES],
            intercept: -1.0,
            fallbacks: 0,
            warned: false,
        };
        assert_eq!(p.probability(&sample), 0.5, "stub falls back to 0.5");
        assert_eq!(p.fallback_count(), 1, "fallback must be counted");
        assert!(p.warned, "first fallback warns");
        p.probability(&sample);
        assert_eq!(p.fallback_count(), 2, "every fallback is counted");
    }
}
