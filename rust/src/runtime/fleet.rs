//! Fleet-scale serving: a health-monitored pool of simulated GPUs with
//! SLO admission control, elastic scaling, and chip-to-chip live
//! migration.
//!
//! [`serve_fleet`] shards a seeded multi-tenant trace across a pool of
//! [`SystemConfig`] chips (heterogeneous shapes allowed). The scheduler
//! is trace-driven and fully deterministic:
//!
//! * **Admission / placement** — tenants are processed in arrival order
//!   (first-launch cycle, tenant index as the tie-break) and routed to
//!   the least-loaded active chip with a free cluster. A tenant whose
//!   turnaround SLO cannot be met at the destination's current load is
//!   **rejected** with an honest [`RejectReason`] — never a fake
//!   completion. The admission test is the fair-share projection
//!   `alone_worst_turnaround * (residents + 1) <= slo`, where the
//!   isolated reference run comes from the same memoized executor the
//!   ANTT math uses.
//! * **Elastic scaling** — the active chip count is a prefix of the pool
//!   that grows/shrinks one step per arrival event as the live tenant
//!   count (tenants whose arrival window covers the decision cycle)
//!   crosses `tenants_per_chip` thresholds, gated by a cooldown so the
//!   fleet cannot thrash. Every action lands in the [`ScaleEvent`]
//!   ledger.
//! * **Per-chip health** — each chip serves its shard under its own
//!   [`FaultTrace`]. A chip whose clusters all retire, or whose run
//!   deadline-hits with launches stranded, is **dead**; a chip that took
//!   faults (or truncated) but kept serving is **degraded**. Failed
//!   chips enter a quarantine/backoff ledger with the
//!   [`FailoverConfig`] knobs of [`serve_with_failover`]
//!   (`crate::runtime::serve::serve_with_failover`).
//! * **Chip-to-chip migration** — tenants stranded on a dead/degraded
//!   chip are checkpoint-migrated onto a shape-identical healthy peer:
//!   the tenant's stream is replayed alone on the *source* chip's
//!   config with a checkpoint armed at the first fault cycle (the
//!   capture is pre-injection, i.e. healthy state), pending faults are
//!   stripped, and the run restores onto the *destination* chip to
//!   completion. Launches the migrated run did not finish are honestly
//!   dropped, as are stranded launches with no eligible peer.
//!
//! Chip shards are served through the caller's [`SweepExec`] as one
//! batch, so they fan across worker threads; the executor's memo
//! contract makes the fleet report bit-identical for any thread count,
//! and the underlying skip==dense contract of `serve_streams` makes it
//! invariant under `AMOEBA_DENSE` (both enforced in
//! `tests/exec_determinism.rs`).

use crate::config::SystemConfig;
use crate::errors::{err, Result};
use crate::harness::{cfg_fingerprint, p95_u64, StreamJob, SweepExec};
use crate::sim::fault::FaultTrace;
use crate::sim::gpu::{
    dense_env, serve_streams_resume, serve_streams_snapshot, PartitionPolicy, StreamReport,
};
use crate::workload::KernelStream;

use super::serve::{alone_streams, antt_slowdown, backoff_delay, FailoverConfig};

/// Knobs of the fleet scheduler (see [`serve_fleet`]).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// The chip pool in activation order (index = chip id). Heterogeneous
    /// shapes are allowed; checkpoint migration needs a shape-identical
    /// peer (same config fingerprint).
    pub chips: Vec<SystemConfig>,
    /// Cluster-partition policy every chip serves its shard under.
    pub policy: PartitionPolicy,
    /// Chips active before the first arrival (clamped to `[1, pool]`).
    pub initial_active: usize,
    /// Scaling threshold: the scheduler grows the active prefix when the
    /// live tenant count exceeds `tenants_per_chip * active`, and shrinks
    /// it when the count falls below the next-lower step and the top chip
    /// is idle.
    pub tenants_per_chip: usize,
    /// Minimum cycles between scaling actions (thrash guard).
    pub scale_cooldown: u64,
    /// Quarantine/backoff knobs for the per-chip health ledger: a chip
    /// with `quarantine_after` failed serve rounds is quarantined (it is
    /// never a migration destination) and its retry backoff is computed
    /// by [`backoff_delay`].
    pub failover: FailoverConfig,
}

impl FleetConfig {
    /// A homogeneous pool of `n` copies of `chip`, with the defaults the
    /// fleet tests and CLI use: static partitions, one chip active,
    /// two tenants per chip before scaling, no cooldown, and a one-strike
    /// chip quarantine (a chip that stranded launches once is not a
    /// migration destination).
    pub fn pool(chip: SystemConfig, n: usize) -> Self {
        FleetConfig {
            chips: vec![chip; n],
            policy: PartitionPolicy::Static,
            initial_active: 1,
            tenants_per_chip: 2,
            scale_cooldown: 0,
            failover: FailoverConfig { quarantine_after: 1, ..FailoverConfig::default() },
        }
    }
}

/// Why a tenant was refused admission (honest accounting: a rejected
/// tenant is never placed and none of its launches are served).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// No active chip had a free cluster at the tenant's arrival.
    Capacity,
    /// The fair-share projection said the tenant's turnaround SLO cannot
    /// be met at the destination chip's current load (or even alone).
    Slo,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RejectReason::Capacity => "capacity",
            RejectReason::Slo => "slo",
        })
    }
}

/// Per-tenant outcome ledger. Exactly one of `chip`/`rejected` is set;
/// `served + dropped` equals the tenant's launch count when admitted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetTenant {
    /// Tenant (stream) index in the fleet trace.
    pub tenant: usize,
    /// Chip the tenant was admitted to (`None` = rejected).
    pub chip: Option<usize>,
    /// Set when admission refused the tenant.
    pub rejected: Option<RejectReason>,
    /// Destination chip of the checkpoint migration, if stranded
    /// launches were rescued onto a peer.
    pub migrated_to: Option<usize>,
    /// Launches that completed (in place or on the migration peer).
    pub served: u32,
    /// Launches never completed (stranded with no rescue).
    pub dropped: u32,
}

/// Health verdict for one chip after its serve round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChipHealth {
    /// Served its shard cleanly (or sat idle).
    Healthy,
    /// Faults fired (or the deadline hit) but the chip kept serving.
    Degraded,
    /// Every cluster retired, or the run deadline-truncated with
    /// launches stranded: candidates for migration off this chip.
    Dead,
}

impl std::fmt::Display for ChipHealth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ChipHealth::Healthy => "healthy",
            ChipHealth::Degraded => "degraded",
            ChipHealth::Dead => "dead",
        })
    }
}

/// Per-chip serve record and health/quarantine ledger.
#[derive(Debug, Clone, PartialEq)]
pub struct ChipReport {
    /// Chip id (index into [`FleetConfig::chips`]).
    pub chip: usize,
    /// Was the chip ever inside the active prefix?
    pub activated: bool,
    /// Tenants placed here (fleet indices, placement order — the chip's
    /// local tenant `i` is `tenants[i]`).
    pub tenants: Vec<usize>,
    /// Health verdict from the serve round.
    pub health: ChipHealth,
    /// Serve rounds that stranded launches (0 or 1 per fleet run; the
    /// ledger shape matches [`super::serve::TenantHealth`]).
    pub failures: u32,
    /// `failures >= failover.quarantine_after`: the chip takes no
    /// migrated-in tenants.
    pub quarantined: bool,
    /// Backoff (cycles) before this chip would be retried, per
    /// [`backoff_delay`]; 0 for clean chips.
    pub backoff: u64,
    /// Tenants checkpoint-migrated in from failed peers.
    pub migrated_in: Vec<usize>,
    /// The shard's serve run (`None` if the chip served no tenants).
    pub report: Option<StreamReport>,
    /// Shard IPC (thread instructions per cycle; 0 when idle) — the
    /// per-chip utilisation figure the fleet sweep reports.
    pub ipc: f64,
}

/// One elastic-scaling action.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleEvent {
    /// Arrival cycle that triggered the action.
    pub cycle: u64,
    /// Active chip count before the action.
    pub from: usize,
    /// Active chip count after.
    pub to: usize,
    /// Live tenant count at the decision point (incoming tenant included).
    pub live: usize,
}

/// Everything one fleet run produced. `PartialEq` is the determinism
/// equality the serial-vs-parallel tests assert.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    /// One entry per pool chip (including never-activated standbys).
    pub chips: Vec<ChipReport>,
    /// One entry per tenant in trace order.
    pub tenants: Vec<FleetTenant>,
    /// Elastic-scaling ledger in decision order.
    pub scaling: Vec<ScaleEvent>,
    /// Mean ANTT-style slowdown over tenants with served launches, each
    /// against its isolated run on its own chip (1.0 = no interference).
    pub antt: f64,
    /// Mean queueing delay over served launches, fleet-wide.
    pub mean_queue_delay: f64,
    /// p95 queueing delay over served launches, fleet-wide.
    pub p95_queue_delay: u64,
    /// Launches completed (in place + migrated).
    pub served: u32,
    /// Launches stranded with no rescue.
    pub dropped: u32,
    /// Tenants checkpoint-migrated onto a peer chip.
    pub migrations: u32,
    /// Tenants refused admission.
    pub rejections: u32,
    /// Launches belonging to rejected tenants (never queued anywhere).
    pub rejected_launches: u32,
    /// Longest chip run (cycles) — the fleet's makespan.
    pub makespan: u64,
}

fn clusters_of(cfg: &SystemConfig) -> usize {
    cfg.num_sms / 2
}

/// Tenants of `chip` whose arrival window covers `t` (the load the
/// placement and scaling decisions see).
fn residents(assigned: &[Vec<usize>], windows: &[(u64, u64)], chip: usize, t: u64) -> usize {
    assigned[chip].iter().filter(|&&o| windows[o].1 >= t).count()
}

/// Serve `streams` across the chip pool of `fc`, with `faults[c]` (if
/// present) injected on chip `c`. See the module docs for the admission,
/// scaling, health, and migration contracts. Deterministic end to end:
/// same trace + pool + fault schedules produce a bit-identical
/// [`FleetReport`] for any executor thread count and execution mode.
pub fn serve_fleet(
    exec: &SweepExec,
    fc: &FleetConfig,
    streams: &[KernelStream],
    faults: &[FaultTrace],
) -> Result<FleetReport> {
    let pool = fc.chips.len();
    if pool == 0 {
        return Err(err("fleet needs at least one chip"));
    }
    if fc.tenants_per_chip == 0 {
        return Err(err("fleet tenants_per_chip must be >= 1"));
    }
    if faults.len() > pool {
        return Err(err(format!("{} fault traces for a {pool}-chip pool", faults.len())));
    }
    for (c, trace) in faults.iter().enumerate() {
        trace
            .validate(clusters_of(&fc.chips[c]), fc.chips[c].num_mcs)
            .map_err(|e| err(format!("chip {c} fault trace: {e}")))?;
    }
    let trace_of = |c: usize| faults.get(c).cloned().unwrap_or_default();

    // Tenant arrival windows: [first, last] launch arrival. Scaling and
    // placement are trace-driven (open-loop): a tenant is "live" while
    // its window covers the decision cycle. Service-time feedback would
    // need the very simulations placement gates — the window model keeps
    // the whole placement pass computable up front, hence deterministic.
    let windows: Vec<(u64, u64)> = streams
        .iter()
        .map(|s| {
            let first = s.launches.first().map(|l| l.arrival).unwrap_or(0);
            let last = s.launches.last().map(|l| l.arrival).unwrap_or(0);
            (first, last)
        })
        .collect();
    let mut order: Vec<usize> = (0..streams.len()).collect();
    order.sort_by_key(|&ti| (windows[ti].0, ti));

    let mut active = fc.initial_active.clamp(1, pool);
    let mut max_active = active;
    let mut last_scale: Option<u64> = None;
    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); pool];
    let mut scaling: Vec<ScaleEvent> = Vec::new();
    let mut tenants: Vec<FleetTenant> = (0..streams.len())
        .map(|ti| FleetTenant {
            tenant: ti,
            chip: None,
            rejected: None,
            migrated_to: None,
            served: 0,
            dropped: 0,
        })
        .collect();

    for &ti in &order {
        let t = windows[ti].0;
        let live: usize =
            (0..pool).map(|c| residents(&assigned, &windows, c, t)).sum::<usize>() + 1;

        // Elastic scaling: one step per arrival event, cooldown-gated.
        // The active set is a prefix of the pool; shrinking only closes
        // the top chip for new placements (its past tenants keep their
        // shard) and only when that chip is idle at the decision cycle.
        let cooled = last_scale.map_or(true, |s| t.saturating_sub(s) >= fc.scale_cooldown);
        let desired = live.div_ceil(fc.tenants_per_chip).clamp(1, pool);
        if cooled && desired > active && active < pool {
            scaling.push(ScaleEvent { cycle: t, from: active, to: active + 1, live });
            active += 1;
            max_active = max_active.max(active);
            last_scale = Some(t);
        } else if cooled
            && desired < active
            && active > 1
            && residents(&assigned, &windows, active - 1, t) == 0
        {
            scaling.push(ScaleEvent { cycle: t, from: active, to: active - 1, live });
            active -= 1;
            last_scale = Some(t);
        }

        // Placement: least-loaded active chip with a free cluster (every
        // resident tenant needs at least one cluster of its own).
        let dest = (0..active)
            .filter(|&c| residents(&assigned, &windows, c, t) < clusters_of(&fc.chips[c]))
            .min_by_key(|&c| (residents(&assigned, &windows, c, t), c));
        let Some(c) = dest else {
            tenants[ti].rejected = Some(RejectReason::Capacity);
            continue;
        };

        // SLO admission: the tenant's isolated run on the destination
        // chip (memoized — it doubles as the ANTT reference) bounds the
        // fair-share slowdown at `residents + 1` co-tenants. A launch
        // the chip cannot finish even alone is unmeetable outright.
        if let Some(slo) = streams[ti].slo_turnaround {
            let alone = exec.run_stream(&StreamJob::new(
                fc.chips[c].clone(),
                alone_streams(streams, ti),
                PartitionPolicy::Static,
            ));
            let worst = alone
                .launches
                .iter()
                .map(|l| if l.finish == u64::MAX { u64::MAX } else { l.turnaround() })
                .max()
                .unwrap_or(0);
            let share = residents(&assigned, &windows, c, t) as u64 + 1;
            if worst.saturating_mul(share) > slo {
                tenants[ti].rejected = Some(RejectReason::Slo);
                continue;
            }
        }
        tenants[ti].chip = Some(c);
        assigned[c].push(ti);
    }

    // Serve every chip's shard plus every admitted tenant's isolated
    // reference as ONE executor batch: the chip runs fan across worker
    // threads, and the memo contract makes the fan-out bit-identical to
    // the serial walk.
    let serving: Vec<usize> = (0..pool).filter(|&c| !assigned[c].is_empty()).collect();
    let mut jobs: Vec<StreamJob> = Vec::new();
    for &c in &serving {
        let shard: Vec<KernelStream> =
            assigned[c].iter().map(|&ti| streams[ti].clone()).collect();
        jobs.push(StreamJob::new(fc.chips[c].clone(), shard, fc.policy).with_fault(trace_of(c)));
    }
    let mut alone_ix = std::collections::HashMap::new();
    for &c in &serving {
        for &ti in &assigned[c] {
            alone_ix.insert(ti, jobs.len());
            jobs.push(StreamJob::new(
                fc.chips[c].clone(),
                alone_streams(streams, ti),
                PartitionPolicy::Static,
            ));
        }
    }
    let out = exec.run_stream_batch(jobs);

    // Health + quarantine/backoff ledger per serving chip.
    let fo = &fc.failover;
    let mut chips: Vec<ChipReport> = (0..pool)
        .map(|c| ChipReport {
            chip: c,
            activated: c < max_active,
            tenants: assigned[c].clone(),
            health: ChipHealth::Healthy,
            failures: 0,
            quarantined: false,
            backoff: 0,
            migrated_in: Vec::new(),
            report: None,
            ipc: 0.0,
        })
        .collect();
    for (bi, &c) in serving.iter().enumerate() {
        let rep = (*out[bi]).clone();
        let n_cl = clusters_of(&fc.chips[c]) as u64;
        let stranded = rep.launches.iter().any(|l| l.finish == u64::MAX);
        let health = if rep.chip.clusters_retired >= n_cl || (rep.deadline_hit && stranded) {
            ChipHealth::Dead
        } else if rep.chip.faults_injected > 0 || rep.deadline_hit {
            ChipHealth::Degraded
        } else {
            ChipHealth::Healthy
        };
        let failures = stranded as u32;
        let ch = &mut chips[c];
        ch.health = health;
        ch.failures = failures;
        ch.quarantined = failures >= fo.quarantine_after;
        ch.backoff = if failures > 0 { backoff_delay(fo, c, failures) } else { 0 };
        ch.ipc = if rep.cycles > 0 { rep.sm.thread_insns as f64 / rep.cycles as f64 } else { 0.0 };
        ch.report = Some(rep);
    }

    // Tenant accounting: completions in place, then chip-to-chip
    // migration for launches stranded on failed chips.
    struct Stranded {
        ti: usize,
        src: usize,
        pending: Vec<usize>,
    }
    let mut stranded_list: Vec<Stranded> = Vec::new();
    for &c in &serving {
        let rep = chips[c].report.as_ref().expect("serving chip has a report");
        for (local, &ti) in assigned[c].iter().enumerate() {
            let mut pending = Vec::new();
            for l in rep.launches.iter().filter(|l| l.tenant == local as u32) {
                if l.finish == u64::MAX {
                    pending.push(l.kernel as usize);
                } else {
                    tenants[ti].served += 1;
                }
            }
            if !pending.is_empty() {
                stranded_list.push(Stranded { ti, src: c, pending });
            }
        }
    }
    let dense = dense_env();
    let mut migrations = 0u32;
    for s in stranded_list {
        // Destination: a healthy, non-quarantined, shape-identical peer
        // (the checkpoint holds per-cluster and per-MC machine state, so
        // restore needs the same config fingerprint) — least loaded,
        // lowest index. Never-activated standby chips qualify: failover
        // may recruit spare capacity the scaler has not opened yet.
        let src_fp = cfg_fingerprint(&fc.chips[s.src]);
        let dst = (0..pool)
            .filter(|&d| {
                d != s.src
                    && chips[d].health == ChipHealth::Healthy
                    && !chips[d].quarantined
                    && cfg_fingerprint(&fc.chips[d]) == src_fp
            })
            .min_by_key(|&d| (chips[d].tenants.len() + chips[d].migrated_in.len(), d));
        let trace = trace_of(s.src);
        let mut rescued = 0usize;
        // The migration recipe of `serve_with_failover`, chip-to-chip:
        // capture the tenant alone on the SOURCE config pre-fault, strip
        // the unfired faults, finish on the DESTINATION chip. Without a
        // fault schedule there is no pre-fault cycle to arm (a deadline
        // death has no healthy state to capture) — the launches drop.
        if let Some(d) = dst {
            if !trace.is_empty() {
                let alone = alone_streams(streams, s.ti);
                let first_fault = trace.events[0].cycle;
                let (_, cp) = serve_streams_snapshot(
                    &fc.chips[s.src],
                    &alone,
                    PartitionPolicy::Static,
                    dense,
                    first_fault,
                    Some(&trace),
                )?;
                if let Some(mut cp) = cp {
                    cp.strip_pending_faults()?;
                    let rep = serve_streams_resume(
                        &fc.chips[d],
                        &alone,
                        PartitionPolicy::Static,
                        dense,
                        &cp,
                    )?;
                    for &ord in &s.pending {
                        if rep
                            .launches
                            .iter()
                            .any(|r| r.kernel as usize == ord && r.finish != u64::MAX)
                        {
                            rescued += 1;
                        }
                    }
                    if rescued > 0 {
                        tenants[s.ti].migrated_to = Some(d);
                        chips[d].migrated_in.push(s.ti);
                        migrations += 1;
                    }
                }
            }
        }
        tenants[s.ti].served += rescued as u32;
        tenants[s.ti].dropped = (s.pending.len() - rescued) as u32;
    }

    // Fleet-wide service metrics over the in-place runs.
    let mut delays: Vec<u64> = Vec::new();
    let mut antt_sum = 0.0;
    let mut antt_n = 0usize;
    for &c in &serving {
        let rep = chips[c].report.as_ref().expect("serving chip has a report");
        delays.extend(rep.launches.iter().filter(|l| l.finish != u64::MAX).map(|l| l.queue_delay));
        for (local, &ti) in assigned[c].iter().enumerate() {
            if rep.launches.iter().any(|l| l.tenant == local as u32 && l.finish != u64::MAX) {
                let alone = &out[alone_ix[&ti]];
                antt_sum += antt_slowdown(rep, alone, local);
                antt_n += 1;
            }
        }
    }
    let served: u32 = tenants.iter().map(|t| t.served).sum();
    let dropped: u32 = tenants.iter().map(|t| t.dropped).sum();
    let rejections = tenants.iter().filter(|t| t.rejected.is_some()).count() as u32;
    let rejected_launches: u32 = tenants
        .iter()
        .filter(|t| t.rejected.is_some())
        .map(|t| streams[t.tenant].launches.len() as u32)
        .sum();
    let makespan = serving
        .iter()
        .map(|&c| chips[c].report.as_ref().expect("serving chip has a report").cycles)
        .max()
        .unwrap_or(0);
    let mean_queue_delay = if delays.is_empty() {
        0.0
    } else {
        delays.iter().sum::<u64>() as f64 / delays.len() as f64
    };
    let p95_queue_delay = p95_u64(&delays);
    Ok(FleetReport {
        chips,
        tenants,
        scaling,
        antt: if antt_n > 0 { antt_sum / antt_n as f64 } else { 0.0 },
        mean_queue_delay,
        p95_queue_delay,
        served,
        dropped,
        migrations,
        rejections,
        rejected_launches,
        makespan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;
    use crate::sim::fault::{FaultEvent, FaultKind};
    use crate::workload::{bench, shrink_streams, traffic_trace};

    fn tiny_chip() -> SystemConfig {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 300_000;
        cfg
    }

    fn fleet_streams(n: usize, mean_gap: u64, seed: u64) -> Vec<KernelStream> {
        let picks = ["CP", "BFS"];
        let tenants: Vec<_> = (0..n)
            .map(|i| (bench(picks[i % picks.len()]).unwrap(), Scheme::Baseline))
            .collect();
        let mut streams = traffic_trace(&tenants, 2, mean_gap, seed);
        shrink_streams(&mut streams, 4, 40);
        streams
    }

    fn kill_both_clusters() -> FaultTrace {
        FaultTrace::new(vec![
            FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 0 } },
            FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 1 } },
        ])
    }

    #[test]
    fn healthy_pool_serves_everything_deterministically() {
        let fc = FleetConfig::pool(tiny_chip(), 2);
        let streams = fleet_streams(3, 0, 17);
        let exec = SweepExec::new(2);
        let rep = serve_fleet(&exec, &fc, &streams, &[]).unwrap();
        assert_eq!(rep.rejections, 0);
        assert_eq!(rep.migrations, 0);
        assert_eq!(rep.dropped, 0);
        let total: u32 = streams.iter().map(|s| s.launches.len() as u32).sum();
        assert_eq!(rep.served, total, "healthy fleet serves every launch");
        for t in &rep.tenants {
            assert!(t.chip.is_some());
            assert_eq!(t.served as usize, streams[t.tenant].launches.len());
        }
        for c in &rep.chips {
            assert_eq!(c.health, ChipHealth::Healthy);
            assert!(!c.quarantined);
            assert_eq!(c.failures, 0);
        }
        assert!(rep.antt >= 0.99, "antt {}", rep.antt);
        // Bit-identical on a fresh executor (memo cold) and a re-run.
        let again = serve_fleet(&SweepExec::new(1), &fc, &streams, &[]).unwrap();
        assert_eq!(rep, again);
    }

    #[test]
    fn placement_routes_to_least_loaded_chip() {
        // 2 chips, 2 simultaneous tenants, threshold 1 tenant/chip: the
        // scaler opens chip 1 and each tenant gets its own chip.
        let mut fc = FleetConfig::pool(tiny_chip(), 2);
        fc.tenants_per_chip = 1;
        let streams = fleet_streams(2, 0, 17);
        let rep = serve_fleet(&SweepExec::new(2), &fc, &streams, &[]).unwrap();
        assert_eq!(rep.tenants[0].chip, Some(0));
        assert_eq!(rep.tenants[1].chip, Some(1));
        assert_eq!(rep.scaling.len(), 1, "one grow action");
        assert_eq!((rep.scaling[0].from, rep.scaling[0].to), (1, 2));
    }

    #[test]
    fn capacity_rejection_is_honest() {
        // One tiny chip (2 clusters), 4 simultaneous tenants: two are
        // admitted, two rejected — and the rejected launches are
        // accounted, never faked as served.
        let fc = FleetConfig::pool(tiny_chip(), 1);
        let streams = fleet_streams(4, 0, 17);
        let rep = serve_fleet(&SweepExec::new(2), &fc, &streams, &[]).unwrap();
        assert_eq!(rep.rejections, 2);
        for t in &rep.tenants[2..] {
            assert_eq!(t.rejected, Some(RejectReason::Capacity));
            assert_eq!(t.served, 0);
            assert_eq!(t.chip, None);
        }
        let total: u32 = streams.iter().map(|s| s.launches.len() as u32).sum();
        assert_eq!(rep.served + rep.dropped + rep.rejected_launches, total);
        assert!(rep.rejected_launches > 0);
    }

    #[test]
    fn slo_admission_rejects_the_unmeetable_and_admits_the_generous() {
        let fc = FleetConfig::pool(tiny_chip(), 2);
        let mut streams = fleet_streams(2, 0, 17);
        streams[0].slo_turnaround = Some(1); // unmeetable even alone
        streams[1].slo_turnaround = Some(u64::MAX); // trivially met
        let rep = serve_fleet(&SweepExec::new(2), &fc, &streams, &[]).unwrap();
        assert_eq!(rep.tenants[0].rejected, Some(RejectReason::Slo));
        assert_eq!(rep.tenants[0].served, 0, "rejection is never a fake completion");
        assert_eq!(rep.tenants[1].rejected, None);
        assert_eq!(rep.tenants[1].served as usize, streams[1].launches.len());
    }

    #[test]
    fn dead_chip_migrates_stranded_tenants_to_peer() {
        // Both tenants land on chip 0 (threshold 2 keeps the fleet at one
        // active chip); chip 0 dies at cycle 10. Every stranded launch
        // must finish on the standby peer via checkpoint migration.
        let fc = FleetConfig::pool(tiny_chip(), 2);
        let streams = fleet_streams(2, 0, 17);
        let faults = [kill_both_clusters()];
        let exec = SweepExec::new(2);
        let rep = serve_fleet(&exec, &fc, &streams, &faults).unwrap();
        assert_eq!(rep.chips[0].health, ChipHealth::Dead);
        assert!(rep.chips[0].quarantined, "one-strike quarantine");
        assert!(rep.chips[0].backoff > 0);
        assert_eq!(rep.chips[1].health, ChipHealth::Healthy);
        assert_eq!(rep.migrations, 2);
        assert_eq!(rep.dropped, 0, "migration must rescue every stranded launch");
        assert_eq!(rep.chips[1].migrated_in, vec![0, 1]);
        for t in &rep.tenants {
            assert_eq!(t.chip, Some(0));
            assert_eq!(t.migrated_to, Some(1));
            assert_eq!(t.served as usize, streams[t.tenant].launches.len());
        }
        // Deterministic end to end, cold memo and serial executor.
        let again = serve_fleet(&SweepExec::new(1), &fc, &streams, &faults).unwrap();
        assert_eq!(rep, again);
    }

    #[test]
    fn dead_chip_with_no_peer_drops_honestly() {
        let fc = FleetConfig::pool(tiny_chip(), 1);
        let streams = fleet_streams(2, 0, 17);
        let faults = [kill_both_clusters()];
        let rep = serve_fleet(&SweepExec::new(2), &fc, &streams, &faults).unwrap();
        assert_eq!(rep.migrations, 0, "no peer to migrate to");
        let total: u32 = streams.iter().map(|s| s.launches.len() as u32).sum();
        assert_eq!(rep.served + rep.dropped, total, "every launch accounted");
        assert!(rep.dropped > 0, "a dead single-chip fleet must drop");
    }

    #[test]
    fn elastic_scaling_grows_and_shrinks_with_cooldown() {
        let mut streams = fleet_streams(4, 0, 17);
        // Overlapping windows for tenants 0-2 (arrivals 0/100/200, all
        // lasting to ~50k), then a late loner at 300k.
        for (ti, (first, second)) in
            [(0u64, 50_000u64), (100, 50_100), (200, 50_200), (300_000, 300_001)]
                .into_iter()
                .enumerate()
        {
            streams[ti].launches[0].arrival = first;
            streams[ti].launches[1].arrival = second;
        }
        let mut fc = FleetConfig::pool(tiny_chip(), 3);
        fc.tenants_per_chip = 1;
        let rep = serve_fleet(&SweepExec::new(2), &fc, &streams, &[]).unwrap();
        let steps: Vec<(u64, usize, usize)> =
            rep.scaling.iter().map(|e| (e.cycle, e.from, e.to)).collect();
        assert_eq!(
            steps,
            vec![(100, 1, 2), (200, 2, 3), (300_000, 3, 2)],
            "grow on overlap, shrink when the fleet drains"
        );
        assert!(rep.chips.iter().all(|c| c.activated), "all three chips were opened");
        // A long cooldown suppresses the second grow and the shrink.
        fc.scale_cooldown = 1_000_000;
        let cooled = serve_fleet(&SweepExec::new(2), &fc, &streams, &[]).unwrap();
        assert_eq!(cooled.scaling.len(), 1, "cooldown blocks back-to-back actions");
        assert_eq!(cooled.rejections, 0, "capacity still absorbs everyone");
    }

    #[test]
    fn fleet_rejects_bad_inputs() {
        let fc = FleetConfig { chips: Vec::new(), ..FleetConfig::pool(tiny_chip(), 1) };
        let streams = fleet_streams(1, 0, 17);
        assert!(serve_fleet(&SweepExec::new(1), &fc, &streams, &[]).is_err());
        let fc = FleetConfig { tenants_per_chip: 0, ..FleetConfig::pool(tiny_chip(), 1) };
        assert!(serve_fleet(&SweepExec::new(1), &fc, &streams, &[]).is_err());
        let fc = FleetConfig::pool(tiny_chip(), 1);
        let two_traces = [FaultTrace::default(), FaultTrace::default()];
        assert!(serve_fleet(&SweepExec::new(1), &fc, &streams, &two_traces).is_err());
        // A fault trace naming a cluster the chip does not have.
        let bad = [FaultTrace::new(vec![FaultEvent {
            cycle: 5,
            kind: FaultKind::Cluster { cluster: 99 },
        }])];
        assert!(serve_fleet(&SweepExec::new(1), &fc, &streams, &bad).is_err());
    }
}
