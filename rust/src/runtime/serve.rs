//! Server-simulation front-end: tenant specs, standard service traces,
//! and the ANTT-style slowdown math for the multi-tenant stream mode.
//!
//! This is the runtime layer the `amoeba serve-sim` subcommand and the
//! harness's server sweep share: it turns a human-readable tenant spec
//! (`"SM:hetero,BFS:warp_regrouping,CP:baseline"`) into a seeded
//! [`KernelStream`] trace, and computes per-tenant service metrics from
//! the resulting [`StreamReport`]s. Simulation itself stays in
//! [`crate::sim::gpu`]; scheduling policy stays in
//! [`crate::sim::gpu::PartitionPolicy`].
//!
//! The serving layer is also where fault tolerance lives:
//! [`serve_with_failover`] runs a shared trace under a
//! [`FaultTrace`](crate::sim::fault::FaultTrace), then retries each
//! tenant's unserved launches on spare healthy capacity with seeded
//! exponential backoff, bounded retries, and quarantine after repeated
//! failures — every step deterministic, so degraded-mode service is as
//! reproducible as the healthy path.

use crate::config::{Scheme, SystemConfig};
use crate::harness::{p95_u64, StreamJob};
use crate::sim::fault::FaultTrace;
use crate::sim::gpu::{
    serve_streams, serve_streams_faulted, serve_streams_resume, serve_streams_snapshot,
    PartitionPolicy, StreamReport,
};
use crate::workload::{
    bench, hash_combine, BenchProfile, KernelStream, Priority, StreamLaunch, TenantQosSpec,
};

/// Parse a tenant spec: comma-separated `BENCH[:SCHEME]` entries, e.g.
/// `"SM:hetero,BFS:warp_regrouping,CP"`. A missing scheme defaults to
/// `hetero` — per-cluster control is the server mode's reason to exist.
pub fn parse_tenant_spec(spec: &str) -> Result<Vec<(BenchProfile, Scheme)>, String> {
    let mut out = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (name, scheme) = match entry.split_once(':') {
            Some((n, s)) => (n.trim(), s.trim().parse::<Scheme>()?),
            None => (entry, Scheme::Hetero),
        };
        let profile =
            bench(name).ok_or_else(|| format!("unknown benchmark '{name}' in tenant spec"))?;
        out.push((profile, scheme));
    }
    if out.is_empty() {
        return Err("tenant spec names no tenants".into());
    }
    Ok(out)
}

/// Parse a QoS tenant spec: comma-separated
/// `BENCH[:SCHEME[:PRIORITY[@SLO]]]` entries, e.g.
/// `"SM:hetero:high@400000,BFS:warp_regrouping:low,CP"`. Scheme defaults
/// to `hetero`, priority to `normal`, and the SLO — a per-launch
/// turnaround target in cycles — to none (best effort). Underscores in
/// the SLO are ignored (`400_000` reads naturally).
pub fn parse_tenant_spec_qos(spec: &str) -> Result<Vec<TenantQosSpec>, String> {
    let mut out = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let mut parts = entry.splitn(3, ':');
        let name = parts.next().expect("splitn yields at least one part").trim();
        let profile =
            bench(name).ok_or_else(|| format!("unknown benchmark '{name}' in tenant spec"))?;
        let scheme = match parts.next() {
            Some(s) => s.trim().parse::<Scheme>()?,
            None => Scheme::Hetero,
        };
        let (priority, slo_turnaround) = match parts.next() {
            Some(p) => match p.trim().split_once('@') {
                Some((pr, slo)) => {
                    let cycles = slo
                        .trim()
                        .replace('_', "")
                        .parse::<u64>()
                        .map_err(|e| format!("bad SLO '{slo}' (cycles): {e}"))?;
                    (pr.trim().parse::<Priority>()?, Some(cycles))
                }
                None => (p.trim().parse::<Priority>()?, None),
            },
            None => (Priority::Normal, None),
        };
        out.push(TenantQosSpec { profile, scheme, priority, slo_turnaround });
    }
    if out.is_empty() {
        return Err("tenant spec names no tenants".into());
    }
    Ok(out)
}

/// The standard three-tenant mix the server sweep and `serve-sim` default
/// to: a cache-sharing scale-up winner under per-cluster control, a
/// divergent graph workload under warp regrouping, and a compute-dense
/// scale-out tenant — the divergent scalability profiles the paper argues
/// one fixed SM shape cannot serve at once.
pub fn default_tenants() -> Vec<(BenchProfile, Scheme)> {
    vec![
        (bench("SM").expect("SM profile"), Scheme::Hetero),
        (bench("BFS").expect("BFS profile"), Scheme::WarpRegroup),
        (bench("CP").expect("CP profile"), Scheme::Baseline),
    ]
}

/// ANTT-style slowdown of tenant `ti` in `shared` against its isolated
/// reference run `alone` (the same stream served alone, as tenant 0):
/// the mean over kernels of `shared turnaround / alone turnaround`.
/// 1.0 = no interference; launches the deadline truncated are skipped.
pub fn antt_slowdown(shared: &StreamReport, alone: &StreamReport, ti: usize) -> f64 {
    let shared_launches = shared.launches.iter().filter(|l| l.tenant == ti as u32);
    let alone_launches: Vec<_> =
        alone.launches.iter().filter(|l| l.tenant == 0).collect();
    let mut acc = 0.0;
    let mut n = 0u32;
    for (s, a) in shared_launches.zip(alone_launches) {
        if s.finish == u64::MAX || a.finish == u64::MAX {
            continue;
        }
        acc += s.turnaround() as f64 / a.turnaround().max(1) as f64;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        acc / n as f64
    }
}

/// Whole-stream slowdown: tenant completion cycle in the shared run over
/// its completion when served alone.
pub fn stream_slowdown(shared: &StreamReport, alone: &StreamReport, ti: usize) -> f64 {
    let a = alone.tenants[0].cycles;
    if a == 0 {
        0.0
    } else {
        shared.tenants[ti].cycles as f64 / a as f64
    }
}

/// Per-tenant service-quality summary of one shared run, derived from
/// its [`LaunchStat`](crate::sim::gpu::LaunchStat) records.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantQos {
    /// Tenant (stream) index.
    pub tenant: usize,
    /// Priority class the stream was served under.
    pub priority: Priority,
    /// Per-launch turnaround SLO in cycles, if any.
    pub slo_turnaround: Option<u64>,
    /// Launches that completed before any deadline truncation.
    pub served: u32,
    /// Served launches whose turnaround met the SLO. With no SLO set,
    /// every served launch counts as attained (best effort always meets
    /// its — vacuous — target).
    pub slo_met: u32,
    /// Mean queueing delay (launch start minus arrival) over served
    /// launches, in cycles.
    pub mean_queue_delay: f64,
    /// 95th-percentile queueing delay over served launches (nearest
    /// rank), in cycles.
    pub p95_queue_delay: u64,
    /// Mean per-launch slowdown (turnaround over service) in milli-units;
    /// 1000 = every launch ran unqueued.
    pub mean_slowdown_milli: u64,
}

impl TenantQos {
    /// Fraction of served launches that met the SLO (0.0 when nothing
    /// was served — an unserved tenant attains nothing).
    pub fn slo_attainment(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.slo_met as f64 / self.served as f64
        }
    }
}

/// Mean of an integer sample set, rounded to nearest (half away from
/// zero) rather than truncated. Truncation biased every reported mean
/// low by up to one unit — at milli-slowdown scale that is exactly the
/// granularity [`qos_objective`] scores on, so the bias leaked into
/// policy choice. Returns 0 for an empty set.
pub(crate) fn rounded_mean_u64(values: impl Iterator<Item = u64>) -> u64 {
    let (mut sum, mut n) = (0u64, 0u64);
    for v in values {
        sum += v;
        n += 1;
    }
    if n == 0 {
        0
    } else {
        (sum + n / 2) / n
    }
}

/// Summarise a shared run's per-launch service records into one
/// [`TenantQos`] per tenant. `streams` must be the same streams the
/// report was produced from (it carries the priority / SLO specs).
pub fn qos_summary(report: &StreamReport, streams: &[KernelStream]) -> Vec<TenantQos> {
    (0..streams.len())
        .map(|ti| {
            let served: Vec<_> = report
                .launches
                .iter()
                .filter(|l| l.tenant == ti as u32 && l.finish != u64::MAX)
                .collect();
            let slo = streams[ti].slo_turnaround;
            let slo_met = served
                .iter()
                .filter(|l| slo.map_or(true, |target| l.turnaround() <= target))
                .count() as u32;
            let delays: Vec<u64> = served.iter().map(|l| l.queue_delay).collect();
            let mean_queue_delay = if delays.is_empty() {
                0.0
            } else {
                delays.iter().sum::<u64>() as f64 / delays.len() as f64
            };
            let mean_slowdown_milli =
                rounded_mean_u64(served.iter().map(|l| l.slowdown_milli));
            TenantQos {
                tenant: ti,
                priority: streams[ti].priority,
                slo_turnaround: slo,
                served: served.len() as u32,
                slo_met,
                mean_queue_delay,
                p95_queue_delay: p95_u64(&delays),
                mean_slowdown_milli,
            }
        })
        .collect()
}

/// Objective weight of a priority class: High tenants' service quality
/// counts four times a Low tenant's, Normal twice.
pub fn priority_weight(p: Priority) -> f64 {
    match p {
        Priority::Low => 1.0,
        Priority::Normal => 2.0,
        Priority::High => 4.0,
    }
}

/// SLO-aware controller objective over one shared run: the
/// priority-weighted mean of each tenant's service score, where the
/// score trades a latency term (SLO attainment) against a throughput
/// term (inverse mean slowdown, 1.0 when every launch ran unqueued) by
/// `latency_weight` in `[0, 1]`. Higher is better; both terms live in
/// `[0, 1]`, so so does the objective. An unserved tenant scores zero.
pub fn qos_objective(tenants: &[TenantQos], latency_weight: f64) -> f64 {
    let lw = latency_weight.clamp(0.0, 1.0);
    let mut acc = 0.0;
    let mut wsum = 0.0;
    for t in tenants {
        let w = priority_weight(t.priority);
        let latency = t.slo_attainment();
        let throughput =
            if t.served == 0 { 0.0 } else { 1000.0 / t.mean_slowdown_milli.max(1000) as f64 };
        acc += w * (lw * latency + (1.0 - lw) * throughput);
        wsum += w;
    }
    if wsum == 0.0 {
        0.0
    } else {
        acc / wsum
    }
}

/// Serve `streams` under each candidate partition policy and pick the
/// argmax of [`qos_objective`]. Returns the winner plus every
/// candidate's score in evaluation order; ties keep the earlier
/// candidate (Static), so the choice is deterministic.
pub fn choose_policy(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    latency_weight: f64,
) -> crate::errors::Result<(PartitionPolicy, Vec<(PartitionPolicy, f64)>)> {
    let mut scored: Vec<(PartitionPolicy, f64)> = Vec::new();
    for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
        let rep = serve_streams(cfg, streams, policy)?;
        let score = qos_objective(&qos_summary(&rep, streams), latency_weight);
        scored.push((policy, score));
    }
    let mut best = scored[0];
    for &c in &scored[1..] {
        if c.1 > best.1 {
            best = c;
        }
    }
    Ok((best.0, scored))
}

/// The isolated-reference job for tenant `ti` of `streams`: the same
/// stream (same arrivals, same kernel seeds) served alone on the full
/// chip. Memoizes cleanly through the stream cache.
pub fn alone_streams(streams: &[KernelStream], ti: usize) -> Vec<KernelStream> {
    vec![streams[ti].clone()]
}

/// The canonical server job list every front-end submits: one shared run
/// per policy in `shared` (in order), then each tenant alone (the
/// interference-free reference, always `Static` — policy is moot for a
/// single tenant). Result indexing: `out[i]` is `shared[i]`'s run,
/// `out[shared.len() + ti]` is tenant `ti` alone.
pub fn server_jobs(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    shared: &[PartitionPolicy],
) -> Vec<StreamJob> {
    let mut jobs: Vec<StreamJob> = shared
        .iter()
        .map(|&p| StreamJob::new(cfg.clone(), streams.to_vec(), p))
        .collect();
    for ti in 0..streams.len() {
        jobs.push(StreamJob::new(cfg.clone(), alone_streams(streams, ti), PartitionPolicy::Static));
    }
    jobs
}

/// Knobs for degraded-mode serving ([`serve_with_failover`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailoverConfig {
    /// Retry attempts per tenant after the shared run leaves launches
    /// unserved (deadline truncation on a faulted chip).
    pub max_retries: u32,
    /// Failed attempts (shared run included) before the tenant is
    /// quarantined: no further retries, no migration, remaining launches
    /// dropped.
    pub quarantine_after: u32,
    /// Base backoff in cycles; attempt `a` waits `base * 2^a` plus a
    /// seeded jitter below `base`.
    pub backoff_base: u64,
    /// Seed for the deterministic backoff jitter.
    pub backoff_seed: u64,
    /// Minimum cycles between reconfigurations, raised onto the machine
    /// config before serving (a faulted chip should not thrash layouts).
    pub reconfig_cooldown: u64,
}

impl Default for FailoverConfig {
    fn default() -> Self {
        FailoverConfig {
            max_retries: 2,
            quarantine_after: 3,
            backoff_base: 10_000,
            backoff_seed: 0xFA11,
            reconfig_cooldown: 0,
        }
    }
}

/// Per-tenant health ledger [`serve_with_failover`] returns alongside the
/// shared report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantHealth {
    /// Tenant (stream) index.
    pub tenant: usize,
    /// Serve attempts made: the shared run plus any retries.
    pub attempts: u32,
    /// Attempts that ended with launches still unserved.
    pub failures: u32,
    /// The tenant hit `quarantine_after` failures and was cut off.
    pub quarantined: bool,
    /// Launches that completed, across all attempts.
    pub served: u32,
    /// Launches never completed (dropped on quarantine / retry budget).
    pub dropped: u32,
    /// Out of retries with launches still unserved, the tenant was
    /// checkpoint-migrated: its in-flight state was captured just before
    /// the first fault fired, pending faults were stripped from the
    /// checkpoint, and the stream finished on a restored healthy machine
    /// (see [`serve_with_failover`]).
    pub migrated: bool,
}

/// Deterministic backoff before retry `attempt` (1-based) of `tenant`:
/// exponential in the attempt with a seeded jitter below the base, so
/// co-failing tenants deterministically desynchronise their retries.
pub fn backoff_delay(fo: &FailoverConfig, tenant: usize, attempt: u32) -> u64 {
    let exp = fo.backoff_base.saturating_mul(1u64 << attempt.min(16));
    let jitter = if fo.backoff_base == 0 {
        0
    } else {
        hash_combine(&[fo.backoff_seed, tenant as u64, attempt as u64]) % fo.backoff_base
    };
    exp.saturating_add(jitter)
}

/// Build the retry stream for a tenant's `pending` launches: the batch
/// is pushed out to `delay`, but each launch keeps its original
/// inter-arrival offset relative to the earliest pending one
/// (`delay + (arrival - first_pending_arrival)`), so the retry preserves
/// the trace's shape and its `queue_delay` stats stay meaningful instead
/// of every launch landing on the same cycle.
pub(crate) fn retry_stream(
    stream: &KernelStream,
    pending: &[(usize, StreamLaunch)],
    delay: u64,
) -> KernelStream {
    let first = pending.iter().map(|(_, l)| l.arrival).min().unwrap_or(0);
    KernelStream {
        name: stream.name.clone(),
        profile: stream.profile.clone(),
        scheme: stream.scheme,
        priority: stream.priority,
        slo_turnaround: stream.slo_turnaround,
        launches: pending
            .iter()
            .map(|(_, l)| StreamLaunch {
                arrival: delay + (l.arrival - first),
                kernel: l.kernel.clone(),
            })
            .collect(),
    }
}

/// Serve `streams` on a chip with `faults` injected, then heal: every
/// launch the shared run left unserved (its cluster retired, or the
/// deadline hit while degraded) is retried on spare healthy capacity —
/// alone on the chip, fault-free, arrivals pushed out by
/// [`backoff_delay`] — up to `fo.max_retries` times. A tenant whose
/// attempts keep failing is quarantined after `fo.quarantine_after`
/// failures.
///
/// Launches still unserved after the retry budget get one **live
/// migration** — unless the tenant is already at the quarantine bar
/// (`fo.quarantine_after` failures), which cuts it off from retries
/// *and* migration alike: the tenant's stream is replayed alone under the same
/// fault schedule with a checkpoint armed at the first injection cycle —
/// the capture runs *before* injection, so it holds the tenant's
/// in-flight, still-healthy machine state at a CTA dispatch boundary —
/// then the not-yet-fired faults are stripped from the checkpoint and
/// the run restores onto a healthy machine that serves the stream to
/// completion. Only launches the migrated run actually finished move out
/// of the dropped column. Returns the shared run's report plus one
/// [`TenantHealth`] per tenant. Fully deterministic: same inputs, same
/// report, same ledger.
pub fn serve_with_failover(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    policy: PartitionPolicy,
    fo: &FailoverConfig,
    faults: &FaultTrace,
) -> crate::errors::Result<(StreamReport, Vec<TenantHealth>)> {
    let mut cfg = cfg.clone();
    cfg.reconfig_cooldown = cfg.reconfig_cooldown.max(fo.reconfig_cooldown);
    let shared = serve_streams_faulted(&cfg, streams, policy, faults)?;

    let mut health = Vec::with_capacity(streams.len());
    for (ti, stream) in streams.iter().enumerate() {
        let mut h = TenantHealth {
            tenant: ti,
            attempts: 1,
            failures: 0,
            quarantined: false,
            served: 0,
            dropped: 0,
            migrated: false,
        };
        // LaunchStat.kernel is the launch's ordinal within its stream, so
        // it indexes straight back into `stream.launches`; the ordinal
        // rides along so the migration path can match completions.
        let mut pending: Vec<(usize, StreamLaunch)> = Vec::new();
        for l in shared.launches.iter().filter(|l| l.tenant == ti as u32) {
            if l.finish == u64::MAX {
                pending.push((l.kernel as usize, stream.launches[l.kernel as usize].clone()));
            } else {
                h.served += 1;
            }
        }
        if !pending.is_empty() {
            h.failures = 1;
        }

        let mut attempt = 0u32;
        while !pending.is_empty() && attempt < fo.max_retries && h.failures < fo.quarantine_after {
            attempt += 1;
            h.attempts += 1;
            let delay = backoff_delay(fo, ti, attempt);
            let retry = retry_stream(stream, &pending, delay);
            let rep = serve_streams(&cfg, &[retry], PartitionPolicy::Static)?;
            let mut done = vec![false; pending.len()];
            for l in rep.launches.iter().filter(|l| l.finish != u64::MAX) {
                done[l.kernel as usize] = true;
            }
            let mut keep = Vec::new();
            for (i, entry) in pending.into_iter().enumerate() {
                if done[i] {
                    h.served += 1;
                } else {
                    keep.push(entry);
                }
            }
            pending = keep;
            if !pending.is_empty() {
                h.failures += 1;
            }
        }

        // Retry budget spent and launches still stranded: live-migrate.
        // Replay the stream alone under the same fault schedule with a
        // checkpoint armed at the first injection cycle (captured state
        // is pre-injection, i.e. healthy), strip the faults that have
        // not fired yet, and finish the stream on a restored machine.
        // A tenant at the quarantine bar is cut off here too — the
        // `quarantine_after` contract drops its remaining launches.
        if !pending.is_empty() && !faults.is_empty() && h.failures < fo.quarantine_after {
            let alone = alone_streams(streams, ti);
            let first_fault = faults.events[0].cycle;
            let dense = crate::sim::gpu::dense_env();
            let (_, cp) = serve_streams_snapshot(
                &cfg,
                &alone,
                PartitionPolicy::Static,
                dense,
                first_fault,
                Some(faults),
            )?;
            if let Some(mut cp) = cp {
                cp.strip_pending_faults()?;
                let rep = serve_streams_resume(&cfg, &alone, PartitionPolicy::Static, dense, &cp)?;
                // CTA conservation must survive the capture/restore seam.
                debug_assert_eq!(
                    rep.chip.ctas_dispatched,
                    rep.sm.ctas_retired + rep.chip.ctas_requeued,
                    "migrated run broke CTA conservation"
                );
                h.attempts += 1;
                let mut keep = Vec::new();
                for (ord, l) in pending.into_iter() {
                    let done = rep
                        .launches
                        .iter()
                        .any(|r| r.kernel as usize == ord && r.finish != u64::MAX);
                    if done {
                        h.served += 1;
                        h.migrated = true;
                    } else {
                        keep.push((ord, l));
                    }
                }
                pending = keep;
            }
        }

        h.dropped = pending.len() as u32;
        h.quarantined = h.failures >= fo.quarantine_after;
        health.push(h);
    }
    Ok((shared, health))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::gpu::{serve_streams, PartitionPolicy};
    use crate::workload::{shrink_streams, traffic_trace};

    #[test]
    fn tenant_spec_parses_schemes_and_defaults() {
        let t = parse_tenant_spec("SM:hetero, BFS:warp_regrouping ,CP").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].0.name, "SM");
        assert_eq!(t[0].1, Scheme::Hetero);
        assert_eq!(t[1].1, Scheme::WarpRegroup);
        assert_eq!(t[2].1, Scheme::Hetero, "missing scheme defaults to hetero");
        assert!(parse_tenant_spec("NOPE:hetero").is_err());
        assert!(parse_tenant_spec("SM:bogus").is_err());
        assert!(parse_tenant_spec("  ,").is_err());
        assert_eq!(default_tenants().len(), 3);
    }

    #[test]
    fn qos_tenant_spec_parses_priority_and_slo() {
        let t = parse_tenant_spec_qos("SM:hetero:high@400_000, BFS:warp_regrouping:low ,CP").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].priority, Priority::High);
        assert_eq!(t[0].slo_turnaround, Some(400_000));
        assert_eq!(t[1].priority, Priority::Low);
        assert_eq!(t[1].slo_turnaround, None);
        assert_eq!(t[2].scheme, Scheme::Hetero, "missing scheme defaults to hetero");
        assert_eq!(t[2].priority, Priority::Normal, "missing priority defaults to normal");
        assert_eq!(t[2].slo_turnaround, None);
        assert!(parse_tenant_spec_qos("SM:hetero:urgent").is_err());
        assert!(parse_tenant_spec_qos("SM:hetero:high@soon").is_err());
        assert!(parse_tenant_spec_qos("NOPE:hetero:high").is_err());
        assert!(parse_tenant_spec_qos("").is_err());
    }

    #[test]
    fn qos_summary_on_a_real_run() {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let tenants =
            vec![(bench("CP").unwrap(), Scheme::Baseline), (bench("BFS").unwrap(), Scheme::Baseline)];
        let mut streams = traffic_trace(&tenants, 2, 0, 11);
        shrink_streams(&mut streams, 4, 40);
        // Tenant 0: a generous SLO it must meet. Tenant 1: an impossible
        // one-cycle SLO it must miss on every launch.
        streams[0].priority = Priority::High;
        streams[0].slo_turnaround = Some(u64::MAX);
        streams[1].slo_turnaround = Some(1);
        let rep = serve_streams(&cfg, &streams, PartitionPolicy::Static).unwrap();
        let qos = qos_summary(&rep, &streams);
        assert_eq!(qos.len(), 2);
        assert_eq!(qos[0].served, 2);
        assert_eq!(qos[0].slo_met, 2);
        assert!((qos[0].slo_attainment() - 1.0).abs() < 1e-12);
        assert_eq!(qos[1].slo_met, 0, "a one-cycle SLO is unmeetable");
        assert_eq!(qos[1].slo_attainment(), 0.0);
        for q in &qos {
            assert!(q.mean_slowdown_milli >= 1000, "turnaround >= service");
            assert!(q.mean_queue_delay >= 0.0);
            assert!(q.p95_queue_delay as f64 >= q.mean_queue_delay.floor() - f64::EPSILON || q.served <= 1);
        }
    }

    #[test]
    fn mean_slowdown_rounds_to_nearest_milli() {
        // True mean 1000.5 milli: truncating division reported 1000
        // (indistinguishable from an unqueued tenant); nearest-rank
        // rounding keeps the half-milli of real queueing visible.
        assert_eq!(rounded_mean_u64([1000, 1001].into_iter()), 1001);
        // Below the half-way point the mean still rounds down.
        assert_eq!(rounded_mean_u64([1000, 1000, 1001].into_iter()), 1000);
        // And above it, up: mean 1250.75 -> 1251.
        assert_eq!(rounded_mean_u64([1000, 1001, 1001, 2001].into_iter()), 1251);
        // Exact means are untouched, and an unserved tenant stays 0.
        assert_eq!(rounded_mean_u64([3000, 1000].into_iter()), 2000);
        assert_eq!(rounded_mean_u64(std::iter::empty()), 0);
    }

    #[test]
    fn qos_objective_weights_priority_and_latency() {
        let hi_good = TenantQos {
            tenant: 0,
            priority: Priority::High,
            slo_turnaround: Some(1000),
            served: 4,
            slo_met: 4,
            mean_queue_delay: 0.0,
            p95_queue_delay: 0,
            mean_slowdown_milli: 1000,
        };
        let lo_bad = TenantQos {
            tenant: 1,
            priority: Priority::Low,
            slo_turnaround: Some(1000),
            served: 4,
            slo_met: 0,
            mean_queue_delay: 500.0,
            p95_queue_delay: 900,
            mean_slowdown_milli: 4000,
        };
        // Perfect service scores 1.0 at any weighting.
        assert!((qos_objective(&[hi_good.clone()], 0.5) - 1.0).abs() < 1e-12);
        // Pure latency weighting sees only the missed SLOs.
        assert_eq!(qos_objective(&[lo_bad.clone()], 1.0), 0.0);
        // Pure throughput weighting sees the 4x slowdown instead.
        assert!((qos_objective(&[lo_bad.clone()], 0.0) - 0.25).abs() < 1e-12);
        // The High tenant dominates the mix 4:1.
        let mixed = qos_objective(&[hi_good, lo_bad], 1.0);
        assert!((mixed - 0.8).abs() < 1e-12, "got {mixed}");
        // An unserved tenant scores zero no matter the weighting.
        let starved = TenantQos {
            tenant: 2,
            priority: Priority::Normal,
            slo_turnaround: None,
            served: 0,
            slo_met: 0,
            mean_queue_delay: 0.0,
            p95_queue_delay: 0,
            mean_slowdown_milli: 0,
        };
        assert_eq!(qos_objective(&[starved], 0.5), 0.0);
    }

    #[test]
    fn choose_policy_is_deterministic() {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let tenants =
            vec![(bench("CP").unwrap(), Scheme::Baseline), (bench("BFS").unwrap(), Scheme::Baseline)];
        let mut streams = traffic_trace(&tenants, 2, 0, 13);
        shrink_streams(&mut streams, 4, 40);
        let (best, scored) = choose_policy(&cfg, &streams, 0.5).unwrap();
        assert_eq!(scored.len(), 2);
        assert!(scored.iter().any(|&(p, _)| p == best));
        assert!(scored.iter().all(|&(_, s)| (0.0..=1.0).contains(&s)));
        let (best2, scored2) = choose_policy(&cfg, &streams, 0.5).unwrap();
        assert_eq!(best, best2);
        assert_eq!(scored, scored2);
    }

    #[test]
    fn slowdown_math_on_real_runs() {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let tenants =
            vec![(bench("CP").unwrap(), Scheme::Baseline), (bench("BFS").unwrap(), Scheme::Baseline)];
        let mut streams = traffic_trace(&tenants, 2, 0, 11);
        shrink_streams(&mut streams, 4, 40);
        let shared = serve_streams(&cfg, &streams, PartitionPolicy::Static).unwrap();
        for ti in 0..streams.len() {
            let alone =
                serve_streams(&cfg, &alone_streams(&streams, ti), PartitionPolicy::Static).unwrap();
            let antt = antt_slowdown(&shared, &alone, ti);
            let slow = stream_slowdown(&shared, &alone, ti);
            // Sharing the chip can only slow a tenant down (it owns a
            // strict subset of the clusters it gets alone).
            assert!(antt >= 0.99, "tenant {ti}: antt {antt}");
            assert!(slow >= 0.99, "tenant {ti}: slowdown {slow}");
            assert!(antt.is_finite() && slow.is_finite());
        }
    }

    #[test]
    fn backoff_is_deterministic_and_monotonic() {
        let fo = FailoverConfig::default();
        for ti in 0..4 {
            for a in 1..6 {
                assert_eq!(backoff_delay(&fo, ti, a), backoff_delay(&fo, ti, a));
                // base*2^(a+1) > base*2^a + jitter (jitter < base), so the
                // backoff strictly grows with the attempt.
                assert!(backoff_delay(&fo, ti, a + 1) > backoff_delay(&fo, ti, a));
            }
        }
        // Different tenants jitter apart (desynchronised retry storms).
        assert_ne!(backoff_delay(&fo, 0, 1), backoff_delay(&fo, 1, 1));
        let other = FailoverConfig { backoff_seed: 0xBEEF, ..fo };
        assert_ne!(backoff_delay(&other, 0, 1), backoff_delay(&fo, 0, 1));
    }

    fn failover_streams() -> (SystemConfig, Vec<KernelStream>) {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 300_000;
        let tenants =
            vec![(bench("CP").unwrap(), Scheme::Baseline), (bench("BFS").unwrap(), Scheme::Baseline)];
        let mut streams = traffic_trace(&tenants, 2, 0, 17);
        shrink_streams(&mut streams, 4, 40);
        (cfg, streams)
    }

    #[test]
    fn healthy_chip_needs_no_retries() {
        let (cfg, streams) = failover_streams();
        let fo = FailoverConfig::default();
        let (shared, health) =
            serve_with_failover(&cfg, &streams, PartitionPolicy::Static, &fo, &FaultTrace::default())
                .unwrap();
        assert!(!shared.deadline_hit);
        for (ti, h) in health.iter().enumerate() {
            assert_eq!(h.attempts, 1, "tenant {ti} retried on a healthy chip");
            assert_eq!(h.failures, 0);
            assert!(!h.quarantined);
            assert_eq!(h.dropped, 0);
            assert_eq!(h.served as usize, streams[ti].launches.len());
        }
    }

    #[test]
    fn retry_serves_launches_the_faulted_run_dropped() {
        use crate::sim::fault::{FaultEvent, FaultKind};
        let (cfg, streams) = failover_streams();
        // Kill both clusters almost immediately: the shared run can serve
        // nothing and truncates at the deadline with every launch pending.
        let faults = FaultTrace::new(vec![
            FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 0 } },
            FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 1 } },
        ]);
        let fo = FailoverConfig::default();
        let (shared, health) =
            serve_with_failover(&cfg, &streams, PartitionPolicy::Static, &fo, &faults).unwrap();
        assert!(shared.deadline_hit, "dead chip must truncate the shared run");
        for (ti, h) in health.iter().enumerate() {
            assert!(h.attempts >= 2, "tenant {ti} must have retried");
            assert!(h.failures >= 1);
            assert!(!h.quarantined, "one failure is below the quarantine bar");
            assert_eq!(h.dropped, 0, "fault-free retry must serve everything");
            assert_eq!(h.served as usize, streams[ti].launches.len());
        }
        // Deterministic end to end.
        let again = serve_with_failover(&cfg, &streams, PartitionPolicy::Static, &fo, &faults).unwrap();
        assert_eq!(shared, again.0);
        assert_eq!(health, again.1);
    }

    #[test]
    fn migration_rescues_stranded_launches() {
        use crate::sim::fault::{FaultEvent, FaultKind};
        let (cfg, streams) = failover_streams();
        // Kill the whole chip early and grant no retry budget: every
        // unserved launch must be rescued by checkpoint migration —
        // captured pre-fault, faults stripped, finished on a restored
        // healthy machine.
        let faults = FaultTrace::new(vec![
            FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 0 } },
            FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 1 } },
        ]);
        let fo = FailoverConfig { max_retries: 0, quarantine_after: 2, ..FailoverConfig::default() };
        let (shared, health) =
            serve_with_failover(&cfg, &streams, PartitionPolicy::Static, &fo, &faults).unwrap();
        assert!(shared.deadline_hit, "dead chip must truncate the shared run");
        for (ti, h) in health.iter().enumerate() {
            assert!(!h.quarantined, "one failure stays below the quarantine bar");
            assert!(h.migrated, "tenant {ti} must have been migrated");
            assert_eq!(h.attempts, 2, "shared attempt + the migration");
            assert_eq!(h.dropped, 0, "migration must serve everything");
            assert_eq!(h.served as usize, streams[ti].launches.len());
        }
        // Deterministic end to end.
        let again = serve_with_failover(&cfg, &streams, PartitionPolicy::Static, &fo, &faults).unwrap();
        assert_eq!(shared, again.0);
        assert_eq!(health, again.1);
    }

    #[test]
    fn quarantined_tenant_is_never_migrated() {
        use crate::sim::fault::{FaultEvent, FaultKind};
        let (cfg, streams) = failover_streams();
        // Same dead chip as the migration test, but a one-strike
        // quarantine: the shared-run failure alone hits the bar, so the
        // `quarantine_after` contract ("no further retries, no migration,
        // remaining launches dropped") must hold — the migration block
        // may not run for a quarantined tenant.
        let faults = FaultTrace::new(vec![
            FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 0 } },
            FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 1 } },
        ]);
        let fo = FailoverConfig { max_retries: 0, quarantine_after: 1, ..FailoverConfig::default() };
        let (shared, health) =
            serve_with_failover(&cfg, &streams, PartitionPolicy::Static, &fo, &faults).unwrap();
        assert!(shared.deadline_hit);
        for (ti, h) in health.iter().enumerate() {
            assert!(h.quarantined, "tenant {ti} hit the one-strike bar");
            assert!(!h.migrated, "quarantine must cut off migration");
            assert_eq!(h.attempts, 1, "the shared run only — no retry, no migration");
            assert_eq!(h.served, 0);
            assert_eq!(h.dropped as usize, streams[ti].launches.len(), "drops stay honest");
        }
    }

    #[test]
    fn retry_stream_preserves_inter_arrival_spacing() {
        let (_, streams) = failover_streams();
        let stream = &streams[0];
        // Pending launches with distinct original arrivals 100/250/600.
        let pending: Vec<(usize, StreamLaunch)> = [100u64, 250, 600]
            .iter()
            .enumerate()
            .map(|(i, &arrival)| {
                (i, StreamLaunch { arrival, kernel: stream.launches[0].kernel.clone() })
            })
            .collect();
        let retry = retry_stream(stream, &pending, 5_000);
        let arrivals: Vec<u64> = retry.launches.iter().map(|l| l.arrival).collect();
        assert_eq!(
            arrivals,
            vec![5_000, 5_150, 5_500],
            "batch starts at the backoff delay and keeps the trace shape"
        );
        // The tenant identity rides along unchanged.
        assert_eq!(retry.name, stream.name);
        assert_eq!(retry.scheme, stream.scheme);
        // A single pending launch degenerates to the bare delay.
        let solo = retry_stream(stream, &pending[1..2], 7_777);
        assert_eq!(solo.launches[0].arrival, 7_777);
    }

    #[test]
    fn hopeless_tenant_is_quarantined() {
        let (mut cfg, streams) = failover_streams();
        // A deadline so tight nothing ever completes, faulted or not.
        cfg.max_cycles = 50;
        let fo = FailoverConfig { max_retries: 5, quarantine_after: 2, ..FailoverConfig::default() };
        let (shared, health) =
            serve_with_failover(&cfg, &streams, PartitionPolicy::Static, &fo, &FaultTrace::default())
                .unwrap();
        assert!(shared.deadline_hit);
        for h in &health {
            assert!(h.quarantined, "tenant {} should be quarantined", h.tenant);
            assert_eq!(h.failures, 2, "quarantine engages at exactly the bar");
            assert_eq!(h.attempts, 2, "shared attempt + one retry, then cut off");
            assert_eq!(h.served, 0);
            assert_eq!(h.dropped as usize, streams[h.tenant].launches.len());
        }
    }
}
