//! Server-simulation front-end: tenant specs, standard service traces,
//! and the ANTT-style slowdown math for the multi-tenant stream mode.
//!
//! This is the runtime layer the `amoeba serve-sim` subcommand and the
//! harness's server sweep share: it turns a human-readable tenant spec
//! (`"SM:hetero,BFS:warp_regrouping,CP:baseline"`) into a seeded
//! [`KernelStream`] trace, and computes per-tenant service metrics from
//! the resulting [`StreamReport`]s. Simulation itself stays in
//! [`crate::sim::gpu`]; scheduling policy stays in
//! [`crate::sim::gpu::PartitionPolicy`].

use crate::config::{Scheme, SystemConfig};
use crate::harness::StreamJob;
use crate::sim::gpu::{PartitionPolicy, StreamReport};
use crate::workload::{bench, BenchProfile, KernelStream};

/// Parse a tenant spec: comma-separated `BENCH[:SCHEME]` entries, e.g.
/// `"SM:hetero,BFS:warp_regrouping,CP"`. A missing scheme defaults to
/// `hetero` — per-cluster control is the server mode's reason to exist.
pub fn parse_tenant_spec(spec: &str) -> Result<Vec<(BenchProfile, Scheme)>, String> {
    let mut out = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (name, scheme) = match entry.split_once(':') {
            Some((n, s)) => (n.trim(), s.trim().parse::<Scheme>()?),
            None => (entry, Scheme::Hetero),
        };
        let profile =
            bench(name).ok_or_else(|| format!("unknown benchmark '{name}' in tenant spec"))?;
        out.push((profile, scheme));
    }
    if out.is_empty() {
        return Err("tenant spec names no tenants".into());
    }
    Ok(out)
}

/// The standard three-tenant mix the server sweep and `serve-sim` default
/// to: a cache-sharing scale-up winner under per-cluster control, a
/// divergent graph workload under warp regrouping, and a compute-dense
/// scale-out tenant — the divergent scalability profiles the paper argues
/// one fixed SM shape cannot serve at once.
pub fn default_tenants() -> Vec<(BenchProfile, Scheme)> {
    vec![
        (bench("SM").expect("SM profile"), Scheme::Hetero),
        (bench("BFS").expect("BFS profile"), Scheme::WarpRegroup),
        (bench("CP").expect("CP profile"), Scheme::Baseline),
    ]
}

/// ANTT-style slowdown of tenant `ti` in `shared` against its isolated
/// reference run `alone` (the same stream served alone, as tenant 0):
/// the mean over kernels of `shared turnaround / alone turnaround`.
/// 1.0 = no interference; launches the deadline truncated are skipped.
pub fn antt_slowdown(shared: &StreamReport, alone: &StreamReport, ti: usize) -> f64 {
    let shared_launches = shared.launches.iter().filter(|l| l.tenant == ti as u32);
    let alone_launches: Vec<_> =
        alone.launches.iter().filter(|l| l.tenant == 0).collect();
    let mut acc = 0.0;
    let mut n = 0u32;
    for (s, a) in shared_launches.zip(alone_launches) {
        if s.finish == u64::MAX || a.finish == u64::MAX {
            continue;
        }
        acc += s.turnaround() as f64 / a.turnaround().max(1) as f64;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        acc / n as f64
    }
}

/// Whole-stream slowdown: tenant completion cycle in the shared run over
/// its completion when served alone.
pub fn stream_slowdown(shared: &StreamReport, alone: &StreamReport, ti: usize) -> f64 {
    let a = alone.tenants[0].cycles;
    if a == 0 {
        0.0
    } else {
        shared.tenants[ti].cycles as f64 / a as f64
    }
}

/// The isolated-reference job for tenant `ti` of `streams`: the same
/// stream (same arrivals, same kernel seeds) served alone on the full
/// chip. Memoizes cleanly through the stream cache.
pub fn alone_streams(streams: &[KernelStream], ti: usize) -> Vec<KernelStream> {
    vec![streams[ti].clone()]
}

/// The canonical server job list every front-end submits: one shared run
/// per policy in `shared` (in order), then each tenant alone (the
/// interference-free reference, always `Static` — policy is moot for a
/// single tenant). Result indexing: `out[i]` is `shared[i]`'s run,
/// `out[shared.len() + ti]` is tenant `ti` alone.
pub fn server_jobs(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    shared: &[PartitionPolicy],
) -> Vec<StreamJob> {
    let mut jobs: Vec<StreamJob> = shared
        .iter()
        .map(|&p| StreamJob::new(cfg.clone(), streams.to_vec(), p))
        .collect();
    for ti in 0..streams.len() {
        jobs.push(StreamJob::new(cfg.clone(), alone_streams(streams, ti), PartitionPolicy::Static));
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::gpu::{serve_streams, PartitionPolicy};
    use crate::workload::{shrink_streams, traffic_trace};

    #[test]
    fn tenant_spec_parses_schemes_and_defaults() {
        let t = parse_tenant_spec("SM:hetero, BFS:warp_regrouping ,CP").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].0.name, "SM");
        assert_eq!(t[0].1, Scheme::Hetero);
        assert_eq!(t[1].1, Scheme::WarpRegroup);
        assert_eq!(t[2].1, Scheme::Hetero, "missing scheme defaults to hetero");
        assert!(parse_tenant_spec("NOPE:hetero").is_err());
        assert!(parse_tenant_spec("SM:bogus").is_err());
        assert!(parse_tenant_spec("  ,").is_err());
        assert_eq!(default_tenants().len(), 3);
    }

    #[test]
    fn slowdown_math_on_real_runs() {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let tenants =
            vec![(bench("CP").unwrap(), Scheme::Baseline), (bench("BFS").unwrap(), Scheme::Baseline)];
        let mut streams = traffic_trace(&tenants, 2, 0, 11);
        shrink_streams(&mut streams, 4, 40);
        let shared = serve_streams(&cfg, &streams, PartitionPolicy::Static);
        for ti in 0..streams.len() {
            let alone = serve_streams(&cfg, &alone_streams(&streams, ti), PartitionPolicy::Static);
            let antt = antt_slowdown(&shared, &alone, ti);
            let slow = stream_slowdown(&shared, &alone, ti);
            // Sharing the chip can only slow a tenant down (it owns a
            // strict subset of the clusters it gets alone).
            assert!(antt >= 0.99, "tenant {ti}: antt {antt}");
            assert!(slow >= 0.99, "tenant {ti}: slowdown {slow}");
            assert!(antt.is_finite() && slow.is_finite());
        }
    }
}
