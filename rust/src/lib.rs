//! # amoeba-gpu — AMOEBA paper reproduction
//!
//! A cycle-level GPU simulator plus the AMOEBA coarse-grained reconfigurable
//! SM architecture from *"AMOEBA: A Coarse Grained Reconfigurable
//! Architecture for Dynamic GPU Scaling"* (Cheng et al., cs.AR 2019).
//!
//! The crate is organised in three layers (see `DESIGN.md`):
//!
//! * **Substrates** — everything the paper's evaluation assumed from
//!   GPGPU-Sim, rebuilt here: SIMT cores ([`sim::core`]), the memory system
//!   ([`sim::mem`]), a mesh NoC ([`sim::noc`]), the top-level GPU
//!   ([`sim::gpu`]) and synthetic workload models ([`workload`]).
//! * **Contribution** — the AMOEBA reconfiguration machinery ([`amoeba`]):
//!   the online controller, scalability metrics, the binary-logistic
//!   predictor (native + PJRT-compiled HLO), SM fusion and the dynamic
//!   split/fuse policies; baselines (incl. DWS) live in [`baselines`].
//! * **Runtime & harness** — [`runtime`] wraps the `xla` PJRT client that
//!   executes the AOT-compiled predictor artifacts; [`harness`] regenerates
//!   every table and figure of the paper's evaluation section.
//!
//! ## Quickstart
//!
//! ```no_run
//! use amoeba_gpu::prelude::*;
//!
//! let cfg = SystemConfig::gtx480();
//! let bench = workload::bench("RAY").expect("known benchmark");
//! let report =
//!     sim::gpu::run_benchmark(&cfg, &bench, Scheme::WarpRegroup).expect("valid config");
//! println!("IPC = {:.2}", report.ipc());
//! ```

pub mod amoeba;
pub mod baselines;
pub mod config;
pub mod errors;
pub mod harness;
pub mod isa;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod workload;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::config::{NocMode, Scheme, SystemConfig};
    pub use crate::harness::{SimJob, StreamJob, SweepExec};
    pub use crate::sim::{
        self,
        gpu::{PartitionPolicy, SimReport, StreamReport},
    };
    pub use crate::workload::{self, BenchProfile, KernelStream};
}
