//! Simulation statistics: per-SM and machine-wide counters, and the derived
//! metrics every paper figure reports.

mod report;

pub use report::{fmt_row, Table};

/// Why an SM scheduler failed to issue in a cycle (stall breakdown).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StallReason {
    /// No resident warps at all (SM idle).
    Idle,
    /// All warps waiting on memory (scoreboard).
    Memory,
    /// All warps held by divergence-serialisation (control stall, Fig 6/13).
    Control,
    /// Warps waiting at a CTA barrier.
    Barrier,
    /// Execution unit busy (initiation interval not elapsed).
    ExecBusy,
    /// Downstream memory structure full (MSHR / miss queue / NoC inject).
    MemStructFull,
}

/// Every additive counter of [`SmStats`], in declaration order. A single
/// field list feeds both [`SmStats::absorb`] and [`SmStats::delta`], so a
/// newly added counter can never be summed by one and silently dropped by
/// the other (the multi-tenant stream engine attributes cluster counters
/// to tenants by ownership-period deltas and would miscount otherwise).
macro_rules! sm_counter_fields {
    ($apply:ident) => {
        $apply!(
            cycles, warp_insns, thread_insns, stall_idle, stall_memory, stall_control,
            stall_barrier, stall_exec, stall_mem_struct, inactive_lane_cycles,
            total_lane_cycles, branches, divergent_branches, mem_insns, st_insns, mem_requests,
            mem_transactions, l1d_accesses, l1d_misses, l1i_accesses, l1i_misses,
            l1c_accesses, l1c_misses, l1t_accesses, l1t_misses, mshr_merges, mshr_allocs,
            mem_struct_stall_cycles, noc_packets, noc_flits, noc_latency_sum,
            noc_latency_samples, ctas_retired, warps_retired, fused_cycles, split_cycles,
            fuse_events, split_events,
        );
    };
}

/// Counters for one SM (or one fused SM cluster half).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SmStats {
    /// Cycles this SM was powered (driven by the cycle loop).
    pub cycles: u64,
    /// Warp-instructions issued.
    pub warp_insns: u64,
    /// Thread-instructions committed (sum of active lanes over issues).
    pub thread_insns: u64,
    /// Issue-slot cycles lost, by reason.
    pub stall_idle: u64,
    pub stall_memory: u64,
    pub stall_control: u64,
    pub stall_barrier: u64,
    pub stall_exec: u64,
    pub stall_mem_struct: u64,
    /// Lane-cycles lost to inactive lanes during divergent execution
    /// (the paper's "inactive thread rate" numerator).
    pub inactive_lane_cycles: u64,
    /// Lane-cycles available (width x issue cycles).
    pub total_lane_cycles: u64,
    /// Branch instructions executed / those that diverged.
    pub branches: u64,
    pub divergent_branches: u64,
    /// Memory-instruction accounting before/after coalescing (Fig 4/16).
    pub mem_insns: u64,
    /// Store subset of `mem_insns` (the predictor's load/store split).
    pub st_insns: u64,
    pub mem_requests: u64,
    pub mem_transactions: u64,
    /// L1 data cache.
    pub l1d_accesses: u64,
    pub l1d_misses: u64,
    /// L1 instruction cache.
    pub l1i_accesses: u64,
    pub l1i_misses: u64,
    /// L1 constant cache.
    pub l1c_accesses: u64,
    pub l1c_misses: u64,
    /// L1 texture cache.
    pub l1t_accesses: u64,
    pub l1t_misses: u64,
    /// MSHR: misses merged into an in-flight entry / total miss attempts.
    pub mshr_merges: u64,
    pub mshr_allocs: u64,
    /// Cycles where an L1 miss could not proceed (MSHR full / inject full).
    pub mem_struct_stall_cycles: u64,
    /// NoC packets/flits injected by this SM and reply latency samples.
    pub noc_packets: u64,
    pub noc_flits: u64,
    pub noc_latency_sum: u64,
    pub noc_latency_samples: u64,
    /// CTAs and warps retired.
    pub ctas_retired: u64,
    pub warps_retired: u64,
    /// Cycles spent fused / split (for Fig 19-style accounting).
    pub fused_cycles: u64,
    pub split_cycles: u64,
    /// Fuse/split transitions performed by the dynamic controller.
    pub fuse_events: u64,
    pub split_events: u64,
}

impl SmStats {
    /// Record an issue-slot stall.
    pub fn stall(&mut self, r: StallReason) {
        self.stall_n(r, 1);
    }

    /// Record `n` consecutive cycles of the same issue-slot stall (the
    /// event-horizon skip path replays a quiescent window in one call).
    pub fn stall_n(&mut self, r: StallReason, n: u64) {
        match r {
            StallReason::Idle => self.stall_idle += n,
            StallReason::Memory => self.stall_memory += n,
            StallReason::Control => self.stall_control += n,
            StallReason::Barrier => self.stall_barrier += n,
            StallReason::ExecBusy => self.stall_exec += n,
            StallReason::MemStructFull => self.stall_mem_struct += n,
        }
    }

    /// L1D miss rate in [0,1].
    pub fn l1d_miss_rate(&self) -> f64 {
        ratio(self.l1d_misses, self.l1d_accesses)
    }

    /// L1I miss rate in [0,1].
    pub fn l1i_miss_rate(&self) -> f64 {
        ratio(self.l1i_misses, self.l1i_accesses)
    }

    /// L1C miss rate in [0,1].
    pub fn l1c_miss_rate(&self) -> f64 {
        ratio(self.l1c_misses, self.l1c_accesses)
    }

    /// Actual-memory-access rate after coalescing (Fig 4/16): transactions
    /// issued to the memory system / lane-level requests in instructions.
    pub fn actual_access_rate(&self) -> f64 {
        ratio(self.mem_transactions, self.mem_requests)
    }

    /// MSHR merge rate: merged misses / all missing accesses.
    pub fn mshr_rate(&self) -> f64 {
        ratio(self.mshr_merges, self.mshr_merges + self.mshr_allocs)
    }

    /// Inactive-thread rate from control divergence (§4.1.2 metric 6).
    pub fn inactive_thread_rate(&self) -> f64 {
        ratio(self.inactive_lane_cycles, self.total_lane_cycles)
    }

    /// Control-stall rate (Fig 6/13): issue cycles lost to divergence
    /// serialisation over total cycles.
    pub fn control_stall_rate(&self) -> f64 {
        ratio(self.stall_control, self.cycles)
    }

    /// Mean NoC round-trip latency observed by this SM's requests.
    pub fn avg_noc_latency(&self) -> f64 {
        ratio(self.noc_latency_sum, self.noc_latency_samples)
    }

    /// Merge another SM's counters into this one (suite aggregation).
    pub fn absorb(&mut self, o: &SmStats) {
        macro_rules! add {
            ($($f:ident),+ $(,)?) => { $( self.$f += o.$f; )+ };
        }
        sm_counter_fields!(add);
    }

    /// Counter-wise difference `self - base` (saturating): the counters
    /// accumulated since `base` was snapshotted. The stream engine uses
    /// this to attribute a cluster's activity to the tenant that owned it
    /// over a period; `delta` then `absorb` over disjoint periods
    /// reconstructs the total exactly.
    pub fn delta(&self, base: &SmStats) -> SmStats {
        let mut d = SmStats::default();
        macro_rules! sub {
            ($($f:ident),+ $(,)?) => { $( d.$f = self.$f.saturating_sub(base.$f); )+ };
        }
        sm_counter_fields!(sub);
        d
    }

    /// Serialize every counter in declaration order (checkpoint format).
    /// Driven by the same field list as `absorb`/`delta`, so a new
    /// counter can never be summed but silently dropped from snapshots.
    pub fn write_to(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        macro_rules! emit {
            ($($f:ident),+ $(,)?) => { $( w.u64(self.$f); )+ };
        }
        sm_counter_fields!(emit);
    }

    /// Inverse of [`SmStats::write_to`].
    pub fn read_from(
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<SmStats> {
        let mut s = SmStats::default();
        macro_rules! load {
            ($($f:ident),+ $(,)?) => { $( s.$f = r.u64()?; )+ };
        }
        sm_counter_fields!(load);
        Ok(s)
    }
}

/// Machine-wide counters outside the SMs.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChipStats {
    /// Total GPU cycles simulated.
    pub cycles: u64,
    /// L2 accesses/misses summed over slices.
    pub l2_accesses: u64,
    pub l2_misses: u64,
    /// DRAM reads/writes and row hit/miss counts.
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub dram_row_hits: u64,
    pub dram_row_misses: u64,
    /// Cycles an MC had a reply ready but its injection queue was full
    /// (Fig 17's "ICNT-to-shader" stall).
    pub mc_inject_stall_cycles: u64,
    /// Cycles an MC was enabled (denominator for the stall rate).
    pub mc_cycles: u64,
    /// Total flits traversing the NoC (both subnets).
    pub noc_flits_routed: u64,
    /// Kernel launches completed.
    pub kernels_completed: u64,
    /// Reconfigurations performed (static fuse decisions).
    pub reconfig_events: u64,
    /// Cycles paid for reconfiguration drains.
    pub reconfig_cycles: u64,
    /// Scale-up decisions taken by the predictor (per kernel, or per
    /// cluster per kernel under the heterogeneous scheme).
    pub predictor_scale_up: u64,
    pub predictor_scale_out: u64,
    /// Times the predictor backend failed and a default probability was
    /// substituted (see `ScalePredictor::fallback_count`); nonzero means
    /// decisions were NOT measured by the configured backend.
    pub predictor_fallbacks: u64,
    /// Fault events applied from the run's `FaultTrace` (0 in healthy runs).
    pub faults_injected: u64,
    /// Clusters permanently retired by whole-cluster (or intolerable
    /// half-SM) faults.
    pub clusters_retired: u64,
    /// CTAs handed to a cluster by the dispatch path (conservation
    /// invariant: `ctas_dispatched == sm.ctas_retired + ctas_requeued` on
    /// completed runs).
    pub ctas_dispatched: u64,
    /// In-flight CTAs pulled back from a failing cluster and redispatched.
    pub ctas_requeued: u64,
    /// CTA-boundary preemptions: times a higher-priority tenant took a
    /// cluster from a lower-priority one at a launch boundary.
    pub preemptions: u64,
    /// In-flight CTAs bounced off a preempted cluster (a subset of
    /// `ctas_requeued`; the conservation invariant is unchanged).
    pub ctas_preempted: u64,
}

/// Every counter of [`ChipStats`], in declaration order — feeds the
/// checkpoint serializer the same way `sm_counter_fields!` feeds the
/// [`SmStats`] one (exhaustive destructuring makes a newly added field a
/// compile error until it is serialized too).
macro_rules! chip_counter_fields {
    ($apply:ident) => {
        $apply!(
            cycles, l2_accesses, l2_misses, dram_reads, dram_writes, dram_row_hits,
            dram_row_misses, mc_inject_stall_cycles, mc_cycles, noc_flits_routed,
            kernels_completed, reconfig_events, reconfig_cycles, predictor_scale_up,
            predictor_scale_out, predictor_fallbacks, faults_injected, clusters_retired,
            ctas_dispatched, ctas_requeued, preemptions, ctas_preempted,
        );
    };
}

impl ChipStats {
    /// Normalised MC injection stall rate (Fig 17).
    pub fn mc_inject_stall_rate(&self) -> f64 {
        ratio(self.mc_inject_stall_cycles, self.mc_cycles)
    }

    /// L2 miss rate.
    pub fn l2_miss_rate(&self) -> f64 {
        ratio(self.l2_misses, self.l2_accesses)
    }

    /// DRAM row-hit rate (FR-FCFS effectiveness).
    pub fn dram_row_hit_rate(&self) -> f64 {
        ratio(self.dram_row_hits, self.dram_row_hits + self.dram_row_misses)
    }

    /// Serialize every counter in declaration order (checkpoint format).
    pub fn write_to(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        macro_rules! emit {
            ($($f:ident),+ $(,)?) => {
                // Exhaustive destructuring: adding a ChipStats field
                // without extending chip_counter_fields! fails to build.
                let ChipStats { $($f),+ } = *self;
                $( w.u64($f); )+
            };
        }
        chip_counter_fields!(emit);
    }

    /// Inverse of [`ChipStats::write_to`].
    pub fn read_from(
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<ChipStats> {
        let mut s = ChipStats::default();
        macro_rules! load {
            ($($f:ident),+ $(,)?) => { $( s.$f = r.u64()?; )+ };
        }
        chip_counter_fields!(load);
        Ok(s)
    }
}

/// Safe ratio helper: 0 when the denominator is 0.
pub fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

/// Geometric mean of positive values (paper reports geomean IPC speedups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_handle_zero_denominator() {
        assert_eq!(ratio(5, 0), 0.0);
        assert_eq!(ratio(1, 2), 0.5);
        let s = SmStats::default();
        assert_eq!(s.l1d_miss_rate(), 0.0);
        assert_eq!(s.mshr_rate(), 0.0);
    }

    #[test]
    fn stall_breakdown_routes() {
        let mut s = SmStats::default();
        s.stall(StallReason::Memory);
        s.stall(StallReason::Memory);
        s.stall(StallReason::Control);
        assert_eq!(s.stall_memory, 2);
        assert_eq!(s.stall_control, 1);
        assert_eq!(s.stall_idle, 0);
    }

    #[test]
    fn absorb_sums_everything() {
        let mut a = SmStats { warp_insns: 10, l1d_misses: 3, ..Default::default() };
        let b = SmStats { warp_insns: 5, l1d_misses: 2, fused_cycles: 7, ..Default::default() };
        a.absorb(&b);
        assert_eq!(a.warp_insns, 15);
        assert_eq!(a.l1d_misses, 5);
        assert_eq!(a.fused_cycles, 7);
    }

    #[test]
    fn delta_inverts_absorb_per_field() {
        let base = SmStats { warp_insns: 10, l1d_misses: 3, cycles: 100, ..Default::default() };
        let mut cur = base.clone();
        let gained =
            SmStats { warp_insns: 7, l1d_misses: 2, cycles: 50, st_insns: 4, ..Default::default() };
        cur.absorb(&gained);
        assert_eq!(cur.delta(&base), gained, "delta(base) recovers exactly what was absorbed");
        // Splitting a run into two ownership periods loses nothing.
        let mid = cur.clone();
        let mut cur2 = cur.clone();
        cur2.absorb(&gained);
        let mut acc = mid.delta(&base);
        acc.absorb(&cur2.delta(&mid));
        assert_eq!(acc, cur2.delta(&base));
    }

    #[test]
    fn stats_serializers_round_trip() {
        let mut s = SmStats::default();
        s.cycles = 123;
        s.warp_insns = 456;
        s.split_events = u64::MAX;
        let mut w = crate::sim::snapshot::ByteWriter::new();
        s.write_to(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::sim::snapshot::ByteReader::new(&bytes);
        assert_eq!(SmStats::read_from(&mut r).unwrap(), s);
        r.expect_end().unwrap();

        let mut c = ChipStats::default();
        c.cycles = 9;
        c.ctas_preempted = 77;
        let mut w = crate::sim::snapshot::ByteWriter::new();
        c.write_to(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::sim::snapshot::ByteReader::new(&bytes);
        assert_eq!(ChipStats::read_from(&mut r).unwrap(), c);
        r.expect_end().unwrap();
    }

    #[test]
    fn geomean_matches_hand_math() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        let g = geomean(&[2.0, 2.0, 2.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn derived_rates() {
        let s = SmStats {
            mem_requests: 100,
            mem_transactions: 25,
            inactive_lane_cycles: 10,
            total_lane_cycles: 40,
            cycles: 50,
            stall_control: 5,
            ..Default::default()
        };
        assert!((s.actual_access_rate() - 0.25).abs() < 1e-12);
        assert!((s.inactive_thread_rate() - 0.25).abs() < 1e-12);
        assert!((s.control_stall_rate() - 0.1).abs() < 1e-12);
    }
}
