//! Tiny table/TSV formatting used by the figure harness and CLI output.

/// A labelled table of f64 series, printed as aligned text or TSV.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table title (figure id, e.g. "Fig 12").
    pub title: String,
    /// Column headers (first column is the row label).
    pub columns: Vec<String>,
    /// Rows: (label, values aligned with `columns[1..]`).
    pub rows: Vec<(String, Vec<f64>)>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) -> &mut Self {
        self.rows.push((label.into(), values));
        self
    }

    /// Column-wise arithmetic mean over rows.
    pub fn mean_row(&self) -> Vec<f64> {
        if self.rows.is_empty() {
            return Vec::new();
        }
        let ncols = self.rows[0].1.len();
        let mut sums = vec![0.0; ncols];
        for (_, vals) in &self.rows {
            for (s, v) in sums.iter_mut().zip(vals) {
                *s += v;
            }
        }
        sums.iter().map(|s| s / self.rows.len() as f64).collect()
    }

    /// Column-wise geometric mean over rows.
    pub fn geomean_row(&self) -> Vec<f64> {
        if self.rows.is_empty() {
            return Vec::new();
        }
        let ncols = self.rows[0].1.len();
        (0..ncols)
            .map(|c| {
                let col: Vec<f64> = self.rows.iter().map(|(_, v)| v[c]).collect();
                super::geomean(&col)
            })
            .collect()
    }

    /// Render as aligned human-readable text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for (label, vals) in &self.rows {
            widths[0] = widths[0].max(label.len());
            for (i, v) in vals.iter().enumerate() {
                widths[i + 1] = widths.get(i + 1).copied().unwrap_or(8).max(fmt_row(*v).len());
            }
        }
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect();
        out.push_str(&header.join("  "));
        out.push('\n');
        for (label, vals) in &self.rows {
            let mut cells = vec![format!("{:>w$}", label, w = widths[0])];
            for (i, v) in vals.iter().enumerate() {
                cells.push(format!("{:>w$}", fmt_row(*v), w = widths[i + 1]));
            }
            out.push_str(&cells.join("  "));
            out.push('\n');
        }
        out
    }

    /// Render as TSV (machine-readable; one header line).
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.columns.join("\t"));
        out.push('\n');
        for (label, vals) in &self.rows {
            out.push_str(label);
            for v in vals {
                out.push('\t');
                out.push_str(&fmt_row(*v));
            }
            out.push('\n');
        }
        out
    }
}

/// Compact numeric formatting for table cells.
pub fn fmt_row(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("Fig X", &["bench", "a", "b"]);
        t.row("SM", vec![4.25, 1.0]).row("MUM", vec![2.11, 2.0]);
        let txt = t.render();
        assert!(txt.contains("Fig X"));
        assert!(txt.contains("SM"));
        assert!(txt.contains("4.250"));
        let tsv = t.to_tsv();
        assert_eq!(tsv.lines().count(), 3);
        assert!(tsv.starts_with("bench\ta\tb"));
    }

    #[test]
    fn mean_and_geomean_rows() {
        let mut t = Table::new("t", &["r", "x"]);
        t.row("a", vec![1.0]).row("b", vec![4.0]);
        assert!((t.mean_row()[0] - 2.5).abs() < 1e-12);
        assert!((t.geomean_row()[0] - 2.0).abs() < 1e-12);
        assert!(Table::new("e", &["r"]).mean_row().is_empty());
    }

    #[test]
    fn fmt_row_ranges() {
        assert_eq!(fmt_row(0.0), "0");
        assert_eq!(fmt_row(0.4567), "0.457");
        assert_eq!(fmt_row(47.12), "47.1");
        assert_eq!(fmt_row(4700.0), "4700");
    }
}
