//! Parallel sweep executor with a memoized simulation cache.
//!
//! Regenerating the paper's figures means sweeping the benchmark suite
//! across every scheme and many machine configurations — and several
//! figures consume the *same* deterministic simulation (e.g. the
//! `Baseline` run of each benchmark feeds Figs 12–18). [`SweepExec`]
//! makes that cheap twice over:
//!
//! 1. **Memoization** — every result is cached under a
//!    [`JobKey`] = (bench, scheme, config fingerprint, profile
//!    fingerprint, seed, fault-trace fingerprint), so each unique
//!    simulation runs exactly once per process no matter how many figures
//!    ask for it.
//! 2. **Parallel fan-out** — batches spread across `std::thread::scope`
//!    workers (no external crates; the vendored registry is offline).
//!    Work distribution is a single atomic cursor over the job list —
//!    work-stealing-free and therefore trivially deadlock-free.
//!
//! Determinism: simulations are pure functions of `(cfg, profile,
//! scheme, seed)` (the simulator has no global state and every random
//! choice flows through the seeded PCG32), so the parallel path is
//! bit-identical to serial execution — asserted by
//! `tests/exec_determinism.rs`.
//!
//! Thread count: `AMOEBA_JOBS` env var, else the machine's available
//! parallelism. `SweepExec::new(1)` degrades to a purely serial,
//! still-memoized executor.
//!
//! **Disk spill**: [`SweepExec::from_env`] executors additionally spill
//! every report to `target/amoeba-memo/` (override with
//! `AMOEBA_MEMO_DIR`; `0`/`off`/empty disables) and consult it on
//! in-memory misses, so repeated CLI invocations skip re-simulating.
//! Spill files carry a format-version header plus a full key echo;
//! corrupt, truncated, or stale files are ignored — and overwritten —
//! never panicked on. Explicitly sized executors (`new`, `serial`, and
//! therefore every test) keep the disk memo off.
//!
//! Execution mode: simulations run with event-horizon cycle skipping
//! unless `AMOEBA_DENSE=1` forces the dense reference loop. The mode is
//! deliberately **not** part of [`JobKey`] — skip and dense runs are
//! bit-identical by contract (`tests/exec_determinism.rs`), so a cached
//! report is valid under either mode and the fingerprints stay
//! mode-agnostic.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::{Scheme, SystemConfig};
use crate::errors::err;
use crate::sim::fault::FaultTrace;
use crate::sim::gpu::{run_benchmark_faulted, PartitionPolicy, SimReport, StreamReport};
use crate::sim::snapshot::{ByteReader, ByteWriter};
use crate::workload::{BenchProfile, KernelStream};

/// FNV-1a over a string — the fingerprint primitive. Configs and
/// profiles are hashed through their `Debug` rendering so that *every*
/// field participates automatically (a newly added knob can never be
/// silently left out of the cache key).
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Stable fingerprint of a full system configuration. The execution mode
/// (event-horizon vs `AMOEBA_DENSE`) is intentionally outside the
/// fingerprint: both modes produce bit-identical reports, so including
/// it would only split the cache.
pub fn cfg_fingerprint(cfg: &SystemConfig) -> u64 {
    fnv1a(&format!("{cfg:?}"))
}

/// Stable fingerprint of a (possibly shrunken) workload profile.
pub fn profile_fingerprint(p: &BenchProfile) -> u64 {
    fnv1a(&format!("{p:?}"))
}

/// Stable fingerprint of a fault trace. An empty trace hashes to the same
/// value everywhere, so fault-free jobs share cache entries with the
/// historical key space; any injected event perturbs the fingerprint and
/// forces a fresh simulation.
pub fn fault_fingerprint(t: &FaultTrace) -> u64 {
    fnv1a(&format!("{t:?}"))
}

/// Memoization key of one simulation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct JobKey {
    /// Benchmark name (human-readable anchor; the fingerprints do the
    /// heavy lifting).
    pub bench: &'static str,
    /// Scheme simulated.
    pub scheme: Scheme,
    /// [`cfg_fingerprint`] of the machine configuration.
    pub cfg_fp: u64,
    /// [`profile_fingerprint`] of the workload (quick-mode shrinking
    /// yields a different profile, hence a different key).
    pub profile_fp: u64,
    /// Workload seed.
    pub seed: u64,
    /// [`fault_fingerprint`] of the injected fault trace (the empty-trace
    /// fingerprint for ordinary fault-free jobs).
    pub fault_fp: u64,
}

/// One simulation request: everything `run_benchmark_faulted` needs.
#[derive(Debug, Clone)]
pub struct SimJob {
    /// Machine configuration.
    pub cfg: SystemConfig,
    /// Workload profile (already shrunken for quick mode, if desired).
    pub profile: BenchProfile,
    /// Scheme to simulate.
    pub scheme: Scheme,
    /// Workload seed.
    pub seed: u64,
    /// Deterministic fault trace injected during the run (empty = healthy).
    pub fault: FaultTrace,
}

impl SimJob {
    /// Bundle a fault-free job.
    pub fn new(cfg: SystemConfig, profile: BenchProfile, scheme: Scheme, seed: u64) -> Self {
        SimJob { cfg, profile, scheme, seed, fault: FaultTrace::default() }
    }

    /// Attach a fault trace to the job (builder style).
    pub fn with_fault(mut self, fault: FaultTrace) -> Self {
        self.fault = fault;
        self
    }

    /// The job's memoization key.
    pub fn key(&self) -> JobKey {
        JobKey {
            bench: self.profile.name,
            scheme: self.scheme,
            cfg_fp: cfg_fingerprint(&self.cfg),
            profile_fp: profile_fingerprint(&self.profile),
            seed: self.seed,
            fault_fp: fault_fingerprint(&self.fault),
        }
    }

    fn simulate(&self) -> SimReport {
        run_benchmark_faulted(&self.cfg, &self.profile, self.scheme, self.seed, &self.fault)
            .expect("sweep job must carry a valid config and fault trace")
    }
}

/// Memoization key of one multi-tenant stream simulation: the config
/// fingerprint plus a fingerprint over the full trace (stream names,
/// profiles, schemes, arrivals, kernel seeds — everything is in the
/// `Debug` rendering) and the partition policy. Like [`JobKey`], the
/// execution mode is deliberately outside the key: dense and skip stream
/// runs are bit-identical by contract.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StreamKey {
    /// [`cfg_fingerprint`] of the machine configuration.
    pub cfg_fp: u64,
    /// FNV-1a over the `Debug` rendering of the whole stream set.
    pub trace_fp: u64,
    /// Cluster-partitioning policy.
    pub policy: PartitionPolicy,
    /// [`fault_fingerprint`] of the injected fault trace.
    pub fault_fp: u64,
}

/// One stream-sweep request: a full multi-tenant trace on one machine.
#[derive(Debug, Clone)]
pub struct StreamJob {
    /// Machine configuration.
    pub cfg: SystemConfig,
    /// One kernel stream per tenant (arrivals and kernel seeds inside).
    pub streams: Vec<KernelStream>,
    /// Cluster-partitioning policy.
    pub policy: PartitionPolicy,
    /// Deterministic fault trace injected during the run (empty = healthy).
    pub fault: FaultTrace,
}

impl StreamJob {
    /// Bundle a fault-free stream job.
    pub fn new(cfg: SystemConfig, streams: Vec<KernelStream>, policy: PartitionPolicy) -> Self {
        StreamJob { cfg, streams, policy, fault: FaultTrace::default() }
    }

    /// Attach a fault trace to the job (builder style).
    pub fn with_fault(mut self, fault: FaultTrace) -> Self {
        self.fault = fault;
        self
    }

    /// The job's memoization key.
    pub fn key(&self) -> StreamKey {
        StreamKey {
            cfg_fp: cfg_fingerprint(&self.cfg),
            trace_fp: fnv1a(&format!("{:?}", self.streams)),
            policy: self.policy,
            fault_fp: fault_fingerprint(&self.fault),
        }
    }

    fn simulate(&self) -> StreamReport {
        crate::sim::gpu::serve_streams_faulted(&self.cfg, &self.streams, self.policy, &self.fault)
            .expect("stream job must carry a valid config, streams and fault trace")
    }
}

// ---------------------------------------------------------------------------
// Disk-persistent memo spill
// ---------------------------------------------------------------------------

/// Magic header of a spilled report file.
const MEMO_MAGIC: &[u8; 4] = b"AMRM";
/// Memo format version. Bump on ANY change to the report byte layout —
/// readers silently ignore (and overwrite) files from other versions.
const MEMO_VERSION: u32 = 1;
/// Default spill directory, relative to the working directory.
const MEMO_DEFAULT_DIR: &str = "target/amoeba-memo";

/// Spill-file path of one memoized report: the key collapses to an
/// FNV-1a of its `Debug` rendering (every field participates), the echo
/// inside the file guards against collisions and staleness.
fn memo_path(dir: &Path, kind: &str, key_debug: &str) -> PathBuf {
    dir.join(format!("{kind}-{:016x}.bin", fnv1a(key_debug)))
}

/// Best-effort spill: serialize under a tmp name, then rename into
/// place (readers never see a half-written file). IO failures are
/// swallowed — the disk memo is an accelerator, never a correctness
/// dependency.
fn memo_store(dir: &Path, kind: &str, key_debug: &str, bytes: Vec<u8>) {
    let path = memo_path(dir, kind, key_debug);
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    if std::fs::create_dir_all(dir).is_ok() && std::fs::write(&tmp, bytes).is_ok() {
        let _ = std::fs::rename(&tmp, &path);
    }
}

/// Serialize one [`SimReport`] spill file: magic, version, kind tag, the
/// full key echo, then the report bytes.
fn sim_memo_bytes(key: &JobKey, rep: &SimReport) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.raw(MEMO_MAGIC);
    w.u32(MEMO_VERSION);
    w.u8(0);
    w.str(key.bench);
    w.str(&key.scheme.to_string());
    w.u64(key.cfg_fp);
    w.u64(key.profile_fp);
    w.u64(key.seed);
    w.u64(key.fault_fp);
    rep.write_to(&mut w);
    w.into_bytes()
}

/// Parse a [`SimReport`] spill file against the key that looked it up.
/// Truncated, corrupt, wrong-version, or stale-key bytes are an error —
/// never a panic — and the caller treats any error as a plain miss.
pub fn parse_sim_memo(bytes: &[u8], key: &JobKey) -> crate::errors::Result<SimReport> {
    let mut r = ByteReader::new(bytes);
    if r.take(4)? != MEMO_MAGIC {
        return Err(err("memo: bad magic"));
    }
    if r.u32()? != MEMO_VERSION {
        return Err(err("memo: format version mismatch"));
    }
    if r.u8()? != 0 {
        return Err(err("memo: not a sim-report file"));
    }
    if r.str()? != key.bench
        || r.str()? != key.scheme.to_string()
        || r.u64()? != key.cfg_fp
        || r.u64()? != key.profile_fp
        || r.u64()? != key.seed
        || r.u64()? != key.fault_fp
    {
        return Err(err("memo: stale key echo"));
    }
    let rep = SimReport::read_from(&mut r)?;
    r.expect_end()?;
    Ok(rep)
}

/// Serialize one [`StreamReport`] spill file (kind tag 1).
fn stream_memo_bytes(key: &StreamKey, rep: &StreamReport) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.raw(MEMO_MAGIC);
    w.u32(MEMO_VERSION);
    w.u8(1);
    w.u64(key.cfg_fp);
    w.u64(key.trace_fp);
    w.str(&key.policy.to_string());
    w.u64(key.fault_fp);
    rep.write_to(&mut w);
    w.into_bytes()
}

/// Parse a [`StreamReport`] spill file against its key; errors like
/// [`parse_sim_memo`].
pub fn parse_stream_memo(bytes: &[u8], key: &StreamKey) -> crate::errors::Result<StreamReport> {
    let mut r = ByteReader::new(bytes);
    if r.take(4)? != MEMO_MAGIC {
        return Err(err("memo: bad magic"));
    }
    if r.u32()? != MEMO_VERSION {
        return Err(err("memo: format version mismatch"));
    }
    if r.u8()? != 1 {
        return Err(err("memo: not a stream-report file"));
    }
    if r.u64()? != key.cfg_fp
        || r.u64()? != key.trace_fp
        || r.str()? != key.policy.to_string()
        || r.u64()? != key.fault_fp
    {
        return Err(err("memo: stale key echo"));
    }
    let rep = StreamReport::read_from(&mut r)?;
    r.expect_end()?;
    Ok(rep)
}

/// The parallel, memoizing sweep executor.
pub struct SweepExec {
    threads: usize,
    cache: Mutex<HashMap<JobKey, Arc<SimReport>>>,
    /// Separate memo for multi-tenant stream runs (the server sweep).
    stream_cache: Mutex<HashMap<StreamKey, Arc<StreamReport>>>,
    /// Spill directory for the cross-process disk memo (`None` = memory
    /// only, the default for explicitly sized executors and tests).
    disk_dir: Option<PathBuf>,
    hits: AtomicU64,
    misses: AtomicU64,
    disk_hits: AtomicU64,
}

impl SweepExec {
    /// Executor with an explicit worker count (clamped to >= 1). The
    /// disk memo is off; opt in with [`SweepExec::with_disk_memo`].
    pub fn new(threads: usize) -> Self {
        SweepExec {
            threads: threads.max(1),
            cache: Mutex::new(HashMap::new()),
            stream_cache: Mutex::new(HashMap::new()),
            disk_dir: None,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
        }
    }

    /// Spill every memoized report to `dir` and consult it on misses
    /// (builder style). Files carry a format-version header and a full
    /// key echo; anything corrupt, truncated, or stale is silently
    /// ignored and overwritten by a fresh simulation.
    pub fn with_disk_memo(mut self, dir: impl Into<PathBuf>) -> Self {
        self.disk_dir = Some(dir.into());
        self
    }

    /// Parse a worker-count env value, clamped to >= 1. `AMOEBA_JOBS=0`
    /// used to fall through to the machine-parallelism default — the
    /// opposite of what an explicit zero asks for; it now means "one
    /// worker", the smallest executor that exists. Unparsable values
    /// stay `None` (caller falls back). The simulator applies the same
    /// clamp to `AMOEBA_TICK_JOBS` (`crate::sim::gpu`); both knobs are
    /// execution policy and, like `AMOEBA_DENSE`, deliberately stay
    /// outside the sweep-memo keys ([`JobKey`]/[`StreamKey`] carry no
    /// thread counts), so cached reports are valid under any setting.
    pub(crate) fn parse_jobs(v: &str) -> Option<usize> {
        v.parse::<usize>().ok().map(|n| n.max(1))
    }

    /// Executor sized from the environment: `AMOEBA_JOBS` if set (an
    /// integer, clamped to >= 1), else the machine's available
    /// parallelism. The disk memo is ON, at `target/amoeba-memo` —
    /// `AMOEBA_MEMO_DIR` overrides the directory, and the values `0`,
    /// `off`, or the empty string disable spilling entirely.
    pub fn from_env() -> Self {
        let threads = std::env::var("AMOEBA_JOBS")
            .ok()
            .and_then(|v| Self::parse_jobs(&v))
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            });
        let exec = Self::new(threads);
        match std::env::var("AMOEBA_MEMO_DIR") {
            Ok(v) if v.is_empty() || v == "0" || v.eq_ignore_ascii_case("off") => exec,
            Ok(v) => exec.with_disk_memo(v),
            Err(_) => exec.with_disk_memo(MEMO_DEFAULT_DIR),
        }
    }

    /// A purely serial (but still memoizing) executor.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// (cache hits, unique simulations executed) so far. Disk-memo hits
    /// count toward `misses` (the in-memory cache missed) — see
    /// [`SweepExec::disk_hits`] for how many of those skipped the
    /// simulation.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// In-memory misses that were served from the disk memo instead of
    /// simulating.
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }

    /// Recall one sim report from the disk memo (any problem = miss).
    fn disk_load_sim(&self, key: &JobKey) -> Option<SimReport> {
        let dir = self.disk_dir.as_deref()?;
        let bytes = std::fs::read(memo_path(dir, "sim", &format!("{key:?}"))).ok()?;
        let rep = parse_sim_memo(&bytes, key).ok()?;
        self.disk_hits.fetch_add(1, Ordering::Relaxed);
        Some(rep)
    }

    /// Best-effort spill of one sim report to the disk memo.
    fn disk_store_sim(&self, key: &JobKey, rep: &SimReport) {
        if let Some(dir) = self.disk_dir.as_deref() {
            memo_store(dir, "sim", &format!("{key:?}"), sim_memo_bytes(key, rep));
        }
    }

    /// Recall one stream report from the disk memo (any problem = miss).
    fn disk_load_stream(&self, key: &StreamKey) -> Option<StreamReport> {
        let dir = self.disk_dir.as_deref()?;
        let bytes = std::fs::read(memo_path(dir, "stream", &format!("{key:?}"))).ok()?;
        let rep = parse_stream_memo(&bytes, key).ok()?;
        self.disk_hits.fetch_add(1, Ordering::Relaxed);
        Some(rep)
    }

    /// Best-effort spill of one stream report to the disk memo.
    fn disk_store_stream(&self, key: &StreamKey, rep: &StreamReport) {
        if let Some(dir) = self.disk_dir.as_deref() {
            memo_store(dir, "stream", &format!("{key:?}"), stream_memo_bytes(key, rep));
        }
    }

    /// Number of memoized reports currently held.
    pub fn cached_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Drop all memoized reports (counters are kept).
    pub fn clear(&self) {
        self.cache.lock().unwrap().clear();
        self.stream_cache.lock().unwrap().clear();
    }

    /// Run (or recall) a single simulation.
    pub fn run(
        &self,
        cfg: &SystemConfig,
        profile: &BenchProfile,
        scheme: Scheme,
        seed: u64,
    ) -> Arc<SimReport> {
        let job = SimJob::new(cfg.clone(), profile.clone(), scheme, seed);
        let key = job.key();
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(hit);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let report = match self.disk_load_sim(&key) {
            Some(rep) => Arc::new(rep),
            None => {
                let rep = Arc::new(job.simulate());
                self.disk_store_sim(&key, &rep);
                rep
            }
        };
        self.cache.lock().unwrap().insert(key, Arc::clone(&report));
        report
    }

    /// Run a batch of jobs, fanning uncached ones across the worker
    /// threads. Returns one report per input job, **in input order**;
    /// duplicate and previously-cached jobs are simulated exactly once.
    pub fn run_batch(&self, jobs: Vec<SimJob>) -> Vec<Arc<SimReport>> {
        let keys: Vec<JobKey> = jobs.iter().map(|j| j.key()).collect();

        // Partition into cached / to-run under one short lock.
        let mut todo: Vec<(JobKey, SimJob)> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            let mut queued: HashSet<JobKey> = HashSet::new();
            for (job, key) in jobs.into_iter().zip(keys.iter()) {
                if cache.contains_key(key) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                } else if queued.insert(key.clone()) {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    todo.push((key.clone(), job));
                } else {
                    // Duplicate within this batch: first occurrence runs it.
                    self.hits.fetch_add(1, Ordering::Relaxed);
                }
            }
        }

        // Disk memo first (outside any lock): spilled reports from a
        // previous process satisfy misses without simulating.
        if self.disk_dir.is_some() {
            let mut still = Vec::with_capacity(todo.len());
            let mut loaded: Vec<(JobKey, Arc<SimReport>)> = Vec::new();
            for (key, job) in todo {
                match self.disk_load_sim(&key) {
                    Some(rep) => loaded.push((key, Arc::new(rep))),
                    None => still.push((key, job)),
                }
            }
            if !loaded.is_empty() {
                let mut cache = self.cache.lock().unwrap();
                for (k, r) in loaded {
                    cache.insert(k, r);
                }
            }
            todo = still;
        }

        if !todo.is_empty() {
            let results = self.execute(&todo);
            let mut cache = self.cache.lock().unwrap();
            for (i, report) in results {
                self.disk_store_sim(&todo[i].0, &report);
                cache.insert(todo[i].0.clone(), report);
            }
        }

        // Everything is cached now; answer in input order.
        let cache = self.cache.lock().unwrap();
        keys.iter()
            .map(|k| Arc::clone(cache.get(k).expect("job simulated above")))
            .collect()
    }

    /// Simulate `todo` on up to `self.threads` scoped workers. Jobs are
    /// claimed through one atomic cursor; each worker returns its
    /// `(index, report)` pairs and the caller reassembles them.
    fn execute(&self, todo: &[(JobKey, SimJob)]) -> Vec<(usize, Arc<SimReport>)> {
        self.execute_with(todo.len(), |i| Arc::new(todo[i].1.simulate()))
    }

    /// The generic fan-out primitive behind both batch paths: run `f`
    /// over indices `0..count` on up to `self.threads` scoped workers
    /// (atomic-cursor claiming, deadlock-free), returning `(index,
    /// result)` pairs in nondeterministic order — results are pure
    /// functions of the index, so assembly order never affects values.
    fn execute_with<R: Send>(
        &self,
        count: usize,
        f: impl Fn(usize) -> R + Sync,
    ) -> Vec<(usize, R)> {
        let workers = self.threads.min(count);
        if workers <= 1 {
            return (0..count).map(|i| (i, f(i))).collect();
        }
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(|| {
                        let mut local: Vec<(usize, R)> = Vec::new();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= count {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("sweep worker panicked"))
                .collect()
        })
    }

    /// Run (or recall) a single multi-tenant stream simulation.
    pub fn run_stream(&self, job: &StreamJob) -> Arc<StreamReport> {
        let key = job.key();
        if let Some(hit) = self.stream_cache.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(hit);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let report = match self.disk_load_stream(&key) {
            Some(rep) => Arc::new(rep),
            None => {
                let rep = Arc::new(job.simulate());
                self.disk_store_stream(&key, &rep);
                rep
            }
        };
        self.stream_cache.lock().unwrap().insert(key, Arc::clone(&report));
        report
    }

    /// Run a batch of stream jobs, fanning uncached ones across the
    /// worker threads. Returns one report per input job, in input order;
    /// duplicate and previously-cached jobs simulate exactly once (the
    /// server sweep replays the same trace under several policies and
    /// configs, so the memo pays the same way it does for figures).
    pub fn run_stream_batch(&self, jobs: Vec<StreamJob>) -> Vec<Arc<StreamReport>> {
        let keys: Vec<StreamKey> = jobs.iter().map(|j| j.key()).collect();
        let mut todo: Vec<(StreamKey, StreamJob)> = Vec::new();
        {
            let cache = self.stream_cache.lock().unwrap();
            let mut queued: HashSet<StreamKey> = HashSet::new();
            for (job, key) in jobs.into_iter().zip(keys.iter()) {
                if cache.contains_key(key) || !queued.insert(key.clone()) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    todo.push((key.clone(), job));
                }
            }
        }

        if self.disk_dir.is_some() {
            let mut still = Vec::with_capacity(todo.len());
            let mut loaded: Vec<(StreamKey, Arc<StreamReport>)> = Vec::new();
            for (key, job) in todo {
                match self.disk_load_stream(&key) {
                    Some(rep) => loaded.push((key, Arc::new(rep))),
                    None => still.push((key, job)),
                }
            }
            if !loaded.is_empty() {
                let mut cache = self.stream_cache.lock().unwrap();
                for (k, r) in loaded {
                    cache.insert(k, r);
                }
            }
            todo = still;
        }

        if !todo.is_empty() {
            let results = self.execute_with(todo.len(), |i| Arc::new(todo[i].1.simulate()));
            let mut cache = self.stream_cache.lock().unwrap();
            for (i, report) in results {
                self.disk_store_stream(&todo[i].0, &report);
                cache.insert(todo[i].0.clone(), report);
            }
        }

        let cache = self.stream_cache.lock().unwrap();
        keys.iter()
            .map(|k| Arc::clone(cache.get(k).expect("stream job simulated above")))
            .collect()
    }
}

impl Default for SweepExec {
    fn default() -> Self {
        Self::from_env()
    }
}

impl std::fmt::Debug for SweepExec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (hits, misses) = self.cache_stats();
        f.debug_struct("SweepExec")
            .field("threads", &self.threads)
            .field("cached", &self.cached_len())
            .field("hits", &hits)
            .field("misses", &misses)
            .field("disk_dir", &self.disk_dir)
            .field("disk_hits", &self.disk_hits())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::bench;

    fn tiny_job(name: &str, scheme: Scheme, seed: u64) -> SimJob {
        let cfg = SystemConfig::tiny();
        let mut p = bench(name).unwrap();
        p.num_ctas = 4;
        p.insns_per_thread = 40;
        p.num_kernels = 1;
        SimJob::new(cfg, p, scheme, seed)
    }

    #[test]
    fn fingerprints_track_every_field() {
        let a = SystemConfig::tiny();
        let mut b = a.clone();
        assert_eq!(cfg_fingerprint(&a), cfg_fingerprint(&b));
        b.mshr_per_sm += 1;
        assert_ne!(cfg_fingerprint(&a), cfg_fingerprint(&b));

        let p = bench("CP").unwrap();
        let mut q = p.clone();
        assert_eq!(profile_fingerprint(&p), profile_fingerprint(&q));
        q.insns_per_thread += 1;
        assert_ne!(profile_fingerprint(&p), profile_fingerprint(&q));
    }

    #[test]
    fn job_keys_separate_schemes_and_seeds() {
        let a = tiny_job("CP", Scheme::Baseline, 1).key();
        let b = tiny_job("CP", Scheme::ScaleUp, 1).key();
        let c = tiny_job("CP", Scheme::Baseline, 2).key();
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, tiny_job("CP", Scheme::Baseline, 1).key());
    }

    #[test]
    fn memoizes_repeat_runs() {
        let exec = SweepExec::new(2);
        let job = tiny_job("CP", Scheme::Baseline, 7);
        let a = exec.run(&job.cfg, &job.profile, job.scheme, job.seed);
        let b = exec.run(&job.cfg, &job.profile, job.scheme, job.seed);
        assert!(Arc::ptr_eq(&a, &b), "second run must be the cached Arc");
        let (hits, misses) = exec.cache_stats();
        assert_eq!((hits, misses), (1, 1));
        assert_eq!(exec.cached_len(), 1);
    }

    #[test]
    fn batch_dedupes_and_preserves_order() {
        let exec = SweepExec::new(4);
        let jobs = vec![
            tiny_job("CP", Scheme::Baseline, 7),
            tiny_job("BFS", Scheme::Baseline, 7),
            tiny_job("CP", Scheme::Baseline, 7), // duplicate of job 0
        ];
        let out = exec.run_batch(jobs);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].bench, "CP");
        assert_eq!(out[1].bench, "BFS");
        assert!(Arc::ptr_eq(&out[0], &out[2]), "duplicate served from cache");
        let (hits, misses) = exec.cache_stats();
        assert_eq!(misses, 2, "two unique simulations");
        assert_eq!(hits, 1, "one in-batch duplicate");
    }

    #[test]
    fn stream_jobs_memoize_and_key_on_policy() {
        use crate::sim::gpu::PartitionPolicy;
        use crate::workload::{shrink_streams, traffic_trace};
        let cfg = SystemConfig::tiny();
        let tenants =
            vec![(bench("CP").unwrap(), Scheme::Baseline), (bench("BFS").unwrap(), Scheme::Baseline)];
        let mut streams = traffic_trace(&tenants, 1, 0, 3);
        shrink_streams(&mut streams, 4, 40);
        let exec = SweepExec::new(2);
        let job = StreamJob::new(cfg.clone(), streams.clone(), PartitionPolicy::Static);
        assert_eq!(job.key(), job.key(), "key is stable");
        let other = StreamJob::new(cfg.clone(), streams.clone(), PartitionPolicy::Adaptive);
        assert_ne!(job.key(), other.key(), "policy is part of the key");
        let a = exec.run_stream(&job);
        let b = exec.run_stream(&job);
        assert!(Arc::ptr_eq(&a, &b), "second stream run must be the cached Arc");
        let batch = exec.run_stream_batch(vec![job.clone(), other, job.clone()]);
        assert_eq!(batch.len(), 3);
        assert!(Arc::ptr_eq(&batch[0], &a), "batch serves the memoized report");
        assert!(Arc::ptr_eq(&batch[0], &batch[2]), "in-batch duplicate deduped");
    }

    #[test]
    fn fault_trace_perturbs_job_keys() {
        use crate::sim::fault::{FaultEvent, FaultKind, FaultTrace};
        let base = tiny_job("CP", Scheme::Baseline, 1);
        let faulted = base.clone().with_fault(FaultTrace::new(vec![FaultEvent {
            cycle: 100,
            kind: FaultKind::Cluster { cluster: 0 },
        }]));
        assert_ne!(base.key(), faulted.key(), "fault trace is part of the key");
        let empty = base.clone().with_fault(FaultTrace::default());
        assert_eq!(base.key(), empty.key(), "empty trace shares the healthy key");
    }

    #[test]
    fn thread_count_is_clamped_and_env_sized() {
        assert_eq!(SweepExec::new(0).threads(), 1);
        assert_eq!(SweepExec::serial().threads(), 1);
        assert!(SweepExec::from_env().threads() >= 1);
    }

    #[test]
    fn jobs_env_values_clamp_to_at_least_one_worker() {
        // `AMOEBA_JOBS=0` means "one worker", not "machine default" —
        // a zero-worker executor cannot exist and the machine-width
        // fallback is the opposite of what an explicit 0 asks for.
        assert_eq!(SweepExec::parse_jobs("0"), Some(1));
        assert_eq!(SweepExec::parse_jobs("1"), Some(1));
        assert_eq!(SweepExec::parse_jobs("8"), Some(8));
        // Unparsable values fall through to the machine default.
        assert_eq!(SweepExec::parse_jobs(""), None);
        assert_eq!(SweepExec::parse_jobs("many"), None);
        assert_eq!(SweepExec::parse_jobs("-2"), None);
    }

    #[test]
    fn disk_memo_round_trips_and_shrugs_off_corruption() {
        let dir = std::env::temp_dir().join(format!("amoeba-memo-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let exec = SweepExec::new(1).with_disk_memo(&dir);
        let job = tiny_job("CP", Scheme::Baseline, 7);
        let a = exec.run(&job.cfg, &job.profile, job.scheme, job.seed);
        assert_eq!(exec.disk_hits(), 0, "first run simulates and spills");

        // A fresh executor (fresh process, as far as the memo knows)
        // recalls the spilled report bit-for-bit without simulating.
        let exec2 = SweepExec::new(1).with_disk_memo(&dir);
        let b = exec2.run(&job.cfg, &job.profile, job.scheme, job.seed);
        assert_eq!(*a, *b, "disk recall must be bit-identical");
        assert_eq!(exec2.disk_hits(), 1);

        // Batch path recalls from disk too.
        let exec3 = SweepExec::new(2).with_disk_memo(&dir);
        let out = exec3.run_batch(vec![job.clone()]);
        assert_eq!(*out[0], *a);
        assert_eq!(exec3.disk_hits(), 1);

        // Stream reports spill and recall through the same machinery.
        use crate::sim::gpu::PartitionPolicy;
        use crate::workload::{shrink_streams, traffic_trace};
        let tenants = vec![(bench("CP").unwrap(), Scheme::Baseline)];
        let mut streams = traffic_trace(&tenants, 1, 0, 3);
        shrink_streams(&mut streams, 4, 40);
        let sjob = StreamJob::new(SystemConfig::tiny(), streams, PartitionPolicy::Static);
        let sa = exec3.run_stream(&sjob);
        let exec4 = SweepExec::new(1).with_disk_memo(&dir);
        let sb = exec4.run_stream(&sjob);
        assert_eq!(*sa, *sb, "stream disk recall must be bit-identical");
        assert_eq!(exec4.disk_hits(), 1);

        // Corrupt every spill file: the loader must treat them as plain
        // misses (no panic) and re-simulate to the same report.
        for e in std::fs::read_dir(&dir).unwrap() {
            std::fs::write(e.unwrap().path(), b"not a memo file").unwrap();
        }
        let exec5 = SweepExec::new(1).with_disk_memo(&dir);
        let c = exec5.run(&job.cfg, &job.profile, job.scheme, job.seed);
        assert_eq!(*a, *c, "corrupt memo must fall back to simulation");
        assert_eq!(exec5.disk_hits(), 0);

        // Truncated files (every prefix) are also plain errors.
        let good = sim_memo_bytes(&job.key(), &a);
        for n in 0..good.len().min(64) {
            assert!(parse_sim_memo(&good[..n], &job.key()).is_err());
        }
        assert!(parse_sim_memo(&good, &job.key()).is_ok());

        let _ = std::fs::remove_dir_all(&dir);
    }
}
