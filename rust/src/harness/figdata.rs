//! Static datasets used by figures that do not require simulation.

use crate::stats::Table;

/// Fig 2: NVIDIA GTX SM-scaling trend — SM count vs cores/SM over the
/// product generations the paper plots (public spec data, techpowerup).
/// Reprinted as a dataset; no simulation involved.
pub fn gtx_scaling_trend() -> Table {
    let mut t = Table::new("Fig 2 — GTX SM scaling trend", &["gpu", "year", "num_sms", "cores_per_sm"]);
    // (name, year, SMs, CUDA cores per SM)
    let data: [(&str, f64, f64, f64); 8] = [
        ("GTX 280", 2008.0, 30.0, 8.0),
        ("GTX 480", 2010.0, 15.0, 32.0),
        ("GTX 580", 2011.0, 16.0, 32.0),
        ("GTX 680", 2012.0, 8.0, 192.0),
        ("GTX 780", 2013.0, 12.0, 192.0),
        ("GTX 980", 2014.0, 16.0, 128.0),
        ("GTX 1080", 2016.0, 20.0, 128.0),
        ("GTX 2080", 2018.0, 46.0, 64.0),
    ];
    for (name, year, sms, cores) in data {
        t.row(name, vec![year, sms, cores]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trend_shows_recent_scale_out() {
        let t = gtx_scaling_trend();
        assert_eq!(t.rows.len(), 8);
        // The most recent part (2018) has more SMs with fewer cores than
        // the 2012 peak scale-up design — the paper's §2.2 observation.
        let r2012 = &t.rows.iter().find(|(n, _)| n == "GTX 680").unwrap().1;
        let r2018 = &t.rows.iter().find(|(n, _)| n == "GTX 2080").unwrap().1;
        assert!(r2018[1] > r2012[1], "more SMs in 2018");
        assert!(r2018[2] < r2012[2], "fewer cores/SM in 2018");
    }
}
