//! Minimal in-repo micro-benchmark harness (criterion is not available in
//! the offline vendored registry). Measures wall-clock per iteration with
//! warmup, reports min/median/mean, and supports setup-per-batch like
//! criterion's `iter_batched`.

use std::time::{Duration, Instant};

/// A named benchmark group printing aligned results.
pub struct Bencher {
    group: String,
    /// Target measurement iterations per benchmark.
    pub iters: usize,
    /// Warmup iterations.
    pub warmup: usize,
}

impl Bencher {
    /// New group with sensible defaults (tune with `iters`/`warmup`).
    pub fn new(group: impl Into<String>) -> Self {
        Bencher { group: group.into(), iters: 30, warmup: 3 }
    }

    /// Benchmark `f` (the closure result is kept alive to prevent the
    /// optimizer from deleting the work).
    pub fn bench<R>(&self, name: &str, mut f: impl FnMut() -> R) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        let r = BenchResult::from_samples(&self.group, name, samples);
        println!("{r}");
        r
    }

    /// Benchmark with per-iteration setup excluded from timing.
    pub fn bench_batched<S, R>(
        &self,
        name: &str,
        mut setup: impl FnMut() -> S,
        mut f: impl FnMut(S) -> R,
    ) -> BenchResult {
        for _ in 0..self.warmup {
            let s = setup();
            std::hint::black_box(f(s));
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let s = setup();
            let t0 = Instant::now();
            std::hint::black_box(f(s));
            samples.push(t0.elapsed());
        }
        let r = BenchResult::from_samples(&self.group, name, samples);
        println!("{r}");
        r
    }
}

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// "group/name" label.
    pub label: String,
    /// Fastest observed iteration.
    pub min: Duration,
    /// Median iteration.
    pub median: Duration,
    /// Mean iteration.
    pub mean: Duration,
    /// 95th-percentile iteration (nearest-rank on the sorted samples).
    pub p95: Duration,
    /// Number of samples.
    pub n: usize,
}

impl BenchResult {
    fn from_samples(group: &str, name: &str, mut samples: Vec<Duration>) -> Self {
        samples.sort_unstable();
        let n = samples.len();
        assert!(n > 0, "need at least one sample");
        let min = samples[0];
        let median = samples[n / 2];
        // Mean in integer nanoseconds: summing `Duration`s and dividing
        // by `n as u32` would truncate the divisor on huge sample counts
        // (and `Duration / u32` can only see 32 bits of n); u128 math is
        // exact for any realistic run.
        let total_ns: u128 = samples.iter().map(|d| d.as_nanos()).sum();
        let mean_ns = total_ns / n as u128;
        let mean = Duration::from_nanos(mean_ns.min(u64::MAX as u128) as u64);
        // Nearest-rank p95, routed through the tested [`p95_u64`] helper
        // (integer nanoseconds, exact for any realistic sample) so the
        // two rank computations can't drift apart.
        let ns: Vec<u64> =
            samples.iter().map(|d| d.as_nanos().min(u64::MAX as u128) as u64).collect();
        let p95 = Duration::from_nanos(p95_u64(&ns));
        BenchResult { label: format!("{group}/{name}"), min, median, mean, p95, n }
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:40} min {:>12} | median {:>12} | mean {:>12} | p95 {:>12} | n={}",
            self.label,
            fmt_duration(self.min),
            fmt_duration(self.median),
            fmt_duration(self.mean),
            fmt_duration(self.p95),
            self.n
        )
    }
}

/// Nearest-rank 95th percentile of integer samples (same rank rule as
/// [`BenchResult`]'s wall-clock p95: ceil(0.95 * n) in 1-based terms).
/// Returns 0 for an empty slice — the natural "no samples" reading for
/// the cycle-count metrics this serves (queueing delays, turnarounds).
pub fn p95_u64(samples: &[u64]) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    let n = sorted.len();
    sorted[((n * 95).div_ceil(100)).saturating_sub(1).min(n - 1)]
}

/// Human-friendly duration formatting (ns/us/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new("test");
        b.iters = 5;
        b.warmup = 1;
        let r = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..10_000u64 {
                x = x.wrapping_add(i * i);
            }
            x
        });
        assert!(r.min.as_nanos() > 0);
        assert!(r.median >= r.min);
        assert!(r.p95 >= r.median, "p95 {:?} < median {:?}", r.p95, r.median);
        assert!(r.mean >= r.min);
        assert_eq!(r.n, 5);
    }

    #[test]
    fn mean_uses_integer_nanosecond_math() {
        // 3 samples of 1/2/3 us => mean exactly 2 us.
        let r = BenchResult::from_samples(
            "test",
            "mean",
            vec![
                Duration::from_micros(1),
                Duration::from_micros(2),
                Duration::from_micros(3),
            ],
        );
        assert_eq!(r.mean, Duration::from_micros(2));
        assert_eq!(r.p95, Duration::from_micros(3), "p95 of 3 samples is the max");
        assert_eq!(r.min, Duration::from_micros(1));
    }

    #[test]
    fn batched_excludes_setup() {
        let mut b = Bencher::new("test");
        b.iters = 3;
        b.warmup = 0;
        let r = b.bench_batched(
            "noop",
            || std::thread::sleep(std::time::Duration::from_millis(2)),
            |_| 42,
        );
        // Setup sleeps 2ms but timed body is ~instant.
        assert!(r.median < Duration::from_millis(1), "median={:?}", r.median);
    }

    #[test]
    fn p95_u64_nearest_rank() {
        assert_eq!(p95_u64(&[]), 0);
        assert_eq!(p95_u64(&[7]), 7);
        assert_eq!(p95_u64(&[3, 1, 2]), 3, "p95 of 3 samples is the max");
        // 20 samples: rank ceil(0.95*20) = 19 (1-based) => value 19.
        let v: Vec<u64> = (1..=20).rev().collect();
        assert_eq!(p95_u64(&v), 19);
        // 100 samples: rank 95.
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(p95_u64(&v), 95);
    }

    #[test]
    fn bench_p95_agrees_with_p95_u64_on_sub_microsecond_samples() {
        // 20 samples of 1..=20 ns: nearest rank 19. The shared helper
        // must see whole nanoseconds — a coarser unit would truncate
        // these to zero and let p95 fall below the median.
        let samples: Vec<Duration> = (1..=20u64).map(Duration::from_nanos).collect();
        let ns: Vec<u64> = samples.iter().map(|d| d.as_nanos() as u64).collect();
        let r = BenchResult::from_samples("test", "rank", samples);
        assert_eq!(r.p95, Duration::from_nanos(19));
        assert_eq!(r.p95.as_nanos() as u64, p95_u64(&ns));
        assert!(r.p95 >= r.median);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
    }
}
