//! Simulation-backed figure regeneration. Every function runs the
//! relevant workload sweep and returns the series the paper plots.
//!
//! `quick` mode shrinks grids/trace lengths (used by tests and CI); full
//! mode (the default for `cargo run --bin figures`) uses the profile
//! shapes as-is.
//!
//! All simulations flow through the caller-provided [`SweepExec`]: each
//! figure submits its whole `(bench, scheme, config)` grid as one batch
//! (parallel fan-out), and results shared between figures — e.g. every
//! per-scheme sweep needs the same `Baseline` runs — are served from the
//! executor's memo cache instead of being re-simulated.

use std::sync::Arc;

use crate::amoeba::{MetricsSample, NativePredictor, FEATURES, NUM_FEATURES, PAPER_COEFFS};
use crate::config::{Scheme, SystemConfig};
use crate::harness::{p95_u64, SimJob, StreamJob, SweepExec};
use crate::runtime::serve;
use crate::sim::core::ClusterMode;
use crate::sim::gpu::{PartitionPolicy, SimReport};
use crate::stats::Table;
use crate::workload::{
    bench, shrink_streams, traffic_trace, traffic_trace_qos, BenchProfile, Priority, TenantQosSpec,
    TrafficPattern, FIG12_SET, FIG20_SET, FIG3_SET, FIG5_SET,
};

/// Seed used by all harness runs (determinism across invocations).
const SEED: u64 = 0xA30EBA;

/// Shrink a profile for quick mode.
fn shrink(p: &mut BenchProfile, quick: bool) {
    if quick {
        p.num_ctas = p.num_ctas.min(16);
        p.insns_per_thread = p.insns_per_thread.min(120);
        p.num_kernels = p.num_kernels.min(1).max(1);
    }
}

/// Look up `name` and apply quick-mode shrinking.
fn profile(name: &str, quick: bool) -> BenchProfile {
    let mut p = bench(name).unwrap_or_else(|| panic!("unknown benchmark {name}"));
    shrink(&mut p, quick);
    p
}

fn run(exec: &SweepExec, cfg: &SystemConfig, name: &str, scheme: Scheme, quick: bool) -> Arc<SimReport> {
    exec.run(cfg, &profile(name, quick), scheme, SEED)
}

fn base_cfg(quick: bool) -> SystemConfig {
    let mut c = SystemConfig::gtx480();
    if quick {
        c.num_sms = 8;
        c.num_mcs = 4;
        c.max_cycles = 2_000_000;
        c.profile_window = 1_000;
    }
    c
}

// ---------------------------------------------------------------------
// Fig 3: IPC vs SM count (resource-fixed), mesh vs perfect NoC
// ---------------------------------------------------------------------

/// Fig 3(a)/(b): normalised IPC across {16,25,36,64}-SM scalings (the
/// paper normalises to the 16-SM point).
pub fn fig3_scaling(exec: &SweepExec, perfect_noc: bool, quick: bool) -> Table {
    let title = if perfect_noc {
        "Fig 3b — SM scaling, perfect NoC (IPC normalised to 16 SMs)"
    } else {
        "Fig 3a — SM scaling, mesh NoC (IPC normalised to 16 SMs)"
    };
    // Even SM counts so clusters pair up exactly (the paper's 25/36 grid
    // points fall between; we use the nearest even configurations).
    let sm_counts = [16usize, 24, 36, 64];
    let mut t = Table::new(title, &["bench", "16", "24", "36", "64"]);
    let benches: &[&str] = if quick { &FIG3_SET[..4] } else { &FIG3_SET };

    let mut jobs = Vec::new();
    for name in benches {
        for n in sm_counts {
            let mut cfg = base_cfg(false).with_sm_count(n);
            if perfect_noc {
                cfg.noc_mode = crate::config::NocMode::Perfect;
            }
            if quick {
                cfg.max_cycles = 1_200_000;
            }
            let mut p = profile(name, quick);
            if quick {
                p.num_ctas = 12;
                p.insns_per_thread = 100;
            }
            jobs.push(SimJob::new(cfg, p, Scheme::Baseline, SEED));
        }
    }
    let reports = exec.run_batch(jobs);

    for (bi, name) in benches.iter().enumerate() {
        let mut row = Vec::new();
        let mut base_ipc = None;
        for ni in 0..sm_counts.len() {
            let ipc = reports[bi * sm_counts.len() + ni].ipc();
            let b = *base_ipc.get_or_insert(ipc);
            row.push(ipc / b);
        }
        t.row(*name, row);
    }
    t
}

// ---------------------------------------------------------------------
// Fig 4 / 16: actual memory access rate after coalescing
// ---------------------------------------------------------------------

/// Fig 4: actual-memory-access rate vs SM scaling {16,24,36,64}.
pub fn fig4_coalescing(exec: &SweepExec, quick: bool) -> Table {
    let sm_counts = [16usize, 24, 36, 64];
    let mut t = Table::new(
        "Fig 4 — actual memory access rate after coalescing vs SM count",
        &["bench", "16", "24", "36", "64"],
    );
    let benches: &[&str] = if quick { &FIG3_SET[..3] } else { &FIG3_SET };

    let mut jobs = Vec::new();
    for name in benches {
        for n in sm_counts {
            let mut cfg = base_cfg(false).with_sm_count(n);
            if quick {
                cfg.max_cycles = 1_200_000;
            }
            let mut p = profile(name, quick);
            if quick {
                p.num_ctas = 10;
                p.insns_per_thread = 90;
            }
            jobs.push(SimJob::new(cfg, p, Scheme::Baseline, SEED));
        }
    }
    let reports = exec.run_batch(jobs);

    for (bi, name) in benches.iter().enumerate() {
        let row: Vec<f64> = (0..sm_counts.len())
            .map(|ni| reports[bi * sm_counts.len() + ni].sm.actual_access_rate())
            .collect();
        t.row(*name, row);
    }
    t
}

/// Fig 16: actual-memory-access rate per scheme on the main suite.
pub fn fig16_mem_access(exec: &SweepExec, quick: bool) -> Table {
    scheme_sweep_table(
        exec,
        "Fig 16 — actual memory access rate (after coalescing)",
        quick,
        |r| r.sm.actual_access_rate(),
    )
}

// ---------------------------------------------------------------------
// Fig 5: L1 sharing with increased capacity
// ---------------------------------------------------------------------

/// Fig 5: rate of shared data in neighbouring SMs' L1s at 1x/2x/4x L1
/// capacity. Measured as the relative L1D miss reduction when capacity
/// grows (shared lines dedup once both neighbours fit).
pub fn fig5_l1_sharing(exec: &SweepExec, quick: bool) -> Table {
    let mut t = Table::new(
        "Fig 5 — neighbouring-SM L1 data sharing vs L1 capacity",
        &["bench", "1x", "2x", "4x"],
    );
    let mults = [1usize, 2, 4];

    let mut jobs = Vec::new();
    for name in FIG5_SET {
        for mult in mults {
            let mut cfg = base_cfg(quick);
            cfg.l1d_bytes *= mult;
            cfg.l1_assoc *= mult;
            jobs.push(SimJob::new(cfg, profile(name, quick), Scheme::Baseline, SEED));
        }
    }
    let reports = exec.run_batch(jobs);

    for (bi, name) in FIG5_SET.iter().enumerate() {
        let mut row = Vec::new();
        let mut base_miss = None;
        for mi in 0..mults.len() {
            let miss = reports[bi * mults.len() + mi].sm.l1d_miss_rate();
            let b = *base_miss.get_or_insert(miss.max(1e-9));
            // Sharing rate proxy: fraction of baseline misses removed by
            // the larger cache (duplicated neighbour lines now resident).
            row.push(((b - miss) / b).max(0.0));
        }
        t.row(*name, row);
    }
    t
}

// ---------------------------------------------------------------------
// Fig 6 / 13: control-divergence stalls
// ---------------------------------------------------------------------

/// Fig 6: control-stall fraction, scale-up vs scale-out machines.
pub fn fig6_control_stalls(exec: &SweepExec, quick: bool) -> Table {
    let mut t = Table::new(
        "Fig 6 — control-divergence stall fraction by scaling",
        &["bench", "scale_out", "scale_up"],
    );
    let benches = ["RAY", "BFS", "WP", "MUM", "SM", "CP"];
    let cfg = base_cfg(quick);

    let mut jobs = Vec::new();
    for name in benches {
        for s in [Scheme::Baseline, Scheme::ScaleUp] {
            jobs.push(SimJob::new(cfg.clone(), profile(name, quick), s, SEED));
        }
    }
    let reports = exec.run_batch(jobs);

    for (bi, name) in benches.iter().enumerate() {
        let out = &reports[bi * 2];
        let up = &reports[bi * 2 + 1];
        t.row(*name, vec![out.sm.control_stall_rate(), up.sm.control_stall_rate()]);
    }
    t
}

/// Fig 13: control-stall rate for every scheme on the main suite.
pub fn fig13_control_stalls(exec: &SweepExec, quick: bool) -> Table {
    scheme_sweep_table(exec, "Fig 13 — control-divergence stall rate", quick, |r| {
        r.sm.control_stall_rate()
    })
}

// ---------------------------------------------------------------------
// Fig 8: kernel vs CTA scalability consistency
// ---------------------------------------------------------------------

/// Fig 8: per-CTA-wave IPC trend vs whole-kernel trend (LIB scale-out,
/// RAY scale-up). Rows: bench x {kernel, cta} normalised IPC at 16 vs 48
/// SMs (ratio > 1 means scale-out wins).
pub fn fig8_cta_consistency(exec: &SweepExec, quick: bool) -> Table {
    let mut t = Table::new(
        "Fig 8 — kernel vs CTA scaling consistency (IPC 48SM / IPC 24SM-fused)",
        &["bench", "kernel_ratio", "cta_wave_ratio"],
    );
    let benches = ["LIB", "RAY"];
    let cfg = base_cfg(quick);

    let mut jobs = Vec::new();
    for name in benches {
        // Whole-kernel runs.
        for s in [Scheme::Baseline, Scheme::ScaleUp] {
            jobs.push(SimJob::new(cfg.clone(), profile(name, quick), s, SEED));
        }
        // Single-CTA-wave runs: same machines, one wave of CTAs.
        let mut p = profile(name, quick);
        p.num_ctas = (cfg.num_sms as u32).max(4);
        p.num_kernels = 1;
        for s in [Scheme::Baseline, Scheme::ScaleUp] {
            jobs.push(SimJob::new(cfg.clone(), p.clone(), s, SEED));
        }
    }
    let reports = exec.run_batch(jobs);

    for (bi, name) in benches.iter().enumerate() {
        let r = &reports[bi * 4..bi * 4 + 4];
        let kernel_ratio = r[0].ipc() / r[1].ipc().max(1e-9);
        let cta_ratio = r[2].ipc() / r[3].ipc().max(1e-9);
        t.row(*name, vec![kernel_ratio, cta_ratio]);
    }
    t
}

// ---------------------------------------------------------------------
// Fig 12 / 14 / 15 / 17 / 18: the main per-scheme sweeps
// ---------------------------------------------------------------------

/// Run every Fig-12 benchmark under every Fig-12 scheme (one batched
/// sweep) and tabulate `metric` (column per scheme).
fn scheme_sweep_table(
    exec: &SweepExec,
    title: &str,
    quick: bool,
    metric: fn(&SimReport) -> f64,
) -> Table {
    let mut t = Table::new(
        title,
        &["bench", "baseline", "scale_up", "static_fuse", "direct_split", "warp_regrouping"],
    );
    let benches: &[&str] = if quick { &FIG12_SET[..4] } else { &FIG12_SET };
    let cfg = base_cfg(quick);

    let mut jobs = Vec::new();
    for name in benches {
        for s in Scheme::FIG12 {
            jobs.push(SimJob::new(cfg.clone(), profile(name, quick), s, SEED));
        }
    }
    let reports = exec.run_batch(jobs);

    for (bi, name) in benches.iter().enumerate() {
        let row: Vec<f64> = (0..Scheme::FIG12.len())
            .map(|si| metric(&reports[bi * Scheme::FIG12.len() + si]))
            .collect();
        t.row(*name, row);
    }
    t
}

/// Fig 12 — the headline: IPC speedup over baseline per scheme.
pub fn fig12_performance(exec: &SweepExec, quick: bool) -> Table {
    let mut t = Table::new(
        "Fig 12 — IPC speedup over the scale-out baseline",
        &["bench", "scale_up", "static_fuse", "direct_split", "warp_regrouping"],
    );
    let benches: &[&str] = if quick { &FIG12_SET[..4] } else { &FIG12_SET };
    let cfg = base_cfg(quick);

    let mut jobs = Vec::new();
    for name in benches {
        for s in Scheme::FIG12 {
            jobs.push(SimJob::new(cfg.clone(), profile(name, quick), s, SEED));
        }
    }
    let reports = exec.run_batch(jobs);

    for (bi, name) in benches.iter().enumerate() {
        let r = &reports[bi * Scheme::FIG12.len()..(bi + 1) * Scheme::FIG12.len()];
        let base = r[0].ipc().max(1e-9);
        let row: Vec<f64> = r[1..].iter().map(|rep| rep.ipc() / base).collect();
        t.row(*name, row);
    }
    let g = t.geomean_row();
    t.row("GEOMEAN", g);
    t
}

/// Fig 14 — L1 instruction-cache miss rate per scheme.
pub fn fig14_l1i_miss(exec: &SweepExec, quick: bool) -> Table {
    scheme_sweep_table(exec, "Fig 14 — L1-I miss rate", quick, |r| r.sm.l1i_miss_rate())
}

/// Fig 15 — L1 data-cache miss rate per scheme.
pub fn fig15_l1d_miss(exec: &SweepExec, quick: bool) -> Table {
    scheme_sweep_table(exec, "Fig 15 — L1-D miss rate", quick, |r| r.sm.l1d_miss_rate())
}

/// Fig 17 — normalised MC-injection (ICNT) stall rate per scheme.
pub fn fig17_icnt_stalls(exec: &SweepExec, quick: bool) -> Table {
    scheme_sweep_table(exec, "Fig 17 — MC injection stall rate (normalised)", quick, |r| {
        r.chip.mc_inject_stall_rate()
    })
}

/// Fig 18 — NoC data injection rate (flits/cycle/SM-node) per scheme.
pub fn fig18_injection(exec: &SweepExec, quick: bool) -> Table {
    scheme_sweep_table(exec, "Fig 18 — NoC injection rate (flits/cycle/node)", quick, |r| {
        r.sm.noc_flits as f64 / r.cycles.max(1) as f64
    })
}

// ---------------------------------------------------------------------
// Fig 19: fuse/split phase dynamics
// ---------------------------------------------------------------------

/// Fig 19: mode timeline of the first 5 clusters under warp-regrouping on
/// RAY (1 = fused, 0 = split, -1 = private/baseline).
pub fn fig19_phases(exec: &SweepExec, quick: bool) -> Table {
    let cfg = base_cfg(quick);
    let r = run(exec, &cfg, "RAY", Scheme::WarpRegroup, quick);
    let mut t = Table::new(
        "Fig 19 — SM fuse(1)/split(0) phases over time (RAY, warp_regrouping)",
        &["cycle", "sm0", "sm1", "sm2", "sm3", "sm4"],
    );
    for p in r.phases.iter() {
        let vals: Vec<f64> = p
            .modes
            .iter()
            .take(5)
            .map(|m| match m {
                ClusterMode::Fused => 1.0,
                ClusterMode::FusedSplit => 0.0,
                ClusterMode::PrivatePair => -1.0,
            })
            .collect();
        if vals.len() == 5 {
            t.row(p.cycle.to_string(), vals);
        }
    }
    t
}

/// Fig 19h (extension): per-cluster mode timeline under the §4.4
/// heterogeneous scheme, where clusters decide independently and the
/// fabric can be mixed (some clusters fused/split, some private) in the
/// same cycle. `frac_fused` is the fraction of clusters not private.
pub fn fig19_hetero(exec: &SweepExec, quick: bool) -> Table {
    let cfg = base_cfg(quick);
    let r = run(exec, &cfg, "RAY", Scheme::Hetero, quick);
    let shown = 4usize;
    let mut t = Table::new(
        "Fig 19h — heterogeneous per-cluster modes (RAY, hetero): 1=fused 0=split -1=private",
        &["cycle", "sm0", "sm1", "sm2", "sm3", "frac_fused"],
    );
    for p in r.phases.iter() {
        if p.modes.len() < shown {
            continue;
        }
        let mut vals: Vec<f64> = p
            .modes
            .iter()
            .take(shown)
            .map(|m| match m {
                ClusterMode::Fused => 1.0,
                ClusterMode::FusedSplit => 0.0,
                ClusterMode::PrivatePair => -1.0,
            })
            .collect();
        let non_private =
            p.modes.iter().filter(|m| !matches!(m, ClusterMode::PrivatePair)).count();
        vals.push(non_private as f64 / p.modes.len() as f64);
        t.row(p.cycle.to_string(), vals);
    }
    t
}

// ---------------------------------------------------------------------
// Fig 20: per-metric impact magnitudes
// ---------------------------------------------------------------------

/// Fig 20: coefficient x measured-value impact magnitudes for the four
/// analysis benchmarks, using the repo-trained coefficients.
pub fn fig20_impacts(exec: &SweepExec, quick: bool) -> Table {
    let mut cols: Vec<&str> = vec!["bench"];
    cols.extend(FEATURES);
    cols.push("sum");
    let mut t = Table::new("Fig 20 — predictor impact magnitudes", &cols);
    let predictor = NativePredictor::new();
    let cfg = base_cfg(quick);

    let jobs: Vec<SimJob> = FIG20_SET
        .iter()
        .map(|name| SimJob::new(cfg.clone(), profile(name, quick), Scheme::StaticFuse, SEED))
        .collect();
    let reports = exec.run_batch(jobs);

    for (name, r) in FIG20_SET.iter().zip(reports.iter()) {
        let sample = r
            .samples
            .first()
            .copied()
            .unwrap_or(MetricsSample { features: [0.0; NUM_FEATURES] });
        let impacts = predictor.impacts(&sample);
        let mut row: Vec<f64> = impacts.to_vec();
        row.push(impacts.iter().sum::<f64>() + predictor.coeffs().intercept);
        t.row(*name, row);
    }
    t
}

// ---------------------------------------------------------------------
// Fig 21: AMOEBA vs DWS
// ---------------------------------------------------------------------

/// Fig 21: warp-regrouping AMOEBA speedup over DWS per benchmark.
pub fn fig21_vs_dws(exec: &SweepExec, quick: bool) -> Table {
    let mut t = Table::new("Fig 21 — AMOEBA (warp_regrouping) speedup over DWS", &["bench", "speedup"]);
    let benches: &[&str] = if quick { &FIG12_SET[..4] } else { &FIG12_SET };
    let cfg = base_cfg(quick);

    let mut jobs = Vec::new();
    for name in benches {
        for s in [Scheme::Dws, Scheme::WarpRegroup] {
            jobs.push(SimJob::new(cfg.clone(), profile(name, quick), s, SEED));
        }
    }
    let reports = exec.run_batch(jobs);

    for (bi, name) in benches.iter().enumerate() {
        let dws = reports[bi * 2].ipc().max(1e-9);
        let amoeba = reports[bi * 2 + 1].ipc();
        t.row(*name, vec![amoeba / dws]);
    }
    let g = t.geomean_row();
    t.row("GEOMEAN", g);
    t
}

// ---------------------------------------------------------------------
// Server sweep: concurrent multi-tenant streams
// ---------------------------------------------------------------------

/// The server-mode sweep ("srv"): replay a seeded service trace of
/// interleaved tenant launches (the [`serve::default_tenants`] mix)
/// under both partition policies, plus each tenant alone as the
/// interference-free reference, and report per-tenant completion,
/// throughput, and ANTT-style slowdown. All runs flow through the
/// executor's stream memo, so regenerating the figure twice simulates
/// nothing new.
pub fn server_sweep(exec: &SweepExec, quick: bool) -> Table {
    let cfg = base_cfg(quick);
    let tenants = serve::default_tenants();
    let (kernels_each, mean_gap) = if quick { (2, 20_000) } else { (4, 100_000) };
    let mut streams = traffic_trace(&tenants, kernels_each, mean_gap, SEED);
    if quick {
        shrink_streams(&mut streams, 8, 80);
    }

    let shared = [PartitionPolicy::Static, PartitionPolicy::Adaptive];
    let out = exec.run_stream_batch(serve::server_jobs(&cfg, &streams, &shared));
    let (shared_static, shared_adaptive) = (&out[0], &out[1]);

    let mut t = Table::new(
        "Server sweep — per-tenant service metrics (concurrent streams)",
        &[
            "tenant",
            "finish_kcyc",
            "tput_ipc",
            "antt_static",
            "antt_adaptive",
            "slowdown",
            "p95_qdel_st_kcyc",
            "p95_qdel_ad_kcyc",
        ],
    );
    for ti in 0..streams.len() {
        let alone = &out[shared.len() + ti];
        // p95 queueing delay (launch start minus arrival) per tenant,
        // under each shared policy — the tail-latency view ANTT's mean
        // hides.
        let p95_qdel = |rep: &crate::sim::gpu::StreamReport| {
            let delays: Vec<u64> = rep
                .launches
                .iter()
                .filter(|l| l.tenant == ti as u32 && l.finish != u64::MAX)
                .map(|l| l.queue_delay)
                .collect();
            p95_u64(&delays) as f64 / 1000.0
        };
        t.row(
            streams[ti].name.as_str(),
            vec![
                shared_static.tenants[ti].cycles as f64 / 1000.0,
                shared_static.tenant_throughput(ti),
                serve::antt_slowdown(shared_static, alone, ti),
                serve::antt_slowdown(shared_adaptive, alone, ti),
                serve::stream_slowdown(shared_static, alone, ti),
                p95_qdel(shared_static),
                p95_qdel(shared_adaptive),
            ],
        );
    }
    t
}

// ---------------------------------------------------------------------
// Fault sweep: graceful degradation under injected half-SM failures
// ---------------------------------------------------------------------

/// The degradation sweep ("fault"): IPC as half-SM faults accumulate,
/// per scheme, each curve normalised to that scheme's healthy
/// (zero-fault) run. Faults land on distinct clusters at staggered
/// cycles. Schemes that can run a cluster split keep serving on the
/// healthy half and shed roughly half an SM per fault; the rigid
/// scale-up machine loses the whole cluster every time — the
/// degradation asymmetry AMOEBA's reconfigurability buys.
pub fn fault_sweep(exec: &SweepExec, quick: bool) -> Table {
    use crate::sim::fault::{FaultEvent, FaultKind, FaultTrace};
    let cfg = base_cfg(quick);
    let n_clusters = cfg.num_sms / 2;
    let max_faults = n_clusters.min(4);
    let schemes =
        [Scheme::Baseline, Scheme::ScaleUp, Scheme::StaticFuse, Scheme::WarpRegroup, Scheme::Hetero];
    let p = profile("BFS", quick);

    let mut jobs = Vec::new();
    for &s in &schemes {
        for k in 0..=max_faults {
            let trace = FaultTrace::new(
                (0..k)
                    .map(|i| FaultEvent {
                        cycle: 2_000 * (i as u64 + 1),
                        kind: FaultKind::HalfSm { cluster: i as u32, half: 0 },
                    })
                    .collect(),
            );
            jobs.push(SimJob::new(cfg.clone(), p.clone(), s, SEED).with_fault(trace));
        }
    }
    let reports = exec.run_batch(jobs);

    let fault_cols: Vec<String> = (0..=max_faults).map(|k| format!("{k}_faults")).collect();
    let mut cols: Vec<&str> = vec!["scheme"];
    cols.extend(fault_cols.iter().map(String::as_str));
    let mut t = Table::new(
        "Fault sweep — IPC under accumulating half-SM faults (normalised to healthy)",
        &cols,
    );
    let points = max_faults + 1;
    for (si, s) in schemes.iter().enumerate() {
        let healthy = reports[si * points].ipc().max(1e-9);
        let row: Vec<f64> =
            (0..points).map(|k| reports[si * points + k].ipc() / healthy).collect();
        t.row(s.to_string(), row);
    }
    t
}

// ---------------------------------------------------------------------
// QoS sweep: priority mix x load under partition-scoped drain
// ---------------------------------------------------------------------

/// The QoS sweep ("qos"): the [`serve::default_tenants`] mix annotated
/// with a priority ladder (High with a turnaround SLO, Normal, Low) and
/// replayed under the Adaptive policy across a load (mean arrival gap)
/// x arrival-pattern grid, where `bursty` clumps each tenant's launches
/// into noisy-neighbour bursts. Rows are one (scenario, tenant) pair and
/// report SLO attainment, launches served, p95 queueing delay, mean
/// per-launch slowdown (1000 = unqueued), and the scenario's total
/// CTA-boundary preemptions — the service-quality picture that
/// partition-scoped draining and priority scheduling exist to improve.
pub fn qos_sweep(exec: &SweepExec, quick: bool) -> Table {
    let cfg = base_cfg(quick);
    let prios = [Priority::High, Priority::Normal, Priority::Low];
    // SLO sized so the High tenant comfortably meets it when served
    // promptly and misses it when parked behind a saturated machine.
    let slo = if quick { 400_000 } else { 4_000_000 };
    let specs: Vec<TenantQosSpec> = serve::default_tenants()
        .into_iter()
        .zip(prios)
        .map(|((profile, scheme), priority)| TenantQosSpec {
            profile,
            scheme,
            priority,
            slo_turnaround: (priority == Priority::High).then_some(slo),
        })
        .collect();
    let kernels_each = if quick { 2 } else { 4 };
    let gaps: &[(&str, u64)] =
        if quick { &[("hi_load", 2_000), ("lo_load", 20_000)] } else { &[("hi_load", 20_000), ("lo_load", 100_000)] };
    let patterns = [
        ("uniform", TrafficPattern::Uniform),
        ("bursty", TrafficPattern::Bursty { burst_len: 4, dilation: 8 }),
    ];

    let mut scenarios = Vec::new();
    let mut jobs = Vec::new();
    for &(gname, gap) in gaps {
        for (pname, pattern) in patterns {
            let mut streams = traffic_trace_qos(&specs, kernels_each, gap, SEED, pattern);
            if quick {
                shrink_streams(&mut streams, 8, 80);
            }
            jobs.push(StreamJob::new(cfg.clone(), streams.clone(), PartitionPolicy::Adaptive));
            scenarios.push((format!("{gname}/{pname}"), streams));
        }
    }
    let out = exec.run_stream_batch(jobs);

    let mut t = Table::new(
        "QoS sweep — SLO attainment and queueing by priority class (Adaptive)",
        &["scenario/tenant", "slo_attain", "served", "p95_qdel_kcyc", "slowdown_milli", "preempt"],
    );
    for ((label, streams), rep) in scenarios.iter().zip(&out) {
        for q in serve::qos_summary(rep, streams) {
            t.row(
                format!("{label}/{}:{}", streams[q.tenant].name, q.priority),
                vec![
                    q.slo_attainment(),
                    q.served as f64,
                    q.p95_queue_delay as f64 / 1000.0,
                    q.mean_slowdown_milli as f64,
                    rep.chip.preemptions as f64,
                ],
            );
        }
    }
    t
}

// ---------------------------------------------------------------------
// Fleet sweep: tenants vs chips across a health-monitored GPU pool
// ---------------------------------------------------------------------

/// The fleet-serving headline sweep ("fleet"): a tenants-vs-chips grid
/// served through [`crate::runtime::fleet::serve_fleet`], plus one
/// chip-loss scenario on the widest pool (chip 0's clusters all retire
/// early, forcing checkpoint migration onto the peers). Each row is one
/// scenario and reports the chips the elastic scaler actually opened,
/// fleet ANTT, mean queueing delay, launches served/dropped, tenants
/// migrated/rejected, and mean per-chip utilisation (IPC over serving
/// chips) — the honest-accounting picture: served + dropped + rejected
/// launches always add up to the trace.
pub fn fleet_sweep(exec: &SweepExec, quick: bool) -> Table {
    use crate::runtime::fleet::{serve_fleet, FleetConfig};
    use crate::sim::fault::{FaultEvent, FaultKind, FaultTrace};

    let mut chip = SystemConfig::tiny();
    if !quick {
        chip.num_sms = 8;
        chip.num_mcs = 4;
    }
    chip.max_cycles = 300_000;
    let n_clusters = chip.num_sms / 2;

    let fleet_streams = |n: usize| {
        let picks = ["CP", "BFS", "SM"];
        let tenants: Vec<_> = (0..n)
            .map(|i| (bench(picks[i % picks.len()]).unwrap(), Scheme::Baseline))
            .collect();
        let mut streams = traffic_trace(&tenants, 2, 5_000, SEED);
        shrink_streams(&mut streams, 4, 40);
        streams
    };
    let kill_chip0 = || {
        FaultTrace::new(
            (0..n_clusters)
                .map(|c| FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: c as u32 } })
                .collect(),
        )
    };

    let (pools, tenant_counts): (&[usize], &[usize]) =
        if quick { (&[1, 2], &[2, 4]) } else { (&[1, 2, 4], &[4, 8]) };

    let mut t = Table::new(
        "Fleet sweep — tenants vs chips (pool serving, honest accounting)",
        &["pool/tenants", "act", "antt", "qdel_kcyc", "served", "dropped", "migr", "rej", "ipc"],
    );
    let mut scenarios: Vec<(String, usize, usize, Vec<FaultTrace>)> = Vec::new();
    for &p in pools {
        for &n in tenant_counts {
            scenarios.push((format!("{p}chips/{n}t"), p, n, Vec::new()));
        }
    }
    // The chip-loss headline: widest pool, largest tenant count, chip 0
    // dead almost immediately.
    let (p, n) = (*pools.last().unwrap(), *tenant_counts.last().unwrap());
    scenarios.push((format!("{p}chips/{n}t/kill0"), p, n, vec![kill_chip0()]));

    for (label, p, n, faults) in scenarios {
        let fc = FleetConfig::pool(chip.clone(), p);
        let streams = fleet_streams(n);
        let rep = serve_fleet(exec, &fc, &streams, &faults)
            .expect("fleet sweep scenario must be valid");
        let serving: Vec<f64> =
            rep.chips.iter().filter(|c| c.report.is_some()).map(|c| c.ipc).collect();
        let mean_ipc = if serving.is_empty() {
            0.0
        } else {
            serving.iter().sum::<f64>() / serving.len() as f64
        };
        t.row(
            label,
            vec![
                rep.chips.iter().filter(|c| c.activated).count() as f64,
                rep.antt,
                rep.mean_queue_delay / 1000.0,
                rep.served as f64,
                rep.dropped as f64,
                rep.migrations as f64,
                rep.rejections as f64,
                mean_ipc,
            ],
        );
    }
    t
}

// ---------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------

/// Table 1: the system configuration actually used.
pub fn table1_config() -> Table {
    let c = SystemConfig::gtx480();
    let mut t = Table::new("Table 1 — system configuration", &["parameter", "value"]);
    t.row("num_computing_cores(SMs)", vec![c.num_sms as f64]);
    t.row("num_memory_controllers", vec![c.num_mcs as f64]);
    t.row("mshr_per_core", vec![c.mshr_per_sm as f64]);
    t.row("warp_size", vec![c.warp_size as f64]);
    t.row("simd_pipeline_width", vec![c.simd_width as f64]);
    t.row("threads_per_core", vec![c.max_threads_per_sm as f64]);
    t.row("ctas_per_core", vec![c.max_ctas_per_sm as f64]);
    t.row("l1_cache_kb", vec![(c.l1d_bytes >> 10) as f64]);
    t.row("l2_cache_kb_per_mc", vec![(c.l2_slice_bytes >> 10) as f64]);
    t.row("registers_per_core", vec![c.registers_per_sm as f64]);
    t.row("shared_memory_kb", vec![(c.shared_mem_bytes >> 10) as f64]);
    t.row("noc_channel_bits", vec![c.noc_channel_bits as f64]);
    t.row("noc_router_stages", vec![c.noc_router_stages as f64]);
    t
}

/// Table 2: predictor coefficients — the paper's alongside this repo's
/// retrained set (our feature scaling differs; see DESIGN.md).
pub fn table2_coefficients() -> Table {
    let ours = NativePredictor::new();
    let mut t = Table::new("Table 2 — scalability-predictor coefficients", &["feature", "paper", "this_repo"]);
    for (i, f) in FEATURES.iter().enumerate() {
        t.row(*f, vec![PAPER_COEFFS.weights[i], ours.coeffs().weights[i]]);
    }
    t.row("intercept", vec![PAPER_COEFFS.intercept, ours.coeffs().intercept]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_prints_table() {
        let t = table1_config();
        assert!(t.rows.len() >= 12);
        assert!(t.render().contains("warp_size"));
    }

    #[test]
    fn table2_includes_paper_and_repo_coeffs() {
        let t = table2_coefficients();
        assert_eq!(t.rows.len(), NUM_FEATURES + 1);
        let coalescing = t.rows.iter().find(|(n, _)| n == "coalescing").unwrap();
        assert_eq!(coalescing.1[0], 2057.050);
    }

    #[test]
    fn fig2_static_data() {
        assert_eq!(crate::harness::gtx_scaling_trend().rows.len(), 8);
    }

    #[test]
    fn fig19h_traces_hetero_through_executor() {
        let exec = SweepExec::new(2);
        let t = fig19_hetero(&exec, true);
        assert!(!t.rows.is_empty(), "phase trace must have samples");
        // 4 per-cluster mode columns + frac_fused.
        assert_eq!(t.rows[0].1.len(), 5);
        assert!(t.rows.iter().all(|(_, v)| (0.0..=1.0).contains(&v[4])));
        assert!(t
            .rows
            .iter()
            .all(|(_, v)| v[..4].iter().all(|m| [-1.0, 0.0, 1.0].contains(m))));
    }

    #[test]
    fn fault_sweep_degrades_gracefully() {
        let exec = SweepExec::new(2);
        let t = fault_sweep(&exec, true);
        assert_eq!(t.rows.len(), 5, "five schemes");
        let points = t.rows[0].1.len();
        assert!(points >= 2, "at least healthy + one fault count");
        for (name, vals) in &t.rows {
            assert!((vals[0] - 1.0).abs() < 1e-12, "{name}: healthy point normalises to 1");
            assert!(vals.iter().all(|v| v.is_finite() && *v >= 0.0));
        }
        let row = |n: &str| &t.rows.iter().find(|(name, _)| name == n).unwrap().1;
        let (hetero, scale_up) = (row("hetero"), row("scale_up"));
        // The reconfigurable machine keeps serving on healthy half-SMs;
        // the rigid fused machine loses whole clusters — at every fault
        // count it can do no better, and at the heaviest it does worse.
        for k in 1..points {
            assert!(
                hetero[k] >= scale_up[k] - 1e-9,
                "fault count {k}: hetero {} < scale_up {}",
                hetero[k],
                scale_up[k]
            );
        }
        assert!(
            hetero[points - 1] > scale_up[points - 1],
            "heaviest fault load must separate the schemes"
        );
    }

    #[test]
    fn qos_sweep_reports_every_scenario_tenant_pair() {
        let exec = SweepExec::new(2);
        let t = qos_sweep(&exec, true);
        // 2 loads x 2 patterns x 3 tenants.
        assert_eq!(t.rows.len(), 12, "one row per (scenario, tenant)");
        for (name, vals) in &t.rows {
            assert_eq!(vals.len(), 5, "{name}: five metric columns");
            assert!(vals.iter().all(|v| v.is_finite() && *v >= 0.0), "{name}: {vals:?}");
            let (attain, served) = (vals[0], vals[1]);
            assert!((0.0..=1.0).contains(&attain), "{name}: attainment {attain}");
            assert!(served >= 1.0, "{name}: every tenant must serve at least one launch");
            assert!(vals[3] >= 1000.0, "{name}: slowdown_milli is >= 1000 by construction");
        }
        // The preemption column is a per-scenario chip total: constant
        // across the scenario's three tenant rows.
        for scenario in t.rows.chunks(3) {
            let p = scenario[0].1[4];
            assert!(scenario.iter().all(|(_, v)| v[4] == p), "preempt differs within scenario");
        }
        // Priority ladder shows in the row labels.
        assert!(t.rows.iter().any(|(n, _)| n.ends_with(":high")));
        assert!(t.rows.iter().any(|(n, _)| n.ends_with(":low")));
    }

    #[test]
    fn fleet_sweep_covers_grid_and_chip_loss() {
        let exec = SweepExec::new(2);
        let t = fleet_sweep(&exec, true);
        // 2 pools x 2 tenant counts + the kill-chip-0 scenario.
        assert_eq!(t.rows.len(), 5, "one row per fleet scenario");
        for (name, vals) in &t.rows {
            assert_eq!(vals.len(), 8, "{name}: eight metric columns");
            assert!(vals.iter().all(|v| v.is_finite() && *v >= 0.0), "{name}: {vals:?}");
            let (act, served) = (vals[0], vals[3]);
            assert!(act >= 1.0, "{name}: at least one chip active");
            assert!(served >= 1.0, "{name}: the fleet must serve something");
        }
        let kill = &t.rows.iter().find(|(n, _)| n.ends_with("kill0")).unwrap().1;
        // The chip-loss scenario exercises the robustness path: stranded
        // work is migrated or dropped/rejected, never silently lost.
        assert!(
            kill[5] >= 1.0 || kill[4] >= 1.0 || kill[6] >= 1.0,
            "chip loss must surface as migrations, drops, or rejections: {kill:?}"
        );
        // Memoized: regenerating the sweep simulates nothing new.
        let (_, misses_before) = exec.cache_stats();
        let t2 = fleet_sweep(&exec, true);
        let (_, misses_after) = exec.cache_stats();
        assert_eq!(misses_before, misses_after, "regeneration must be pure cache hits");
        assert_eq!(t.rows, t2.rows, "memoized fleet sweep is identical");
    }

    #[test]
    fn fig6_row_shape_through_executor() {
        // Smoke: a simulation-backed figure runs through the executor and
        // its per-scheme sweep lands cache hits when regenerated.
        let exec = SweepExec::new(2);
        let t = fig6_control_stalls(&exec, true);
        assert_eq!(t.rows.len(), 6);
        assert_eq!(t.rows[0].1.len(), 2);
        let (_, misses_before) = exec.cache_stats();
        let t2 = fig6_control_stalls(&exec, true);
        let (hits, misses_after) = exec.cache_stats();
        assert_eq!(misses_before, misses_after, "regeneration must be pure cache hits");
        assert!(hits >= 12);
        assert_eq!(t.rows[0].1, t2.rows[0].1, "memoized figure is identical");
    }
}
