//! Figure/table regeneration harness: one entry point per table and
//! figure of the paper's evaluation section (see DESIGN.md §3 for the
//! full index). Each function returns a [`Table`] whose rows/series match
//! the paper's plot axes.
//!
//! Every simulation-backed figure routes through a [`SweepExec`]: jobs
//! fan out across cores and identical `(bench, scheme, config, seed)`
//! runs are memoized, so regenerating *all* figures simulates each unique
//! configuration exactly once.

pub mod bencher;
pub mod exec;
mod figdata;
mod figures;

pub use bencher::{p95_u64, BenchResult, Bencher};
pub use exec::{
    cfg_fingerprint, fault_fingerprint, parse_sim_memo, parse_stream_memo, profile_fingerprint,
    JobKey, SimJob, StreamJob, StreamKey, SweepExec,
};
pub use figdata::gtx_scaling_trend;
pub use figures::*;

use std::sync::OnceLock;

use crate::stats::Table;

/// All figure ids the harness can regenerate ("srv" is the server-mode
/// concurrent-stream sweep, "fault" the graceful-degradation sweep,
/// "qos" the priority-mix/load sweep of SLO attainment under
/// partition-scoped drain + preemption, and "fleet" the tenants-vs-chips
/// pool-serving sweep with admission, elastic scaling, and chip-loss
/// migration — not paper figures, but the scenario classes the ROADMAP's
/// serving and robustness north stars ask for).
pub const ALL_FIGURES: [&str; 24] = [
    "2", "3a", "3b", "4", "5", "6", "8", "12", "13", "14", "15", "16", "17", "18", "19", "19h",
    "20", "21", "srv", "fault", "qos", "fleet", "t1", "t2",
];

/// The process-wide executor used by the [`figure`] convenience wrapper:
/// sized from the environment (`AMOEBA_JOBS`), shared so that repeated
/// `figure` calls reuse each other's simulations.
pub fn default_exec() -> &'static SweepExec {
    static EXEC: OnceLock<SweepExec> = OnceLock::new();
    EXEC.get_or_init(SweepExec::from_env)
}

/// Regenerate one figure/table by id on `exec`. `quick` shrinks
/// workloads for CI.
pub fn figure_with(exec: &SweepExec, id: &str, quick: bool) -> Option<Table> {
    match id {
        "2" => Some(gtx_scaling_trend()),
        "3a" => Some(fig3_scaling(exec, false, quick)),
        "3b" => Some(fig3_scaling(exec, true, quick)),
        "4" => Some(fig4_coalescing(exec, quick)),
        "5" => Some(fig5_l1_sharing(exec, quick)),
        "6" => Some(fig6_control_stalls(exec, quick)),
        "8" => Some(fig8_cta_consistency(exec, quick)),
        "12" => Some(fig12_performance(exec, quick)),
        "13" => Some(fig13_control_stalls(exec, quick)),
        "14" => Some(fig14_l1i_miss(exec, quick)),
        "15" => Some(fig15_l1d_miss(exec, quick)),
        "16" => Some(fig16_mem_access(exec, quick)),
        "17" => Some(fig17_icnt_stalls(exec, quick)),
        "18" => Some(fig18_injection(exec, quick)),
        "19" => Some(fig19_phases(exec, quick)),
        "19h" => Some(fig19_hetero(exec, quick)),
        "20" => Some(fig20_impacts(exec, quick)),
        "21" => Some(fig21_vs_dws(exec, quick)),
        "srv" => Some(server_sweep(exec, quick)),
        "fault" => Some(fault_sweep(exec, quick)),
        "qos" => Some(qos_sweep(exec, quick)),
        "fleet" => Some(fleet_sweep(exec, quick)),
        "t1" => Some(table1_config()),
        "t2" => Some(table2_coefficients()),
        _ => None,
    }
}

/// Regenerate one figure/table by id on the shared [`default_exec`].
pub fn figure(id: &str, quick: bool) -> Option<Table> {
    figure_with(default_exec(), id, quick)
}
