//! Figure/table regeneration harness: one entry point per table and
//! figure of the paper's evaluation section (see DESIGN.md §3 for the
//! full index). Each function returns a [`Table`] whose rows/series match
//! the paper's plot axes.

pub mod bencher;
mod figdata;
mod figures;

pub use bencher::{BenchResult, Bencher};
pub use figdata::gtx_scaling_trend;
pub use figures::*;

use crate::stats::Table;

/// All figure ids the harness can regenerate.
pub const ALL_FIGURES: [&str; 19] = [
    "2", "3a", "3b", "4", "5", "6", "8", "12", "13", "14", "15", "16", "17", "18", "19", "20",
    "21", "t1", "t2",
];

/// Regenerate one figure/table by id. `quick` shrinks workloads for CI.
pub fn figure(id: &str, quick: bool) -> Option<Table> {
    match id {
        "2" => Some(gtx_scaling_trend()),
        "3a" => Some(fig3_scaling(false, quick)),
        "3b" => Some(fig3_scaling(true, quick)),
        "4" => Some(fig4_coalescing(quick)),
        "5" => Some(fig5_l1_sharing(quick)),
        "6" => Some(fig6_control_stalls(quick)),
        "8" => Some(fig8_cta_consistency(quick)),
        "12" => Some(fig12_performance(quick)),
        "13" => Some(fig13_control_stalls(quick)),
        "14" => Some(fig14_l1i_miss(quick)),
        "15" => Some(fig15_l1d_miss(quick)),
        "16" => Some(fig16_mem_access(quick)),
        "17" => Some(fig17_icnt_stalls(quick)),
        "18" => Some(fig18_injection(quick)),
        "19" => Some(fig19_phases(quick)),
        "20" => Some(fig20_impacts(quick)),
        "21" => Some(fig21_vs_dws(quick)),
        "t1" => Some(table1_config()),
        "t2" => Some(table2_coefficients()),
        _ => None,
    }
}
