//! Dependency-free error plumbing for the binaries and examples.
//!
//! The offline build ships no `anyhow`; CLI entry points return
//! [`Result`] (a boxed [`std::error::Error`]) and construct ad-hoc
//! errors with [`err`]. Library modules keep their own typed errors
//! (e.g. [`crate::runtime::RuntimeError`]) — this module is only the
//! thin glue that lets `fn main() -> Result<()>` print something
//! readable and `?` convert from any std error type.

use std::fmt;

/// A plain-message error. `Debug` prints the bare message so that a
/// `fn main() -> Result<()>` failure reads as `Error: <message>` rather
/// than a struct dump.
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias used by `main()` in the binaries and examples.
pub type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

/// Build a boxed error from a message (the `anyhow!` stand-in).
pub fn err(msg: impl Into<String>) -> Box<dyn std::error::Error> {
    Box::new(Error(msg.into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_prints_bare_message() {
        let e = err("no such benchmark");
        assert_eq!(format!("{e}"), "no such benchmark");
        assert_eq!(format!("{e:?}"), "no such benchmark");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "42".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);
    }
}
