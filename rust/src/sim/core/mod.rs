//! SM core substrates: warp contexts and the reconfigurable SM cluster.

pub mod cluster;
pub mod warp;

pub use cluster::{ClusterMode, DivergenceMode, SmCluster};
pub use warp::{CtaState, Replay, ShadowWarp, WarpCtx};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::noc::Noc;
    use crate::workload::{bench, kernel_launches, TraceGen};

    fn setup(mode: ClusterMode) -> (SmCluster, Noc, TraceGen, crate::isa::KernelLaunch) {
        let cfg = SystemConfig::tiny();
        let cluster = SmCluster::new(0, &cfg, mode);
        // Node map: cluster halves at nodes 0/1, MCs at the end.
        let noc = Noc::with_nodes(&cfg, 6);
        let profile = bench("CP").unwrap();
        let k = kernel_launches(&profile, 3)[0].clone();
        let gen = TraceGen::new(&profile, &k);
        (cluster, noc, gen, k)
    }

    #[test]
    fn dispatch_creates_expected_warps() {
        let (mut c, _, gen, k) = setup(ClusterMode::PrivatePair);
        c.dispatch_cta(&k, 0, &gen);
        assert_eq!(c.warps.len(), k.warps_per_cta(32) as usize);
        assert!(c.warps.iter().all(|w| w.width == 32 && w.n_subwarps == 1));
        let (mut c, _, gen, k) = setup(ClusterMode::Fused);
        c.dispatch_cta(&k, 0, &gen);
        assert_eq!(c.warps.len(), k.warps_per_cta(32).div_ceil(2) as usize);
        assert!(c.warps.iter().all(|w| w.width == 64));
    }

    #[test]
    fn cluster_executes_cta_to_completion() {
        for mode in [ClusterMode::PrivatePair, ClusterMode::Fused, ClusterMode::FusedSplit] {
            let (mut c, mut noc, gen, k) = setup(mode);
            c.dispatch_cta(&k, 0, &gen);
            let mut now = 0u64;
            let limit = 2_000_000;
            while !c.idle() && now < limit {
                c.tick(now, &mut noc, [0, 1], &gen);
                noc.tick(now);
                // Service memory requests with a fake zero-latency memory:
                // eject requests at MC nodes and immediately reply.
                for mc_node in 4..6 {
                    while let Some(p) = noc.eject(crate::sim::noc::Subnet::Request, mc_node) {
                        if let crate::sim::noc::Payload::MemRequest { line, requester, is_write } =
                            p.payload
                        {
                            let reply = crate::sim::noc::Packet {
                                src: mc_node,
                                dst: p.src,
                                flits: 9,
                                born: now,
                                payload: crate::sim::noc::Payload::MemReply {
                                    line,
                                    requester,
                                    is_write,
                                },
                            };
                            let _ = noc.inject(crate::sim::noc::Subnet::Reply, reply);
                        }
                    }
                }
                for node in 0..2 {
                    while let Some(p) = noc.eject(crate::sim::noc::Subnet::Reply, node) {
                        if let crate::sim::noc::Payload::MemReply { line, is_write, .. } = p.payload
                        {
                            c.on_reply(now, line, is_write);
                        }
                    }
                }
                now += 1;
            }
            assert!(c.idle(), "mode {mode:?} deadlocked at cycle {now}");
            assert_eq!(c.completed_ctas(), 1, "mode {mode:?}");
            assert!(c.stats.thread_insns > 0);
            // All per-thread instructions executed exactly once outside
            // divergent replays: thread_insns >= threads * insns.
            let min = k.cta_threads as u64 * k.insns_per_thread as u64;
            assert!(
                c.stats.thread_insns >= min * 95 / 100,
                "mode {mode:?}: thread insns {} < {min}",
                c.stats.thread_insns
            );
        }
    }

    #[test]
    fn occupancy_limits_respected() {
        let (mut c, _, gen, k) = setup(ClusterMode::PrivatePair);
        let mut accepted = 0;
        while c.can_accept_cta(&k) {
            c.dispatch_cta(&k, accepted, &gen);
            accepted += 1;
            assert!(accepted < 100, "occupancy never saturates");
        }
        // tiny cfg: 1024 threads/SM, 256-thread CTAs, 8 CTA slots
        // => 4 CTAs per half, 8 per cluster.
        assert_eq!(accepted, 8);
        // Fused pools both halves.
        let (mut cf, _, genf, kf) = setup(ClusterMode::Fused);
        let mut n = 0;
        while cf.can_accept_cta(&kf) {
            cf.dispatch_cta(&kf, n, &genf);
            n += 1;
        }
        assert_eq!(n, 8, "2048 threads / 256 = 8 fused CTAs");
    }

    #[test]
    fn fused_mode_reports_fused_cycles() {
        let (mut c, mut noc, gen, k) = setup(ClusterMode::Fused);
        c.dispatch_cta(&k, 0, &gen);
        for now in 0..100 {
            c.tick(now, &mut noc, [0, 1], &gen);
        }
        assert_eq!(c.stats.fused_cycles, 100);
        assert_eq!(c.stats.split_cycles, 0);
    }

    #[test]
    fn divergent_ratio_counts() {
        let (mut c, _, gen, k) = setup(ClusterMode::Fused);
        c.dispatch_cta(&k, 0, &gen);
        assert_eq!(c.divergent_ratio(), 0.0);
        let n = c.warps.len();
        c.warps[0].divergent = true;
        assert!((c.divergent_ratio() - 1.0 / n as f32).abs() < 1e-6);
    }
}
