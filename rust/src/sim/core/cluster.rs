//! An SM *cluster*: two neighbouring baseline SMs and the machinery to run
//! them privately (baseline), fused (scale-up), or dynamically split.
//!
//! The cluster is the reconfiguration unit of AMOEBA (§4.2): fusing merges
//! the pair's L1s (double associativity, +1 cycle), keeps one warp
//! scheduler walking both datapaths (64-wide warps), shares one coalescing
//! unit and bypasses the second NoC router. Dynamic splitting (§4.3)
//! re-separates the schedulers/datapaths while *keeping* the merged L1s
//! and the single NoC interface.

use crate::config::{SplitPolicy, SystemConfig};
use crate::isa::{ActiveMask, KernelLaunch, MemSpace, Op, WarpId};
use crate::sim::mem::{coalesce_fused_into, coalesce_into, Access, Cache};
use crate::sim::noc::{Noc, NocPort, Packet, Payload, Subnet};
use crate::stats::{SmStats, StallReason};
use crate::workload::TraceGen;

use super::warp::{CtaState, ShadowWarp, WarpCtx};

/// How a divergent branch is handled at issue time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DivergenceMode {
    /// Serialise both paths on the issuing warp (baseline GPUs).
    Serial,
    /// Run the slow path as an independently-schedulable shadow warp
    /// (DWS on a baseline SM; warp-regrouping on a split cluster).
    Shadowed,
}

/// Execution mode of a cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterMode {
    /// Baseline: two independent 32-wide SMs with private L1s and their
    /// own NoC routers.
    PrivatePair,
    /// Fused scale-up SM: one scheduler, 64-wide warps, merged L1s, one
    /// NoC interface.
    Fused,
    /// Dynamically split fused SM: two schedulers / datapaths, but the
    /// L1s and NoC interface remain merged (paper §4.3).
    FusedSplit,
}

/// Which cache a transaction belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CacheKind {
    Data = 0,
    Instr = 1,
    Const = 2,
    Texture = 3,
}

/// A memory client waiting on a line fill.
#[derive(Debug, Clone, Copy)]
enum Waiter {
    /// Load scoreboard release for a warp (by table index).
    Warp(usize),
    /// Load scoreboard release for a shadow warp.
    Shadow(usize),
    /// Instruction-fetch release for a warp.
    IFetchWarp(usize),
    /// Instruction-fetch release for a shadow warp.
    IFetchShadow(usize),
    /// Store/write-through (no one waits).
    None,
}

/// One line in flight beyond L1 and everyone waiting on it.
#[derive(Debug)]
struct PendingLine {
    /// Lookup key: line | kind | cache-index (see `pending_key`).
    key: u64,
    /// Line address (replies carry only this).
    line: u64,
    kind: CacheKind,
    half: u8,
    waiters: Vec<Waiter>,
    /// Cycle the NoC request left (latency accounting); set on injection.
    sent: u64,
    /// Request actually injected into the NoC yet?
    injected: bool,
}

/// Hasher for pending-line keys: one multiply-xor mix of the already
/// high-entropy `line|kind|index` packing (line addresses). Avoids the
/// default SipHash setup cost on a lookup that runs once per L1 miss
/// and once per injection retry.
#[derive(Default)]
struct PendingKeyHasher(u64);

impl std::hash::Hasher for PendingKeyHasher {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, _: &[u8]) {
        unreachable!("pending keys are u64");
    }
    fn write_u64(&mut self, k: u64) {
        // splitmix64 finaliser: full-avalanche, two multiplies.
        let mut z = k.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        self.0 = z ^ (z >> 31);
    }
}

type PendingIndex =
    std::collections::HashMap<u64, u32, std::hash::BuildHasherDefault<PendingKeyHasher>>;

/// Slot table for lines in flight beyond L1 — the MSHR-style replacement
/// for the previous per-miss `HashMap`. Entries live in a dense pooled
/// array (no allocation in the steady-state cycle loop), and a
/// persistent key -> slot index replaces the former O(n) linear probe on
/// the per-access hot path (`get_mut`/`contains` run for every miss,
/// merge, and injection retry). Reply matching (`take_reply`) still
/// scans: replies carry only a line address, which is not the key, and
/// they arrive at most a few per cycle.
#[derive(Debug, Default)]
struct PendingTable {
    entries: Vec<PendingLine>,
    /// key -> position in `entries`, kept exact across `swap_remove`.
    index: PendingIndex,
    /// Recycled waiter vectors (avoids one heap alloc per L1 miss).
    waiter_pool: Vec<Vec<Waiter>>,
}

impl PendingTable {
    fn with_capacity(cap: usize) -> Self {
        PendingTable {
            entries: Vec::with_capacity(cap),
            index: PendingIndex::with_capacity_and_hasher(cap * 2, Default::default()),
            waiter_pool: Vec::with_capacity(cap),
        }
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn iter(&self) -> std::slice::Iter<'_, PendingLine> {
        self.entries.iter()
    }

    fn get_mut(&mut self, key: u64) -> Option<&mut PendingLine> {
        let i = *self.index.get(&key)?;
        let e = &mut self.entries[i as usize];
        debug_assert_eq!(e.key, key, "pending index out of sync");
        Some(e)
    }

    fn contains(&self, key: u64) -> bool {
        self.index.contains_key(&key)
    }

    /// Allocate a slot for a new in-flight line with its first waiter.
    fn insert(&mut self, key: u64, line: u64, kind: CacheKind, half: u8, waiter: Waiter, now: u64) {
        debug_assert!(!self.contains(key), "MissNew on an already-pending line");
        let mut waiters = self.waiter_pool.pop().unwrap_or_default();
        waiters.clear();
        waiters.push(waiter);
        self.index.insert(key, self.entries.len() as u32);
        self.entries.push(PendingLine { key, line, kind, half, waiters, sent: now, injected: false });
    }

    /// Remove and return the first *injected* entry for `line` (replies
    /// carry only the line address). Pass the drained entry back through
    /// [`PendingTable::recycle`] to keep its waiter storage pooled.
    fn take_reply(&mut self, line: u64) -> Option<PendingLine> {
        let i = self.entries.iter().position(|e| e.line == line && e.injected)?;
        let entry = self.entries.swap_remove(i);
        self.index.remove(&entry.key);
        if let Some(moved) = self.entries.get(i) {
            self.index.insert(moved.key, i as u32);
        }
        Some(entry)
    }

    /// Return an entry's waiter storage to the pool.
    fn recycle(&mut self, mut entry: PendingLine) {
        entry.waiters.clear();
        self.waiter_pool.push(entry.waiters);
    }

    /// Drop all entries (reconfiguration / kernel-boundary flush),
    /// keeping the pooled storage.
    fn clear(&mut self) {
        while let Some(e) = self.entries.pop() {
            self.recycle(e);
        }
        self.index.clear();
    }
}

/// An LSU queue entry: one post-coalescing transaction.
#[derive(Debug, Clone, Copy)]
struct Transaction {
    line: u64,
    kind: CacheKind,
    is_write: bool,
    waiter: Waiter,
    /// Which half issued it (selects the L1 in PrivatePair mode).
    half: u8,
    /// The L1 lookup already happened (MissNew) and only the NoC injection
    /// remains. Guarantees `Cache::access` runs exactly once per txn.
    needs_inject: bool,
}

/// Per-half scheduler state.
#[derive(Debug, Default, Clone)]
struct HalfSched {
    /// Exec pipeline busy until this cycle (initiation interval).
    busy_until: u64,
    /// Greedy-then-oldest: last issued warp table index.
    greedy: Option<usize>,
    /// Greedy shadow index.
    greedy_shadow: Option<usize>,
}

/// The reconfigurable SM cluster.
pub struct SmCluster {
    /// Cluster index on the chip.
    pub id: usize,
    mode: ClusterMode,
    cfg: SystemConfig,

    /// All resident warps (both halves; `home` selects the scheduler).
    pub warps: Vec<WarpCtx>,
    /// Shadow warps (regroup slow passes / DWS subdivisions).
    pub shadows: Vec<ShadowWarp>,
    /// Resident CTAs.
    pub ctas: Vec<CtaState>,

    /// L1 caches. In PrivatePair mode index [0]/[1] are the two private
    /// sets; in Fused/FusedSplit only index [0] is live (merged).
    l1d: [Cache; 2],
    l1i: [Cache; 2],
    l1c: [Cache; 2],
    l1t: [Cache; 2],

    /// LSU: post-coalescing transactions awaiting cache/NoC processing.
    lsu: std::collections::VecDeque<Transaction>,
    /// Lines in flight beyond L1, keyed by line|kind|cache-index (the low
    /// 7 bits of a line address are zero, so the key packing is lossless).
    pending: PendingTable,
    /// Reusable coalescing output buffer (hot-path alloc elimination:
    /// one buffer serves every memory instruction issued by the cluster).
    coalesce_scratch: Vec<u64>,

    sched: [HalfSched; 2],
    age_counter: u64,

    /// Ready-warp index: count of issuable warps filed per home half
    /// (mirrors `WarpCtx::issuable` via `refile_warp`). `pick` consults
    /// it to fail in O(1) on stall cycles instead of scanning the warp
    /// table; a fused scheduler sums both halves.
    ready_count: [u32; 2],
    /// Monotone stamp bumped by every warp/shadow/mode state change;
    /// keys the per-slot stall-classification cache below.
    sched_stamp: u64,
    /// Cached `stall_reason` result per issue slot: (stamp, reason). A
    /// stalled-but-active cluster re-derives its stall breakdown only
    /// when something actually changed, not every cycle.
    stall_cache: [(u64, StallReason); 2],

    /// Statistics (aggregated over both halves).
    pub stats: SmStats,
    /// Fault state: a permanently dead half-SM (fault injection). The
    /// cluster must run `PrivatePair` with every CTA homed on the healthy
    /// half; `lighter_half` and `can_accept_cta` enforce it.
    dead_half: Option<u8>,
    /// Reconfiguration drain: no issue until this cycle.
    pub frozen_until: u64,
    /// Divergence handling (DWS sets `Shadowed` machine-wide).
    pub divergence_mode: DivergenceMode,
    /// Split policy active while in `FusedSplit` (None otherwise).
    pub split_policy: Option<SplitPolicy>,

    // Cached per-kernel CTA resource costs (set at dispatch; all CTAs of a
    // kernel are identical).
    cta_threads: u32,
    cta_regs: u32,
    cta_smem: u32,
}

/// LSU transactions processed per cycle (one per original SM port).
const LSU_WIDTH: usize = 2;
/// LSU queue length at which memory instructions stop issuing.
pub const LSU_QUEUE_CAP: usize = 96;

impl SmCluster {
    /// Build a cluster in the given mode.
    pub fn new(id: usize, cfg: &SystemConfig, mode: ClusterMode) -> Self {
        let mk = |bytes: usize| {
            Cache::new(bytes, cfg.l1_assoc, cfg.line_bytes, cfg.l1_hit_latency, cfg.mshr_per_sm)
        };
        let mut c = SmCluster {
            id,
            mode: ClusterMode::PrivatePair,
            cfg: cfg.clone(),
            warps: Vec::new(),
            shadows: Vec::new(),
            ctas: Vec::new(),
            l1d: [mk(cfg.l1d_bytes), mk(cfg.l1d_bytes)],
            l1i: [mk(cfg.l1i_bytes), mk(cfg.l1i_bytes)],
            l1c: [mk(cfg.l1c_bytes), mk(cfg.l1c_bytes)],
            l1t: [mk(cfg.l1t_bytes), mk(cfg.l1t_bytes)],
            lsu: std::collections::VecDeque::new(),
            // Worst-case occupancy: 4 cache kinds x 2 halves, each with
            // its own MSHR budget (the fused data cache doubles to
            // 2*mshr_per_sm but merged modes use one cache index), so
            // 8*mshr_per_sm covers every mode without regrowth.
            pending: PendingTable::with_capacity(8 * cfg.mshr_per_sm),
            coalesce_scratch: Vec::with_capacity(8),
            sched: [HalfSched::default(), HalfSched::default()],
            age_counter: 0,
            ready_count: [0, 0],
            sched_stamp: 0,
            stall_cache: [(u64::MAX, StallReason::Idle); 2],
            stats: SmStats::default(),
            dead_half: None,
            frozen_until: 0,
            divergence_mode: DivergenceMode::Serial,
            split_policy: None,
            cta_threads: 0,
            cta_regs: 0,
            cta_smem: 0,
        };
        c.apply_cache_layout(mode);
        c.mode = mode;
        c
    }

    /// Current execution mode.
    pub fn mode(&self) -> ClusterMode {
        self.mode
    }

    /// Switch mode. Cache geometry is rebuilt only when crossing the
    /// merged/private boundary; Fused <-> FusedSplit keeps the merged L1s
    /// warm (paper: split SMs share the fused L1).
    pub fn set_mode(&mut self, mode: ClusterMode) {
        let was_merged = matches!(self.mode, ClusterMode::Fused | ClusterMode::FusedSplit);
        let now_merged = matches!(mode, ClusterMode::Fused | ClusterMode::FusedSplit);
        if was_merged != now_merged {
            self.apply_cache_layout(mode);
        }
        self.mode = mode;
        // Mode changes alter the issue-slot structure and shadow
        // eligibility, and the split machinery migrates warp homes around
        // the same transitions: refile everything.
        self.rebuild_sched();
    }

    fn apply_cache_layout(&mut self, mode: ClusterMode) {
        let cfg = &self.cfg;
        let merged = matches!(mode, ClusterMode::Fused | ClusterMode::FusedSplit);
        if merged {
            let lat = cfg.l1_hit_latency + cfg.fused_l1_extra_latency;
            self.l1d[0].resize(cfg.l1d_bytes * 2, cfg.l1_assoc * 2, lat, cfg.mshr_per_sm * 2);
            self.l1i[0].resize(cfg.l1i_bytes * 2, cfg.l1_assoc * 2, lat, cfg.mshr_per_sm);
            self.l1c[0].resize(cfg.l1c_bytes * 2, cfg.l1_assoc * 2, lat, cfg.mshr_per_sm);
            self.l1t[0].resize(cfg.l1t_bytes * 2, cfg.l1_assoc * 2, lat, cfg.mshr_per_sm);
        } else {
            let lat = cfg.l1_hit_latency;
            for i in 0..2 {
                self.l1d[i].resize(cfg.l1d_bytes, cfg.l1_assoc, lat, cfg.mshr_per_sm);
                self.l1i[i].resize(cfg.l1i_bytes, cfg.l1_assoc, lat, cfg.mshr_per_sm);
                self.l1c[i].resize(cfg.l1c_bytes, cfg.l1_assoc, lat, cfg.mshr_per_sm);
                self.l1t[i].resize(cfg.l1t_bytes, cfg.l1_assoc, lat, cfg.mshr_per_sm);
            }
        }
        self.pending.clear();
        self.lsu.clear();
    }

    /// Cache index serving `half` in the current mode.
    fn cache_idx(&self, half: u8) -> usize {
        match self.mode {
            ClusterMode::PrivatePair => half as usize,
            _ => 0,
        }
    }

    fn pending_key(line: u64, kind: CacheKind, ci: usize) -> u64 {
        debug_assert_eq!(line & 0x7, 0, "line addresses are >=8B aligned");
        line | (kind as u64) << 1 | ci as u64
    }

    // ------------------------------------------------------------------
    // Occupancy & dispatch
    // ------------------------------------------------------------------

    /// Warp width this cluster runs in its current mode.
    pub fn warp_width(&self) -> usize {
        match self.mode {
            ClusterMode::Fused => self.cfg.warp_size * 2,
            _ => self.cfg.warp_size,
        }
    }

    /// Can a CTA of `kernel` be accepted right now?
    pub fn can_accept_cta(&self, kernel: &KernelLaunch) -> bool {
        // A dead half forces PrivatePair-only service on the healthy half;
        // merged modes would execute on broken lanes.
        if self.dead_half.is_some() && self.mode != ClusterMode::PrivatePair {
            return false;
        }
        let need_regs = (kernel.cta_threads * kernel.regs_per_thread) as usize;
        if self.mode == ClusterMode::PrivatePair {
            let h = self.lighter_half();
            let (t, c, r, s) = self.occupancy_half(h, kernel);
            t + kernel.cta_threads as usize <= self.cfg.max_threads_per_sm
                && c < self.cfg.max_ctas_per_sm
                && r + need_regs <= self.cfg.registers_per_sm
                && s + kernel.smem_per_cta as usize <= self.cfg.shared_mem_bytes
        } else {
            let (t0, c0, r0, s0) = self.occupancy_half(0, kernel);
            let (t1, c1, r1, s1) = self.occupancy_half(1, kernel);
            t0 + t1 + kernel.cta_threads as usize <= self.cfg.max_threads_per_sm * 2
                && c0 + c1 < self.cfg.max_ctas_per_sm * 2
                && r0 + r1 + need_regs <= self.cfg.registers_per_sm * 2
                && s0 + s1 + kernel.smem_per_cta as usize <= self.cfg.shared_mem_bytes * 2
        }
    }

    fn lighter_half(&self) -> u8 {
        if let Some(dead) = self.dead_half {
            return 1 - dead;
        }
        let c0 = self.ctas.iter().filter(|c| c.home == 0 && !c.complete()).count();
        let c1 = self.ctas.iter().filter(|c| c.home == 1 && !c.complete()).count();
        u8::from(c1 < c0)
    }

    fn occupancy_half(&self, half: u8, kernel: &KernelLaunch) -> (usize, usize, usize, usize) {
        let mut threads = 0;
        let mut ctas = 0;
        let mut regs = 0;
        let mut smem = 0;
        for c in self.ctas.iter().filter(|c| !c.complete()) {
            if self.mode == ClusterMode::PrivatePair && c.home != half {
                continue;
            }
            // Merged modes pool both halves: attribute whole CTAs.
            let div = if self.mode == ClusterMode::PrivatePair { 1 } else { 2 };
            threads += kernel.cta_threads as usize / div;
            ctas += 1;
            regs += (kernel.cta_threads * kernel.regs_per_thread) as usize / div;
            smem += kernel.smem_per_cta as usize / div;
        }
        // In merged modes each "half" reports half the pooled usage; the
        // caller sums both halves against the doubled capacity.
        let _ = self.cta_threads;
        let _ = self.cta_regs;
        let _ = self.cta_smem;
        let cta_div: usize = if self.mode == ClusterMode::PrivatePair { 1 } else { 2 };
        (threads, (ctas as usize).div_ceil(cta_div), regs, smem)
    }

    /// Dispatch a CTA onto the cluster.
    pub fn dispatch_cta(&mut self, kernel: &KernelLaunch, cta: u32, _gen: &TraceGen) {
        let width = self.warp_width();
        let subwarps_total = kernel.warps_per_cta(self.cfg.warp_size);
        let home = if self.mode == ClusterMode::PrivatePair { self.lighter_half() } else { 0 };
        let slot = self.ctas.len();
        let first = self.warps.len();
        let mut warps_made = 0;
        if width == self.cfg.warp_size {
            for sw in 0..subwarps_total {
                self.age_counter += 1;
                self.warps.push(Self::fresh_warp(
                    kernel, cta, sw, [sw, u32::MAX], 1, width, slot, self.age_counter, home,
                ));
                warps_made += 1;
            }
        } else {
            // Fused 64-wide warps: pair consecutive sub-warps.
            let mut sw = 0;
            while sw < subwarps_total {
                let hi = if sw + 1 < subwarps_total { sw + 1 } else { u32::MAX };
                let n = if hi == u32::MAX { 1 } else { 2 };
                let w = if n == 2 { width } else { self.cfg.warp_size };
                self.age_counter += 1;
                let mut warp = Self::fresh_warp(
                    kernel, cta, sw / 2, [sw, hi], n, width, slot, self.age_counter, 0,
                );
                warp.mask = ActiveMask::full(w);
                warp.full_mask = warp.mask;
                self.warps.push(warp);
                warps_made += 1;
                sw += 2;
            }
        }
        let warp_ids: Vec<u32> = (first..self.warps.len()).map(|i| i as u32).collect();
        self.ctas.push(CtaState {
            cta,
            warps_total: warps_made,
            warps_done: 0,
            barrier_count: 0,
            home,
            warp_ids,
        });
        for wi in first..self.warps.len() {
            self.refile_warp(wi);
        }
        self.cta_threads = kernel.cta_threads;
        self.cta_regs = kernel.cta_threads * kernel.regs_per_thread;
        self.cta_smem = kernel.smem_per_cta;
    }

    #[allow(clippy::too_many_arguments)]
    fn fresh_warp(
        kernel: &KernelLaunch,
        cta: u32,
        warp: u32,
        subwarps: [u32; 2],
        n_subwarps: u8,
        width: usize,
        slot: usize,
        age: u64,
        home: u8,
    ) -> WarpCtx {
        WarpCtx {
            id: WarpId { kernel: kernel.id, cta, warp },
            subwarps,
            n_subwarps,
            width,
            pc: 0,
            trace_len: kernel.insns_per_thread,
            mask: ActiveMask::full(width),
            full_mask: ActiveMask::full(width),
            outstanding_loads: 0,
            at_barrier: false,
            ifetch_pending: false,
            finished: false,
            replay: None,
            shadow_outstanding: false,
            cta_slot: slot,
            age,
            divergent: false,
            home,
            sched_ready: false,
            sched_home: home,
        }
    }

    /// All work (warps + shadows + memory) fully drained?
    pub fn idle(&self) -> bool {
        self.warps.iter().all(|w| w.finished)
            && self.shadows.iter().all(|s| s.complete())
            && self.lsu.is_empty()
            && self.pending.is_empty()
    }

    /// Number of unfinished warps.
    pub fn live_warps(&self) -> usize {
        self.warps.iter().filter(|w| !w.finished).count()
    }

    /// Retired-CTA count.
    pub fn completed_ctas(&self) -> usize {
        self.ctas.iter().filter(|c| c.complete()).count()
    }

    /// Remove retired state between kernels (when fully drained).
    pub fn reap(&mut self) {
        if self.idle() {
            self.warps.clear();
            self.shadows.clear();
            self.ctas.clear();
            self.sched = [HalfSched::default(), HalfSched::default()];
            self.ready_count = [0, 0];
            self.sched_stamp += 1;
        }
    }

    // ------------------------------------------------------------------
    // Ready-warp index (per-warp sleep/wake)
    // ------------------------------------------------------------------
    //
    // `ready_count` mirrors `WarpCtx::issuable` per home half so that a
    // scheduler slot with nothing to issue discovers it in O(1) instead
    // of scanning the warp table — per-warp parking with explicit wakes:
    // a warp leaves the ready set when it blocks (scoreboard, I-fetch,
    // barrier, reconvergence) and `refile_warp` re-admits it at exactly
    // the releasing event (load return, fill, barrier release, shadow
    // reconvergence). Every internal mutation path refiles the warps it
    // touches; external mutators (the dynamic-split controller moving
    // homes, tests poking flags) call [`SmCluster::rebuild_sched`].

    /// Re-evaluate warp `wi`'s filing after any state change.
    fn refile_warp(&mut self, wi: usize) {
        self.sched_stamp += 1;
        let w = &mut self.warps[wi];
        let now_ready = w.issuable();
        if w.sched_ready {
            self.ready_count[w.sched_home as usize] -= 1;
        }
        if now_ready {
            self.ready_count[w.home as usize] += 1;
        }
        w.sched_ready = now_ready;
        w.sched_home = w.home;
    }

    /// Record a shadow-warp state change (shadows are few and stay
    /// scan-scheduled, but the stall-classification cache reads them).
    #[inline]
    fn note_shadow_change(&mut self) {
        self.sched_stamp += 1;
    }

    /// Rebuild the ready index from scratch. Required after any code
    /// outside the cluster mutates warp state directly (mode switches,
    /// the dynamic-split controller's home migrations).
    pub fn rebuild_sched(&mut self) {
        self.sched_stamp += 1;
        self.ready_count = [0, 0];
        for w in &mut self.warps {
            let r = w.issuable();
            w.sched_ready = r;
            w.sched_home = w.home;
            if r {
                self.ready_count[w.home as usize] += 1;
            }
        }
    }

    /// Full coherence check of the ready index (only evaluated inside
    /// `debug_assert!`, i.e. in debug builds — which is what `cargo
    /// test` runs, so the determinism suites exercise it everywhere).
    #[allow(dead_code)]
    fn sched_coherent(&self) -> bool {
        let mut want = [0u32; 2];
        for w in &self.warps {
            if w.sched_ready != w.issuable() || w.sched_home != w.home {
                return false;
            }
            if w.sched_ready {
                want[w.home as usize] += 1;
            }
        }
        want == self.ready_count
    }

    /// Unfinished warps of CTA `slot` (its warp list, not the table).
    fn live_in_cta(&self, slot: usize) -> u32 {
        let live = self
            .ctas[slot]
            .warp_ids
            .iter()
            .filter(|&&wj| !self.warps[wj as usize].finished)
            .count() as u32;
        debug_assert_eq!(
            live,
            self.warps.iter().filter(|w| w.cta_slot == slot && !w.finished).count() as u32,
            "per-CTA warp list out of sync with the warp table"
        );
        live
    }

    /// Release every warp of CTA `slot` from the barrier.
    fn release_barrier(&mut self, slot: usize) {
        self.ctas[slot].barrier_count = 0;
        for k in 0..self.ctas[slot].warp_ids.len() {
            let wj = self.ctas[slot].warp_ids[k] as usize;
            if self.warps[wj].at_barrier {
                self.warps[wj].at_barrier = false;
                self.refile_warp(wj);
            }
        }
    }

    // ------------------------------------------------------------------
    // Cycle
    // ------------------------------------------------------------------

    /// Advance one cycle. `noc_nodes` are this cluster's NoC endpoints
    /// ([half0, half1] in per-SM layouts; both equal in fused layouts).
    pub fn tick(&mut self, now: u64, noc: &mut Noc, noc_nodes: [usize; 2], gen: &TraceGen) {
        self.tick_port(now, &mut NocPort::Direct(noc), noc_nodes, gen);
    }

    /// [`SmCluster::tick`] against an abstract interconnect port: the
    /// serial loops pass the shared [`Noc`] directly, the intra-parallel
    /// cluster phase a private [`crate::sim::noc::ClusterOutbox`]. The
    /// cluster cannot observe the difference (buffered admission is
    /// exact by the outbox snapshot-and-reserve contract), which is what
    /// keeps thread-count a pure wall-clock knob.
    pub fn tick_port(&mut self, now: u64, noc: &mut NocPort<'_>, noc_nodes: [usize; 2], gen: &TraceGen) {
        debug_assert!(self.sched_coherent(), "ready index diverged from warp state");
        self.stats.cycles += 1;
        match self.mode {
            ClusterMode::Fused => self.stats.fused_cycles += 1,
            ClusterMode::FusedSplit => self.stats.split_cycles += 1,
            ClusterMode::PrivatePair => {}
        }
        if now < self.frozen_until {
            return;
        }
        self.process_lsu(now, noc, noc_nodes);
        match self.mode {
            ClusterMode::Fused => {
                self.issue_half(now, 0, true, gen);
            }
            ClusterMode::PrivatePair | ClusterMode::FusedSplit => {
                self.issue_half(now, 0, false, gen);
                self.issue_half(now, 1, false, gen);
            }
        }
    }

    /// The `(half, all_homes)` issue-slot list `tick` walks in the
    /// current mode — shared by the event probe and the skip replay so
    /// they can never disagree with the dense loop about which schedulers
    /// run.
    fn issue_slots(&self) -> &'static [(u8, bool)] {
        match self.mode {
            ClusterMode::Fused => &[(0, true)],
            ClusterMode::PrivatePair | ClusterMode::FusedSplit => &[(0, false), (1, false)],
        }
    }

    /// Earliest cycle at which ticking this cluster could change state
    /// beyond the per-cycle accounting [`SmCluster::skip`] replays.
    /// Mirrors `tick` / `process_lsu` / `issue_half` exactly, stopping
    /// one step before every mutation:
    ///
    /// * frozen cluster: nothing until `frozen_until`;
    /// * LSU head that would hit, merge, or allocate: `Progress` (it
    ///   dequeues); a head blocked on injection is `Progress` too (the
    ///   NoC either has space — so it injects — or is busy and reports
    ///   `Progress` itself); only an `MshrFull` head stalls, and only a
    ///   reply (an external event) can unblock it;
    /// * a schedulable pick whose instruction is not LSU-backpressured:
    ///   `Progress`; a busy issue port wakes at `busy_until`.
    ///
    /// Any divergence between this pair and the dense path is a
    /// determinism bug — `tests/exec_determinism.rs` pins skip == dense
    /// bit-for-bit across every scheme.
    pub fn next_event(&self, now: u64, gen: &TraceGen) -> crate::sim::NextEvent {
        use crate::sim::NextEvent;
        debug_assert!(self.sched_coherent(), "ready index diverged from warp state");
        if now < self.frozen_until {
            return NextEvent::At(self.frozen_until);
        }
        if let Some(tx) = self.lsu.front() {
            if tx.needs_inject || tx.is_write {
                return NextEvent::Progress;
            }
            let ci = self.cache_idx(tx.half);
            let cache = self.cache_ref(tx.kind, ci);
            if cache.probe(tx.line) || cache.has_pending(tx.line) || !cache.mshr_full() {
                return NextEvent::Progress; // Hit / MissMerged / MissNew all dequeue
            }
            // MshrFull: the head retries (accounting only) until a reply
            // frees an MSHR — an external event the GPU loop delivers.
        }
        let mut ev = NextEvent::Idle;
        for &(half, all_homes) in self.issue_slots() {
            let sched = &self.sched[half as usize];
            if sched.busy_until > now {
                ev = ev.min_with(NextEvent::At(sched.busy_until));
                continue;
            }
            let blocked = match self.pick(half, all_homes) {
                None => true,
                Some(Pick::Warp(wi)) => {
                    let w = &self.warps[wi];
                    let op = gen.resolve(w.id.cta, w.subwarps[0], w.pc);
                    op.is_cached_mem() && self.lsu_full()
                }
                Some(Pick::Shadow(si)) => {
                    let s = &self.shadows[si];
                    let op = gen.resolve(s.cta, s.subwarp, s.pc);
                    op.is_cached_mem() && self.lsu_full()
                }
            };
            if !blocked {
                return NextEvent::Progress;
            }
        }
        ev
    }

    /// Replay `cycles` quiescent ticks' worth of accounting in O(1):
    /// exactly what the dense loop's `tick` would have recorded over a
    /// window in which [`SmCluster::next_event`] promised no state
    /// change. Counter-for-counter mirror of the dense path:
    ///
    /// * `stats.cycles` and the fused/split mode counters, always;
    /// * a frozen cluster records nothing else (`tick` returns early);
    /// * an `MshrFull`-blocked LSU head: one `Cache::access` LRU-clock
    ///   bump plus one `MemStructFull` stall per cycle (`process_lsu`);
    /// * per issue slot: `ExecBusy` while the port is busy, the
    ///   `stall_reason` classification when nothing is pickable, or the
    ///   `MemStructFull` backpressure stall when the pick's memory
    ///   instruction cannot enter the full LSU (`issue_half`/`issue_warp`).
    pub fn skip(&mut self, now: u64, cycles: u64) {
        self.stats.cycles += cycles;
        match self.mode {
            ClusterMode::Fused => self.stats.fused_cycles += cycles,
            ClusterMode::FusedSplit => self.stats.split_cycles += cycles,
            ClusterMode::PrivatePair => {}
        }
        if now < self.frozen_until {
            debug_assert!(now + cycles <= self.frozen_until, "skip across a thaw boundary");
            return;
        }
        if let Some(tx) = self.lsu.front().copied() {
            debug_assert!(!tx.needs_inject && !tx.is_write, "head not MshrFull-blocked");
            let ci = self.cache_idx(tx.half);
            self.cache_mut(tx.kind, ci).advance_clock(cycles);
            self.stats.stall_n(StallReason::MemStructFull, cycles);
            self.stats.mem_struct_stall_cycles += cycles;
        }
        for &(half, all_homes) in self.issue_slots() {
            if self.sched[half as usize].busy_until > now {
                debug_assert!(now + cycles <= self.sched[half as usize].busy_until);
                self.stats.stall_n(StallReason::ExecBusy, cycles);
                continue;
            }
            match self.pick(half, all_homes) {
                None => {
                    let r = self.stall_reason(half, all_homes);
                    self.stats.stall_n(r, cycles);
                }
                Some(_) => {
                    // next_event guaranteed the pick is LSU-backpressured.
                    self.stats.stall_n(StallReason::MemStructFull, cycles);
                    self.stats.mem_struct_stall_cycles += cycles;
                }
            }
        }
    }

    /// GTO pick for `half` (greedy last-issued, else oldest issuable).
    fn pick(&self, half: u8, all_homes: bool) -> Option<Pick> {
        let sched = &self.sched[half as usize];
        let eligible = |w: &WarpCtx| (all_homes || w.home == half) && w.issuable();
        if let Some(g) = sched.greedy {
            if g < self.warps.len() && eligible(&self.warps[g]) {
                return Some(Pick::Warp(g));
            }
        }
        // Ready-warp index: a stalled slot fails in O(1); the table scan
        // below runs only when a pick is guaranteed to exist.
        let have_ready = if all_homes {
            self.ready_count[0] + self.ready_count[1] > 0
        } else {
            self.ready_count[half as usize] > 0
        };
        if have_ready {
            // Oldest issuable warp: ages are assigned in dispatch order
            // and warps are appended in dispatch order, so the first
            // eligible entry in table order *is* the oldest.
            debug_assert!(self.warps.windows(2).all(|w| w[0].age <= w[1].age));
            if let Some(i) = self.warps.iter().position(eligible) {
                return Some(Pick::Warp(i));
            }
            debug_assert!(false, "ready count nonzero but no eligible warp");
        } else {
            debug_assert!(
                !self.warps.iter().any(eligible),
                "eligible warp missed by the ready count"
            );
        }
        if let Some(g) = sched.greedy_shadow {
            if g < self.shadows.len()
                && self.shadows[g].issuable()
                && (all_homes || self.shadow_eligible(g, half))
            {
                return Some(Pick::Shadow(g));
            }
        }
        self.shadows
            .iter()
            .enumerate()
            .find(|(i, s)| s.issuable() && (all_homes || self.shadow_eligible(*i, half)))
            .map(|(i, _)| Pick::Shadow(i))
    }

    /// May `half`'s scheduler issue shadow `idx`?
    ///
    /// On a split cluster, slow warps belong to the second half (§4.3) but
    /// shadows are picked *after* warps, so the first half only reaches
    /// them in otherwise-idle slots — this is the paper's "periodically
    /// move some fast warps so the resources are not wasted" in reverse:
    /// spare fast-half slots drain the slow bin instead of idling.
    fn shadow_eligible(&self, idx: usize, half: u8) -> bool {
        match self.mode {
            ClusterMode::FusedSplit => true,
            // DWS / others: same half as the parent warp.
            _ => self.warps[self.shadows[idx].parent].home == half,
        }
    }

    fn issue_half(&mut self, now: u64, half: u8, all_homes: bool, gen: &TraceGen) {
        if self.sched[half as usize].busy_until > now {
            self.stats.stall(StallReason::ExecBusy);
            return;
        }
        let Some(pick) = self.pick(half, all_homes) else {
            self.account_stall(half, all_homes);
            return;
        };
        match pick {
            Pick::Warp(i) => self.issue_warp(now, half, i, gen),
            Pick::Shadow(i) => self.issue_shadow(now, half, i, gen),
        }
    }

    /// Classify why nothing was issuable (stall breakdown, Fig 6/13).
    fn account_stall(&mut self, half: u8, all_homes: bool) {
        let r = self.stall_reason(half, all_homes);
        self.stats.stall(r);
    }

    /// The stall reason `account_stall` would record for `half` this
    /// cycle, memoized on `sched_stamp`: a stalled slot whose warp and
    /// shadow state has not changed since the last classification reuses
    /// it in O(1) instead of re-scanning the tables every cycle (the
    /// partially-busy regime: one half issuing, the other parked on
    /// memory). Every mutation path bumps the stamp, so the cache can
    /// never serve a stale class — re-verified against the scan in
    /// debug builds.
    fn stall_reason(&mut self, half: u8, all_homes: bool) -> StallReason {
        let slot = half as usize;
        let (stamp, cached) = self.stall_cache[slot];
        if stamp == self.sched_stamp {
            debug_assert_eq!(cached, self.stall_reason_uncached(half, all_homes));
            return cached;
        }
        let r = self.stall_reason_uncached(half, all_homes);
        self.stall_cache[slot] = (self.sched_stamp, r);
        r
    }

    /// The uncached classification scan (also the skip path's oracle:
    /// warp/shadow state is frozen across a promised window, so one
    /// classification multiplies across it).
    fn stall_reason_uncached(&self, half: u8, all_homes: bool) -> StallReason {
        let mut any = false;
        let mut mem = false;
        let mut bar = false;
        let mut ctrl = false;
        for w in &self.warps {
            if w.finished || (!all_homes && w.home != half) {
                continue;
            }
            any = true;
            if w.waiting_on_shadow() {
                ctrl = true;
            } else if w.at_barrier {
                bar = true;
            } else if w.outstanding_loads > 0 || w.ifetch_pending {
                mem = true;
            }
        }
        for (i, s) in self.shadows.iter().enumerate() {
            if s.complete() || (!all_homes && !self.shadow_eligible(i, half)) {
                continue;
            }
            any = true;
            if s.outstanding_loads > 0 || s.ifetch_pending {
                mem = true;
            }
        }
        if !any {
            StallReason::Idle
        } else if ctrl {
            StallReason::Control
        } else if mem {
            StallReason::Memory
        } else if bar {
            StallReason::Barrier
        } else {
            StallReason::ExecBusy
        }
    }

    /// Initiation interval: cycles the issue port is held per instruction.
    fn ii(&self, width: usize) -> u64 {
        let lanes = match self.mode {
            ClusterMode::Fused => self.cfg.simd_width * 2,
            _ => self.cfg.simd_width,
        };
        width.div_ceil(lanes) as u64
    }

    /// Is the LSU too full to accept another memory instruction?
    fn lsu_full(&self) -> bool {
        self.lsu.len() >= LSU_QUEUE_CAP
    }

    fn issue_warp(&mut self, now: u64, half: u8, wi: usize, gen: &TraceGen) {
        let pc = self.warps[wi].pc;
        // Memory-instruction backpressure: peek the op kind first.
        let cta = self.warps[wi].id.cta;
        let sub0 = self.warps[wi].subwarps[0];
        let op0 = gen.resolve(cta, sub0, pc);
        if op0.is_cached_mem() && self.lsu_full() {
            self.stats.stall(StallReason::MemStructFull);
            self.stats.mem_struct_stall_cycles += 1;
            return;
        }
        // Instruction fetch.
        if !self.fetch(self.cache_idx(half), half, gen.code_addr(pc), Waiter::IFetchWarp(wi)) {
            return;
        }
        let w = &self.warps[wi];
        let width = w.width;
        let sub1 = w.subwarps[1];
        let n_sub = w.n_subwarps;
        let in_replay = w.replay.is_some();
        let mask = w.mask;
        let ii = self.ii(width);

        self.stats.warp_insns += 1;
        self.stats.thread_insns += mask.count() as u64;
        self.stats.total_lane_cycles += (width as u64) * ii;
        self.stats.inactive_lane_cycles += (width as u64 - mask.count() as u64) * ii;
        if in_replay {
            // Replay passes are the control-divergence serialisation cost.
            self.stats.stall_control += ii;
        }
        self.sched[half as usize].busy_until = now + ii;
        self.sched[half as usize].greedy = Some(wi);

        match op0 {
            Op::IAlu | Op::FAlu | Op::Sfu => {}
            Op::Ld { space: MemSpace::Shared, .. } | Op::St { space: MemSpace::Shared, .. } => {}
            Op::Ld { space, pattern } => {
                let mut lines = std::mem::take(&mut self.coalesce_scratch);
                let requests = self.coalesce_for(gen, cta, sub1, n_sub, pc, &pattern, mask, width, &mut lines);
                self.stats.mem_insns += 1;
                self.stats.mem_requests += requests as u64;
                self.stats.mem_transactions += lines.len() as u64;
                let kind = match space {
                    MemSpace::Const => CacheKind::Const,
                    MemSpace::Texture => CacheKind::Texture,
                    _ => CacheKind::Data,
                };
                self.warps[wi].outstanding_loads += lines.len() as u32;
                for &line in &lines {
                    self.lsu.push_back(Transaction {
                        line,
                        kind,
                        is_write: false,
                        waiter: Waiter::Warp(wi),
                        half,
                        needs_inject: false,
                    });
                }
                self.coalesce_scratch = lines;
            }
            Op::St { pattern, .. } => {
                let mut lines = std::mem::take(&mut self.coalesce_scratch);
                let requests = self.coalesce_for(gen, cta, sub1, n_sub, pc, &pattern, mask, width, &mut lines);
                self.stats.mem_insns += 1;
                self.stats.st_insns += 1;
                self.stats.mem_requests += requests as u64;
                self.stats.mem_transactions += lines.len() as u64;
                for &line in &lines {
                    self.lsu.push_back(Transaction {
                        line,
                        kind: CacheKind::Data,
                        is_write: true,
                        waiter: Waiter::None,
                        half,
                        needs_inject: false,
                    });
                }
                self.coalesce_scratch = lines;
            }
            Op::Branch { diverges, region_len } => {
                self.stats.branches += 1;
                if !in_replay && region_len > 0 {
                    // A fused warp diverges if EITHER sub-warp diverges —
                    // the wider-pipeline penalty of §3.1(3).
                    let div1 = n_sub == 2
                        && matches!(gen.resolve(cta, sub1, pc), Op::Branch { diverges: true, .. });
                    if diverges || div1 {
                        self.stats.divergent_branches += 1;
                        let slow =
                            self.slow_mask(gen, cta, sub0, sub1, n_sub, pc, diverges, div1, width);
                        self.handle_divergence(wi, pc, region_len, slow, cta, sub0, width);
                    }
                }
            }
            Op::Bar => {
                let slot = self.warps[wi].cta_slot;
                self.warps[wi].at_barrier = true;
                self.ctas[slot].barrier_count += 1;
                if self.ctas[slot].barrier_count >= self.live_in_cta(slot) {
                    self.release_barrier(slot);
                }
            }
            Op::Exit => {}
        }

        if self.warps[wi].advance() {
            let slot = self.warps[wi].cta_slot;
            self.ctas[slot].warps_done += 1;
            self.stats.warps_retired += 1;
            if self.ctas[slot].complete() {
                self.stats.ctas_retired += 1;
            }
            // Barrier bookkeeping: a retiring warp lowers the live count;
            // re-check release for its CTA.
            let live = self.live_in_cta(slot);
            if live > 0 && self.ctas[slot].barrier_count >= live {
                self.release_barrier(slot);
            }
        }
        self.refile_warp(wi);
    }

    /// Route a fresh divergence through the active policy:
    ///
    /// * `Shadowed` divergence mode (DWS machine-wide, or warp-regrouping
    ///   on a split cluster): the slow pass becomes an independently
    ///   schedulable [`ShadowWarp`]; the issuing warp runs only the fast
    ///   pass and waits at the reconvergence point.
    /// * `FusedSplit` + direct-split policy: the whole warp migrates to
    ///   the second half (SM_1) and serialises both paths there (§4.3).
    /// * otherwise: classic serial two-pass replay.
    #[allow(clippy::too_many_arguments)]
    fn handle_divergence(
        &mut self,
        wi: usize,
        pc: u32,
        region_len: u16,
        slow: ActiveMask,
        cta: u32,
        sub0: u32,
        width: usize,
    ) {
        let shadowed = self.divergence_mode == DivergenceMode::Shadowed
            || (self.mode == ClusterMode::FusedSplit
                && self.split_policy == Some(SplitPolicy::Regroup));
        if shadowed && slow.count() > 0 && slow.count() < width as u32 {
            self.warps[wi].begin_divergence(region_len, slow, true);
            self.spawn_shadow(ShadowWarp {
                parent: wi,
                cta,
                subwarp: sub0,
                pc: pc + 1,
                end_pc: pc + 1 + region_len as u32,
                mask: slow,
                width,
                outstanding_loads: 0,
                ifetch_pending: false,
                done: false,
            });
        } else {
            self.warps[wi].begin_divergence(region_len, slow, false);
            if self.mode == ClusterMode::FusedSplit
                && self.split_policy == Some(SplitPolicy::Direct)
            {
                // Move the divergent warp to the slow half.
                self.warps[wi].home = 1;
            }
        }
    }

    /// Coalesce one warp access into `lines` (cleared first; the caller
    /// passes the cluster's reusable scratch buffer). Returns the
    /// lane-level request count.
    #[allow(clippy::too_many_arguments)]
    fn coalesce_for(
        &self,
        gen: &TraceGen,
        cta: u32,
        sub1: u32,
        n_sub: u8,
        pc: u32,
        pattern: &crate::isa::AccessPattern,
        mask: ActiveMask,
        width: usize,
        lines: &mut Vec<u64>,
    ) -> u32 {
        if n_sub == 2 {
            let pat1 = match gen.resolve(cta, sub1, pc) {
                Op::Ld { pattern, .. } | Op::St { pattern, .. } => pattern,
                _ => *pattern,
            };
            coalesce_fused_into(pattern, &pat1, mask, self.cfg.line_bytes, lines)
        } else {
            coalesce_into(pattern, mask, width, self.cfg.line_bytes, lines)
        }
    }

    /// Build the slow-lane mask for a diverging (possibly fused) warp.
    #[allow(clippy::too_many_arguments)]
    fn slow_mask(
        &self,
        gen: &TraceGen,
        cta: u32,
        sub0: u32,
        sub1: u32,
        n_sub: u8,
        pc: u32,
        div0: bool,
        div1: bool,
        width: usize,
    ) -> ActiveMask {
        let mut slow = ActiveMask::empty();
        let half_w = if n_sub == 2 { width / 2 } else { width };
        if div0 {
            let frac = gen.divergence_split(cta, sub0, pc);
            let n = ((half_w as f64 * frac).round() as usize).clamp(1, half_w - 1);
            for i in 0..n {
                slow.set(i);
            }
        }
        if n_sub == 2 && div1 {
            let frac = gen.divergence_split(cta, sub1, pc);
            let n = ((half_w as f64 * frac).round() as usize).clamp(1, half_w - 1);
            for i in 0..n {
                slow.set(half_w + i);
            }
        }
        slow
    }

    fn issue_shadow(&mut self, now: u64, half: u8, si: usize, gen: &TraceGen) {
        let pc = self.shadows[si].pc;
        let cta = self.shadows[si].cta;
        let sub = self.shadows[si].subwarp;
        let op = gen.resolve(cta, sub, pc);
        if op.is_cached_mem() && self.lsu_full() {
            self.stats.stall(StallReason::MemStructFull);
            self.stats.mem_struct_stall_cycles += 1;
            return;
        }
        if !self.fetch(self.cache_idx(half), half, gen.code_addr(pc), Waiter::IFetchShadow(si)) {
            return;
        }
        let s = &self.shadows[si];
        let (mask, width) = (s.mask, s.width);
        let ii = self.ii(self.cfg.warp_size);
        self.stats.warp_insns += 1;
        self.stats.thread_insns += mask.count() as u64;
        self.stats.total_lane_cycles += (self.cfg.warp_size as u64) * ii;
        self.stats.inactive_lane_cycles +=
            (self.cfg.warp_size as u64).saturating_sub(mask.count() as u64) * ii;
        self.sched[half as usize].busy_until = now + ii;
        self.sched[half as usize].greedy_shadow = Some(si);

        match op {
            Op::Ld { space, pattern } if space != MemSpace::Shared => {
                let mut lines = std::mem::take(&mut self.coalesce_scratch);
                let requests =
                    coalesce_into(&pattern, mask, width.min(64), self.cfg.line_bytes, &mut lines);
                self.stats.mem_insns += 1;
                self.stats.mem_requests += requests as u64;
                self.stats.mem_transactions += lines.len() as u64;
                let kind = match space {
                    MemSpace::Const => CacheKind::Const,
                    MemSpace::Texture => CacheKind::Texture,
                    _ => CacheKind::Data,
                };
                self.shadows[si].outstanding_loads += lines.len() as u32;
                for &line in &lines {
                    self.lsu.push_back(Transaction {
                        line,
                        kind,
                        is_write: false,
                        waiter: Waiter::Shadow(si),
                        half,
                        needs_inject: false,
                    });
                }
                self.coalesce_scratch = lines;
            }
            Op::St { space, pattern } if space != MemSpace::Shared => {
                let mut lines = std::mem::take(&mut self.coalesce_scratch);
                let requests =
                    coalesce_into(&pattern, mask, width.min(64), self.cfg.line_bytes, &mut lines);
                self.stats.mem_insns += 1;
                self.stats.st_insns += 1;
                self.stats.mem_requests += requests as u64;
                self.stats.mem_transactions += lines.len() as u64;
                for &line in &lines {
                    self.lsu.push_back(Transaction {
                        line,
                        kind: CacheKind::Data,
                        is_write: true,
                        waiter: Waiter::None,
                        half,
                        needs_inject: false,
                    });
                }
                self.coalesce_scratch = lines;
            }
            _ => {}
        }
        if self.shadows[si].advance() && self.shadows[si].complete() {
            self.reconverge_shadow(si);
        }
        self.note_shadow_change();
    }

    /// Instruction fetch: probe the L1I; on a hit, touch LRU and proceed.
    /// On a miss, park the requester and enqueue a fill transaction.
    fn fetch(&mut self, ci: usize, half: u8, code_line: u64, waiter: Waiter) -> bool {
        self.stats.l1i_accesses += 1;
        if self.l1i[ci].probe(code_line) {
            let r = self.l1i[ci].access(code_line);
            debug_assert_eq!(r, Access::Hit);
            return true;
        }
        self.stats.l1i_misses += 1;
        match waiter {
            Waiter::IFetchWarp(i) => {
                self.warps[i].ifetch_pending = true;
                self.refile_warp(i);
            }
            Waiter::IFetchShadow(i) => {
                self.shadows[i].ifetch_pending = true;
                self.note_shadow_change();
            }
            _ => {}
        }
        self.lsu.push_back(Transaction {
            line: code_line,
            kind: CacheKind::Instr,
            is_write: false,
            waiter,
            half,
            needs_inject: false,
        });
        false
    }

    // ------------------------------------------------------------------
    // Memory pipeline
    // ------------------------------------------------------------------

    /// Process LSU transactions: exactly one `Cache::access` per
    /// transaction, with injection retried in a separate state.
    fn process_lsu(&mut self, now: u64, noc: &mut NocPort<'_>, noc_nodes: [usize; 2]) {
        for _ in 0..LSU_WIDTH {
            let Some(tx) = self.lsu.front().copied() else { break };
            let ci = self.cache_idx(tx.half);
            if tx.needs_inject {
                let node = self.node_for(tx.half, noc_nodes);
                if self.inject_request(now, noc, node, tx.line, tx.is_write) {
                    let key = Self::pending_key(tx.line, tx.kind, ci);
                    if let Some(p) = self.pending.get_mut(key) {
                        p.injected = true;
                        p.sent = now;
                    }
                    self.lsu.pop_front();
                } else {
                    self.stats.stall(StallReason::MemStructFull);
                    self.stats.mem_struct_stall_cycles += 1;
                    break;
                }
                continue;
            }
            if tx.is_write {
                // Write-through, no-allocate: straight to the NoC.
                let node = self.node_for(tx.half, noc_nodes);
                if self.inject_request(now, noc, node, tx.line, true) {
                    self.count_access(tx.kind, false);
                    self.lsu.pop_front();
                } else {
                    self.stats.stall(StallReason::MemStructFull);
                    self.stats.mem_struct_stall_cycles += 1;
                    break;
                }
                continue;
            }
            let cache = self.cache_mut(tx.kind, ci);
            match cache.access(tx.line) {
                Access::Hit => {
                    self.count_access(tx.kind, false);
                    self.release(tx.waiter);
                    self.lsu.pop_front();
                }
                Access::MissMerged => {
                    self.count_access(tx.kind, true);
                    self.stats.mshr_merges += 1;
                    let key = Self::pending_key(tx.line, tx.kind, ci);
                    let p = self
                        .pending
                        .get_mut(key)
                        .expect("MissMerged implies a pending entry (MissNew creates it)");
                    p.waiters.push(tx.waiter);
                    self.lsu.pop_front();
                }
                Access::MissNew => {
                    self.count_access(tx.kind, true);
                    self.stats.mshr_allocs += 1;
                    let key = Self::pending_key(tx.line, tx.kind, ci);
                    self.pending.insert(key, tx.line, tx.kind, tx.half, tx.waiter, now);
                    // Transition to the injection state (retries at front).
                    if let Some(front) = self.lsu.front_mut() {
                        front.needs_inject = true;
                    }
                }
                Access::MshrFull => {
                    self.stats.stall(StallReason::MemStructFull);
                    self.stats.mem_struct_stall_cycles += 1;
                    break;
                }
            }
        }
    }

    fn cache_mut(&mut self, kind: CacheKind, ci: usize) -> &mut Cache {
        match kind {
            CacheKind::Data => &mut self.l1d[ci],
            CacheKind::Instr => &mut self.l1i[ci],
            CacheKind::Const => &mut self.l1c[ci],
            CacheKind::Texture => &mut self.l1t[ci],
        }
    }

    fn cache_ref(&self, kind: CacheKind, ci: usize) -> &Cache {
        match kind {
            CacheKind::Data => &self.l1d[ci],
            CacheKind::Instr => &self.l1i[ci],
            CacheKind::Const => &self.l1c[ci],
            CacheKind::Texture => &self.l1t[ci],
        }
    }

    fn count_access(&mut self, kind: CacheKind, miss: bool) {
        match kind {
            CacheKind::Data => {
                self.stats.l1d_accesses += 1;
                self.stats.l1d_misses += miss as u64;
            }
            // I-cache accesses/misses are counted at fetch time.
            CacheKind::Instr => {}
            CacheKind::Const => {
                self.stats.l1c_accesses += 1;
                self.stats.l1c_misses += miss as u64;
            }
            CacheKind::Texture => {
                self.stats.l1t_accesses += 1;
                self.stats.l1t_misses += miss as u64;
            }
        }
    }

    /// NoC node used by `half` in the current machine layout.
    fn node_for(&self, half: u8, noc_nodes: [usize; 2]) -> usize {
        match self.mode {
            ClusterMode::PrivatePair => noc_nodes[half as usize],
            // Fused/FusedSplit: single shared interface (router bypass).
            _ => noc_nodes[0],
        }
    }

    fn inject_request(&mut self, now: u64, noc: &mut NocPort<'_>, node: usize, line: u64, is_write: bool) -> bool {
        let num_mcs = self.cfg.num_mcs;
        let mc = crate::sim::mem::partition_of(line, self.cfg.line_bytes, num_mcs);
        let dst = noc.nodes() - num_mcs + mc;
        let flits = if is_write {
            self.cfg.flits_for(self.cfg.line_bytes + 16) as u32
        } else {
            1
        };
        let pkt = Packet {
            src: node,
            dst,
            flits,
            born: now,
            payload: Payload::MemRequest { line, requester: self.id as u32, is_write },
        };
        if noc.inject(Subnet::Request, pkt) {
            self.stats.noc_packets += 1;
            self.stats.noc_flits += flits as u64;
            true
        } else {
            false
        }
    }

    /// A reply line arrived from the NoC at this cluster.
    pub fn on_reply(&mut self, now: u64, line: u64, is_write: bool) {
        if is_write {
            return; // write-through acks carry no waiters
        }
        // One scan finds the injected entry regardless of which cache
        // kind / half it belongs to (entries carry their line address).
        let Some(p) = self.pending.take_reply(line) else { return };
        self.stats.noc_latency_sum += now.saturating_sub(p.sent);
        self.stats.noc_latency_samples += 1;
        let ci = self.cache_idx(p.half);
        self.cache_mut(p.kind, ci).fill(line);
        for i in 0..p.waiters.len() {
            self.release(p.waiters[i]);
        }
        self.pending.recycle(p);
    }

    fn release(&mut self, w: Waiter) {
        match w {
            Waiter::Warp(i) => {
                let wp = &mut self.warps[i];
                wp.outstanding_loads = wp.outstanding_loads.saturating_sub(1);
                self.refile_warp(i);
            }
            Waiter::Shadow(i) => {
                let s = &mut self.shadows[i];
                s.outstanding_loads = s.outstanding_loads.saturating_sub(1);
                self.note_shadow_change();
                if self.shadows[i].complete() {
                    self.reconverge_shadow(i);
                }
            }
            Waiter::IFetchWarp(i) => {
                self.warps[i].ifetch_pending = false;
                self.refile_warp(i);
            }
            Waiter::IFetchShadow(i) => {
                self.shadows[i].ifetch_pending = false;
                self.note_shadow_change();
            }
            Waiter::None => {}
        }
    }

    fn reconverge_shadow(&mut self, si: usize) {
        let parent = self.shadows[si].parent;
        if self.warps[parent].shadow_outstanding {
            self.warps[parent].shadow_done();
            self.refile_warp(parent);
        }
        self.note_shadow_change();
    }

    /// Remove fully-complete shadows when no references remain.
    pub fn reap_shadows(&mut self) {
        if self.shadows.iter().all(|s| s.complete())
            && !self
                .pending
                .iter()
                .any(|p| p.waiters.iter().any(|w| matches!(w, Waiter::Shadow(_) | Waiter::IFetchShadow(_))))
            && !self
                .lsu
                .iter()
                .any(|t| matches!(t.waiter, Waiter::Shadow(_) | Waiter::IFetchShadow(_)))
        {
            self.shadows.clear();
            self.sched[0].greedy_shadow = None;
            self.sched[1].greedy_shadow = None;
            self.note_shadow_change();
        }
    }

    /// Spawn a shadow warp (regroup slow pass / DWS subdivision).
    pub fn spawn_shadow(&mut self, shadow: ShadowWarp) {
        self.shadows.push(shadow);
        self.note_shadow_change();
    }

    /// Any shadows still executing?
    pub fn shadows_active(&self) -> bool {
        self.shadows.iter().any(|s| !s.complete())
    }

    /// Fraction of live warps currently flagged divergent (the split
    /// trigger metric of §4.3).
    pub fn divergent_ratio(&self) -> f32 {
        let live = self.live_warps();
        if live == 0 {
            return 0.0;
        }
        let div = self.warps.iter().filter(|w| !w.finished && w.divergent).count();
        div as f32 / live as f32
    }

    /// Fingerprint of the cluster's externally observable progress state:
    /// issue/commit counters, memory-pipeline occupancy, and per-warp
    /// blocking state. Within a window where [`SmCluster::next_event`]
    /// promised no state change this must stay constant — the
    /// multi-stream horizon-tightness property in
    /// `tests/prop_invariants.rs` walks promised horizons and asserts it.
    /// Per-cycle accounting (stall counters, LRU clocks) is deliberately
    /// excluded: the skip engine replays that in O(1).
    pub fn progress_probe(&self) -> u64 {
        crate::workload::hash_combine(&[
            self.stats.warp_insns,
            self.stats.thread_insns,
            self.stats.mem_insns,
            self.stats.l1d_accesses,
            self.stats.l1i_accesses + self.stats.l1c_accesses + self.stats.l1t_accesses,
            self.stats.noc_packets,
            self.stats.ctas_retired,
            self.lsu.len() as u64,
            self.pending.len() as u64,
            self.warps
                .iter()
                .map(|w| w.outstanding_loads as u64 + w.ifetch_pending as u64)
                .sum(),
        ])
    }

    /// One-line state summary for deadlock diagnostics.
    pub fn debug_state(&self) -> String {
        let live = self.live_warps();
        let blocked_mem = self.warps.iter().filter(|w| !w.finished && w.outstanding_loads > 0).count();
        let blocked_if = self.warps.iter().filter(|w| !w.finished && w.ifetch_pending).count();
        let front = self.lsu.front().map(|t| {
            format!("line={:#x} kind={:?} w={} inj={}", t.line, t.kind, t.is_write, t.needs_inject)
        });
        format!(
            "mode={:?} live={live} mem_blocked={blocked_mem} if_blocked={blocked_if} lsu={} pending={} shadows={} dead_half={:?} front={:?}",
            self.mode,
            self.lsu.len(),
            self.pending.len(),
            self.shadows.len(),
            self.dead_half,
            front
        )
    }

    /// Kernel-boundary cleanup (caches cold-start per kernel, as in the
    /// paper's per-kernel reconfiguration loop).
    pub fn flush_caches(&mut self) {
        for i in 0..2 {
            self.l1d[i].flush();
            self.l1i[i].flush();
            self.l1c[i].flush();
            self.l1t[i].flush();
        }
        self.pending.clear();
        self.lsu.clear();
    }

    // ------------------------------------------------------------------
    // Fault injection (sim::fault)
    // ------------------------------------------------------------------

    /// Mark `half` as permanently dead. All future CTA dispatch homes on
    /// the healthy half; merged modes refuse CTAs until the GPU forces
    /// the split layout.
    pub fn set_dead_half(&mut self, half: u8) {
        debug_assert!(half <= 1);
        self.dead_half = Some(half);
        self.sched_stamp += 1;
    }

    /// The permanently dead half-SM, if a half-SM fault hit this cluster.
    pub fn dead_half(&self) -> Option<u8> {
        self.dead_half
    }

    /// Hard-clear the cluster after a fault: abandon every in-flight
    /// warp, shadow, and memory transaction, and return the ids of the
    /// CTAs that had not completed (the GPU requeues them elsewhere).
    /// Unlike [`SmCluster::reap`] this does not require the cluster to be
    /// idle — that is the point. In-flight NoC replies addressed here are
    /// safe: [`SmCluster::on_reply`] drops lines with no pending entry.
    pub fn fail_clear(&mut self) -> Vec<u32> {
        let lost: Vec<u32> =
            self.ctas.iter().filter(|c| !c.complete()).map(|c| c.cta).collect();
        self.warps.clear();
        self.shadows.clear();
        self.ctas.clear();
        self.sched = [HalfSched::default(), HalfSched::default()];
        self.ready_count = [0, 0];
        self.sched_stamp += 1;
        self.flush_caches();
        lost
    }

    // ------------------------------------------------------------------
    // Checkpoint (sim::snapshot)
    // ------------------------------------------------------------------

    /// Serialize the cluster's full mutable state. Derived scheduler
    /// structures (ready-warp index, stall-classification cache, pending
    /// index, pooled scratch) are rebuilt on load; everything the machine
    /// computes from is captured verbatim, including `sched_stamp` so a
    /// restored machine re-saves byte-identically.
    pub fn save_state(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        w.u8(match self.mode {
            ClusterMode::PrivatePair => 0,
            ClusterMode::Fused => 1,
            ClusterMode::FusedSplit => 2,
        });
        w.usize(self.warps.len());
        for wp in &self.warps {
            wp.write_to(w);
        }
        w.usize(self.shadows.len());
        for s in &self.shadows {
            s.write_to(w);
        }
        w.usize(self.ctas.len());
        for c in &self.ctas {
            c.write_to(w);
        }
        for i in 0..2 {
            self.l1d[i].save_state(w);
            self.l1i[i].save_state(w);
            self.l1c[i].save_state(w);
            self.l1t[i].save_state(w);
        }
        w.usize(self.lsu.len());
        for t in &self.lsu {
            w.u64(t.line);
            w.u8(t.kind as u8);
            w.bool(t.is_write);
            write_waiter(w, &t.waiter);
            w.u8(t.half);
            w.bool(t.needs_inject);
        }
        w.usize(self.pending.len());
        for p in self.pending.iter() {
            w.u64(p.key);
            w.u64(p.line);
            w.u8(p.kind as u8);
            w.u8(p.half);
            w.usize(p.waiters.len());
            for wt in &p.waiters {
                write_waiter(w, wt);
            }
            w.u64(p.sent);
            w.bool(p.injected);
        }
        for s in &self.sched {
            w.u64(s.busy_until);
            write_opt_usize(w, s.greedy);
            write_opt_usize(w, s.greedy_shadow);
        }
        w.u64(self.age_counter);
        w.u64(self.sched_stamp);
        self.stats.write_to(w);
        match self.dead_half {
            Some(h) => {
                w.bool(true);
                w.u8(h);
            }
            None => w.bool(false),
        }
        w.u64(self.frozen_until);
        w.u8(match self.divergence_mode {
            DivergenceMode::Serial => 0,
            DivergenceMode::Shadowed => 1,
        });
        match self.split_policy {
            Some(SplitPolicy::Direct) => {
                w.bool(true);
                w.u8(0);
            }
            Some(SplitPolicy::Regroup) => {
                w.bool(true);
                w.u8(1);
            }
            None => w.bool(false),
        }
        w.u32(self.cta_threads);
        w.u32(self.cta_regs);
        w.u32(self.cta_smem);
    }

    /// Inverse of [`SmCluster::save_state`] into a cluster built for the
    /// same config. Validates every cross-reference (CTA slots, shadow
    /// parents, waiter indices) so corrupt input errors here instead of
    /// panicking mid-simulation.
    pub fn load_state(
        &mut self,
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<()> {
        use crate::errors::err;
        let mode = match r.u8()? {
            0 => ClusterMode::PrivatePair,
            1 => ClusterMode::Fused,
            2 => ClusterMode::FusedSplit,
            t => return Err(err(format!("unknown cluster mode tag {t}"))),
        };
        // set_mode rebuilds the cache geometry across the merged/private
        // boundary; the second cache index always keeps private geometry,
        // so a fresh cluster reaches the saved shape from any start mode.
        self.set_mode(mode);
        let nw = r.seq_len(64)?;
        self.warps.clear();
        for _ in 0..nw {
            self.warps.push(WarpCtx::read_from(r)?);
        }
        let ns = r.seq_len(40)?;
        self.shadows.clear();
        for _ in 0..ns {
            self.shadows.push(ShadowWarp::read_from(r)?);
        }
        let nc = r.seq_len(21)?;
        self.ctas.clear();
        for _ in 0..nc {
            self.ctas.push(CtaState::read_from(r)?);
        }
        for i in 0..2 {
            self.l1d[i].load_state(r)?;
            self.l1i[i].load_state(r)?;
            self.l1c[i].load_state(r)?;
            self.l1t[i].load_state(r)?;
        }
        let nl = r.seq_len(12)?;
        self.lsu.clear();
        for _ in 0..nl {
            let line = r.u64()?;
            let kind = read_cache_kind(r)?;
            let is_write = r.bool()?;
            let waiter = read_waiter(r)?;
            let half = r.u8()?;
            let needs_inject = r.bool()?;
            self.lsu.push_back(Transaction { line, kind, is_write, waiter, half, needs_inject });
        }
        let np = r.seq_len(27)?;
        self.pending.clear();
        for _ in 0..np {
            let key = r.u64()?;
            let line = r.u64()?;
            let kind = read_cache_kind(r)?;
            let half = r.u8()?;
            let nwt = r.seq_len(1)?;
            let mut waiters = self.pending.waiter_pool.pop().unwrap_or_default();
            waiters.clear();
            for _ in 0..nwt {
                waiters.push(read_waiter(r)?);
            }
            let sent = r.u64()?;
            let injected = r.bool()?;
            self.pending.index.insert(key, self.pending.entries.len() as u32);
            self.pending.entries.push(PendingLine { key, line, kind, half, waiters, sent, injected });
        }
        for s in self.sched.iter_mut() {
            s.busy_until = r.u64()?;
            s.greedy = read_opt_usize(r)?;
            s.greedy_shadow = read_opt_usize(r)?;
        }
        self.age_counter = r.u64()?;
        let sched_stamp = r.u64()?;
        self.stats = SmStats::read_from(r)?;
        self.dead_half = if r.bool()? { Some(r.u8()?) } else { None };
        self.frozen_until = r.u64()?;
        self.divergence_mode = match r.u8()? {
            0 => DivergenceMode::Serial,
            1 => DivergenceMode::Shadowed,
            t => return Err(err(format!("unknown divergence mode tag {t}"))),
        };
        self.split_policy = if r.bool()? {
            Some(match r.u8()? {
                0 => SplitPolicy::Direct,
                1 => SplitPolicy::Regroup,
                t => return Err(err(format!("unknown split policy tag {t}"))),
            })
        } else {
            None
        };
        self.cta_threads = r.u32()?;
        self.cta_regs = r.u32()?;
        self.cta_smem = r.u32()?;
        // Cross-reference validation: a panic-free contract for corrupt
        // (but structurally parseable) input.
        let check_waiter = |wt: &Waiter, nw: usize, ns: usize| -> bool {
            match *wt {
                Waiter::Warp(i) | Waiter::IFetchWarp(i) => i < nw,
                Waiter::Shadow(i) | Waiter::IFetchShadow(i) => i < ns,
                Waiter::None => true,
            }
        };
        let (nw, ns) = (self.warps.len(), self.shadows.len());
        for wp in &self.warps {
            if wp.cta_slot >= self.ctas.len() {
                return Err(err("checkpoint warp references a missing CTA slot"));
            }
        }
        for s in &self.shadows {
            if s.parent >= nw {
                return Err(err("checkpoint shadow references a missing parent warp"));
            }
        }
        for c in &self.ctas {
            if c.warp_ids.iter().any(|&wi| wi as usize >= nw) {
                return Err(err("checkpoint CTA references a missing warp"));
            }
        }
        if self.lsu.iter().any(|t| !check_waiter(&t.waiter, nw, ns))
            || self.pending.iter().any(|p| p.waiters.iter().any(|wt| !check_waiter(wt, nw, ns)))
        {
            return Err(err("checkpoint memory waiter references a missing warp/shadow"));
        }
        // Rebuild the derived scheduler state, then restore the stamp so a
        // re-save is byte-identical to the original capture.
        self.rebuild_sched();
        self.sched_stamp = sched_stamp;
        self.stall_cache = [(u64::MAX, StallReason::Idle); 2];
        Ok(())
    }
}

/// Serialize one memory waiter (checkpoint format).
fn write_waiter(w: &mut crate::sim::snapshot::ByteWriter, wt: &Waiter) {
    match *wt {
        Waiter::Warp(i) => {
            w.u8(0);
            w.usize(i);
        }
        Waiter::Shadow(i) => {
            w.u8(1);
            w.usize(i);
        }
        Waiter::IFetchWarp(i) => {
            w.u8(2);
            w.usize(i);
        }
        Waiter::IFetchShadow(i) => {
            w.u8(3);
            w.usize(i);
        }
        Waiter::None => w.u8(4),
    }
}

/// Inverse of [`write_waiter`].
fn read_waiter(r: &mut crate::sim::snapshot::ByteReader<'_>) -> crate::errors::Result<Waiter> {
    Ok(match r.u8()? {
        0 => Waiter::Warp(r.usize()?),
        1 => Waiter::Shadow(r.usize()?),
        2 => Waiter::IFetchWarp(r.usize()?),
        3 => Waiter::IFetchShadow(r.usize()?),
        4 => Waiter::None,
        t => return Err(crate::errors::err(format!("unknown waiter tag {t}"))),
    })
}

/// Decode a cache-kind tag.
fn read_cache_kind(
    r: &mut crate::sim::snapshot::ByteReader<'_>,
) -> crate::errors::Result<CacheKind> {
    Ok(match r.u8()? {
        0 => CacheKind::Data,
        1 => CacheKind::Instr,
        2 => CacheKind::Const,
        3 => CacheKind::Texture,
        t => return Err(crate::errors::err(format!("unknown cache kind tag {t}"))),
    })
}

/// `Option<usize>` as a bool tag + value.
fn write_opt_usize(w: &mut crate::sim::snapshot::ByteWriter, v: Option<usize>) {
    match v {
        Some(x) => {
            w.bool(true);
            w.usize(x);
        }
        None => w.bool(false),
    }
}

/// Inverse of [`write_opt_usize`].
fn read_opt_usize(
    r: &mut crate::sim::snapshot::ByteReader<'_>,
) -> crate::errors::Result<Option<usize>> {
    Ok(if r.bool()? { Some(r.usize()?) } else { None })
}

/// Scheduler pick.
#[derive(Debug, Clone, Copy)]
enum Pick {
    Warp(usize),
    Shadow(usize),
}
