//! Warp execution contexts: the schedulable entities of an SM.
//!
//! A `WarpCtx` is a (possibly fused, 64-wide) warp walking its procedural
//! trace. Control divergence is modelled by *replay*: a divergent branch
//! splits the active mask and serialises the divergent region once per
//! path. Under the warp-regrouping policy (and DWS) the second path runs
//! concurrently as a [`ShadowWarp`] on another scheduler instead.

use crate::isa::{ActiveMask, WarpId};

/// Divergent-region replay state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Replay {
    /// First PC of the divergent region.
    pub start_pc: u32,
    /// One past the last PC of the region (reconvergence point).
    pub end_pc: u32,
    /// Mask of the second (slow) pass.
    pub second_mask: ActiveMask,
    /// Currently executing the second pass?
    pub in_second_pass: bool,
}

/// A resident warp.
#[derive(Debug, Clone)]
pub struct WarpCtx {
    /// Grid identity (kernel, cta, fused-warp index).
    pub id: WarpId,
    /// Sub-warp indices within the CTA this context covers. Baseline warps
    /// cover one; fused 64-wide warps cover two (lanes 0-31 / 32-63).
    pub subwarps: [u32; 2],
    /// Number of sub-warps (1 or 2).
    pub n_subwarps: u8,
    /// Warp width in lanes (32 baseline, 64 fused).
    pub width: usize,
    /// Next trace PC.
    pub pc: u32,
    /// Per-thread trace length (warp retires at `pc == trace_len`).
    pub trace_len: u32,
    /// Current active mask.
    pub mask: ActiveMask,
    /// Mask with every existing lane active.
    pub full_mask: ActiveMask,
    /// Outstanding load transactions (scoreboard; warp blocks until 0).
    pub outstanding_loads: u32,
    /// Waiting at a CTA barrier?
    pub at_barrier: bool,
    /// Waiting for an instruction-cache fill?
    pub ifetch_pending: bool,
    /// All instructions consumed?
    pub finished: bool,
    /// Active divergence replay, if any.
    pub replay: Option<Replay>,
    /// Outstanding shadow warp (regroup/DWS second path), if any.
    pub shadow_outstanding: bool,
    /// Resident-CTA slot index on the owning cluster.
    pub cta_slot: usize,
    /// Dispatch order stamp (GTO "oldest" tiebreak).
    pub age: u64,
    /// True while the warp is in (or heading into) divergence handling —
    /// the signal the split controller and policies act on (§4.3).
    pub divergent: bool,
    /// Which half of the cluster currently executes this warp (0/1); used
    /// by the dynamic-split machinery to migrate warps.
    pub home: u8,
    /// Scheduler-index mirror of [`WarpCtx::issuable`] as of the last
    /// (re)filing — maintained by the cluster's ready-warp index, never
    /// read for architectural decisions. Code that mutates warp state
    /// outside the cluster must trigger `SmCluster::rebuild_sched`.
    pub sched_ready: bool,
    /// Scheduler-index mirror of `home` as of the last (re)filing.
    pub sched_home: u8,
}

impl WarpCtx {
    /// Can the scheduler consider this warp this cycle?
    pub fn issuable(&self) -> bool {
        !self.finished
            && !self.at_barrier
            && !self.ifetch_pending
            && self.outstanding_loads == 0
            && !(self.shadow_outstanding && self.at_reconvergence())
    }

    /// Is the warp blocked only because its shadow has not reconverged?
    pub fn waiting_on_shadow(&self) -> bool {
        self.shadow_outstanding && self.at_reconvergence() && !self.finished
    }

    /// Has the warp reached the reconvergence point of its current region?
    fn at_reconvergence(&self) -> bool {
        match self.replay {
            Some(r) => self.pc >= r.end_pc,
            // Shadow without replay state: the fast pass already finished
            // its region; the warp waits at the current pc.
            None => true,
        }
    }

    /// Advance the PC after an issue, handling replay wrap-around.
    /// Returns true if the warp just retired.
    pub fn advance(&mut self) -> bool {
        self.pc += 1;
        if let Some(r) = self.replay {
            if self.pc >= r.end_pc {
                if r.in_second_pass {
                    // Both paths done: reconverge.
                    self.replay = None;
                    self.mask = self.full_mask;
                    self.divergent = false;
                } else if self.shadow_outstanding {
                    // Second pass runs elsewhere (shadow); wait for it at
                    // the reconvergence point (issuable() gates on it).
                    self.replay = None;
                    self.mask = self.full_mask;
                    // divergent stays true until the shadow returns.
                } else {
                    // Serial second pass: rewind with the slow mask.
                    self.pc = r.start_pc;
                    self.mask = r.second_mask;
                    self.replay = Some(Replay { in_second_pass: true, ..r });
                }
            }
        }
        if self.pc >= self.trace_len && self.replay.is_none() {
            self.finished = true;
        }
        self.finished
    }

    /// Enter a divergent region at `pc+1` of `region_len` instructions.
    /// `slow_mask` is the set of lanes taking the slow path. If
    /// `shadowed`, the slow pass will execute as a shadow warp and this
    /// context only runs the fast pass.
    pub fn begin_divergence(&mut self, region_len: u16, slow_mask: ActiveMask, shadowed: bool) {
        let fast = ActiveMask(self.full_mask.0 & !slow_mask.0);
        self.replay = Some(Replay {
            start_pc: self.pc + 1,
            end_pc: self.pc + 1 + region_len as u32,
            second_mask: slow_mask,
            in_second_pass: false,
        });
        self.mask = if fast.count() == 0 { self.full_mask } else { fast };
        self.divergent = true;
        self.shadow_outstanding = shadowed;
    }

    /// The shadow warp completed: reconverge.
    pub fn shadow_done(&mut self) {
        self.shadow_outstanding = false;
        self.divergent = false;
        if self.pc >= self.trace_len && self.replay.is_none() {
            self.finished = true;
        }
    }

    /// Serialize the full context (checkpoint format). Every field is
    /// architectural or scheduler state; nothing is derived.
    pub fn write_to(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        w.u32(self.id.kernel);
        w.u32(self.id.cta);
        w.u32(self.id.warp);
        w.u32(self.subwarps[0]);
        w.u32(self.subwarps[1]);
        w.u8(self.n_subwarps);
        w.usize(self.width);
        w.u32(self.pc);
        w.u32(self.trace_len);
        w.u64(self.mask.0);
        w.u64(self.full_mask.0);
        w.u32(self.outstanding_loads);
        w.bool(self.at_barrier);
        w.bool(self.ifetch_pending);
        w.bool(self.finished);
        match self.replay {
            Some(r) => {
                w.bool(true);
                w.u32(r.start_pc);
                w.u32(r.end_pc);
                w.u64(r.second_mask.0);
                w.bool(r.in_second_pass);
            }
            None => w.bool(false),
        }
        w.bool(self.shadow_outstanding);
        w.usize(self.cta_slot);
        w.u64(self.age);
        w.bool(self.divergent);
        w.u8(self.home);
        w.bool(self.sched_ready);
        w.u8(self.sched_home);
    }

    /// Inverse of [`WarpCtx::write_to`].
    pub fn read_from(
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<WarpCtx> {
        let id = WarpId { kernel: r.u32()?, cta: r.u32()?, warp: r.u32()? };
        let subwarps = [r.u32()?, r.u32()?];
        let n_subwarps = r.u8()?;
        let width = r.usize()?;
        let pc = r.u32()?;
        let trace_len = r.u32()?;
        let mask = ActiveMask(r.u64()?);
        let full_mask = ActiveMask(r.u64()?);
        let outstanding_loads = r.u32()?;
        let at_barrier = r.bool()?;
        let ifetch_pending = r.bool()?;
        let finished = r.bool()?;
        let replay = if r.bool()? {
            Some(Replay {
                start_pc: r.u32()?,
                end_pc: r.u32()?,
                second_mask: ActiveMask(r.u64()?),
                in_second_pass: r.bool()?,
            })
        } else {
            None
        };
        Ok(WarpCtx {
            id,
            subwarps,
            n_subwarps,
            width,
            pc,
            trace_len,
            mask,
            full_mask,
            outstanding_loads,
            at_barrier,
            ifetch_pending,
            finished,
            replay,
            shadow_outstanding: r.bool()?,
            cta_slot: r.usize()?,
            age: r.u64()?,
            divergent: r.bool()?,
            home: r.u8()?,
            sched_ready: r.bool()?,
            sched_home: r.u8()?,
        })
    }
}

/// The slow-path pass of a divergent warp, scheduled independently
/// (on the split half under warp-regrouping; on the same SM under DWS).
#[derive(Debug, Clone)]
pub struct ShadowWarp {
    /// Index of the parent warp in the cluster warp table.
    pub parent: usize,
    /// Sub-warp (for trace resolution) — inherits the parent's first.
    pub cta: u32,
    pub subwarp: u32,
    /// Current PC within the divergent region.
    pub pc: u32,
    /// One past the region's last PC.
    pub end_pc: u32,
    /// Lanes this shadow executes.
    pub mask: ActiveMask,
    /// Width for accounting (same as parent).
    pub width: usize,
    /// Outstanding load transactions.
    pub outstanding_loads: u32,
    /// Waiting for an I-fetch fill?
    pub ifetch_pending: bool,
    /// Done executing (waiting only for loads to drain)?
    pub done: bool,
}

impl ShadowWarp {
    /// Schedulable this cycle?
    pub fn issuable(&self) -> bool {
        !self.done && !self.ifetch_pending && self.outstanding_loads == 0
    }

    /// Fully complete (retired + memory drained)?
    pub fn complete(&self) -> bool {
        self.done && self.outstanding_loads == 0
    }

    /// Advance past one instruction; returns true when the region ends.
    pub fn advance(&mut self) -> bool {
        self.pc += 1;
        if self.pc >= self.end_pc {
            self.done = true;
        }
        self.done
    }

    /// Serialize the shadow (checkpoint format).
    pub fn write_to(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        w.usize(self.parent);
        w.u32(self.cta);
        w.u32(self.subwarp);
        w.u32(self.pc);
        w.u32(self.end_pc);
        w.u64(self.mask.0);
        w.usize(self.width);
        w.u32(self.outstanding_loads);
        w.bool(self.ifetch_pending);
        w.bool(self.done);
    }

    /// Inverse of [`ShadowWarp::write_to`].
    pub fn read_from(
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<ShadowWarp> {
        Ok(ShadowWarp {
            parent: r.usize()?,
            cta: r.u32()?,
            subwarp: r.u32()?,
            pc: r.u32()?,
            end_pc: r.u32()?,
            mask: ActiveMask(r.u64()?),
            width: r.usize()?,
            outstanding_loads: r.u32()?,
            ifetch_pending: r.bool()?,
            done: r.bool()?,
        })
    }
}

/// A CTA resident on a cluster.
#[derive(Debug, Clone)]
pub struct CtaState {
    /// Grid CTA index.
    pub cta: u32,
    /// Warps this CTA contributed (cluster warp-table indices).
    pub warps_total: u32,
    /// Retired warps.
    pub warps_done: u32,
    /// Warps currently parked at the barrier.
    pub barrier_count: u32,
    /// Which half the CTA was dispatched to (PrivatePair mode), 0/1.
    pub home: u8,
    /// Indices of this CTA's warps in the cluster warp table, built at
    /// dispatch. Barrier release and live-warp counts walk this list
    /// instead of filtering the whole table (warp indices are stable:
    /// the table only ever shrinks at `reap`, which clears CTAs too).
    pub warp_ids: Vec<u32>,
}

impl CtaState {
    /// All warps retired?
    pub fn complete(&self) -> bool {
        self.warps_done >= self.warps_total
    }

    /// Serialize the CTA record (checkpoint format).
    pub fn write_to(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        w.u32(self.cta);
        w.u32(self.warps_total);
        w.u32(self.warps_done);
        w.u32(self.barrier_count);
        w.u8(self.home);
        w.usize(self.warp_ids.len());
        for &wi in &self.warp_ids {
            w.u32(wi);
        }
    }

    /// Inverse of [`CtaState::write_to`].
    pub fn read_from(
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<CtaState> {
        let cta = r.u32()?;
        let warps_total = r.u32()?;
        let warps_done = r.u32()?;
        let barrier_count = r.u32()?;
        let home = r.u8()?;
        let n = r.seq_len(4)?;
        let mut warp_ids = Vec::with_capacity(n);
        for _ in 0..n {
            warp_ids.push(r.u32()?);
        }
        Ok(CtaState { cta, warps_total, warps_done, barrier_count, home, warp_ids })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn warp(width: usize, len: u32) -> WarpCtx {
        WarpCtx {
            id: WarpId { kernel: 0, cta: 0, warp: 0 },
            subwarps: [0, 1],
            n_subwarps: if width == 64 { 2 } else { 1 },
            width,
            pc: 0,
            trace_len: len,
            mask: ActiveMask::full(width),
            full_mask: ActiveMask::full(width),
            outstanding_loads: 0,
            at_barrier: false,
            ifetch_pending: false,
            finished: false,
            replay: None,
            shadow_outstanding: false,
            cta_slot: 0,
            age: 0,
            divergent: false,
            home: 0,
            sched_ready: false,
            sched_home: 0,
        }
    }

    #[test]
    fn linear_execution_retires() {
        let mut w = warp(32, 3);
        assert!(w.issuable());
        assert!(!w.advance());
        assert!(!w.advance());
        assert!(w.advance());
        assert!(w.finished && !w.issuable());
    }

    #[test]
    fn serial_divergence_replays_region_twice() {
        let mut w = warp(32, 20);
        w.pc = 4;
        let slow = ActiveMask(0xFF); // lanes 0-7 slow
        w.begin_divergence(3, slow, false);
        assert_eq!(w.mask.count(), 24, "fast pass: 32-8 lanes");
        assert!(w.divergent);
        // Advance past the branch itself, then the fast pass: pcs 5,6,7.
        for _ in 0..4 {
            assert!(!w.advance());
        }
        // Rewound for the slow pass.
        assert_eq!(w.pc, 5);
        assert_eq!(w.mask.count(), 8);
        for _ in 0..3 {
            w.advance();
        }
        assert_eq!(w.pc, 8);
        assert_eq!(w.mask.count(), 32, "reconverged");
        assert!(!w.divergent);
        // Total extra issues = region length (3).
    }

    #[test]
    fn shadowed_divergence_waits_at_reconvergence() {
        let mut w = warp(64, 20);
        w.pc = 2;
        w.begin_divergence(2, ActiveMask(0xF), true);
        assert!(w.shadow_outstanding);
        // Branch advance, then fast pass 3,4; waits at pc 5.
        w.advance();
        w.advance();
        w.advance();
        assert_eq!(w.pc, 5);
        assert!(w.waiting_on_shadow());
        assert!(!w.issuable());
        w.shadow_done();
        assert!(w.issuable());
        assert!(!w.divergent);
    }

    #[test]
    fn full_slow_mask_does_not_deadlock() {
        // Degenerate draw: every lane slow — fast pass must keep full mask.
        let mut w = warp(32, 10);
        w.begin_divergence(2, ActiveMask::full(32), false);
        assert_eq!(w.mask.count(), 32);
    }

    #[test]
    fn shadow_lifecycle() {
        let mut s = ShadowWarp {
            parent: 3,
            cta: 0,
            subwarp: 1,
            pc: 5,
            end_pc: 7,
            mask: ActiveMask(0b11),
            width: 64,
            outstanding_loads: 0,
            ifetch_pending: false,
            done: false,
        };
        assert!(s.issuable());
        assert!(!s.advance());
        assert!(s.advance());
        assert!(s.complete());
        s.outstanding_loads = 1;
        assert!(!s.complete());
    }

    #[test]
    fn scoreboard_blocks_issue() {
        let mut w = warp(32, 10);
        w.outstanding_loads = 2;
        assert!(!w.issuable());
        w.outstanding_loads = 0;
        assert!(w.issuable());
        w.at_barrier = true;
        assert!(!w.issuable());
    }
}
