//! Top-level GPU: clusters + NoC + memory partitions + CTA dispatcher +
//! the per-kernel AMOEBA reconfiguration loop (Fig 7).
//!
//! The machine layout is a **per-cluster** fused/private vector
//! ([`ChipLayout`], §4.4): a private cluster keeps both of its NoC
//! routers, a fused cluster bypasses the second one, and the two kinds
//! can coexist in one fabric. The homogeneous special cases are the
//! paper's classic machines (all-private baseline: `num_sms + num_mcs`
//! nodes with cluster `i` at `2i`/`2i+1`; all-fused scale-up:
//! `num_sms/2 + num_mcs` nodes with cluster `i` at `i`).
//!
//! The NoC is rebuilt when the layout changes (kernel boundaries only;
//! dynamic split keeps the fused NoC interface, §4.3).
//!
//! ## Active-set ticking (per-component event horizons)
//!
//! Memory-divergent kernels spend most of their cycles with every warp
//! parked on a scoreboard or DRAM release — and multi-tenant runs spend
//! most of theirs with one hot tenant forcing the rest of the chip
//! through dead ticks. The cycle loop therefore tracks an **active
//! set**: every component (each [`SmCluster`], each [`MemPartition`],
//! the router fabric) that reports a quiet window via its `next_event`
//! ([`crate::sim::NextEvent`]) is *parked* in a wake-ordered structure
//! ([`crate::sim::ActiveSet`]) and simply not ticked, so cycle cost
//! scales with live work instead of chip size. Any event that can
//! unblock a parked component wakes it eagerly — a reply packet at a
//! cluster, a request delivered to a partition, an injection into the
//! fabric, CTA dispatch, reconfiguration, a DynSplit check, a stats
//! read — and the wake replays the parked window's per-cycle accounting
//! (stall breakdowns, mode counters, LRU clocks, powered-MC cycles) in
//! O(1), exactly as the dense loop would have recorded it. When *every*
//! component is parked and no CTA can dispatch, `now` fast-forwards to
//! the earliest wake (the PR 3 whole-chip horizon skip, now an O(1)
//! heap peek instead of an O(chip) probe).
//!
//! The contract is **bit-identical `SimReport`s** to the dense loop —
//! parking is pure wall-clock policy — enforced by
//! `tests/exec_determinism.rs` and the golden suite; `AMOEBA_DENSE=1`
//! (or [`Gpu::set_dense`]) forces the dense reference loop for
//! auditing. The mode is deliberately *not* part of [`SystemConfig`],
//! so sweep-cache fingerprints ([`crate::harness::cfg_fingerprint`])
//! stay mode-agnostic. New stallable state MUST either register a wake
//! (report its horizon from `next_event` / wake eagerly on message
//! arrival) or report `Progress` conservatively, and any new per-cycle
//! counter in a `tick` needs a mirror in the component's replay path
//! (`SmCluster::skip` or [`Gpu::replay_component`]); the determinism
//! tests catch omissions.
//!
//! ## Concurrent kernel streams (server mode)
//!
//! [`Gpu::run_streams`] serves several applications **simultaneously**:
//! the chip's clusters are spatially partitioned across tenants (one
//! [`crate::workload::KernelStream`] each), every tenant runs its own
//! ordered, arrival-timed kernel launches on its own clusters, and the
//! AMOEBA controller takes its per-cluster decisions *per tenant* through
//! the same [`Gpu::reconfigure`] / `Controller::decide_cluster` path the
//! single-application loop uses. The NoC and the memory system stay
//! shared, so tenants contend for them like co-resident kernels on a real
//! chip. Reconfiguration is **partition-scoped**: a reconfiguring tenant
//! first drains only its *own* clusters ([`TPhase::Drain`]) while every
//! other tenant keeps dispatching and executing, then briefly gates new
//! Request-subnet injections chip-wide ([`TPhase::Quiesce`]) so in-flight
//! packets finish before the NoC is rebuilt — packets already in flight
//! and the Reply subnet keep moving throughout. Only the short quiesce
//! window is a shared cost; the long pipeline drain is private to the
//! tenant that reshapes. Tenants carry a priority class and optional SLO
//! target, and a high-priority tenant below its fair cluster share may
//! preempt a lower-priority tenant at a **CTA boundary**: the victim's
//! resident CTAs on the stolen cluster are checkpointed (requeued through
//! the fault-requeue machinery, no mid-warp state) and the cluster is
//! frozen for `preempt_cost` cycles before the claimant may use it.
//! The event-horizon engine spans tenants: the chip skips only
//! when **every** stream is quiescent, and the horizon is the min over
//! tenants' components and triggers (arrivals, profiling windows, split
//! checks). Dense and skip stream runs are bit-identical, enforced by
//! `tests/exec_determinism.rs` on [`StreamReport`]s.

use crate::amoeba::controller::{Controller, KernelDecision};
use crate::amoeba::dynsplit::DynSplit;
use crate::amoeba::metrics::MetricsSample;
use crate::config::{Scheme, SystemConfig};
use crate::errors::err;
use crate::isa::KernelLaunch;
use crate::sim::core::{ClusterMode, DivergenceMode, SmCluster};
use crate::sim::fault::{FaultEvent, FaultKind, FaultTrace, RunOutcome};
use crate::sim::mem::{MemPartition, PartitionReply};
use crate::sim::noc::{ChipLayout, ClusterOutbox, Noc, NocPort, Packet, Payload, Subnet};
use crate::sim::sched::ActiveSet;
use crate::sim::snapshot::{ByteReader, ByteWriter, Checkpoint};
use crate::stats::{ChipStats, SmStats};
use crate::workload::{kernel_launches, BenchProfile, KernelStream, Priority, TraceGen};

/// Cached `AMOEBA_DENSE` escape hatch: any non-empty value other than
/// `0` forces the dense cycle loop (read once per process).
pub(crate) fn dense_env() -> bool {
    static DENSE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *DENSE.get_or_init(|| {
        std::env::var("AMOEBA_DENSE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
    })
}

/// Cached `AMOEBA_TICK_JOBS` policy for intra-simulation parallel
/// ticking: how many threads [`Gpu::tick_active`] fans the live cluster
/// set across *within one cycle*. Returns `(fixed_count, auto)`:
/// a numeric value pins the count (zero or unparsable values clamp to 1,
/// the serial loop); the literal `auto` enables adaptive sizing, where
/// the fan-out is derived from the live-set width every cycle (see
/// [`Gpu::set_tick_jobs_auto`]). Like `AMOEBA_DENSE`, this is pure
/// execution policy — reports are bit-identical for any count, fixed or
/// adaptive (enforced in `tests/exec_determinism.rs`) — so it
/// deliberately stays outside the sweep-memo fingerprints in
/// [`crate::harness`].
pub(crate) fn tick_jobs_env() -> (usize, bool) {
    static JOBS: std::sync::OnceLock<(usize, bool)> = std::sync::OnceLock::new();
    *JOBS.get_or_init(|| match std::env::var("AMOEBA_TICK_JOBS") {
        Ok(v) if v.trim().eq_ignore_ascii_case("auto") => (1, true),
        Ok(v) => (v.parse::<usize>().ok().unwrap_or(1).max(1), false),
        Err(_) => (1, false),
    })
}

/// Live clusters per worker the adaptive (`auto`) tick-jobs policy aims
/// for: chips at or below one batch stay on the plain serial loop, wider
/// live sets get one worker per `AUTO_TICK_CLUSTERS_PER_JOB` clusters
/// (capped at the machine's parallelism). The divisor keeps per-worker
/// batches large enough that the outbox/merge overhead stays amortised.
pub(crate) const AUTO_TICK_CLUSTERS_PER_JOB: usize = 8;

/// Cached host parallelism cap for the adaptive tick-jobs policy (a
/// wall-clock knob only: worker count never changes simulation results).
fn host_parallelism() -> usize {
    static PAR: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *PAR.get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// One Fig 19 sample: cycle + per-cluster mode snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseSample {
    /// Sample cycle.
    pub cycle: u64,
    /// Mode of every cluster at that cycle.
    pub modes: Vec<ClusterMode>,
}

/// Result of simulating one application under one scheme.
///
/// `PartialEq` compares every counter, decision, phase sample, and
/// metric sample — the equality the skip-vs-dense and parallel-vs-serial
/// determinism tests assert (float fields compare by value; the tests
/// additionally pin their bit patterns).
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// Benchmark name.
    pub bench: String,
    /// Scheme simulated.
    pub scheme: Scheme,
    /// Total GPU cycles.
    pub cycles: u64,
    /// Aggregated SM statistics (all clusters).
    pub sm: SmStats,
    /// Chip-level statistics.
    pub chip: ChipStats,
    /// Fuse decisions taken: one per kernel for chip-global schemes, one
    /// per cluster per kernel for the heterogeneous scheme (§4.4).
    pub decisions: Vec<KernelDecision>,
    /// Periodic cluster-mode snapshots (Fig 19).
    pub phases: Vec<PhaseSample>,
    /// Metric samples collected during each kernel's profiling window
    /// (empty for schemes that do not profile; one per cluster per kernel
    /// under the heterogeneous scheme).
    pub samples: Vec<MetricsSample>,
    /// Did the safety-net cycle deadline truncate the run? When true the
    /// counters above are honest partials, not fabricated completions.
    pub deadline_hit: bool,
    /// Watchdog triage captured at the deadline (`None` on clean runs):
    /// forward-progress horizons + state dumps, deadlock vs slow going.
    pub outcome: Option<RunOutcome>,
}

impl SimReport {
    /// Thread-instructions per cycle — the paper's headline metric.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.sm.thread_insns as f64 / self.cycles as f64
        }
    }

    /// Serialize every field to the checkpoint byte format (the disk memo
    /// uses this to spill sweep results; round-trips exactly, floats by
    /// bit pattern).
    pub fn write_to(&self, w: &mut ByteWriter) {
        w.str(&self.bench);
        w.str(&self.scheme.to_string());
        w.u64(self.cycles);
        self.sm.write_to(w);
        self.chip.write_to(w);
        w.usize(self.decisions.len());
        for d in &self.decisions {
            write_decision(w, d);
        }
        w.usize(self.phases.len());
        for p in &self.phases {
            write_phase_sample(w, p);
        }
        w.usize(self.samples.len());
        for s in &self.samples {
            s.write_to(w);
        }
        w.bool(self.deadline_hit);
        write_opt_outcome(w, &self.outcome);
    }

    /// Inverse of [`SimReport::write_to`]. Errors (never panics) on
    /// truncated or malformed bytes.
    pub fn read_from(r: &mut ByteReader) -> crate::errors::Result<SimReport> {
        let bench = r.str()?.to_string();
        let scheme: Scheme = r
            .str()?
            .parse()
            .map_err(|e| err(format!("report: bad scheme: {e}")))?;
        let cycles = r.u64()?;
        let sm = SmStats::read_from(r)?;
        let chip = ChipStats::read_from(r)?;
        let n_dec = r.seq_len(10)?;
        let mut decisions = Vec::with_capacity(n_dec);
        for _ in 0..n_dec {
            decisions.push(read_decision(r)?);
        }
        let n_ph = r.seq_len(9)?;
        let mut phases = Vec::with_capacity(n_ph);
        for _ in 0..n_ph {
            phases.push(read_phase_sample(r)?);
        }
        let n_samp = r.seq_len(80)?;
        let mut samples = Vec::with_capacity(n_samp);
        for _ in 0..n_samp {
            samples.push(MetricsSample::read_from(r)?);
        }
        let deadline_hit = r.bool()?;
        let outcome = read_opt_outcome(r)?;
        Ok(SimReport {
            bench,
            scheme,
            cycles,
            sm,
            chip,
            decisions,
            phases,
            samples,
            deadline_hit,
            outcome,
        })
    }
}

/// How [`Gpu::run_streams`] assigns clusters to tenants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PartitionPolicy {
    /// Clusters are split across tenants once (contiguous, near-even
    /// blocks) and never move.
    Static,
    /// Static start, plus demand-driven repartitioning at kernel
    /// boundaries: clusters freed by a finished tenant are adopted by the
    /// next tenant that starts a kernel, growing its partition.
    Adaptive,
}

impl std::fmt::Display for PartitionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PartitionPolicy::Static => "static",
            PartitionPolicy::Adaptive => "adaptive",
        })
    }
}

impl std::str::FromStr for PartitionPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Ok(PartitionPolicy::Static),
            "adaptive" | "dynamic" => Ok(PartitionPolicy::Adaptive),
            other => Err(format!("unknown partition policy '{other}'")),
        }
    }
}

/// Service record of one kernel launch in a stream run (ANTT-style
/// slowdown and throughput metrics derive from these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchStat {
    /// Tenant (stream) index.
    pub tenant: u32,
    /// Kernel ordinal within the stream.
    pub kernel: u32,
    /// Arrival cycle from the traffic trace.
    pub arrival: u64,
    /// Cycle the launch actually started (>= arrival; queueing + the
    /// tenant's own partition drain push it later). `u64::MAX` if the
    /// run's deadline hit first.
    pub start: u64,
    /// Cycle the launch completed. `u64::MAX` if never.
    pub finish: u64,
    /// Queueing delay: `start - arrival` (0 if the run's deadline hit
    /// before the launch started).
    pub queue_delay: u64,
    /// Per-launch slowdown in milli-units: `turnaround * 1000 /
    /// max(service, 1)` where `service = finish - start`. 1000 means the
    /// launch ran unqueued; 0 if it never finished.
    pub slowdown_milli: u64,
}

impl LaunchStat {
    /// Turnaround time: completion relative to arrival.
    pub fn turnaround(&self) -> u64 {
        self.finish.saturating_sub(self.arrival)
    }
}

/// Result of serving several concurrent kernel streams on one chip.
///
/// Per-tenant [`SimReport`]s attribute cluster-side counters by ownership
/// period (exact under repartitioning); the shared NoC / L2 / DRAM
/// counters live in the chip-wide `sm`/`chip` aggregates, since the
/// memory system serves all tenants from common queues. `PartialEq` is
/// the skip-vs-dense / parallel-vs-serial determinism equality.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamReport {
    /// One report per tenant, in stream order: `bench` is the stream
    /// name, `cycles` the tenant's completion cycle, `sm` the counters of
    /// clusters while owned by this tenant, `decisions`/`samples` its
    /// controller history. Tenant reports carry no phase samples — the
    /// chip-wide trace is in [`StreamReport::phases`].
    pub tenants: Vec<SimReport>,
    /// Chip-wide SM aggregate (all clusters, whole run).
    pub sm: SmStats,
    /// Shared chip counters (L2, DRAM, NoC, reconfigurations, MC stalls).
    pub chip: ChipStats,
    /// Total cycles until the last tenant finished.
    pub cycles: u64,
    /// Chip-wide Fig-19 phase samples over the whole run.
    pub phases: Vec<PhaseSample>,
    /// Per-launch service records, grouped by tenant in stream order.
    pub launches: Vec<LaunchStat>,
    /// Initial partition: tenant -> owned cluster ids.
    pub partitions: Vec<Vec<usize>>,
    /// CTAs dispatched, by `[tenant][cluster]` — the placement ledger the
    /// tenant-conservation properties check.
    pub ctas_by_cluster: Vec<Vec<u64>>,
    /// Did the deadline truncate the run? Truncated tenants' launches
    /// keep `start`/`finish` at `u64::MAX` (honest partials).
    pub deadline_hit: bool,
    /// Watchdog triage captured at the deadline (`None` on clean runs).
    pub outcome: Option<RunOutcome>,
}

impl StreamReport {
    /// Tenant service throughput: thread-instructions per cycle of
    /// residency (arrival of its first kernel to its completion).
    pub fn tenant_throughput(&self, ti: usize) -> f64 {
        let t = &self.tenants[ti];
        let first_arrival = self
            .launches
            .iter()
            .find(|l| l.tenant == ti as u32)
            .map(|l| l.arrival)
            .unwrap_or(0);
        let residency = t.cycles.saturating_sub(first_arrival);
        if residency == 0 {
            0.0
        } else {
            t.sm.thread_insns as f64 / residency as f64
        }
    }

    /// Serialize every field to the checkpoint byte format (see
    /// [`SimReport::write_to`]).
    pub fn write_to(&self, w: &mut ByteWriter) {
        w.usize(self.tenants.len());
        for t in &self.tenants {
            t.write_to(w);
        }
        self.sm.write_to(w);
        self.chip.write_to(w);
        w.u64(self.cycles);
        w.usize(self.phases.len());
        for p in &self.phases {
            write_phase_sample(w, p);
        }
        w.usize(self.launches.len());
        for l in &self.launches {
            write_launch_stat(w, l);
        }
        w.usize(self.partitions.len());
        for part in &self.partitions {
            w.usize(part.len());
            for &ci in part {
                w.usize(ci);
            }
        }
        w.usize(self.ctas_by_cluster.len());
        for row in &self.ctas_by_cluster {
            w.usize(row.len());
            for &c in row {
                w.u64(c);
            }
        }
        w.bool(self.deadline_hit);
        write_opt_outcome(w, &self.outcome);
    }

    /// Inverse of [`StreamReport::write_to`]. Errors (never panics) on
    /// truncated or malformed bytes.
    pub fn read_from(r: &mut ByteReader) -> crate::errors::Result<StreamReport> {
        let n_t = r.seq_len(60)?;
        let mut tenants = Vec::with_capacity(n_t);
        for _ in 0..n_t {
            tenants.push(SimReport::read_from(r)?);
        }
        let sm = SmStats::read_from(r)?;
        let chip = ChipStats::read_from(r)?;
        let cycles = r.u64()?;
        let n_ph = r.seq_len(9)?;
        let mut phases = Vec::with_capacity(n_ph);
        for _ in 0..n_ph {
            phases.push(read_phase_sample(r)?);
        }
        let n_l = r.seq_len(48)?;
        let mut launches = Vec::with_capacity(n_l);
        for _ in 0..n_l {
            launches.push(read_launch_stat(r)?);
        }
        let n_p = r.seq_len(8)?;
        let mut partitions = Vec::with_capacity(n_p);
        for _ in 0..n_p {
            let n_ci = r.seq_len(8)?;
            let mut part = Vec::with_capacity(n_ci);
            for _ in 0..n_ci {
                part.push(r.usize()?);
            }
            partitions.push(part);
        }
        let n_cbc = r.seq_len(8)?;
        let mut ctas_by_cluster = Vec::with_capacity(n_cbc);
        for _ in 0..n_cbc {
            let n_row = r.seq_len(8)?;
            let mut row = Vec::with_capacity(n_row);
            for _ in 0..n_row {
                row.push(r.u64()?);
            }
            ctas_by_cluster.push(row);
        }
        let deadline_hit = r.bool()?;
        let outcome = read_opt_outcome(r)?;
        Ok(StreamReport {
            tenants,
            sm,
            chip,
            cycles,
            phases,
            launches,
            partitions,
            ctas_by_cluster,
            deadline_hit,
            outcome,
        })
    }
}

/// Dispatch at most this many CTAs per cycle (kernel-launch engine rate).
/// Stream mode grants this rate to each tenant: every stream models its
/// own kernel-launch engine front-end.
const DISPATCH_PER_CYCLE: usize = 2;
/// Fig 19 phase-sampling period in cycles.
const PHASE_SAMPLE_PERIOD: u64 = 512;
/// Replies an MC can inject per cycle (the L2 slice has two reply ports,
/// matching GPGPU-Sim's icnt-to-shader interface width).
const MC_REPLY_BUDGET: usize = 2;
/// Minimum quiet-window length (cycles) worth parking a component for:
/// shorter horizons (an issue port busy for an initiation interval, an
/// L2 hit in flight) stay active and just tick — the heap churn of
/// parking would cost more than the skipped ticks save. Pure policy:
/// any value is bit-identical, only wall-clock changes.
const MIN_PARK_WINDOW: u64 = 8;
/// Bounded per-MC backlog of requests ejected from the NoC but rejected
/// by the partition (queue/MSHR full); retried before new ejections so
/// NoC backpressure is preserved.
const BACKLOG_CAP: usize = 16;

/// Maps each cluster to the trace generator of the kernel it is running.
/// The single-application path shares one kernel chip-wide; stream mode
/// routes every cluster to its owning tenant's current kernel.
#[derive(Clone, Copy)]
enum GenMap<'a> {
    /// One kernel for the whole chip.
    Single(&'a TraceGen),
    /// `owner[cluster]` is the tenant index into `gens`.
    PerTenant { gens: &'a [TraceGen], owner: &'a [usize] },
}

impl<'a> GenMap<'a> {
    #[inline]
    fn get(&self, ci: usize) -> &'a TraceGen {
        match *self {
            GenMap::Single(g) => g,
            GenMap::PerTenant { gens, owner } => &gens[owner[ci]],
        }
    }
}

/// The machine under simulation.
pub struct Gpu {
    cfg: SystemConfig,
    scheme: Scheme,
    clusters: Vec<SmCluster>,
    partitions: Vec<MemPartition>,
    noc: Noc,
    /// Current per-cluster fused/private layout and its NoC node map.
    layout: ChipLayout,
    now: u64,
    chip: ChipStats,
    /// Per-MC replies awaiting injection (bounded by MC_REPLY_BUDGET).
    reply_retry: Vec<std::collections::VecDeque<PartitionReply>>,
    /// Per-MC requests ejected from the NoC but rejected by the partition
    /// (queue/MSHR full); retried before new ejections. Bounded so NoC
    /// backpressure is preserved.
    req_backlog: Vec<std::collections::VecDeque<Packet>>,
    controller: Controller,
    /// One split/fuse state machine per cluster ("watched independently",
    /// §4.3 — a single shared instance let one cluster's rebalance starve
    /// every other cluster's rebalance period).
    dynsplits: Vec<DynSplit>,
    phases: Vec<PhaseSample>,
    samples: Vec<MetricsSample>,
    decisions: Vec<KernelDecision>,
    /// Reusable per-cycle partition-reply buffer (hot-path alloc
    /// elimination: one buffer serves every MC every cycle).
    reply_scratch: Vec<PartitionReply>,
    /// Force the dense cycle loop (no event-horizon skipping). Defaults
    /// to the `AMOEBA_DENSE` env var; see [`Gpu::set_dense`].
    dense: bool,
    /// Intra-simulation worker count for the active-set cluster phase
    /// (>= 1; 1 = serial). Defaults to `AMOEBA_TICK_JOBS`; see
    /// [`Gpu::set_tick_jobs`]. The dense reference loop ignores it.
    tick_jobs: usize,
    /// Adaptive tick-jobs sizing (`AMOEBA_TICK_JOBS=auto` /
    /// [`Gpu::set_tick_jobs_auto`]): the cluster-phase fan-out is derived
    /// from the live-set width each cycle instead of the fixed
    /// `tick_jobs` count. The dense reference loop ignores it too.
    tick_jobs_auto: bool,
    /// Reusable per-cluster injection buffers for the parallel cluster
    /// phase (scratch — rebuilt each cycle, never checkpointed).
    outboxes: Vec<ClusterOutbox>,
    /// Active-set scheduler state: component ids are clusters
    /// `0..n_clusters`, then partitions, then the interconnect last.
    /// Unused (all components permanently active) in dense mode.
    sched: ActiveSet,
    /// `Noc::inject_epoch` as of the interconnect's last tick; a parked
    /// fabric is revived when the live value has moved past this.
    noc_seen_epoch: u64,
    /// Reusable buffer for due timer-wakes (component, from, upto).
    wake_scratch: Vec<(usize, u64, u64)>,
    /// Fault-injection schedule (sorted by cycle) and its replay cursor.
    /// Applied at main-loop cycle boundaries on live ticks; the
    /// fast-forward caps clamp to the next pending event's cycle.
    fault_events: Vec<FaultEvent>,
    fault_cursor: usize,
    /// Clusters permanently removed from dispatch (whole-cluster faults).
    retired: Vec<bool>,
    /// Clusters serving on one healthy half after a half-SM fault:
    /// pinned private by [`Gpu::reconfigure`]'s target sanitisation.
    half_faulty: Vec<bool>,
    /// Transient MC stalls: partition `mc` services nothing while
    /// `now < mc_stall_until[mc]` (and never parks during the stall).
    mc_stall_until: Vec<u64>,
    /// Cycle of the last actual reconfiguration (cooldown gate).
    last_reconfig: u64,
    /// Watchdog state surfaced on the report.
    deadline_hit: bool,
    outcome: Option<RunOutcome>,
    /// Armed checkpoint capture: the first main-loop cycle boundary with
    /// `now >= snap_at` serializes the machine (see [`Gpu::arm_snapshot`]).
    snap_at: Option<u64>,
    /// The captured checkpoint, once the armed cycle is reached.
    snap_buf: Option<Checkpoint>,
    /// Workload seed of the current run, recorded in checkpoint meta so a
    /// resume against a different workload instance is rejected.
    run_seed: u64,
}

impl Gpu {
    /// Build a machine for `scheme` under `cfg`. Fails on an invalid
    /// config instead of panicking — binaries unwrap at the edge.
    pub fn new(
        cfg: &SystemConfig,
        scheme: Scheme,
        controller: Controller,
    ) -> crate::errors::Result<Self> {
        cfg.validate().map_err(|e| err(format!("invalid system config: {e}")))?;
        let n_clusters = cfg.num_sms / 2;
        if n_clusters == 0 {
            return Err(err("need at least 2 SMs (one cluster)"));
        }
        let initial_fused = scheme == Scheme::ScaleUp;
        let mode = if initial_fused { ClusterMode::Fused } else { ClusterMode::PrivatePair };
        let mut clusters: Vec<SmCluster> =
            (0..n_clusters).map(|i| SmCluster::new(i, cfg, mode)).collect();
        if scheme == Scheme::Dws {
            for c in &mut clusters {
                c.divergence_mode = DivergenceMode::Shadowed;
            }
        }
        let layout = ChipLayout::homogeneous(n_clusters, initial_fused, cfg.num_mcs);
        let (tick_jobs, tick_jobs_auto) = tick_jobs_env();
        Ok(Gpu {
            cfg: cfg.clone(),
            scheme,
            clusters,
            partitions: (0..cfg.num_mcs).map(|_| MemPartition::new(cfg)).collect(),
            noc: Noc::new(cfg, &layout),
            layout,
            now: 0,
            chip: ChipStats::default(),
            reply_retry: (0..cfg.num_mcs).map(|_| std::collections::VecDeque::new()).collect(),
            req_backlog: (0..cfg.num_mcs).map(|_| std::collections::VecDeque::new()).collect(),
            controller,
            dynsplits: (0..n_clusters).map(|_| DynSplit::new(cfg)).collect(),
            phases: Vec::new(),
            samples: Vec::new(),
            decisions: Vec::new(),
            reply_scratch: Vec::with_capacity(MC_REPLY_BUDGET),
            dense: dense_env(),
            tick_jobs,
            tick_jobs_auto,
            outboxes: Vec::new(),
            sched: ActiveSet::new(n_clusters + cfg.num_mcs + 1),
            noc_seen_epoch: 0,
            wake_scratch: Vec::new(),
            fault_events: Vec::new(),
            fault_cursor: 0,
            retired: vec![false; n_clusters],
            half_faulty: vec![false; n_clusters],
            mc_stall_until: vec![0; cfg.num_mcs],
            last_reconfig: 0,
            deadline_hit: false,
            outcome: None,
            snap_at: None,
            snap_buf: None,
            run_seed: 0,
        })
    }

    /// Select the execution mode: `true` runs the dense cycle-by-cycle
    /// loop, `false` (default unless `AMOEBA_DENSE=1`) enables
    /// event-horizon cycle skipping. Both produce bit-identical
    /// [`SimReport`]s; the dense loop is the auditing reference.
    pub fn set_dense(&mut self, dense: bool) {
        self.dense = dense;
    }

    /// Select the intra-simulation worker count for the active-set
    /// cluster phase (clamped to >= 1; default from `AMOEBA_TICK_JOBS`).
    /// Pure wall-clock policy: any count produces bit-identical reports
    /// by the outbox/fixed-merge-order contract, and the dense reference
    /// loop ([`Gpu::set_dense`]) always ticks serially regardless.
    /// Pinning a fixed count disables adaptive sizing
    /// ([`Gpu::set_tick_jobs_auto`]).
    pub fn set_tick_jobs(&mut self, jobs: usize) {
        self.tick_jobs = jobs.max(1);
        self.tick_jobs_auto = false;
    }

    /// Enable adaptive tick-job sizing (`AMOEBA_TICK_JOBS=auto`): instead
    /// of a fixed count, the cluster-phase fan-out is derived from the
    /// live-set width each cycle — one worker per
    /// [`AUTO_TICK_CLUSTERS_PER_JOB`] live clusters, capped at the host's
    /// parallelism — so a mostly-parked chip ticks serially (no spawn
    /// overhead) and a hot wide chip fans out. Chips at or below one
    /// batch of clusters stay on the plain serial loop outright. Like the
    /// fixed count this is pure wall-clock policy: reports are
    /// bit-identical to `tick_jobs = 1` (enforced in
    /// `tests/exec_determinism.rs`), and the dense loop ignores it.
    pub fn set_tick_jobs_auto(&mut self, auto: bool) {
        self.tick_jobs_auto = auto;
        if auto {
            self.tick_jobs = 1;
        }
    }

    /// Worker count for a cluster phase with `live` live clusters under
    /// the current policy (fixed count, or live-width-derived in auto).
    fn effective_tick_jobs(&self, live: usize) -> usize {
        if self.tick_jobs_auto {
            (live / AUTO_TICK_CLUSTERS_PER_JOB).clamp(1, host_parallelism())
        } else {
            self.tick_jobs
        }
    }

    // ------------------------------------------------------------------
    // Checkpoint / restore
    // ------------------------------------------------------------------

    /// Arm a checkpoint capture: the first main-loop cycle boundary (in
    /// [`Gpu::run`] or [`Gpu::run_streams`]) with `now >= cycle` snapshots
    /// the full machine + loop state, *before* that cycle's fault
    /// injection and CTA dispatch. Nested drain loops (profiling-complete
    /// drains, post-fault forced splits) run to completion inside one
    /// main-loop iteration, so the actual capture cycle can overshoot
    /// `cycle`; the overshoot is identical in dense and skip mode, which
    /// is what the restore bit-identity contract needs. Capture is pure
    /// observation: the armed run's report is bit-identical to an
    /// unarmed run's.
    pub fn arm_snapshot(&mut self, cycle: u64) {
        self.snap_at = Some(cycle);
        self.snap_buf = None;
    }

    /// The checkpoint captured by the last armed snapshot (`None` when
    /// the run completed before reaching the armed cycle).
    pub fn take_snapshot(&mut self) -> Option<Checkpoint> {
        self.snap_buf.take()
    }

    /// Serialize the full machine into the sectioned checkpoint format;
    /// the caller passes its loop-local state pre-encoded. Must be called
    /// with every component live and replayed (`wake_everything`) so
    /// parked-accounting lag never leaks into the bytes — that is what
    /// makes dense and skip captures byte-identical. Not serialized, and
    /// rebuilt on load: the active-set scheduler (restored all-active),
    /// `noc_seen_epoch` (reseeded from the fabric), scratch buffers, and
    /// every config-derived field.
    fn save_machine_sections(&mut self, mode_kind: u8, loop_bytes: Vec<u8>) -> Checkpoint {
        let mut cp = Checkpoint::new();

        let mut w = ByteWriter::new();
        w.u8(mode_kind);
        w.str(&self.scheme.to_string());
        w.u64(self.now);
        w.usize(self.cfg.num_sms);
        w.usize(self.cfg.num_mcs);
        w.u64(self.run_seed);
        cp.push("meta", w.into_bytes());

        let mut w = ByteWriter::new();
        w.u64(self.now);
        self.chip.write_to(&mut w);
        w.usize(self.reply_retry.len());
        for q in &self.reply_retry {
            w.usize(q.len());
            for rep in q {
                w.u64(rep.line);
                w.u64(rep.tag);
                w.bool(rep.is_write);
            }
        }
        w.usize(self.req_backlog.len());
        for q in &self.req_backlog {
            w.usize(q.len());
            for pkt in q {
                crate::sim::noc::write_packet(&mut w, pkt);
            }
        }
        w.usize(self.retired.len());
        for &b in &self.retired {
            w.bool(b);
        }
        for &b in &self.half_faulty {
            w.bool(b);
        }
        w.usize(self.mc_stall_until.len());
        for &t in &self.mc_stall_until {
            w.u64(t);
        }
        w.u64(self.last_reconfig);
        w.bool(self.deadline_hit);
        write_opt_outcome(&mut w, &self.outcome);
        w.usize(self.phases.len());
        for p in &self.phases {
            write_phase_sample(&mut w, p);
        }
        w.usize(self.samples.len());
        for s in &self.samples {
            s.write_to(&mut w);
        }
        w.usize(self.decisions.len());
        for d in &self.decisions {
            write_decision(&mut w, d);
        }
        cp.push("gpu", w.into_bytes());

        let mut w = ByteWriter::new();
        self.layout.save_state(&mut w);
        cp.push("layout", w.into_bytes());

        let mut w = ByteWriter::new();
        self.noc.save_state(&mut w);
        cp.push("noc", w.into_bytes());

        for (ci, c) in self.clusters.iter().enumerate() {
            let mut w = ByteWriter::new();
            c.save_state(&mut w);
            cp.push(format!("cluster.{ci}"), w.into_bytes());
        }
        for (mc, p) in self.partitions.iter().enumerate() {
            let mut w = ByteWriter::new();
            p.save_state(&mut w);
            cp.push(format!("mc.{mc}"), w.into_bytes());
        }

        let mut w = ByteWriter::new();
        w.usize(self.controller.history.len());
        for d in &self.controller.history {
            write_decision(&mut w, d);
        }
        w.u8(match self.controller.force {
            Some(false) => 0,
            Some(true) => 1,
            None => 2,
        });
        cp.push("controller", w.into_bytes());

        let mut w = ByteWriter::new();
        w.usize(self.dynsplits.len());
        for ds in &self.dynsplits {
            ds.save_state(&mut w);
        }
        cp.push("dynsplits", w.into_bytes());

        let mut w = ByteWriter::new();
        crate::sim::fault::write_fault_section(&mut w, &self.fault_events, self.fault_cursor);
        cp.push("faults", w.into_bytes());

        cp.push("loop", loop_bytes);
        cp
    }

    /// Restore a machine serialized by [`Gpu::save_machine_sections`]
    /// onto this freshly built machine (same config + scheme + seed).
    /// Returns the opaque loop-state bytes for the caller's resume path.
    /// Shape is validated everywhere against the receiving machine —
    /// truncated, corrupt, or foreign input is an error, never a panic.
    fn load_machine_sections(
        &mut self,
        cp: &Checkpoint,
        mode_kind: u8,
    ) -> crate::errors::Result<Vec<u8>> {
        let sect = |name: &str| {
            cp.section(name)
                .ok_or_else(|| err(format!("checkpoint missing section '{name}'")))
        };

        let mut r = ByteReader::new(sect("meta")?);
        let kind = r.u8()?;
        if kind != mode_kind {
            return Err(err(format!(
                "checkpoint mode {kind} cannot resume into mode {mode_kind}"
            )));
        }
        let scheme_s = r.str()?;
        if scheme_s != self.scheme.to_string() {
            return Err(err(format!(
                "checkpoint scheme '{scheme_s}' != machine scheme '{}'",
                self.scheme
            )));
        }
        let _cap_cycle = r.u64()?;
        let num_sms = r.usize()?;
        let num_mcs = r.usize()?;
        if num_sms != self.cfg.num_sms || num_mcs != self.cfg.num_mcs {
            return Err(err(format!(
                "checkpoint shape ({num_sms} SMs, {num_mcs} MCs) != machine ({}, {})",
                self.cfg.num_sms, self.cfg.num_mcs
            )));
        }
        let meta_seed = r.u64()?;
        if meta_seed != self.run_seed {
            return Err(err(format!(
                "checkpoint seed {meta_seed} != run seed {}",
                self.run_seed
            )));
        }
        r.expect_end()?;

        // Layout before NoC: the fabric is rebuilt against the restored
        // geometry, then overlaid with the serialized router state.
        let mut r = ByteReader::new(sect("layout")?);
        let layout = ChipLayout::load(&mut r)?;
        r.expect_end()?;
        if layout.fused_flags().len() != self.clusters.len() {
            return Err(err("checkpoint layout cluster count mismatch"));
        }
        self.layout = layout;
        self.noc = Noc::new(&self.cfg, &self.layout);
        let mut r = ByteReader::new(sect("noc")?);
        self.noc.load_state(&mut r)?;
        r.expect_end()?;

        let nmc = self.partitions.len();
        let mut r = ByteReader::new(sect("gpu")?);
        self.now = r.u64()?;
        self.chip = ChipStats::read_from(&mut r)?;
        if r.seq_len(8)? != nmc {
            return Err(err("checkpoint reply_retry MC count mismatch"));
        }
        for mc in 0..nmc {
            self.reply_retry[mc].clear();
            for _ in 0..r.seq_len(17)? {
                self.reply_retry[mc].push_back(PartitionReply {
                    line: r.u64()?,
                    tag: r.u64()?,
                    is_write: r.bool()?,
                });
            }
        }
        if r.seq_len(8)? != nmc {
            return Err(err("checkpoint req_backlog MC count mismatch"));
        }
        for mc in 0..nmc {
            self.req_backlog[mc].clear();
            for _ in 0..r.seq_len(30)? {
                self.req_backlog[mc].push_back(crate::sim::noc::read_packet(&mut r)?);
            }
        }
        if r.seq_len(1)? != self.clusters.len() {
            return Err(err("checkpoint retired-flag cluster count mismatch"));
        }
        for i in 0..self.clusters.len() {
            self.retired[i] = r.bool()?;
        }
        for i in 0..self.clusters.len() {
            self.half_faulty[i] = r.bool()?;
        }
        if r.seq_len(8)? != nmc {
            return Err(err("checkpoint mc_stall MC count mismatch"));
        }
        for t in self.mc_stall_until.iter_mut() {
            *t = r.u64()?;
        }
        self.last_reconfig = r.u64()?;
        self.deadline_hit = r.bool()?;
        self.outcome = read_opt_outcome(&mut r)?;
        self.phases.clear();
        for _ in 0..r.seq_len(16)? {
            self.phases.push(read_phase_sample(&mut r)?);
        }
        self.samples.clear();
        for _ in 0..r.seq_len(80)? {
            self.samples.push(MetricsSample::read_from(&mut r)?);
        }
        self.decisions.clear();
        for _ in 0..r.seq_len(14)? {
            self.decisions.push(read_decision(&mut r)?);
        }
        r.expect_end()?;

        for (ci, c) in self.clusters.iter_mut().enumerate() {
            let mut r = ByteReader::new(sect(&format!("cluster.{ci}"))?);
            c.load_state(&mut r)?;
            r.expect_end()?;
        }
        for (mc, p) in self.partitions.iter_mut().enumerate() {
            let mut r = ByteReader::new(sect(&format!("mc.{mc}"))?);
            p.load_state(&mut r)?;
            r.expect_end()?;
        }

        let mut r = ByteReader::new(sect("controller")?);
        self.controller.history.clear();
        for _ in 0..r.seq_len(14)? {
            self.controller.history.push(read_decision(&mut r)?);
        }
        self.controller.force = match r.u8()? {
            0 => Some(false),
            1 => Some(true),
            2 => None,
            t => return Err(err(format!("unknown controller force tag {t}"))),
        };
        r.expect_end()?;

        let mut r = ByteReader::new(sect("dynsplits")?);
        if r.seq_len(8)? != self.dynsplits.len() {
            return Err(err("checkpoint dynsplit cluster count mismatch"));
        }
        for ds in self.dynsplits.iter_mut() {
            ds.load_state(&mut r)?;
        }
        r.expect_end()?;

        let mut r = ByteReader::new(sect("faults")?);
        let (events, cursor) = crate::sim::fault::read_fault_section(&mut r)?;
        r.expect_end()?;
        self.fault_events = events;
        self.fault_cursor = cursor;

        // Derived-state rebuilds. The scheduler comes back all-active
        // (the dense-equivalent state — parking is pure wall-clock
        // policy, so every component simply re-parks on its next quiet
        // probe); the fabric's seen-epoch is reseeded so a live fabric
        // never looks stale.
        self.sched = ActiveSet::new(self.clusters.len() + nmc + 1);
        self.noc_seen_epoch = self.noc.inject_epoch();
        self.reply_scratch.clear();
        self.wake_scratch.clear();
        self.snap_at = None;
        self.snap_buf = None;

        Ok(sect("loop")?.to_vec())
    }

    // ------------------------------------------------------------------
    // Fault injection & graceful degradation
    // ------------------------------------------------------------------

    /// Install a fault-injection schedule. Call before the run starts;
    /// the trace is validated against this machine's shape. An empty
    /// trace is bit-identical to never calling this at all.
    pub fn set_fault_trace(&mut self, trace: &FaultTrace) -> crate::errors::Result<()> {
        trace.validate(self.clusters.len(), self.partitions.len())?;
        self.fault_events = trace.events.clone();
        self.fault_cursor = 0;
        Ok(())
    }

    /// Cycle of the next pending fault event (`u64::MAX` once the
    /// schedule is exhausted). The main loops' fast-forward caps clamp
    /// to one cycle before this, so injection always lands on a live
    /// tick at exactly the dense loop's cycle.
    fn next_fault_cycle(&self) -> u64 {
        self.fault_events.get(self.fault_cursor).map(|e| e.cycle).unwrap_or(u64::MAX)
    }

    /// Retire cluster `ci`: fail-clear its resident work and remove it
    /// from dispatch permanently. Returns the incomplete CTA ids the
    /// caller must requeue. Idempotent. Safe with replies in flight —
    /// `SmCluster::on_reply` tolerates unknown lines.
    fn retire_cluster(&mut self, ci: usize) -> Vec<u32> {
        if self.retired[ci] {
            return Vec::new();
        }
        self.wake_comp(ci, self.now);
        self.retired[ci] = true;
        self.chip.clusters_retired += 1;
        let lost = self.clusters[ci].fail_clear();
        self.chip.ctas_requeued += lost.len() as u64;
        lost
    }

    /// Apply every fault event due at or before `now`. `scheme_of(ci)`
    /// names the scheme governing cluster `ci` (the run's scheme on the
    /// single-application path, the owning tenant's in stream mode);
    /// orphaned CTA ids are pushed through `requeue(ci, cta)`. Every
    /// injection wakes its target before mutating it (active-set
    /// contract). Returns true when a half-SM fault hit a currently
    /// *fused* cluster — the caller must drain and force the split
    /// layout so the healthy half keeps serving.
    fn apply_due_faults(
        &mut self,
        scheme_of: &dyn Fn(usize) -> Scheme,
        requeue: &mut dyn FnMut(usize, u32),
    ) -> bool {
        let mut forced_split = false;
        while self.fault_cursor < self.fault_events.len()
            && self.fault_events[self.fault_cursor].cycle <= self.now
        {
            let ev = self.fault_events[self.fault_cursor];
            self.fault_cursor += 1;
            self.chip.faults_injected += 1;
            match ev.kind {
                FaultKind::Cluster { cluster } => {
                    let ci = cluster as usize;
                    for cta in self.retire_cluster(ci) {
                        requeue(ci, cta);
                    }
                }
                FaultKind::HalfSm { cluster, half } => {
                    let ci = cluster as usize;
                    if self.retired[ci] {
                        continue;
                    }
                    if self.half_faulty[ci] {
                        // Second (different) half dies too: nothing left.
                        if self.clusters[ci].dead_half() != Some(half) {
                            for cta in self.retire_cluster(ci) {
                                requeue(ci, cta);
                            }
                        }
                        continue;
                    }
                    if !scheme_of(ci).tolerates_half_fault() {
                        // A permanently fused machine cannot route around
                        // a dead half: the whole cluster is lost.
                        for cta in self.retire_cluster(ci) {
                            requeue(ci, cta);
                        }
                        continue;
                    }
                    self.wake_comp(ci, self.now);
                    self.half_faulty[ci] = true;
                    let lost = self.clusters[ci].fail_clear();
                    self.chip.ctas_requeued += lost.len() as u64;
                    for cta in lost {
                        requeue(ci, cta);
                    }
                    self.clusters[ci].set_dead_half(half);
                    if self.layout.is_fused(ci) {
                        forced_split = true;
                    }
                }
                FaultKind::NocDegrade { penalty } => {
                    let comp = self.comp_noc();
                    self.wake_comp(comp, self.now);
                    self.noc.set_hop_penalty(self.noc.hop_penalty() + penalty as u64);
                }
                FaultKind::McStall { mc, cycles } => {
                    let mci = mc as usize;
                    self.wake_comp(self.clusters.len() + mci, self.now);
                    self.mc_stall_until[mci] = self.now + cycles;
                }
            }
        }
        forced_split
    }

    /// Drain the machine and re-apply the current layout so that
    /// [`Gpu::reconfigure`]'s fault sanitisation forces every
    /// half-faulted fused cluster into the split layout — the healthy
    /// half keeps serving. Shared aftermath of a forced-split fault on
    /// both main loops.
    fn force_split_after_fault(&mut self, gm: &GenMap, deadline: u64) {
        // A tenant mid-Quiesce may have gated Request injections; this
        // chip-global drain needs clusters to flush their pending loads,
        // so lift the gate (the stream loop's end-of-pass recompute
        // restores it if a Quiesce is still in progress afterwards).
        self.noc.set_request_gate(false);
        while !self.drained() && self.now < deadline {
            self.try_fast_forward(deadline - 1);
            self.step(gm);
        }
        self.wake_everything(self.now);
        for c in &mut self.clusters {
            c.reap();
        }
        let target = self.layout.fused_flags().to_vec();
        self.reconfigure(&target);
    }

    /// May a *policy-driven* reconfiguration fire now? Fault-forced
    /// splits bypass this (routing around dead silicon cannot wait);
    /// the default `reconfig_cooldown = 0` keeps the historical
    /// always-allowed behaviour.
    fn reconfig_allowed(&self) -> bool {
        self.cfg.reconfig_cooldown == 0
            || self.chip.reconfig_events == 0
            || self.now >= self.last_reconfig + self.cfg.reconfig_cooldown
    }

    /// Watchdog triage at a deadline hit: capture every component's
    /// forward-progress horizon plus its debug state. A run where *no*
    /// component reports a pending event is a true deadlock; anything
    /// else is slow progress the cycle budget truncated.
    fn watchdog_outcome(&mut self, gens: &GenMap) -> RunOutcome {
        use std::fmt::Write as _;
        self.wake_everything(self.now);
        let mut dump = String::new();
        let mut any_pending = false;
        for (ci, c) in self.clusters.iter().enumerate() {
            let ev = c.next_event(self.now, gens.get(ci));
            any_pending |= !matches!(ev, crate::sim::NextEvent::Idle);
            let _ = writeln!(
                dump,
                "cluster {ci}: retired={} next={ev:?} {}",
                self.retired[ci],
                c.debug_state()
            );
        }
        for (mc, p) in self.partitions.iter().enumerate() {
            let ev = p.next_event(self.now);
            any_pending |= !matches!(ev, crate::sim::NextEvent::Idle);
            let _ = writeln!(
                dump,
                "partition {mc}: busy={} stall_until={} next={ev:?}",
                p.busy(),
                self.mc_stall_until[mc]
            );
        }
        let ev = self.noc.next_event(self.now);
        any_pending |= !matches!(ev, crate::sim::NextEvent::Idle);
        let _ =
            writeln!(dump, "noc: busy={} next={ev:?} {}", self.noc.busy(), self.noc.debug_state());
        RunOutcome { deadline_hit: true, deadlock: !any_pending, dump }
    }

    /// NoC nodes for cluster `ci` in the current layout.
    fn nodes_of(&self, ci: usize) -> [usize; 2] {
        self.layout.nodes_of(ci)
    }

    /// Cluster owning NoC node `n` (inverse of `nodes_of`).
    fn cluster_of_node(&self, n: usize) -> usize {
        self.layout.cluster_of_node(n)
    }

    fn mc_node(&self, mc: usize) -> usize {
        self.layout.mc_node(mc)
    }

    /// Rebuild the NoC for a new per-cluster layout and flush cluster
    /// caches (the paper drains pipelines and pays a reconfiguration
    /// cost). `target[ci]` selects fused (true) or private (false) for
    /// cluster `ci`; mixed vectors build a heterogeneous fabric (§4.4).
    ///
    /// Only clusters whose mode actually changes are rewired (flush +
    /// freeze): a cluster that decided to stay as-is keeps its warm L1s
    /// and keeps issuing. Callers reconfigure on a quiet *fabric* — the
    /// single-application path drains the whole machine, the stream path
    /// drains the reconfiguring tenant's partition and then quiesces the
    /// NoC via the Request-injection gate ([`Noc::set_request_gate`]) —
    /// so the NoC rebuild never strands in-flight packets of skipped
    /// clusters. (On the chip-global paths every reconfigure crosses the
    /// fused/private boundary for every cluster, so the skip never fires
    /// there and their behaviour is unchanged.)
    fn reconfigure(&mut self, target: &[bool]) {
        debug_assert_eq!(target.len(), self.clusters.len());
        // Fault sanitisation: a cluster with a dead half-SM can only run
        // split (its healthy half serves alone), and a retired cluster
        // keeps whatever wiring it died with — rewiring dead silicon is
        // a cost nobody should pay.
        let effective: Vec<bool> = target
            .iter()
            .enumerate()
            .map(|(ci, &f)| {
                if self.half_faulty[ci] {
                    false
                } else if self.retired[ci] {
                    self.layout.is_fused(ci)
                } else {
                    f
                }
            })
            .collect();
        // Pure no-op: the sanitised target IS the current layout. Every
        // policy call site computes a real layout change before calling,
        // so this fires only when sanitisation cancelled the change —
        // zero-fault runs never take this path.
        if effective == self.layout.fused_flags() {
            return;
        }
        // Reconfiguration mutates cluster state and rebuilds the NoC:
        // every parked component must replay its accounting and resume
        // live ticks before the machine changes shape under it.
        self.wake_everything(self.now);
        for (c, &fused) in self.clusters.iter_mut().zip(&effective) {
            let mode = if fused { ClusterMode::Fused } else { ClusterMode::PrivatePair };
            if c.mode() == mode {
                continue;
            }
            c.set_mode(mode);
            c.flush_caches();
            c.frozen_until = self.now + self.cfg.reconfig_cost;
        }
        self.layout = ChipLayout::new(effective, self.cfg.num_mcs);
        self.noc = Noc::new(&self.cfg, &self.layout);
        self.noc_seen_epoch = self.noc.inject_epoch();
        self.chip.reconfig_events += 1;
        self.chip.reconfig_cycles += self.cfg.reconfig_cost;
        self.last_reconfig = self.now;
    }

    /// Reconfigure every cluster to the same mode (chip-global schemes).
    fn reconfigure_all(&mut self, fused: bool) {
        let target = vec![fused; self.clusters.len()];
        self.reconfigure(&target);
    }

    /// Advance the whole machine one cycle; `gens` resolves each
    /// cluster's instruction traces (one shared kernel on the
    /// single-application path, the owning tenant's kernel in stream
    /// mode).
    fn tick(&mut self, gens: &GenMap) {
        let now = self.now;
        self.chip.cycles += 1;

        // 1. SM clusters (issue + LSU + NoC injection).
        for ci in 0..self.clusters.len() {
            let nodes = self.nodes_of(ci);
            self.clusters[ci].tick(now, &mut self.noc, nodes, gens.get(ci));
        }

        // 2. Interconnect.
        self.noc.tick(now);

        // 3. Memory side: requests into partitions. A transiently
        // stalled MC accepts nothing while the stall holds (requests
        // queue in the fabric; nothing is lost).
        for mc in 0..self.partitions.len() {
            if now < self.mc_stall_until[mc] {
                continue;
            }
            self.mc_drain_requests(mc, now);
        }

        // 4. Partitions tick; replies head for the reply subnet. A
        // stalled MC still burns its powered-controller cycle (the
        // counter `mc_service` would have bumped) but does no work.
        for mc in 0..self.partitions.len() {
            if now < self.mc_stall_until[mc] {
                self.chip.mc_cycles += 1;
                continue;
            }
            self.mc_service(mc, now);
        }

        // 5. SM side: reply delivery.
        let sm_nodes = self.layout.sm_nodes();
        for node in 0..sm_nodes {
            while let Some(pkt) = self.noc.eject(Subnet::Reply, node) {
                if let Payload::MemReply { line, is_write, .. } = pkt.payload {
                    let ci = self.cluster_of_node(node);
                    self.clusters[ci].on_reply(now, line, is_write);
                }
            }
        }

        self.now += 1;
    }

    /// Cycle phase 3 for one MC: feed ejected request packets into its
    /// partition. A rejected request (queue/MSHR full) parks in the
    /// bounded per-MC backlog and is retried before new ejections — its
    /// src (the reply address) is preserved.
    fn mc_drain_requests(&mut self, mc: usize, now: u64) {
        let node = self.mc_node(mc);
        // Retry the backlog first (FIFO).
        while let Some(pkt) = self.req_backlog[mc].front().copied() {
            if self.offer_to_partition(mc, now, &pkt) {
                self.req_backlog[mc].pop_front();
            } else {
                break;
            }
        }
        // New ejections, bounded by backlog space.
        while self.req_backlog[mc].len() < BACKLOG_CAP {
            let Some(pkt) = self.noc.eject(Subnet::Request, node) else { break };
            if !self.offer_to_partition(mc, now, &pkt) {
                self.req_backlog[mc].push_back(pkt);
            }
        }
    }

    /// Cycle phase 4 for one MC: advance the partition and inject ready
    /// replies. The emission buffer is owned by the Gpu and reused
    /// across MCs and cycles (no per-cycle allocation).
    fn mc_service(&mut self, mc: usize, now: u64) {
        self.chip.mc_cycles += 1;
        let node = self.mc_node(mc);
        let mut stalled = false;
        // Retry previously blocked replies first (FIFO; preserve all).
        while let Some(r) = self.reply_retry[mc].front().copied() {
            if self.try_inject_reply(now, node, &r) {
                self.reply_retry[mc].pop_front();
            } else {
                stalled = true;
                break;
            }
        }
        let budget = MC_REPLY_BUDGET.saturating_sub(self.reply_retry[mc].len());
        let mut out = std::mem::take(&mut self.reply_scratch);
        out.clear();
        let emit_stalled = self.partitions[mc].tick(now, &mut out, budget);
        for i in 0..out.len() {
            let r = out[i];
            if !self.try_inject_reply(now, node, &r) {
                self.reply_retry[mc].push_back(r);
                stalled = true;
            }
        }
        self.reply_scratch = out;
        if stalled || emit_stalled {
            // Fig 17: a reply was ready but could not enter the NoC.
            self.chip.mc_inject_stall_cycles += 1;
        }
    }

    /// Offer one ejected request packet to partition `mc`; false = retry.
    fn offer_to_partition(&mut self, mc: usize, now: u64, pkt: &Packet) -> bool {
        let Payload::MemRequest { line, requester, is_write } = pkt.payload else {
            return true; // stray reply payload: drop (cannot happen)
        };
        let tag = (pkt.src as u64) << 32 | requester as u64;
        self.partitions[mc].request(now, line, tag, is_write, self.cfg.l2_hit_latency as u64)
    }

    fn try_inject_reply(&mut self, now: u64, mc_node: usize, r: &PartitionReply) -> bool {
        let dst = (r.tag >> 32) as usize;
        let requester = (r.tag & 0xFFFF_FFFF) as u32;
        let flits = if r.is_write {
            1
        } else {
            self.cfg.flits_for(self.cfg.line_bytes + 16) as u32
        };
        let pkt = Packet {
            src: mc_node,
            dst,
            flits,
            born: now,
            payload: Payload::MemReply { line: r.line, requester, is_write: r.is_write },
        };
        self.noc.inject(Subnet::Reply, pkt)
    }

    // ------------------------------------------------------------------
    // Active-set scheduler (per-component sleep/wake)
    // ------------------------------------------------------------------

    /// Component id of the interconnect (clusters first, then MCs).
    #[inline]
    fn comp_noc(&self) -> usize {
        self.clusters.len() + self.partitions.len()
    }

    /// Replay the per-cycle accounting a parked component missed over
    /// `[from, upto)` — exactly what the dense loop would have recorded
    /// while the component provably could not change state. Clusters
    /// replay their stall/mode/LRU accounting ([`SmCluster::skip`]); a
    /// partition's only per-cycle counter is the powered-controller
    /// cycle; the interconnect has none.
    fn replay_component(&mut self, comp: usize, from: u64, upto: u64) {
        if upto <= from {
            return;
        }
        let nc = self.clusters.len();
        if comp < nc {
            self.clusters[comp].skip(from, upto - from);
        } else if comp < nc + self.partitions.len() {
            self.chip.mc_cycles += upto - from;
        }
    }

    /// Wake `comp` (idempotent), replaying its parked accounting so that
    /// from cycle `upto` onward it ticks live with dense-exact counters.
    /// Must precede *any* externally driven effect on a parked
    /// component: message delivery, CTA dispatch, reconfiguration,
    /// DynSplit checks, direct state mutation.
    fn wake_comp(&mut self, comp: usize, upto: u64) {
        if let Some((from, to)) = self.sched.wake(comp, upto) {
            self.replay_component(comp, from, to);
        }
    }

    /// Replay a parked cluster's accounting up to `upto` without waking
    /// it — for pure reads (profiling-window sampling, tenant
    /// attribution) whose quiet-window promise still holds.
    fn sync_comp(&mut self, comp: usize, upto: u64) {
        if let Some((from, to)) = self.sched.sync(comp, upto) {
            self.replay_component(comp, from, to);
        }
    }

    fn wake_all_clusters(&mut self, upto: u64) {
        for ci in 0..self.clusters.len() {
            self.wake_comp(ci, upto);
        }
    }

    fn sync_all_clusters(&mut self, upto: u64) {
        for ci in 0..self.clusters.len() {
            self.sync_comp(ci, upto);
        }
    }

    /// Wake every component (mass mutation points: reconfiguration,
    /// kernel boundaries, end of run).
    fn wake_everything(&mut self, upto: u64) {
        let n = self.comp_noc() + 1;
        for comp in 0..n {
            self.wake_comp(comp, upto);
        }
    }

    /// Park `comp` from the next cycle if `ev` — its `next_event`
    /// evaluated at `now + 1` — promises a quiet window worth skipping.
    /// Event-free components ([`crate::sim::NextEvent::Idle`]) always
    /// park; short horizons stay active (see [`MIN_PARK_WINDOW`]).
    fn maybe_park(&mut self, comp: usize, now: u64, ev: crate::sim::NextEvent) {
        if let Some(wake) = ev.wake_cycle() {
            if wake == u64::MAX || wake >= now + 1 + MIN_PARK_WINDOW {
                self.sched.park(comp, now + 1, wake);
            }
        }
    }

    /// Whole-chip fast-forward: when every component is parked and the
    /// caller established that no CTA dispatched and no loop trigger is
    /// due, jump `now` to the earliest scheduled wake (or the trigger
    /// cap). Parked components replay lazily at their wakes; only the
    /// chip cycle counter advances here. `cap` is the last admissible
    /// `now`, one cycle before any loop-level trigger, so triggers
    /// always fire on live ticks at exactly the dense loop's cycle.
    fn try_fast_forward(&mut self, cap: u64) {
        if self.dense || cap <= self.now || !self.sched.all_parked() {
            return;
        }
        let target = match self.sched.next_wake() {
            Some(w) => w.min(cap),
            // Fully event-free (e.g. a deadlock the deadline will
            // catch): accounting still advances, so skip to the cap.
            None => cap,
        };
        if target <= self.now {
            return;
        }
        self.chip.cycles += target - self.now;
        self.now = target;
    }

    /// Advance one cycle, dense or active-set per the execution mode.
    fn step(&mut self, gens: &GenMap) {
        if self.dense {
            self.tick(gens);
        } else {
            self.tick_active(gens);
        }
    }

    /// The active-set cycle: identical phase order to [`Gpu::tick`], but
    /// each phase visits only live components, parks the ones that
    /// promise a quiet window, and eagerly wakes parked ones the moment
    /// a message reaches them.
    fn tick_active(&mut self, gens: &GenMap) {
        let now = self.now;
        // Timer wakes due this cycle: replay their parked accounting,
        // then tick them below like any live component.
        let mut due = std::mem::take(&mut self.wake_scratch);
        due.clear();
        self.sched.wake_due(now, |c, from, upto| due.push((c, from, upto)));
        for &(c, from, upto) in &due {
            self.replay_component(c, from, upto);
        }
        self.wake_scratch = due;

        self.chip.cycles += 1;

        // 1. Live SM clusters (table order, as the dense loop). With
        // `tick_jobs > 1` (or adaptive sizing on a chip wide enough to
        // ever warrant fan-out) the live set is fanned across worker
        // threads, each cluster injecting into a private outbox; the
        // outboxes merge into the fabric in cluster-index order
        // afterwards, so the NoC observes exactly the serial loop's
        // sequence. The auto gate is static on the chip's cluster count:
        // a chip at or below one batch takes the plain serial loop and
        // never pays the outbox plumbing.
        if self.tick_jobs > 1
            || (self.tick_jobs_auto && self.clusters.len() > AUTO_TICK_CLUSTERS_PER_JOB)
        {
            self.tick_clusters_parallel(now, gens);
        } else {
            for ci in 0..self.clusters.len() {
                if !self.sched.is_active(ci) {
                    continue;
                }
                let nodes = self.nodes_of(ci);
                self.clusters[ci].tick(now, &mut self.noc, nodes, gens.get(ci));
                let ev = self.clusters[ci].next_event(now + 1, gens.get(ci));
                self.maybe_park(ci, now, ev);
            }
        }

        // 2. Interconnect. A parked fabric is revived by any injection —
        // phase 1 may have injected this very cycle, and a fresh packet
        // can take its first hop at `now`, exactly as in the dense loop.
        let comp_noc = self.comp_noc();
        if !self.sched.is_active(comp_noc) && self.noc.inject_epoch() != self.noc_seen_epoch {
            self.wake_comp(comp_noc, now);
        }
        if self.sched.is_active(comp_noc) {
            self.noc.tick(now);
            self.noc_seen_epoch = self.noc.inject_epoch();
            let ev = self.noc.router_next_event(now + 1);
            self.maybe_park(comp_noc, now, ev);
        }

        // 3+4. Memory partitions: request drain + service, per MC (the
        // per-MC state is disjoint, so fusing the dense loop's two
        // passes per partition is observably identical). A parked
        // partition wakes the moment the fabric has delivered a request
        // to its node — including Perfect-mode deliveries from phase 1.
        let nc = self.clusters.len();
        let any_req = self.noc.ejectable_nodes(Subnet::Request) > 0;
        for mc in 0..self.partitions.len() {
            let comp = nc + mc;
            if now < self.mc_stall_until[mc] {
                // A transiently stalled MC never parks (its own horizon
                // is suspended while the stall holds); it burns exactly
                // the powered cycle the dense loop records and nothing
                // else. Injection woke it, so this wake is usually a
                // no-op — but a wake between injection and stall end
                // (e.g. `wake_everything`) must not let it re-park.
                self.wake_comp(comp, now);
                self.chip.mc_cycles += 1;
                continue;
            }
            if !self.sched.is_active(comp) {
                if any_req && self.noc.has_ejectable(Subnet::Request, self.mc_node(mc)) {
                    self.wake_comp(comp, now);
                } else {
                    continue;
                }
            }
            self.mc_drain_requests(mc, now);
            self.mc_service(mc, now);
            // Park only with empty retry/backlog queues (those are
            // serviced every cycle) and nothing left to eject.
            if self.reply_retry[mc].is_empty()
                && self.req_backlog[mc].is_empty()
                && !self.noc.has_ejectable(Subnet::Request, self.mc_node(mc))
            {
                let ev = self.partitions[mc].next_event(now + 1);
                self.maybe_park(comp, now, ev);
            }
        }

        // 5. Reply delivery. The owning cluster is woken *before* it
        // observes the reply: its parked accounting replays through this
        // cycle with the pre-reply state — the dense loop ticked it at
        // phase 1, before the reply arrived — and it resumes live ticks
        // from the next cycle.
        if self.noc.ejectable_nodes(Subnet::Reply) > 0 {
            let sm_nodes = self.layout.sm_nodes();
            for node in 0..sm_nodes {
                while let Some(pkt) = self.noc.eject(Subnet::Reply, node) {
                    if let Payload::MemReply { line, is_write, .. } = pkt.payload {
                        let ci = self.cluster_of_node(node);
                        self.wake_comp(ci, now + 1);
                        self.clusters[ci].on_reply(now, line, is_write);
                    }
                }
            }
        }

        // A phase-4 reply injection revives a parked fabric for the next
        // cycle; surface that before the fast-forward check runs, or the
        // packet's first movable cycle could be skipped over.
        if !self.sched.is_active(comp_noc) && self.noc.inject_epoch() != self.noc_seen_epoch {
            self.wake_comp(comp_noc, now + 1);
        }

        self.now += 1;
    }

    /// Phase 1 of [`Gpu::tick_active`] fanned across scoped worker
    /// threads — the fixed `self.tick_jobs` count, or a live-set-width
    /// derived count under adaptive sizing ([`Gpu::effective_tick_jobs`]).
    /// Determinism is by construction:
    ///
    /// * each live cluster ticks against a private [`ClusterOutbox`]
    ///   whose admission mirrors the shared fabric exactly — the free
    ///   slots of the cluster's *own* source routers are snapshotted at
    ///   phase start ([`Noc::begin_outbox`]), and source routers are
    ///   disjoint across clusters, so a parallel accept/refuse decision
    ///   equals the serial loop's;
    /// * the cluster's post-tick horizon is probed inside the worker
    ///   (`next_event` is `&self` and sees only cluster-local state,
    ///   which the outbox keeps identical to the serial loop's);
    /// * after the join, outboxes drain into the NoC in cluster-index
    ///   order ([`Noc::drain_outbox`]) and parking decisions replay in
    ///   the same order, so every shared-state mutation happens in the
    ///   serial sequence bit-for-bit.
    ///
    /// Thread count is therefore a pure wall-clock knob, like
    /// `AMOEBA_DENSE` — `tests/exec_determinism.rs` pins jobs-1 == jobs-N
    /// on every scheme, stream, and fault path.
    fn tick_clusters_parallel(&mut self, now: u64, gens: &GenMap) {
        let n_clusters = self.clusters.len();
        let mut outboxes = std::mem::take(&mut self.outboxes);
        outboxes.resize_with(n_clusters, ClusterOutbox::default);
        // Arm the live clusters' outboxes serially (cheap snapshots),
        // pairing each with disjoint &mut borrows for the workers.
        let sched = &self.sched;
        let noc = &self.noc;
        let layout = &self.layout;
        let mut live: Vec<(usize, &mut SmCluster, &mut ClusterOutbox)> = Vec::new();
        for (ci, (cl, ob)) in self.clusters.iter_mut().zip(outboxes.iter_mut()).enumerate() {
            if !sched.is_active(ci) {
                continue;
            }
            noc.begin_outbox(ob, layout.nodes_of(ci));
            live.push((ci, cl, ob));
        }
        if !live.is_empty() {
            let n_workers = self.effective_tick_jobs(live.len()).min(live.len());
            let chunk = live.len().div_ceil(n_workers);
            std::thread::scope(|s| {
                // The spawn loop holds the last chunk for the current
                // thread: with one worker this degenerates to an inline
                // serial pass with zero spawns.
                let mut chunks = live.chunks_mut(chunk);
                let last = chunks.next_back();
                let handles: Vec<_> = chunks
                    .map(|batch| s.spawn(move || Self::tick_cluster_batch(batch, now, gens, layout)))
                    .collect();
                if let Some(batch) = last {
                    Self::tick_cluster_batch(batch, now, gens, layout);
                }
                for h in handles {
                    h.join().expect("intra-sim tick worker panicked");
                }
            });
        }
        drop(live);
        // Merge in cluster-index order: park + drain per cluster, the
        // exact interleaving of the serial loop.
        for (ci, ob) in outboxes.iter_mut().enumerate() {
            if !self.sched.is_active(ci) {
                continue;
            }
            let ev = ob.ev;
            self.maybe_park(ci, now, ev);
            self.noc.drain_outbox(ob);
        }
        self.outboxes = outboxes;
    }

    /// One worker's share of the parallel cluster phase: tick each
    /// cluster against its outbox and record its `now + 1` horizon for
    /// the post-join merge loop.
    fn tick_cluster_batch(
        batch: &mut [(usize, &mut SmCluster, &mut ClusterOutbox)],
        now: u64,
        gens: &GenMap,
        layout: &ChipLayout,
    ) {
        for (ci, cl, ob) in batch.iter_mut() {
            let nodes = layout.nodes_of(*ci);
            let gen = gens.get(*ci);
            cl.tick_port(now, &mut NocPort::Buffered(&mut **ob), nodes, gen);
            ob.ev = cl.next_event(now + 1, gen);
        }
    }

    /// Is every cluster + partition + the NoC fully drained?
    fn drained(&self) -> bool {
        self.clusters.iter().all(|c| c.idle()) && self.fabric_quiet()
    }

    /// Is the shared fabric quiet? True when the memory partitions, the
    /// NoC, and the retry/backlog side queues hold no in-flight work.
    /// With the Request-injection gate up this is the quiesce-complete
    /// condition: clusters may still hold inject-pending loads, but
    /// nothing the NoC rebuild could strand is in flight.
    fn fabric_quiet(&self) -> bool {
        self.partitions.iter().all(|p| !p.busy())
            && !self.noc.busy()
            && self.reply_retry.iter().all(|r| r.is_empty())
            && self.req_backlog.iter().all(|b| b.is_empty())
    }

    /// Have the clusters in `part` (one tenant's partition) finished all
    /// resident work? Unlike [`Gpu::drained`] this says nothing about the
    /// shared fabric or other tenants' clusters.
    fn partition_drained(&self, part: &[usize]) -> bool {
        part.iter().all(|&ci| self.clusters[ci].idle())
    }

    /// Execute one kernel to completion, including the per-kernel AMOEBA
    /// controller loop: profile -> predict -> reconfigure -> run (Fig 7).
    /// With `resume`, the kernel prologue is skipped and the loop
    /// continues from the checkpointed loop-local state instead (the
    /// machine itself was restored by [`Gpu::load_machine_sections`]).
    fn run_kernel(
        &mut self,
        profile: &BenchProfile,
        kernel: &KernelLaunch,
        kidx: u32,
        resume: Option<KernelResume>,
    ) {
        let gen = TraceGen::new(profile, kernel);
        let gm = GenMap::Single(&gen);
        let total_ctas = kernel.num_ctas;
        let mut next_cta: u32;
        // CTAs orphaned by a fault, awaiting re-dispatch onto a healthy
        // cluster (conservation: dispatched == retired + requeued).
        let mut requeue: std::collections::VecDeque<u32>;
        let mut profiling: bool;
        let profile_start: u64;
        let base_stats: SmStats;
        let base_per: Vec<SmStats>;
        let deadline: u64;
        let mut split_check_at: u64;
        if let Some(res) = resume {
            next_cta = res.next_cta;
            requeue = res.requeue;
            profiling = res.profiling;
            profile_start = res.profile_start;
            base_stats = res.base_stats;
            base_per = res.base_per;
            deadline = res.deadline;
            split_check_at = res.split_check_at;
        } else {
            next_cta = 0;
            requeue = std::collections::VecDeque::new();

            // -------- Phase 1: profiling window (predictor schemes only).
            profiling = self.scheme.uses_predictor();
            profile_start = self.now;
            base_stats = self.aggregate_sm();
            // Per-cluster baselines for the heterogeneous decision path:
            // each cluster's window delta is taken against its own
            // counters.
            base_per = if self.scheme.per_cluster() {
                self.clusters.iter().map(|c| c.stats.clone()).collect()
            } else {
                Vec::new()
            };

            // Predictor schemes always profile in the scale-out layout.
            if profiling && self.layout.any_fused() {
                self.reconfigure_all(false);
            }

            deadline = self.now + self.cfg.max_cycles.max(1);
            split_check_at = self.now + self.cfg.split_check_period;
        }

        // While profiling, only a probe wave of CTAs is dispatched (one per
        // cluster — §4.1.1: a CTA tracks its kernel's scaling behaviour);
        // the rest of the grid launches after the reconfiguration decision,
        // so the bulk of the kernel runs in the chosen configuration.
        let probe_cap = self.clusters.len() as u32;

        loop {
            // Armed checkpoint capture — before this cycle's fault
            // injection and dispatch. Every parked component replays its
            // lagged accounting first, so dense and skip captures are
            // byte-identical (parking is pure wall-clock policy).
            if self.snap_at.is_some_and(|at| self.now >= at) {
                self.snap_at = None;
                self.wake_everything(self.now);
                let mut lw = ByteWriter::new();
                write_kernel_resume(
                    &mut lw,
                    kidx,
                    next_cta,
                    &requeue,
                    profiling,
                    profile_start,
                    &base_stats,
                    &base_per,
                    deadline,
                    split_check_at,
                );
                self.snap_buf = Some(self.save_machine_sections(MODE_KERNEL, lw.into_bytes()));
            }

            // Fault injection at the cycle boundary, before dispatch
            // (live ticks only: the ff cap below clamps to the next
            // pending event, so due events always land on live ticks).
            if self.fault_cursor < self.fault_events.len() {
                let scheme = self.scheme;
                let forced =
                    self.apply_due_faults(&|_| scheme, &mut |_, cta| requeue.push_back(cta));
                if forced {
                    // A dead half-SM inside a fused cluster: drain, then
                    // force the split layout so the healthy half serves.
                    self.force_split_after_fault(&gm, deadline);
                }
            }

            // CTA dispatch.
            let cap = if profiling { probe_cap.min(total_ctas) } else { total_ctas };
            let mut dispatched = 0;
            // Requeued fault victims re-dispatch first, onto any healthy
            // cluster with room.
            while dispatched < DISPATCH_PER_CYCLE && !requeue.is_empty() {
                let Some(ci) = (0..self.clusters.len())
                    .find(|&ci| !self.retired[ci] && self.clusters[ci].can_accept_cta(kernel))
                else {
                    break;
                };
                let cta = requeue.pop_front().expect("checked non-empty");
                self.wake_comp(ci, self.now);
                self.clusters[ci].dispatch_cta(kernel, cta, &gen);
                self.chip.ctas_dispatched += 1;
                dispatched += 1;
            }
            if profiling && self.scheme.per_cluster() {
                // Heterogeneous probe wave: CTA `i` lands on cluster `i`,
                // so the per-cluster windows measure disjoint work. Grids
                // smaller than the cluster count leave the tail clusters
                // probeless: their all-zero window decides on the
                // intercept alone, i.e. "no evidence => stay private".
                while next_cta < cap && dispatched < DISPATCH_PER_CYCLE {
                    let ci = next_cta as usize % self.clusters.len();
                    if self.retired[ci] || !self.clusters[ci].can_accept_cta(kernel) {
                        break;
                    }
                    self.wake_comp(ci, self.now);
                    self.clusters[ci].dispatch_cta(kernel, next_cta, &gen);
                    self.chip.ctas_dispatched += 1;
                    next_cta += 1;
                    dispatched += 1;
                }
            } else {
                'dispatch: for ci in 0..self.clusters.len() {
                    if self.retired[ci] {
                        continue;
                    }
                    while next_cta < cap && self.clusters[ci].can_accept_cta(kernel) {
                        self.wake_comp(ci, self.now);
                        self.clusters[ci].dispatch_cta(kernel, next_cta, &gen);
                        self.chip.ctas_dispatched += 1;
                        next_cta += 1;
                        dispatched += 1;
                        if dispatched >= DISPATCH_PER_CYCLE {
                            break 'dispatch;
                        }
                    }
                }
            }

            // Fully parked chip: fast-forward to the earliest wake
            // instead of ticking dead cycles one by one. The cap keeps
            // every loop-level trigger below on a live tick, so skip and
            // dense runs fire them at identical cycles. Dispatch
            // progress this cycle implies a live tick, so skipping is
            // not considered; neither is a loop about to terminate (a
            // fully-drained grid breaks after one more tick — skipping
            // first could carry a still-profiling kernel to its decision
            // point, which the dense loop never reaches).
            if dispatched == 0
                && !(next_cta >= total_ctas && requeue.is_empty() && self.drained())
            {
                let mut cap = deadline - 1;
                if profiling {
                    cap = cap.min((profile_start + self.cfg.profile_window).saturating_sub(1));
                }
                if self.scheme.splits().is_some() && self.layout.any_fused() {
                    cap = cap.min(split_check_at.saturating_sub(1));
                }
                let next_sample = (self.now / PHASE_SAMPLE_PERIOD + 1) * PHASE_SAMPLE_PERIOD;
                cap = cap.min(next_sample - 1);
                // Pending fault events fire on live ticks at the top of
                // the loop: never skip past one.
                cap = cap.min(self.next_fault_cycle().saturating_sub(1));
                // An armed snapshot captures at the loop top: land on it.
                if let Some(at) = self.snap_at {
                    cap = cap.min(at.saturating_sub(1));
                }
                self.try_fast_forward(cap);
            }

            self.step(&gm);

            // Profiling window complete: predict and reconfigure.
            if profiling && self.now >= profile_start + self.cfg.profile_window {
                profiling = false;
                // Parked clusters lag on per-cycle accounting; replay it
                // so the window samples read dense-exact counters.
                self.sync_all_clusters(self.now);
                let target: Vec<bool> = if self.scheme.per_cluster() {
                    // §4.4: one decision per cluster from that cluster's
                    // own window — the chip can come out heterogeneous.
                    (0..self.clusters.len())
                        .map(|ci| {
                            let sample = MetricsSample::from_window_scaled(
                                &base_per[ci],
                                &self.clusters[ci].stats,
                                &self.cfg,
                                2,
                            );
                            let d = self.controller.decide_cluster(ci, &sample);
                            self.samples.push(sample);
                            self.decisions.push(d);
                            if d.scale_up {
                                self.chip.predictor_scale_up += 1;
                            } else {
                                self.chip.predictor_scale_out += 1;
                            }
                            d.scale_up
                        })
                        .collect()
                } else {
                    let cur = self.aggregate_sm();
                    let sample = MetricsSample::from_window(&base_stats, &cur, &self.cfg);
                    let fuse = self.controller.decide(&sample);
                    self.samples.push(sample);
                    self.decisions.push(fuse);
                    if fuse.scale_up {
                        self.chip.predictor_scale_up += 1;
                    } else {
                        self.chip.predictor_scale_out += 1;
                    }
                    vec![fuse.scale_up; self.clusters.len()]
                };
                // The reconfigure cooldown gates the *policy* decision
                // (anti-thrash); the decision itself is still logged.
                if target.iter().any(|&f| f) && self.reconfig_allowed() {
                    // Drain resident work, then fuse. We stop dispatching
                    // during the drain by entering a drain loop here. The
                    // dense drain loop has no sampling or split checks, so
                    // the skip cap is the deadline alone.
                    while !self.drained() && self.now < deadline {
                        self.try_fast_forward(deadline - 1);
                        self.step(&gm);
                    }
                    self.wake_everything(self.now);
                    for c in &mut self.clusters {
                        c.reap();
                    }
                    self.reconfigure(&target);
                    if let Some(policy) = self.scheme.splits() {
                        for (c, &fused) in self.clusters.iter_mut().zip(&target) {
                            c.split_policy = fused.then_some(policy);
                        }
                    }
                }
            }

            // Dynamic split/fuse checks (only meaningful on fused
            // clusters; each cluster's state machine runs independently).
            if self.scheme.splits().is_some()
                && self.layout.any_fused()
                && self.now >= split_check_at
            {
                split_check_at = self.now + self.cfg.split_check_period;
                // The split controller reads ratios and migrates warps:
                // parked clusters replay their accounting and resume
                // live ticks before it touches them.
                self.wake_all_clusters(self.now);
                for (ds, c) in self.dynsplits.iter_mut().zip(&mut self.clusters) {
                    ds.check(self.now, c);
                }
            }

            // Fig 19 phase sampling.
            if self.now % PHASE_SAMPLE_PERIOD == 0 {
                self.phases.push(PhaseSample {
                    cycle: self.now,
                    modes: self.clusters.iter().map(|c| c.mode()).collect(),
                });
            }

            if next_cta >= total_ctas && requeue.is_empty() && self.drained() {
                break;
            }
            if self.now >= deadline {
                // Safety net: the watchdog triages the stuck machine
                // (deadlock vs slow progress) and the report carries the
                // outcome — no silent fake completions.
                let out = self.watchdog_outcome(&gm);
                if std::env::var("AMOEBA_DEBUG").is_ok() {
                    eprintln!(
                        "[deadline] cycle {} kernel {} deadlock={}",
                        self.now, kernel.id, out.deadlock
                    );
                    eprint!("{}", out.dump);
                }
                self.deadline_hit = true;
                self.outcome = Some(out);
                break;
            }
        }

        // Kernel boundary: every component's lagged accounting replays
        // before the flushes mutate state under it.
        self.wake_everything(self.now);
        for c in &mut self.clusters {
            c.reap();
            c.flush_caches();
        }
        for p in &mut self.partitions {
            p.flush();
        }
        self.chip.kernels_completed += 1;
    }

    fn aggregate_sm(&self) -> SmStats {
        let mut acc = SmStats::default();
        for c in &self.clusters {
            acc.absorb(&c.stats);
        }
        acc
    }

    /// Fold the memory-side and NoC counters into the chip stats (end of
    /// run; shared by the single-application and stream paths).
    fn fold_chip(&mut self) {
        for p in &self.partitions {
            self.chip.l2_accesses += p.accesses;
            self.chip.l2_misses += p.misses;
            self.chip.dram_reads += p.mc.reads;
            self.chip.dram_writes += p.mc.writes;
            self.chip.dram_row_hits += p.mc.row_hits;
            self.chip.dram_row_misses += p.mc.row_misses;
        }
        self.chip.noc_flits_routed = self.noc.flits_routed;
        // Surface predictor-backend fallbacks: nonzero means some logged
        // decisions were substituted defaults, not measured inferences.
        self.chip.predictor_fallbacks = self.controller.fallback_count();
    }

    /// Run a full application (all kernels) and report.
    pub fn run(&mut self, profile: &BenchProfile, seed: u64) -> SimReport {
        self.run_inner(profile, seed, None)
    }

    /// [`Gpu::run`] with an optional checkpoint resume: kernels before
    /// the checkpointed one already ran (their effects live in the
    /// restored machine state) and are skipped; the checkpointed kernel
    /// continues from its captured loop-local state.
    fn run_inner(
        &mut self,
        profile: &BenchProfile,
        seed: u64,
        resume: Option<KernelResume>,
    ) -> SimReport {
        self.run_seed = seed;
        let start_k = resume.as_ref().map_or(0, |r| r.kidx as usize);
        let mut resume = resume;
        for (k, kernel) in kernel_launches(profile, seed).iter().enumerate().skip(start_k) {
            self.run_kernel(profile, kernel, k as u32, resume.take());
        }
        self.fold_chip();
        SimReport {
            bench: profile.name.to_string(),
            scheme: self.scheme,
            cycles: self.now,
            sm: self.aggregate_sm(),
            chip: self.chip.clone(),
            decisions: self.decisions.clone(),
            phases: self.phases.clone(),
            samples: self.samples.clone(),
            deadline_hit: self.deadline_hit,
            outcome: self.outcome.clone(),
        }
    }

    // ------------------------------------------------------------------
    // Concurrent kernel streams (server mode)
    // ------------------------------------------------------------------

    /// Aggregate SM counters over one tenant's clusters.
    fn partition_agg(&self, partition: &[usize]) -> SmStats {
        let mut acc = SmStats::default();
        for &ci in partition {
            acc.absorb(&self.clusters[ci].stats);
        }
        acc
    }

    /// Has tenant `t`'s current kernel finished? All of its CTAs
    /// dispatched and all of its clusters drained (outstanding loads and
    /// in-flight lines are tracked per cluster, so `idle` covers the
    /// tenant's NoC/memory traffic; fire-and-forget write-throughs may
    /// still be in flight, exactly like the paper's write-through L1s).
    fn stream_kernel_complete(&self, t: &TenantRun, total_ctas: u32) -> bool {
        t.next_cta >= total_ctas && t.partition.iter().all(|&ci| self.clusters[ci].idle())
    }

    /// Apply a tenant's per-cluster fused/private decision through the
    /// standard [`Gpu::reconfigure`] path: the full chip vector keeps
    /// every other tenant's clusters exactly as they are (they are
    /// skipped by the mode check), while the NoC is rebuilt for the new
    /// mixed layout. Caller guarantees the tenant's partition is drained
    /// and the shared fabric is quiet (the quiesce gate): other tenants'
    /// clusters may hold live warps and not-yet-injected loads, but no
    /// packet or pending reply is in flight for the rebuild to strand.
    fn stream_reconfigure(&mut self, partition: &[usize], target: &[bool]) {
        debug_assert_eq!(partition.len(), target.len());
        let mut v = self.layout.fused_flags().to_vec();
        for (&ci, &f) in partition.iter().zip(target) {
            v[ci] = f;
        }
        self.reconfigure(&v);
    }

    /// Open a profiling window for tenant `t` on its current layout:
    /// per-cluster baselines for the heterogeneous path, a
    /// tenant-aggregate baseline for chip-global-style schemes.
    fn stream_begin_profiling(&self, t: &mut TenantRun) {
        t.base_per = if t.scheme.per_cluster() {
            t.partition.iter().map(|&ci| self.clusters[ci].stats.clone()).collect()
        } else {
            Vec::new()
        };
        t.base_agg = self.partition_agg(&t.partition);
        t.profile_start = self.now;
        t.phase = TPhase::Profiling;
    }

    /// Close tenant `ti`'s cluster-ownership accounting periods: fold the
    /// counters gained since each baseline into the tenant's accumulator
    /// and restart the baselines at the current values.
    fn stream_close_accounting(&self, t: &mut TenantRun) {
        for (i, &ci) in t.partition.iter().enumerate() {
            let d = self.clusters[ci].stats.delta(&t.sm_base[i]);
            t.sm_acc.absorb(&d);
            t.sm_base[i] = self.clusters[ci].stats.clone();
        }
    }

    /// Serve several concurrent kernel streams on this chip (see the
    /// module docs): spatial partitioning of clusters across tenants,
    /// per-tenant CTA dispatch and AMOEBA control, shared NoC and memory
    /// system, event-horizon skipping across all tenants. Must be called
    /// on a freshly built machine; the machine's construction scheme is
    /// ignored (each stream carries its own).
    pub fn run_streams(
        &mut self,
        streams: &[KernelStream],
        policy: PartitionPolicy,
    ) -> crate::errors::Result<StreamReport> {
        self.run_streams_inner(streams, policy, None)
    }

    /// [`Gpu::run_streams`] with an optional checkpoint resume: the
    /// time-zero machine build is skipped (the machine was restored by
    /// [`Gpu::load_machine_sections`]) and the serving loop continues
    /// from the checkpointed loop-local state.
    fn run_streams_inner(
        &mut self,
        streams: &[KernelStream],
        policy: PartitionPolicy,
        resume: Option<StreamResume>,
    ) -> crate::errors::Result<StreamReport> {
        let n_clusters = self.clusters.len();
        let n = streams.len();
        if n == 0 {
            return Err(err("run_streams needs at least one stream"));
        }
        if n > n_clusters {
            return Err(err(format!("more tenants ({n}) than clusters ({n_clusters})")));
        }
        if resume.is_none() {
            assert_eq!(self.now, 0, "run_streams needs a fresh machine");
        }
        for s in streams {
            s.validate().map_err(|e| err(format!("invalid kernel stream: {e}")))?;
        }

        // Initial spatial partition: contiguous near-even blocks. This is
        // also the report's `partitions` ledger — a pure function of the
        // tenant/cluster counts, so a resumed run recomputes it (the
        // *live* ownership vector is checkpointed separately).
        let mut owner = vec![0usize; n_clusters];
        let mut partitions: Vec<Vec<usize>> = Vec::with_capacity(n);
        for ti in 0..n {
            let part: Vec<usize> = (ti * n_clusters / n..(ti + 1) * n_clusters / n).collect();
            for &ci in &part {
                owner[ci] = ti;
            }
            partitions.push(part);
        }

        // Per-launch service records, grouped by tenant in stream order
        // (the skeleton is a pure function of the streams; a resume
        // overwrites it wholesale with the checkpointed records).
        let mut launch_base = vec![0usize; n];
        let mut launches: Vec<LaunchStat> = Vec::new();
        for (ti, s) in streams.iter().enumerate() {
            launch_base[ti] = launches.len();
            for (k, l) in s.launches.iter().enumerate() {
                launches.push(LaunchStat {
                    tenant: ti as u32,
                    kernel: k as u32,
                    arrival: l.arrival,
                    start: u64::MAX,
                    finish: u64::MAX,
                    queue_delay: 0,
                    slowdown_milli: 0,
                });
            }
        }
        let total_kernels: u64 = streams.iter().map(|s| s.launches.len() as u64).sum();
        let last_arrival =
            streams.iter().flat_map(|s| &s.launches).map(|l| l.arrival).max().unwrap_or(0);
        let deadline =
            last_arrival + self.cfg.max_cycles.max(1).saturating_mul(total_kernels.max(1));

        let mut tenants: Vec<TenantRun>;
        let mut gen_kidx: Vec<usize>;
        let mut ctas_by_cluster: Vec<Vec<u64>>;
        let mut phases: Vec<PhaseSample>;
        // Clusters released by finished tenants (Adaptive policy only).
        let mut free_pool: Vec<usize>;
        // Per-tenant queues of CTAs orphaned by faults, awaiting
        // re-dispatch onto a healthy owned cluster.
        let mut requeues: Vec<std::collections::VecDeque<u32>>;
        if let Some(res) = resume {
            if res.tenants.len() != n
                || res.owner.len() != n_clusters
                || res.gen_kidx.len() != n
                || res.requeues.len() != n
                || res.ctas_by_cluster.len() != n
                || res.ctas_by_cluster.iter().any(|v| v.len() != n_clusters)
                || res.launches.len() != launches.len()
            {
                return Err(err("stream checkpoint shape does not match the streams"));
            }
            if res.gen_kidx.iter().zip(streams).any(|(&k, s)| k >= s.launches.len())
                || res.tenants.iter().zip(streams).any(|(t, s)| t.kidx > s.launches.len())
            {
                return Err(err("stream checkpoint kernel index out of range"));
            }
            owner = res.owner;
            tenants = res.tenants;
            gen_kidx = res.gen_kidx;
            launches = res.launches;
            ctas_by_cluster = res.ctas_by_cluster;
            phases = res.phases;
            free_pool = res.free_pool;
            requeues = res.requeues;
        } else {
            // Time-zero machine build (no reconfiguration cost — this is
            // how the chip comes up, like `Gpu::new`'s scheme-dependent
            // mode).
            let fused0: Vec<bool> =
                (0..n_clusters).map(|ci| streams[owner[ci]].scheme == Scheme::ScaleUp).collect();
            for (ci, c) in self.clusters.iter_mut().enumerate() {
                let mode = if fused0[ci] { ClusterMode::Fused } else { ClusterMode::PrivatePair };
                if c.mode() != mode {
                    c.set_mode(mode);
                }
                c.divergence_mode = if streams[owner[ci]].scheme == Scheme::Dws {
                    DivergenceMode::Shadowed
                } else {
                    DivergenceMode::Serial
                };
                c.split_policy = None;
            }
            self.layout = ChipLayout::new(fused0, self.cfg.num_mcs);
            self.noc = Noc::new(&self.cfg, &self.layout);

            tenants = (0..n)
                .map(|ti| TenantRun {
                    scheme: streams[ti].scheme,
                    partition: partitions[ti].clone(),
                    kidx: 0,
                    phase: TPhase::Waiting,
                    next_cta: 0,
                    profile_start: 0,
                    base_per: Vec::new(),
                    base_agg: SmStats::default(),
                    split_check_at: 0,
                    sm_acc: SmStats::default(),
                    sm_base: partitions[ti]
                        .iter()
                        .map(|&ci| self.clusters[ci].stats.clone())
                        .collect(),
                    chip: ChipStats::default(),
                    decisions: Vec::new(),
                    samples: Vec::new(),
                    finish: 0,
                    deadline_hit: false,
                })
                .collect();
            gen_kidx = vec![0; n];
            ctas_by_cluster = vec![vec![0u64; n_clusters]; n];
            phases = Vec::new();
            free_pool = Vec::new();
            requeues = vec![std::collections::VecDeque::new(); n];
        }

        // Current kernel's trace generator per tenant; `gen_kidx` names
        // the launch each generator was built from (initially kernel 0's
        // — unused before the launch starts: the clusters are empty, so
        // nothing resolves through it). Tracked separately from
        // `TenantRun::kidx`, which advances at kernel *completion*, ahead
        // of the next launch's generator rebuild.
        let mut gens: Vec<TraceGen> = streams
            .iter()
            .zip(&gen_kidx)
            .map(|(s, &k)| TraceGen::new(&s.profile, &s.launches[k].kernel))
            .collect();

        loop {
            // Armed checkpoint capture — before this cycle's fault
            // injection and dispatch, with every parked component's
            // lagged accounting replayed (see the run_kernel hook).
            if self.snap_at.is_some_and(|at| self.now >= at) {
                self.snap_at = None;
                self.wake_everything(self.now);
                let mut lw = ByteWriter::new();
                write_stream_resume(
                    &mut lw,
                    &tenants,
                    &owner,
                    &gen_kidx,
                    &launches,
                    &ctas_by_cluster,
                    &phases,
                    &free_pool,
                    &requeues,
                );
                self.snap_buf = Some(self.save_machine_sections(MODE_STREAM, lw.into_bytes()));
            }

            // ---- Fault injection at the cycle boundary (live ticks
            // only; the ff cap clamps to the next pending event).
            // Orphaned CTAs requeue to the cluster's owning tenant; a
            // half-SM fault inside a fused cluster forces a chip drain
            // and a split so the healthy half keeps serving.
            if self.fault_cursor < self.fault_events.len() {
                let forced = self.apply_due_faults(
                    &|ci| streams[owner[ci]].scheme,
                    &mut |ci, cta| requeues[owner[ci]].push_back(cta),
                );
                if forced {
                    let gm = GenMap::PerTenant { gens: &gens, owner: &owner };
                    self.force_split_after_fault(&gm, deadline);
                }
            }

            // ---- CTA dispatch: each tenant's launch engine feeds its own
            // clusters (probe wave while profiling, full grid afterwards).
            // A tenant draining for a reconfiguration pauses only itself
            // (its phase is Drain/Quiesce, not Profiling/Running); every
            // other tenant keeps dispatching and executing — the drain is
            // partition-scoped, not chip-wide.
            let mut dispatched = 0usize;
            for ti in 0..n {
                let probing = matches!(tenants[ti].phase, TPhase::Profiling);
                if !probing && !matches!(tenants[ti].phase, TPhase::Running) {
                    continue;
                }
                let t = &mut tenants[ti];
                let kernel = &streams[ti].launches[t.kidx].kernel;
                let cap = if probing {
                    // One probe CTA per owned cluster (§4.1.1).
                    (t.partition.len() as u32).min(kernel.num_ctas)
                } else {
                    kernel.num_ctas
                };
                let mut mine = 0usize;
                // Requeued fault/preemption victims re-dispatch first,
                // onto any healthy owned cluster with room.
                while mine < DISPATCH_PER_CYCLE && !requeues[ti].is_empty() {
                    let Some(&ci) = t.partition.iter().find(|&&ci| {
                        !self.retired[ci] && self.clusters[ci].can_accept_cta(kernel)
                    }) else {
                        break;
                    };
                    let cta = requeues[ti].pop_front().expect("checked non-empty");
                    self.wake_comp(ci, self.now);
                    self.clusters[ci].dispatch_cta(kernel, cta, &gens[ti]);
                    self.chip.ctas_dispatched += 1;
                    ctas_by_cluster[ti][ci] += 1;
                    mine += 1;
                }
                if probing && t.scheme.per_cluster() {
                    // Heterogeneous probe wave: CTA i lands on the
                    // tenant's i-th cluster so the per-cluster windows
                    // measure disjoint work.
                    while t.next_cta < cap && mine < DISPATCH_PER_CYCLE {
                        let ci = t.partition[t.next_cta as usize % t.partition.len()];
                        if self.retired[ci] || !self.clusters[ci].can_accept_cta(kernel) {
                            break;
                        }
                        self.wake_comp(ci, self.now);
                        self.clusters[ci].dispatch_cta(kernel, t.next_cta, &gens[ti]);
                        self.chip.ctas_dispatched += 1;
                        ctas_by_cluster[ti][ci] += 1;
                        t.next_cta += 1;
                        mine += 1;
                    }
                } else {
                    'dispatch: for &ci in &t.partition {
                        if self.retired[ci] {
                            continue;
                        }
                        while t.next_cta < cap && self.clusters[ci].can_accept_cta(kernel) {
                            self.wake_comp(ci, self.now);
                            self.clusters[ci].dispatch_cta(kernel, t.next_cta, &gens[ti]);
                            self.chip.ctas_dispatched += 1;
                            ctas_by_cluster[ti][ci] += 1;
                            t.next_cta += 1;
                            mine += 1;
                            if mine >= DISPATCH_PER_CYCLE {
                                break 'dispatch;
                            }
                        }
                    }
                }
                dispatched += mine;
            }

            // ---- Event-horizon skip: only when nothing dispatched, no
            // tenant transition is already due (those fire on live ticks
            // at exactly the dense loop's cycle), and every component is
            // quiescent. The cap keeps all time-based triggers — stream
            // arrivals, profiling-window ends, split checks, phase-sample
            // boundaries, the deadline — on live ticks; the horizon is
            // the min over every tenant's components and triggers.
            if dispatched == 0 {
                let mut pending = false;
                for (ti, t) in tenants.iter().enumerate() {
                    pending |= match &t.phase {
                        TPhase::Waiting => self.now >= streams[ti].launches[t.kidx].arrival,
                        TPhase::Drain { .. } => self.partition_drained(&t.partition),
                        TPhase::Quiesce { .. } => self.fabric_quiet(),
                        TPhase::Profiling | TPhase::Running => {
                            requeues[ti].is_empty()
                                && self.stream_kernel_complete(
                                    t,
                                    streams[ti].launches[t.kidx].kernel.num_ctas,
                                )
                        }
                        TPhase::Done => false,
                    };
                    if pending {
                        break;
                    }
                }
                if !pending {
                    let mut cap = deadline - 1;
                    for (ti, t) in tenants.iter().enumerate() {
                        match &t.phase {
                            TPhase::Waiting => {
                                let arrival = streams[ti].launches[t.kidx].arrival;
                                if arrival > self.now {
                                    cap = cap.min(arrival - 1);
                                }
                            }
                            TPhase::Profiling => {
                                cap = cap.min(
                                    (t.profile_start + self.cfg.profile_window)
                                        .saturating_sub(1),
                                );
                            }
                            _ => {}
                        }
                        if t.scheme.splits().is_some()
                            && !matches!(t.phase, TPhase::Done)
                            && t.partition.iter().any(|&ci| self.layout.is_fused(ci))
                        {
                            cap = cap.min(t.split_check_at.saturating_sub(1));
                        }
                    }
                    let next_sample =
                        (self.now / PHASE_SAMPLE_PERIOD + 1) * PHASE_SAMPLE_PERIOD;
                    cap = cap.min(next_sample - 1);
                    // Pending fault events fire on live ticks at the top
                    // of the loop: never skip past one.
                    cap = cap.min(self.next_fault_cycle().saturating_sub(1));
                    // An armed snapshot captures at the loop top: land on it.
                    if let Some(at) = self.snap_at {
                        cap = cap.min(at.saturating_sub(1));
                    }
                    self.try_fast_forward(cap);
                }
            }

            self.step(&GenMap::PerTenant { gens: &gens, owner: &owner });

            // ---- Per-tenant transitions. Tenant index order is part of
            // the deterministic contract (dense and skip runs execute the
            // identical pass on identical state).
            for ti in 0..n {
                // 1. Profiling window complete: one decision per cluster
                // (heterogeneous) or one per tenant, through the same
                // controller paths as the single-application loop.
                if matches!(tenants[ti].phase, TPhase::Profiling)
                    && self.now >= tenants[ti].profile_start + self.cfg.profile_window
                {
                    // Window samples read the tenant's cluster counters:
                    // replay any parked cluster's lagged accounting first.
                    for k in 0..tenants[ti].partition.len() {
                        let ci = tenants[ti].partition[k];
                        self.sync_comp(ci, self.now);
                    }
                    let target: Vec<bool> = if tenants[ti].scheme.per_cluster() {
                        let part = tenants[ti].partition.clone();
                        let mut v = Vec::with_capacity(part.len());
                        for (i, &ci) in part.iter().enumerate() {
                            let sample = MetricsSample::from_window_scaled(
                                &tenants[ti].base_per[i],
                                &self.clusters[ci].stats,
                                &self.cfg,
                                2,
                            );
                            let d = self.controller.decide_cluster(ci, &sample);
                            if d.scale_up {
                                self.chip.predictor_scale_up += 1;
                                tenants[ti].chip.predictor_scale_up += 1;
                            } else {
                                self.chip.predictor_scale_out += 1;
                                tenants[ti].chip.predictor_scale_out += 1;
                            }
                            tenants[ti].samples.push(sample);
                            tenants[ti].decisions.push(d);
                            v.push(d.scale_up);
                        }
                        v
                    } else {
                        // Tenant-global decision over the tenant's window
                        // (2 SMs per owned cluster).
                        let cur = self.partition_agg(&tenants[ti].partition);
                        let sample = MetricsSample::from_window_scaled(
                            &tenants[ti].base_agg,
                            &cur,
                            &self.cfg,
                            2 * tenants[ti].partition.len(),
                        );
                        let d = self.controller.decide(&sample);
                        if d.scale_up {
                            self.chip.predictor_scale_up += 1;
                            tenants[ti].chip.predictor_scale_up += 1;
                        } else {
                            self.chip.predictor_scale_out += 1;
                            tenants[ti].chip.predictor_scale_out += 1;
                        }
                        tenants[ti].samples.push(sample);
                        tenants[ti].decisions.push(d);
                        vec![d.scale_up; tenants[ti].partition.len()]
                    };
                    // The reconfigure cooldown (anti-thrash, serving
                    // layer) gates the policy decision; a blocked tenant
                    // keeps running on the profiling (scale-out) layout.
                    let change = self.reconfig_allowed()
                        && tenants[ti]
                            .partition
                            .iter()
                            .zip(&target)
                            .any(|(&ci, &f)| self.layout.is_fused(ci) != f);
                    if change {
                        tenants[ti].phase = TPhase::Drain { target, then_profile: false };
                    } else {
                        // Stays scale-out everywhere (profiling layout).
                        tenants[ti].phase = TPhase::Running;
                    }
                }

                // 2a. Partition drain complete: the tenant's own clusters
                // are idle (other tenants kept running throughout). Move
                // to Quiesce — the end-of-pass recompute below raises the
                // chip-wide Request-injection gate, and in-flight fabric
                // traffic finishes while the Reply subnet keeps moving.
                if matches!(tenants[ti].phase, TPhase::Drain { .. })
                    && self.partition_drained(&tenants[ti].partition)
                {
                    let TPhase::Drain { target, then_profile } =
                        std::mem::replace(&mut tenants[ti].phase, TPhase::Running)
                    else {
                        unreachable!()
                    };
                    tenants[ti].phase = TPhase::Quiesce { target, then_profile };
                }

                // 2b. Quiesce complete: the shared fabric holds no
                // in-flight work, so the NoC rebuild strands nothing.
                // Apply the pending reconfiguration to the tenant's own
                // clusters, then resume (or open the deferred profiling
                // window). May fire in the same pass as 2a when the
                // fabric is already quiet.
                if matches!(tenants[ti].phase, TPhase::Quiesce { .. }) && self.fabric_quiet() {
                    // The reconfigure below reshapes the chip; every
                    // parked component replays and resumes first.
                    self.wake_everything(self.now);
                    let TPhase::Quiesce { target, then_profile } =
                        std::mem::replace(&mut tenants[ti].phase, TPhase::Running)
                    else {
                        unreachable!()
                    };
                    let part = tenants[ti].partition.clone();
                    // Only the reconfiguring tenant's clusters are reaped:
                    // other tenants' clusters keep their resident CTAs and
                    // resume the moment the rebuilt fabric comes up.
                    for &ci in &part {
                        self.clusters[ci].reap();
                    }
                    self.stream_reconfigure(&part, &target);
                    tenants[ti].chip.reconfig_events += 1;
                    tenants[ti].chip.reconfig_cycles += self.cfg.reconfig_cost;
                    if then_profile {
                        self.stream_begin_profiling(&mut tenants[ti]);
                    } else {
                        // Post-decision: arm the dynamic-split policy on
                        // the tenant's fused clusters.
                        if let Some(sp) = tenants[ti].scheme.splits() {
                            for (i, &ci) in part.iter().enumerate() {
                                self.clusters[ci].split_policy = target[i].then_some(sp);
                            }
                        }
                        tenants[ti].phase = TPhase::Running;
                    }
                }

                // 3. Waiting and the arrival is due: start the next
                // kernel. Another tenant's drain or quiesce no longer
                // holds launches back — draining is partition-scoped.
                if matches!(tenants[ti].phase, TPhase::Waiting)
                    && self.now >= streams[ti].launches[tenants[ti].kidx].arrival
                {
                    // Adaptive repartition at the kernel boundary: adopt
                    // clusters freed by finished tenants. The ownership
                    // baseline snapshot must read dense-exact counters,
                    // and the divergence-mode write mutates the cluster:
                    // wake each adoptee.
                    if policy == PartitionPolicy::Adaptive && !free_pool.is_empty() {
                        for ci in free_pool.drain(..) {
                            owner[ci] = ti;
                            self.wake_comp(ci, self.now);
                            let snap = self.clusters[ci].stats.clone();
                            self.clusters[ci].divergence_mode =
                                if tenants[ti].scheme == Scheme::Dws {
                                    DivergenceMode::Shadowed
                                } else {
                                    DivergenceMode::Serial
                                };
                            tenants[ti].partition.push(ci);
                            tenants[ti].sm_base.push(snap);
                        }
                    }
                    // CTA-boundary preemption: a high-priority tenant
                    // below its fair cluster share takes clusters from
                    // lower-priority tenants at its own launch boundary.
                    // The victim's resident CTAs on the stolen cluster
                    // are checkpointed at the CTA boundary — requeued
                    // whole through the fault-requeue machinery, no
                    // mid-warp state — and the cluster stays frozen for
                    // `preempt_cost` cycles before the claimant may
                    // execute on it.
                    if policy == PartitionPolicy::Adaptive
                        && streams[ti].priority == Priority::High
                    {
                        let live =
                            tenants.iter().filter(|t| !matches!(t.phase, TPhase::Done)).count();
                        let fair = n_clusters.div_ceil(live.max(1));
                        while tenants[ti].partition.len() < fair {
                            // Victim: lowest priority first, then largest
                            // partition, then lowest tenant index (the
                            // deterministic tiebreak). Eligible = strictly
                            // lower priority, not mid-drain/quiesce/done,
                            // keeps at least one cluster, and the cluster
                            // to steal (its last-owned) is not retired.
                            let victim = (0..n)
                                .filter(|&vi| {
                                    vi != ti
                                        && streams[vi].priority < streams[ti].priority
                                        && !matches!(
                                            tenants[vi].phase,
                                            TPhase::Drain { .. }
                                                | TPhase::Quiesce { .. }
                                                | TPhase::Done
                                        )
                                        && tenants[vi].partition.len() > 1
                                        && !self.retired
                                            [*tenants[vi].partition.last().expect("len > 1")]
                                })
                                .min_by_key(|&vi| {
                                    (
                                        streams[vi].priority,
                                        std::cmp::Reverse(tenants[vi].partition.len()),
                                        vi,
                                    )
                                });
                            let Some(vi) = victim else { break };
                            let pos = tenants[vi].partition.len() - 1;
                            let ci = tenants[vi].partition[pos];
                            // The steal mutates the cluster and reads its
                            // counters: replay + resume it first.
                            self.wake_comp(ci, self.now);
                            let lost = self.clusters[ci].fail_clear();
                            self.chip.ctas_requeued += lost.len() as u64;
                            self.chip.ctas_preempted += lost.len() as u64;
                            tenants[vi].chip.ctas_preempted += lost.len() as u64;
                            for cta in lost {
                                requeues[vi].push_back(cta);
                            }
                            // Close the victim's ownership period on the
                            // stolen cluster, then hand it over.
                            let d = self.clusters[ci].stats.delta(&tenants[vi].sm_base[pos]);
                            tenants[vi].sm_acc.absorb(&d);
                            tenants[vi].partition.remove(pos);
                            tenants[vi].sm_base.remove(pos);
                            // A victim mid-profile lost a probe cluster:
                            // restart its window on the shrunk partition
                            // so the baselines stay aligned.
                            if matches!(tenants[vi].phase, TPhase::Profiling) {
                                self.stream_begin_profiling(&mut tenants[vi]);
                            }
                            owner[ci] = ti;
                            let snap = self.clusters[ci].stats.clone();
                            self.clusters[ci].divergence_mode =
                                if tenants[ti].scheme == Scheme::Dws {
                                    DivergenceMode::Shadowed
                                } else {
                                    DivergenceMode::Serial
                                };
                            self.clusters[ci].frozen_until = self.now + self.cfg.preempt_cost;
                            tenants[ti].partition.push(ci);
                            tenants[ti].sm_base.push(snap);
                            self.chip.preemptions += 1;
                            tenants[ti].chip.preemptions += 1;
                        }
                    }
                    let li = launch_base[ti] + tenants[ti].kidx;
                    launches[li].start = self.now;
                    launches[li].queue_delay =
                        self.now - streams[ti].launches[tenants[ti].kidx].arrival;
                    gens[ti] = TraceGen::new(
                        &streams[ti].profile,
                        &streams[ti].launches[tenants[ti].kidx].kernel,
                    );
                    gen_kidx[ti] = tenants[ti].kidx;
                    // Every kernel re-arms split policies after its own
                    // decision; clear leftovers from the previous kernel.
                    // (Kernel start also opens profiling baselines that
                    // read counters: wake the tenant's clusters.)
                    let part = tenants[ti].partition.clone();
                    for &ci in &part {
                        self.wake_comp(ci, self.now);
                        self.clusters[ci].split_policy = None;
                    }
                    let uses_pred = tenants[ti].scheme.uses_predictor();
                    // Predictor schemes profile on the scale-out layout;
                    // fixed schemes run their fixed mode.
                    let want: Vec<bool> = if uses_pred {
                        vec![false; part.len()]
                    } else {
                        vec![tenants[ti].scheme == Scheme::ScaleUp; part.len()]
                    };
                    let change =
                        part.iter().zip(&want).any(|(&ci, &f)| self.layout.is_fused(ci) != f);
                    tenants[ti].next_cta = 0;
                    tenants[ti].split_check_at = self.now + self.cfg.split_check_period;
                    if change {
                        tenants[ti].phase =
                            TPhase::Drain { target: want, then_profile: uses_pred };
                    } else if uses_pred {
                        self.stream_begin_profiling(&mut tenants[ti]);
                    } else {
                        tenants[ti].phase = TPhase::Running;
                    }
                }

                // 4. Kernel complete: flush the tenant's L1s (kernel
                // cold-start, as in the single-application loop — the
                // shared L2/DRAM stay warm: they serve other tenants) and
                // advance the stream.
                if matches!(tenants[ti].phase, TPhase::Profiling | TPhase::Running) {
                    let total = streams[ti].launches[tenants[ti].kidx].kernel.num_ctas;
                    // A kernel with fault-orphaned CTAs still queued is
                    // not complete: they must re-dispatch and retire.
                    if requeues[ti].is_empty() && self.stream_kernel_complete(&tenants[ti], total)
                    {
                        let part = tenants[ti].partition.clone();
                        for &ci in &part {
                            // Reap/flush mutate the cluster, and a Done
                            // tenant's accounting close-out reads its
                            // counters: replay + resume first.
                            self.wake_comp(ci, self.now);
                            self.clusters[ci].reap();
                            self.clusters[ci].flush_caches();
                        }
                        let li = launch_base[ti] + tenants[ti].kidx;
                        launches[li].finish = self.now;
                        let service = self.now.saturating_sub(launches[li].start).max(1);
                        launches[li].slowdown_milli =
                            launches[li].turnaround().saturating_mul(1000) / service;
                        self.chip.kernels_completed += 1;
                        tenants[ti].chip.kernels_completed += 1;
                        tenants[ti].kidx += 1;
                        if tenants[ti].kidx < streams[ti].launches.len() {
                            tenants[ti].phase = TPhase::Waiting;
                        } else {
                            tenants[ti].finish = self.now;
                            tenants[ti].phase = TPhase::Done;
                            self.stream_close_accounting(&mut tenants[ti]);
                            if policy == PartitionPolicy::Adaptive {
                                let mut freed: Vec<usize> =
                                    tenants[ti].partition.drain(..).collect();
                                tenants[ti].sm_base.clear();
                                free_pool.append(&mut freed);
                                free_pool.sort_unstable();
                            }
                        }
                    }
                }

                // 5. Dynamic split/fuse checks on the tenant's fused
                // clusters (each cluster's state machine is independent).
                if tenants[ti].scheme.splits().is_some()
                    && !matches!(tenants[ti].phase, TPhase::Done)
                    && tenants[ti].partition.iter().any(|&ci| self.layout.is_fused(ci))
                    && self.now >= tenants[ti].split_check_at
                {
                    tenants[ti].split_check_at = self.now + self.cfg.split_check_period;
                    let part = tenants[ti].partition.clone();
                    for &ci in &part {
                        self.wake_comp(ci, self.now);
                    }
                    let (ds, cls) = (&mut self.dynsplits, &mut self.clusters);
                    for &ci in &part {
                        ds[ci].check(self.now, &mut cls[ci]);
                    }
                }
            }

            // ---- Request-injection gate: up iff some tenant is mid-
            // quiesce. Recomputed once per pass so (a) dense and skip
            // runs toggle it on identical cycles and (b) a gate dropped
            // by the NoC rebuild inside `stream_reconfigure` (`Noc::new`
            // starts gate-down) is restored for any tenant still waiting
            // to quiesce.
            self.noc.set_request_gate(
                tenants.iter().any(|t| matches!(t.phase, TPhase::Quiesce { .. })),
            );

            // ---- Chip-wide Fig 19 phase sampling.
            if self.now % PHASE_SAMPLE_PERIOD == 0 {
                phases.push(PhaseSample {
                    cycle: self.now,
                    modes: self.clusters.iter().map(|c| c.mode()).collect(),
                });
            }

            if tenants.iter().all(|t| matches!(t.phase, TPhase::Done)) {
                break;
            }
            if self.now >= deadline {
                // Safety net, as in the single-application loop: the
                // watchdog triages the stuck machine (deadlock vs slow
                // progress) and the report carries the outcome.
                let out = {
                    let gm = GenMap::PerTenant { gens: &gens, owner: &owner };
                    self.watchdog_outcome(&gm)
                };
                if std::env::var("AMOEBA_DEBUG").is_ok() {
                    eprintln!(
                        "[deadline] stream run at cycle {} deadlock={}",
                        self.now, out.deadlock
                    );
                    eprint!("{}", out.dump);
                }
                self.deadline_hit = true;
                self.outcome = Some(out);
                for ti in 0..n {
                    if !matches!(tenants[ti].phase, TPhase::Done) {
                        // Truncated launches keep start/finish at
                        // u64::MAX: "all launches served" assertions and
                        // the ANTT math must see the truncation, not a
                        // fake completion at the deadline cycle.
                        tenants[ti].deadline_hit = true;
                        tenants[ti].finish = self.now;
                        tenants[ti].phase = TPhase::Done;
                        self.stream_close_accounting(&mut tenants[ti]);
                    }
                }
                break;
            }
        }

        // Final accounting: anything still parked (idle tail clusters)
        // replays up to the stop cycle before the chip-wide aggregates
        // are read.
        self.wake_everything(self.now);
        self.fold_chip();
        let sm = self.aggregate_sm();
        let tenant_reports: Vec<SimReport> = tenants
            .into_iter()
            .zip(streams)
            .map(|(t, s)| {
                let mut chip = t.chip;
                chip.cycles = t.finish;
                SimReport {
                    bench: s.name.clone(),
                    scheme: t.scheme,
                    cycles: t.finish,
                    sm: t.sm_acc,
                    chip,
                    decisions: t.decisions,
                    phases: Vec::new(),
                    samples: t.samples,
                    deadline_hit: t.deadline_hit,
                    outcome: None,
                }
            })
            .collect();
        Ok(StreamReport {
            tenants: tenant_reports,
            sm,
            chip: self.chip.clone(),
            cycles: self.now,
            phases,
            launches,
            partitions,
            ctas_by_cluster,
            deadline_hit: self.deadline_hit,
            outcome: self.outcome.clone(),
        })
    }
}

/// Simulate `profile` under `scheme` with the default controller.
pub fn run_benchmark(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
) -> crate::errors::Result<SimReport> {
    run_benchmark_seeded(cfg, profile, scheme, 0xAB0EBA)
}

/// Seeded variant (distinct workload instance per seed). Execution mode
/// (event-horizon skipping vs dense) follows `AMOEBA_DENSE`.
pub fn run_benchmark_seeded(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
) -> crate::errors::Result<SimReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    Ok(gpu.run(profile, seed))
}

/// [`run_benchmark_seeded`] with the execution mode pinned explicitly:
/// `dense = true` forces the cycle-by-cycle reference loop, `false` the
/// event-horizon skip engine. Both are bit-identical by contract — this
/// entry point exists so tests and benches can compare the two
/// in-process, independent of the `AMOEBA_DENSE` environment.
pub fn run_benchmark_seeded_dense(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    dense: bool,
) -> crate::errors::Result<SimReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    gpu.set_dense(dense);
    Ok(gpu.run(profile, seed))
}

/// [`run_benchmark_seeded`] with a deterministic fault schedule injected
/// at cycle boundaries. An empty trace is bit-identical to the unfaulted
/// entry points. Execution mode follows `AMOEBA_DENSE`.
pub fn run_benchmark_faulted(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    faults: &FaultTrace,
) -> crate::errors::Result<SimReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    gpu.set_fault_trace(faults)?;
    Ok(gpu.run(profile, seed))
}

/// [`run_benchmark_faulted`] with the execution mode pinned explicitly —
/// fault runs are bit-identical dense-vs-active like everything else
/// (enforced in `tests/exec_determinism.rs`).
pub fn run_benchmark_faulted_dense(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    dense: bool,
    faults: &FaultTrace,
) -> crate::errors::Result<SimReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    gpu.set_dense(dense);
    gpu.set_fault_trace(faults)?;
    Ok(gpu.run(profile, seed))
}

/// [`run_benchmark_seeded_dense`] with the intra-simulation worker count
/// also pinned explicitly, so tests and benches can compare tick-jobs 1
/// vs N in-process, independent of the `AMOEBA_TICK_JOBS` environment.
/// Bit-identical for any count by the outbox/fixed-merge-order contract
/// (and the dense loop ignores `tick_jobs` entirely).
pub fn run_benchmark_seeded_jobs(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    dense: bool,
    tick_jobs: usize,
) -> crate::errors::Result<SimReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    gpu.set_dense(dense);
    gpu.set_tick_jobs(tick_jobs);
    Ok(gpu.run(profile, seed))
}

/// [`run_benchmark_seeded_jobs`] with adaptive tick-job sizing pinned on
/// ([`Gpu::set_tick_jobs_auto`]): the cluster-phase fan-out follows the
/// live-set width each cycle. Bit-identical to any fixed count —
/// adaptive sizing only moves work between threads.
pub fn run_benchmark_seeded_auto(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    dense: bool,
) -> crate::errors::Result<SimReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    gpu.set_dense(dense);
    gpu.set_tick_jobs_auto(true);
    Ok(gpu.run(profile, seed))
}

/// [`run_benchmark_faulted_dense`] with the intra-simulation worker
/// count pinned explicitly (see [`run_benchmark_seeded_jobs`]).
pub fn run_benchmark_faulted_jobs(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    dense: bool,
    tick_jobs: usize,
    faults: &FaultTrace,
) -> crate::errors::Result<SimReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    gpu.set_dense(dense);
    gpu.set_tick_jobs(tick_jobs);
    gpu.set_fault_trace(faults)?;
    Ok(gpu.run(profile, seed))
}

/// [`run_benchmark_seeded_dense`] with a checkpoint armed at `snap_cycle`:
/// the first main-loop cycle boundary at or past it serializes the whole
/// machine (pre-injection, pre-dispatch). Returns the finished report and
/// the captured checkpoint — `None` if the run ended before the armed
/// cycle (arm at `u64::MAX` for a deliberately capture-free run).
pub fn run_benchmark_snapshot(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    dense: bool,
    snap_cycle: u64,
    faults: Option<&FaultTrace>,
) -> crate::errors::Result<(SimReport, Option<Checkpoint>)> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    gpu.set_dense(dense);
    if let Some(f) = faults {
        gpu.set_fault_trace(f)?;
    }
    gpu.arm_snapshot(snap_cycle);
    let report = gpu.run(profile, seed);
    let cp = gpu.take_snapshot();
    Ok((report, cp))
}

/// Restore a [`run_benchmark_snapshot`] checkpoint onto a fresh machine
/// and run it to completion. With the same config/profile/scheme/seed the
/// report is bit-identical to the uninterrupted run, in either execution
/// mode (`tests/exec_determinism.rs` enforces this). The fault trace —
/// including the already-fired prefix — rides inside the checkpoint.
pub fn run_benchmark_resume(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    dense: bool,
    cp: &Checkpoint,
) -> crate::errors::Result<SimReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    gpu.set_dense(dense);
    gpu.run_seed = seed;
    let loop_bytes = gpu.load_machine_sections(cp, MODE_KERNEL)?;
    let mut r = ByteReader::new(&loop_bytes);
    let resume = read_kernel_resume(&mut r)?;
    r.expect_end()?;
    Ok(gpu.run_inner(profile, seed, Some(resume)))
}

/// Execution phase of one tenant in [`Gpu::run_streams`].
enum TPhase {
    /// Waiting for the next launch's arrival.
    Waiting,
    /// Profiling window open (predictor schemes; probe wave resident).
    Profiling,
    /// Draining the tenant's *own* clusters so `target` can be applied:
    /// resident CTAs run to completion while every other tenant keeps
    /// dispatching — the drain is partition-scoped. `then_profile`
    /// defers an interrupted kernel-start profiling window to after the
    /// reconfiguration.
    Drain { target: Vec<bool>, then_profile: bool },
    /// Partition drained; new Request-subnet injections are gated
    /// chip-wide while in-flight fabric traffic finishes (the Reply
    /// subnet keeps moving). The NoC rebuild needs a quiet fabric, but
    /// only this short window — not the pipeline drain — is a shared
    /// cost across tenants.
    Quiesce { target: Vec<bool>, then_profile: bool },
    /// Bulk of the kernel executing.
    Running,
    /// Stream exhausted (or truncated by the deadline).
    Done,
}

/// Book-keeping for one tenant of a stream run.
struct TenantRun {
    scheme: Scheme,
    /// Owned cluster ids (append-only under adoption).
    partition: Vec<usize>,
    /// Index of the current kernel in the stream.
    kidx: usize,
    phase: TPhase,
    next_cta: u32,
    profile_start: u64,
    /// Per-cluster profiling baselines (heterogeneous path), aligned
    /// with `partition`.
    base_per: Vec<SmStats>,
    /// Tenant-aggregate profiling baseline (tenant-global decisions).
    base_agg: SmStats,
    split_check_at: u64,
    /// Counters accumulated over closed ownership periods.
    sm_acc: SmStats,
    /// Ownership-period baselines, aligned with `partition`.
    sm_base: Vec<SmStats>,
    /// Attributable per-tenant chip counters (kernels, reconfigurations,
    /// predictor decisions); shared memory-side counters stay chip-wide.
    chip: ChipStats,
    decisions: Vec<KernelDecision>,
    samples: Vec<MetricsSample>,
    finish: u64,
    /// True when the chip deadline truncated this tenant mid-stream.
    deadline_hit: bool,
}

// ---------------------------------------------------------------------------
// Checkpoint serialization of loop-local state
// ---------------------------------------------------------------------------

/// Checkpoint `meta` mode tag: single-benchmark run ([`Gpu::run`]).
const MODE_KERNEL: u8 = 0;
/// Checkpoint `meta` mode tag: serving run ([`Gpu::run_streams`]).
const MODE_STREAM: u8 = 1;

/// `ClusterMode` wire tags, shared with the per-cluster sections (see
/// `SmCluster::save_state`): 0 = PrivatePair, 1 = Fused, 2 = FusedSplit.
fn mode_tag(m: ClusterMode) -> u8 {
    match m {
        ClusterMode::PrivatePair => 0,
        ClusterMode::Fused => 1,
        ClusterMode::FusedSplit => 2,
    }
}

fn mode_from_tag(t: u8) -> crate::errors::Result<ClusterMode> {
    match t {
        0 => Ok(ClusterMode::PrivatePair),
        1 => Ok(ClusterMode::Fused),
        2 => Ok(ClusterMode::FusedSplit),
        _ => Err(err(format!("checkpoint: unknown cluster mode tag {t}"))),
    }
}

fn write_decision(w: &mut ByteWriter, d: &KernelDecision) {
    w.f64(d.probability);
    w.bool(d.scale_up);
    match d.cluster {
        Some(c) => {
            w.bool(true);
            w.u32(c);
        }
        None => w.bool(false),
    }
}

fn read_decision(r: &mut ByteReader) -> crate::errors::Result<KernelDecision> {
    let probability = r.f64()?;
    let scale_up = r.bool()?;
    let cluster = if r.bool()? { Some(r.u32()?) } else { None };
    Ok(KernelDecision { probability, scale_up, cluster })
}

fn write_phase_sample(w: &mut ByteWriter, s: &PhaseSample) {
    w.u64(s.cycle);
    w.usize(s.modes.len());
    for &m in &s.modes {
        w.u8(mode_tag(m));
    }
}

fn read_phase_sample(r: &mut ByteReader) -> crate::errors::Result<PhaseSample> {
    let cycle = r.u64()?;
    let n = r.seq_len(1)?;
    let mut modes = Vec::with_capacity(n);
    for _ in 0..n {
        modes.push(mode_from_tag(r.u8()?)?);
    }
    Ok(PhaseSample { cycle, modes })
}

fn write_opt_outcome(w: &mut ByteWriter, o: &Option<RunOutcome>) {
    match o {
        Some(o) => {
            w.bool(true);
            w.bool(o.deadline_hit);
            w.bool(o.deadlock);
            w.str(&o.dump);
        }
        None => w.bool(false),
    }
}

fn read_opt_outcome(r: &mut ByteReader) -> crate::errors::Result<Option<RunOutcome>> {
    if !r.bool()? {
        return Ok(None);
    }
    let deadline_hit = r.bool()?;
    let deadlock = r.bool()?;
    let dump = r.str()?.to_string();
    Ok(Some(RunOutcome { deadline_hit, deadlock, dump }))
}

fn write_launch_stat(w: &mut ByteWriter, l: &LaunchStat) {
    w.u32(l.tenant);
    w.u32(l.kernel);
    w.u64(l.arrival);
    w.u64(l.start);
    w.u64(l.finish);
    w.u64(l.queue_delay);
    w.u64(l.slowdown_milli);
}

fn read_launch_stat(r: &mut ByteReader) -> crate::errors::Result<LaunchStat> {
    Ok(LaunchStat {
        tenant: r.u32()?,
        kernel: r.u32()?,
        arrival: r.u64()?,
        start: r.u64()?,
        finish: r.u64()?,
        queue_delay: r.u64()?,
        slowdown_milli: r.u64()?,
    })
}

fn write_bools(w: &mut ByteWriter, bs: &[bool]) {
    w.usize(bs.len());
    for &b in bs {
        w.bool(b);
    }
}

fn read_bools(r: &mut ByteReader) -> crate::errors::Result<Vec<bool>> {
    let n = r.seq_len(1)?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(r.bool()?);
    }
    Ok(v)
}

fn write_tphase(w: &mut ByteWriter, p: &TPhase) {
    match p {
        TPhase::Waiting => w.u8(0),
        TPhase::Profiling => w.u8(1),
        TPhase::Drain { target, then_profile } => {
            w.u8(2);
            write_bools(w, target);
            w.bool(*then_profile);
        }
        TPhase::Quiesce { target, then_profile } => {
            w.u8(3);
            write_bools(w, target);
            w.bool(*then_profile);
        }
        TPhase::Running => w.u8(4),
        TPhase::Done => w.u8(5),
    }
}

fn read_tphase(r: &mut ByteReader) -> crate::errors::Result<TPhase> {
    match r.u8()? {
        0 => Ok(TPhase::Waiting),
        1 => Ok(TPhase::Profiling),
        2 => {
            let target = read_bools(r)?;
            let then_profile = r.bool()?;
            Ok(TPhase::Drain { target, then_profile })
        }
        3 => {
            let target = read_bools(r)?;
            let then_profile = r.bool()?;
            Ok(TPhase::Quiesce { target, then_profile })
        }
        4 => Ok(TPhase::Running),
        5 => Ok(TPhase::Done),
        t => Err(err(format!("checkpoint: unknown tenant phase tag {t}"))),
    }
}

fn write_tenant(w: &mut ByteWriter, t: &TenantRun) {
    w.str(&t.scheme.to_string());
    w.usize(t.partition.len());
    for &ci in &t.partition {
        w.usize(ci);
    }
    w.usize(t.kidx);
    write_tphase(w, &t.phase);
    w.u32(t.next_cta);
    w.u64(t.profile_start);
    w.usize(t.base_per.len());
    for s in &t.base_per {
        s.write_to(w);
    }
    t.base_agg.write_to(w);
    w.u64(t.split_check_at);
    t.sm_acc.write_to(w);
    w.usize(t.sm_base.len());
    for s in &t.sm_base {
        s.write_to(w);
    }
    t.chip.write_to(w);
    w.usize(t.decisions.len());
    for d in &t.decisions {
        write_decision(w, d);
    }
    w.usize(t.samples.len());
    for s in &t.samples {
        s.write_to(w);
    }
    w.u64(t.finish);
    w.bool(t.deadline_hit);
}

fn read_tenant(r: &mut ByteReader) -> crate::errors::Result<TenantRun> {
    let scheme: Scheme = r
        .str()?
        .parse()
        .map_err(|e| err(format!("checkpoint: bad tenant scheme: {e}")))?;
    let n_part = r.seq_len(8)?;
    let mut partition = Vec::with_capacity(n_part);
    for _ in 0..n_part {
        partition.push(r.usize()?);
    }
    let kidx = r.usize()?;
    let phase = read_tphase(r)?;
    let next_cta = r.u32()?;
    let profile_start = r.u64()?;
    let n_bp = r.seq_len(8)?;
    let mut base_per = Vec::with_capacity(n_bp);
    for _ in 0..n_bp {
        base_per.push(SmStats::read_from(r)?);
    }
    let base_agg = SmStats::read_from(r)?;
    let split_check_at = r.u64()?;
    let sm_acc = SmStats::read_from(r)?;
    let n_sb = r.seq_len(8)?;
    let mut sm_base = Vec::with_capacity(n_sb);
    for _ in 0..n_sb {
        sm_base.push(SmStats::read_from(r)?);
    }
    let chip = ChipStats::read_from(r)?;
    let n_dec = r.seq_len(10)?;
    let mut decisions = Vec::with_capacity(n_dec);
    for _ in 0..n_dec {
        decisions.push(read_decision(r)?);
    }
    let n_samp = r.seq_len(80)?;
    let mut samples = Vec::with_capacity(n_samp);
    for _ in 0..n_samp {
        samples.push(MetricsSample::read_from(r)?);
    }
    let finish = r.u64()?;
    let deadline_hit = r.bool()?;
    Ok(TenantRun {
        scheme,
        partition,
        kidx,
        phase,
        next_cta,
        profile_start,
        base_per,
        base_agg,
        split_check_at,
        sm_acc,
        sm_base,
        chip,
        decisions,
        samples,
        finish,
        deadline_hit,
    })
}

/// Loop-local state of [`Gpu::run_kernel`] at the capture cycle — the
/// `loop` section payload for a `MODE_KERNEL` checkpoint.
struct KernelResume {
    kidx: u32,
    next_cta: u32,
    requeue: std::collections::VecDeque<u32>,
    profiling: bool,
    profile_start: u64,
    base_stats: SmStats,
    base_per: Vec<SmStats>,
    deadline: u64,
    split_check_at: u64,
}

#[allow(clippy::too_many_arguments)]
fn write_kernel_resume(
    w: &mut ByteWriter,
    kidx: u32,
    next_cta: u32,
    requeue: &std::collections::VecDeque<u32>,
    profiling: bool,
    profile_start: u64,
    base_stats: &SmStats,
    base_per: &[SmStats],
    deadline: u64,
    split_check_at: u64,
) {
    w.u32(kidx);
    w.u32(next_cta);
    w.usize(requeue.len());
    for &c in requeue {
        w.u32(c);
    }
    w.bool(profiling);
    w.u64(profile_start);
    base_stats.write_to(w);
    w.usize(base_per.len());
    for s in base_per {
        s.write_to(w);
    }
    w.u64(deadline);
    w.u64(split_check_at);
}

fn read_kernel_resume(r: &mut ByteReader) -> crate::errors::Result<KernelResume> {
    let kidx = r.u32()?;
    let next_cta = r.u32()?;
    let n_rq = r.seq_len(4)?;
    let mut requeue = std::collections::VecDeque::with_capacity(n_rq);
    for _ in 0..n_rq {
        requeue.push_back(r.u32()?);
    }
    let profiling = r.bool()?;
    let profile_start = r.u64()?;
    let base_stats = SmStats::read_from(r)?;
    let n_bp = r.seq_len(8)?;
    let mut base_per = Vec::with_capacity(n_bp);
    for _ in 0..n_bp {
        base_per.push(SmStats::read_from(r)?);
    }
    let deadline = r.u64()?;
    let split_check_at = r.u64()?;
    Ok(KernelResume {
        kidx,
        next_cta,
        requeue,
        profiling,
        profile_start,
        base_stats,
        base_per,
        deadline,
        split_check_at,
    })
}

/// Loop-local state of [`Gpu::run_streams`] at the capture cycle — the
/// `loop` section payload for a `MODE_STREAM` checkpoint. The launch
/// skeleton, partition ledger, and deadline are *not* captured: they are
/// pure functions of the streams and are recomputed on resume.
struct StreamResume {
    tenants: Vec<TenantRun>,
    owner: Vec<usize>,
    gen_kidx: Vec<usize>,
    launches: Vec<LaunchStat>,
    ctas_by_cluster: Vec<Vec<u64>>,
    phases: Vec<PhaseSample>,
    free_pool: Vec<usize>,
    requeues: Vec<std::collections::VecDeque<u32>>,
}

#[allow(clippy::too_many_arguments)]
fn write_stream_resume(
    w: &mut ByteWriter,
    tenants: &[TenantRun],
    owner: &[usize],
    gen_kidx: &[usize],
    launches: &[LaunchStat],
    ctas_by_cluster: &[Vec<u64>],
    phases: &[PhaseSample],
    free_pool: &[usize],
    requeues: &[std::collections::VecDeque<u32>],
) {
    w.usize(tenants.len());
    for t in tenants {
        write_tenant(w, t);
    }
    w.usize(owner.len());
    for &o in owner {
        w.usize(o);
    }
    w.usize(gen_kidx.len());
    for &k in gen_kidx {
        w.usize(k);
    }
    w.usize(launches.len());
    for l in launches {
        write_launch_stat(w, l);
    }
    w.usize(ctas_by_cluster.len());
    for row in ctas_by_cluster {
        w.usize(row.len());
        for &c in row {
            w.u64(c);
        }
    }
    w.usize(phases.len());
    for p in phases {
        write_phase_sample(w, p);
    }
    w.usize(free_pool.len());
    for &ci in free_pool {
        w.usize(ci);
    }
    w.usize(requeues.len());
    for q in requeues {
        w.usize(q.len());
        for &c in q {
            w.u32(c);
        }
    }
}

fn read_stream_resume(r: &mut ByteReader) -> crate::errors::Result<StreamResume> {
    let n_t = r.seq_len(60)?;
    let mut tenants = Vec::with_capacity(n_t);
    for _ in 0..n_t {
        tenants.push(read_tenant(r)?);
    }
    let n_own = r.seq_len(8)?;
    let mut owner = Vec::with_capacity(n_own);
    for _ in 0..n_own {
        owner.push(r.usize()?);
    }
    let n_gk = r.seq_len(8)?;
    let mut gen_kidx = Vec::with_capacity(n_gk);
    for _ in 0..n_gk {
        gen_kidx.push(r.usize()?);
    }
    let n_l = r.seq_len(48)?;
    let mut launches = Vec::with_capacity(n_l);
    for _ in 0..n_l {
        launches.push(read_launch_stat(r)?);
    }
    let n_cbc = r.seq_len(8)?;
    let mut ctas_by_cluster = Vec::with_capacity(n_cbc);
    for _ in 0..n_cbc {
        let n_row = r.seq_len(8)?;
        let mut row = Vec::with_capacity(n_row);
        for _ in 0..n_row {
            row.push(r.u64()?);
        }
        ctas_by_cluster.push(row);
    }
    let n_ph = r.seq_len(9)?;
    let mut phases = Vec::with_capacity(n_ph);
    for _ in 0..n_ph {
        phases.push(read_phase_sample(r)?);
    }
    let n_fp = r.seq_len(8)?;
    let mut free_pool = Vec::with_capacity(n_fp);
    for _ in 0..n_fp {
        free_pool.push(r.usize()?);
    }
    let n_rq = r.seq_len(8)?;
    let mut requeues = Vec::with_capacity(n_rq);
    for _ in 0..n_rq {
        let n_q = r.seq_len(4)?;
        let mut q = std::collections::VecDeque::with_capacity(n_q);
        for _ in 0..n_q {
            q.push_back(r.u32()?);
        }
        requeues.push(q);
    }
    Ok(StreamResume {
        tenants,
        owner,
        gen_kidx,
        launches,
        ctas_by_cluster,
        phases,
        free_pool,
        requeues,
    })
}

/// Serve `streams` on a fresh machine with the default (native-predictor)
/// controller. Seeds live inside the streams (see
/// [`crate::workload::traffic_trace`]); execution mode follows
/// `AMOEBA_DENSE`.
pub fn serve_streams(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    policy: PartitionPolicy,
) -> crate::errors::Result<StreamReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, Scheme::Baseline, controller)?;
    gpu.run_streams(streams, policy)
}

/// [`serve_streams`] with the execution mode pinned explicitly: `true`
/// forces the dense cycle-by-cycle reference loop, `false` the
/// event-horizon skip engine. Bit-identical by contract (enforced in
/// `tests/exec_determinism.rs`).
pub fn serve_streams_dense(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    policy: PartitionPolicy,
    dense: bool,
) -> crate::errors::Result<StreamReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, Scheme::Baseline, controller)?;
    gpu.set_dense(dense);
    gpu.run_streams(streams, policy)
}

/// [`serve_streams_dense`] with the intra-simulation worker count also
/// pinned explicitly (see [`run_benchmark_seeded_jobs`]) — the server
/// path shares [`Gpu::tick_active`], so multi-tenant runs (including
/// preemption and partition-scoped drains) are equally thread-count
/// invariant.
pub fn serve_streams_jobs(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    policy: PartitionPolicy,
    dense: bool,
    tick_jobs: usize,
) -> crate::errors::Result<StreamReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, Scheme::Baseline, controller)?;
    gpu.set_dense(dense);
    gpu.set_tick_jobs(tick_jobs);
    gpu.run_streams(streams, policy)
}

/// [`serve_streams_jobs`] with adaptive tick-job sizing pinned on
/// ([`Gpu::set_tick_jobs_auto`]) instead of a fixed worker count — the
/// multi-tenant analog of [`run_benchmark_seeded_auto`].
pub fn serve_streams_auto(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    policy: PartitionPolicy,
    dense: bool,
) -> crate::errors::Result<StreamReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, Scheme::Baseline, controller)?;
    gpu.set_dense(dense);
    gpu.set_tick_jobs_auto(true);
    gpu.run_streams(streams, policy)
}

/// [`serve_streams`] with a deterministic fault schedule injected at
/// cycle boundaries (an empty trace is bit-identical to no trace).
pub fn serve_streams_faulted(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    policy: PartitionPolicy,
    faults: &FaultTrace,
) -> crate::errors::Result<StreamReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, Scheme::Baseline, controller)?;
    gpu.set_fault_trace(faults)?;
    gpu.run_streams(streams, policy)
}

/// [`serve_streams_faulted`] with the execution mode pinned explicitly.
pub fn serve_streams_faulted_dense(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    policy: PartitionPolicy,
    dense: bool,
    faults: &FaultTrace,
) -> crate::errors::Result<StreamReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, Scheme::Baseline, controller)?;
    gpu.set_dense(dense);
    gpu.set_fault_trace(faults)?;
    gpu.run_streams(streams, policy)
}

/// [`serve_streams_faulted_dense`] with a checkpoint armed at
/// `snap_cycle` (see [`run_benchmark_snapshot`] for the capture
/// contract). `None` fault trace serves clean.
pub fn serve_streams_snapshot(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    policy: PartitionPolicy,
    dense: bool,
    snap_cycle: u64,
    faults: Option<&FaultTrace>,
) -> crate::errors::Result<(StreamReport, Option<Checkpoint>)> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, Scheme::Baseline, controller)?;
    gpu.set_dense(dense);
    if let Some(f) = faults {
        gpu.set_fault_trace(f)?;
    }
    gpu.arm_snapshot(snap_cycle);
    let report = gpu.run_streams(streams, policy)?;
    let cp = gpu.take_snapshot();
    Ok((report, cp))
}

/// Restore a [`serve_streams_snapshot`] checkpoint onto a fresh machine
/// and serve to completion — bit-identical to the uninterrupted run with
/// the same config/streams/policy, in either execution mode. The streams
/// passed here need not byte-match the capture-side streams beyond shape
/// (tenant count, launch counts, cluster count): this is what live tenant
/// migration exploits to replay in-flight work onto a healthy machine.
pub fn serve_streams_resume(
    cfg: &SystemConfig,
    streams: &[KernelStream],
    policy: PartitionPolicy,
    dense: bool,
    cp: &Checkpoint,
) -> crate::errors::Result<StreamReport> {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, Scheme::Baseline, controller)?;
    gpu.set_dense(dense);
    let loop_bytes = gpu.load_machine_sections(cp, MODE_STREAM)?;
    let mut r = ByteReader::new(&loop_bytes);
    let resume = read_stream_resume(&mut r)?;
    r.expect_end()?;
    gpu.run_streams_inner(streams, policy, Some(resume))
}

/// Simulate with a caller-supplied controller (e.g. the PJRT-HLO-backed
/// predictor from [`crate::runtime`]).
pub fn run_benchmark_with_controller(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    controller: Controller,
    seed: u64,
) -> crate::errors::Result<SimReport> {
    let mut gpu = Gpu::new(cfg, scheme, controller)?;
    Ok(gpu.run(profile, seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::bench;

    fn quick(profile: &str, scheme: Scheme) -> SimReport {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let mut p = bench(profile).unwrap();
        // Shrink for unit-test speed.
        p.num_ctas = 12;
        p.insns_per_thread = 120;
        p.num_kernels = 1;
        run_benchmark(&cfg, &p, scheme).unwrap()
    }

    #[test]
    fn baseline_completes_and_counts() {
        let r = quick("CP", Scheme::Baseline);
        assert_eq!(r.chip.kernels_completed, 1);
        assert!(r.ipc() > 0.5, "ipc={}", r.ipc());
        assert!(r.sm.thread_insns >= 12 * 256 * 120);
        assert!(r.sm.l1d_accesses > 0);
        assert!(r.chip.dram_reads > 0 || r.chip.l2_accesses > 0 || r.sm.noc_packets > 0);
    }

    #[test]
    fn scale_up_completes() {
        let r = quick("CP", Scheme::ScaleUp);
        assert_eq!(r.chip.kernels_completed, 1);
        assert!(r.sm.fused_cycles > 0);
        assert!(r.ipc() > 0.1);
    }

    #[test]
    fn static_fuse_profiles_and_decides() {
        let r = quick("SM", Scheme::StaticFuse);
        assert_eq!(r.decisions.len(), 1);
        assert_eq!(r.samples.len(), 1);
        assert_eq!(r.chip.kernels_completed, 1);
    }

    #[test]
    fn dynamic_schemes_complete() {
        for s in [Scheme::DirectSplit, Scheme::WarpRegroup, Scheme::Dws] {
            let r = quick("RAY", s);
            assert_eq!(r.chip.kernels_completed, 1, "{s}");
            assert!(r.ipc() > 0.1, "{s}: ipc={}", r.ipc());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SystemConfig::tiny();
        let mut p = bench("BFS").unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        let a = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 9).unwrap();
        let b = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 9).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.sm.thread_insns, b.sm.thread_insns);
        assert_eq!(a.sm.l1d_misses, b.sm.l1d_misses);
        let c = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 10).unwrap();
        assert_ne!(a.cycles, c.cycles, "different seeds should differ");
    }

    #[test]
    fn phase_trace_is_sampled() {
        let r = quick("RAY", Scheme::WarpRegroup);
        assert!(!r.phases.is_empty());
        assert_eq!(r.phases[0].modes.len(), SystemConfig::tiny().num_sms / 2);
    }

    #[test]
    fn hetero_records_one_decision_per_cluster() {
        let r = quick("RAY", Scheme::Hetero);
        let n_clusters = SystemConfig::tiny().num_sms / 2;
        assert_eq!(r.chip.kernels_completed, 1);
        assert_eq!(r.decisions.len(), n_clusters, "one decision per cluster");
        assert_eq!(r.samples.len(), n_clusters, "one sample per cluster");
        for (ci, d) in r.decisions.iter().enumerate() {
            assert_eq!(d.cluster, Some(ci as u32));
        }
        assert!(r.ipc() > 0.1, "ipc={}", r.ipc());
        // Every decision came from a real (finite) sample.
        assert!(r.samples.iter().all(|s| s.features.iter().all(|f| f.is_finite())));
    }

    #[test]
    fn chip_global_schemes_still_record_one_decision_per_kernel() {
        let r = quick("SM", Scheme::StaticFuse);
        assert_eq!(r.decisions.len(), 1);
        assert_eq!(r.decisions[0].cluster, None);
    }

    #[test]
    fn cycle_skip_matches_dense_quick() {
        // The full scheme x bench matrix lives in tests/exec_determinism;
        // this is the in-crate smoke check for the core contract.
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let mut p = bench("BFS").unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        for scheme in [Scheme::Baseline, Scheme::WarpRegroup] {
            let dense = run_benchmark_seeded_dense(&cfg, &p, scheme, 11, true).unwrap();
            let skip = run_benchmark_seeded_dense(&cfg, &p, scheme, 11, false).unwrap();
            assert_eq!(dense, skip, "{scheme}: skip must be bit-identical to dense");
        }
    }

    fn quick_stream(name: &str, scheme: Scheme, ctas: u32, insns: u32, seed: u64) -> KernelStream {
        let mut p = bench(name).unwrap();
        p.num_ctas = ctas;
        p.insns_per_thread = insns;
        p.num_kernels = 2;
        KernelStream::back_to_back(format!("{name}-{scheme}"), p, scheme, seed)
    }

    #[test]
    fn streams_complete_with_per_tenant_reports() {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let streams =
            vec![quick_stream("CP", Scheme::Baseline, 6, 60, 0xA11), quick_stream("BFS", Scheme::Hetero, 6, 60, 0xA12)];
        let r = serve_streams(&cfg, &streams, PartitionPolicy::Static).unwrap();
        assert!(!r.deadline_hit, "quick streams must finish inside the budget");
        assert!(r.outcome.is_none());
        assert_eq!(r.tenants.len(), 2);
        for (ti, t) in r.tenants.iter().enumerate() {
            assert_eq!(t.chip.kernels_completed, 2, "tenant {ti} kernels");
            assert!(t.sm.thread_insns >= 6 * 256 * 60, "tenant {ti} ran its work");
            assert!(t.cycles > 0 && t.cycles <= r.cycles, "tenant {ti} finish in range");
            assert!(r.tenant_throughput(ti) > 0.0);
        }
        assert!(r.launches.iter().all(|l| l.finish != u64::MAX), "all launches served");
        assert!(r.launches.iter().all(|l| l.start >= l.arrival));
        // Tenant conservation: per-tenant counters sum to the chip total,
        // and no CTA landed outside its tenant's (static) partition.
        let sum: u64 = r.tenants.iter().map(|t| t.sm.ctas_retired).sum();
        assert_eq!(sum, r.sm.ctas_retired, "attributed CTAs == chip CTAs");
        let insns: u64 = r.tenants.iter().map(|t| t.sm.thread_insns).sum();
        assert_eq!(insns, r.sm.thread_insns, "attributed insns == chip insns");
        for (ti, per_cluster) in r.ctas_by_cluster.iter().enumerate() {
            for (ci, &count) in per_cluster.iter().enumerate() {
                if count > 0 {
                    assert!(
                        r.partitions[ti].contains(&ci),
                        "tenant {ti} dispatched onto foreign cluster {ci}"
                    );
                }
            }
        }
    }

    #[test]
    fn hetero_tenant_decides_each_owned_cluster_per_kernel() {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let streams =
            vec![quick_stream("CP", Scheme::Baseline, 6, 60, 0xB01), quick_stream("RAY", Scheme::Hetero, 6, 60, 0xB02)];
        let r = serve_streams(&cfg, &streams, PartitionPolicy::Static).unwrap();
        assert!(r.tenants[0].decisions.is_empty(), "baseline tenant never predicts");
        let hetero = &r.tenants[1];
        let owned = r.partitions[1].len();
        assert_eq!(hetero.decisions.len(), owned * 2, "one decision per cluster per kernel");
        assert_eq!(hetero.samples.len(), owned * 2);
        for d in &hetero.decisions {
            let ci = d.cluster.expect("per-cluster decisions carry ids") as usize;
            assert!(r.partitions[1].contains(&ci), "decision for foreign cluster {ci}");
        }
    }

    #[test]
    fn stream_skip_matches_dense_smoke() {
        // The full stream matrix lives in tests/exec_determinism; this is
        // the in-crate smoke check for the multi-tenant skip contract.
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let streams =
            vec![quick_stream("BFS", Scheme::WarpRegroup, 6, 60, 0xC01), quick_stream("CP", Scheme::Baseline, 6, 60, 0xC02)];
        let dense = serve_streams_dense(&cfg, &streams, PartitionPolicy::Static, true).unwrap();
        let skip = serve_streams_dense(&cfg, &streams, PartitionPolicy::Static, false).unwrap();
        assert_eq!(dense, skip, "stream skip must be bit-identical to dense");
    }

    #[test]
    fn adaptive_policy_adopts_freed_clusters() {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        // Tenant 0: one small kernel, done early. Tenant 1: two kernels,
        // the second arriving far enough out that tenant 0 is finished
        // before it starts.
        let mut p0 = bench("CP").unwrap();
        p0.num_ctas = 4;
        p0.insns_per_thread = 40;
        p0.num_kernels = 1;
        let t0 = KernelStream::back_to_back("t0:CP", p0, Scheme::Baseline, 0xD01);
        let mut p1 = bench("BFS").unwrap();
        p1.num_ctas = 6;
        p1.insns_per_thread = 60;
        let mut t1 = KernelStream::back_to_back("t1:BFS", p1, Scheme::WarpRegroup, 0xD02);
        t1.launches.truncate(2);
        t1.launches[1].arrival = 500_000;
        let streams = vec![t0, t1];
        let r = serve_streams(&cfg, &streams, PartitionPolicy::Adaptive).unwrap();
        assert!(r.launches.iter().all(|l| l.finish != u64::MAX), "all launches served");
        // Tenant 1's second kernel ran on the adopted cluster(s) too.
        let foreign: u64 = r.ctas_by_cluster[1]
            .iter()
            .enumerate()
            .filter(|(ci, _)| !r.partitions[1].contains(ci))
            .map(|(_, &c)| c)
            .sum();
        assert!(foreign > 0, "adaptive policy never adopted a freed cluster");
        // Attribution stays conservative under repartitioning.
        let sum: u64 = r.tenants.iter().map(|t| t.sm.ctas_retired).sum();
        assert_eq!(sum, r.sm.ctas_retired);
    }

    #[test]
    fn too_many_tenants_is_rejected() {
        let cfg = SystemConfig::tiny(); // 2 clusters
        let streams = vec![
            quick_stream("CP", Scheme::Baseline, 2, 20, 1),
            quick_stream("CP", Scheme::Baseline, 2, 20, 2),
            quick_stream("CP", Scheme::Baseline, 2, 20, 3),
        ];
        let e = serve_streams(&cfg, &streams, PartitionPolicy::Static).unwrap_err();
        assert!(e.to_string().contains("more tenants"), "got: {e}");
    }

    #[test]
    fn invalid_config_is_an_error_not_a_panic() {
        let mut cfg = SystemConfig::tiny();
        cfg.num_sms = 1; // odd SM count: clusters are SM pairs
        let p = bench("CP").unwrap();
        assert!(run_benchmark(&cfg, &p, Scheme::Baseline).is_err());
    }

    #[test]
    fn cycle_skip_advances_past_dead_windows() {
        // A memory-bound run must still finish with identical cycle
        // counts; the skip engine only changes wall-clock, never `now`.
        let cfg = SystemConfig::tiny();
        let mut p = bench("BFS").unwrap();
        p.num_ctas = 4;
        p.insns_per_thread = 60;
        p.num_kernels = 1;
        let dense = run_benchmark_seeded_dense(&cfg, &p, Scheme::Baseline, 3, true).unwrap();
        let skip = run_benchmark_seeded_dense(&cfg, &p, Scheme::Baseline, 3, false).unwrap();
        assert_eq!(dense.cycles, skip.cycles);
        assert_eq!(dense.chip.cycles, skip.chip.cycles);
        assert_eq!(dense.sm.stall_memory, skip.sm.stall_memory);
    }

    use crate::sim::fault::{FaultEvent, FaultKind, FaultTrace};

    fn small_profile(name: &str, ctas: u32) -> crate::workload::BenchProfile {
        let mut p = bench(name).unwrap();
        p.num_ctas = ctas;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        p
    }

    #[test]
    fn cluster_fault_requeues_and_completes() {
        // Kill cluster 0 mid-run: its CTAs requeue onto cluster 1 and the
        // kernel still completes, conserving CTAs.
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let p = small_profile("CP", 8);
        let trace = FaultTrace::new(vec![FaultEvent {
            cycle: 300,
            kind: FaultKind::Cluster { cluster: 0 },
        }]);
        let r = run_benchmark_faulted(&cfg, &p, Scheme::Baseline, 7, &trace).unwrap();
        assert_eq!(r.chip.kernels_completed, 1);
        assert!(!r.deadline_hit, "degraded chip must still finish");
        assert_eq!(r.chip.faults_injected, 1);
        assert_eq!(r.chip.clusters_retired, 1);
        assert!(r.chip.ctas_requeued > 0, "cluster 0 had resident CTAs at cycle 300");
        // Conservation: every dispatch either retired or was requeued
        // (and a requeued CTA's re-dispatch counts again).
        assert_eq!(r.chip.ctas_dispatched, r.sm.ctas_retired + r.chip.ctas_requeued);
    }

    #[test]
    fn half_fault_serves_on_healthy_half() {
        // A dead half-SM under a split-capable scheme: the cluster stays
        // in service on its healthy half and the run completes.
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let p = small_profile("CP", 8);
        let trace = FaultTrace::new(vec![FaultEvent {
            cycle: 300,
            kind: FaultKind::HalfSm { cluster: 0, half: 0 },
        }]);
        let r = run_benchmark_faulted(&cfg, &p, Scheme::Baseline, 7, &trace).unwrap();
        assert_eq!(r.chip.kernels_completed, 1);
        assert_eq!(r.chip.faults_injected, 1);
        assert_eq!(r.chip.clusters_retired, 0, "tolerant scheme keeps the cluster");
        assert_eq!(r.chip.ctas_dispatched, r.sm.ctas_retired + r.chip.ctas_requeued);
    }

    #[test]
    fn scale_up_loses_whole_cluster_on_half_fault() {
        // The rigid fused machine cannot route around a dead half: the
        // same fault retires the entire cluster.
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let p = small_profile("CP", 8);
        let trace = FaultTrace::new(vec![FaultEvent {
            cycle: 300,
            kind: FaultKind::HalfSm { cluster: 0, half: 1 },
        }]);
        let r = run_benchmark_faulted(&cfg, &p, Scheme::ScaleUp, 7, &trace).unwrap();
        assert_eq!(r.chip.clusters_retired, 1, "ScaleUp loses the whole cluster");
        assert_eq!(r.chip.kernels_completed, 1, "the other cluster still serves");
    }

    #[test]
    fn faulted_skip_matches_dense_smoke() {
        // The full fault matrix lives in tests/exec_determinism; this is
        // the in-crate smoke check that injection preserves the skip
        // contract across all four fault kinds.
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let p = small_profile("BFS", 8);
        let trace = FaultTrace::new(vec![
            FaultEvent { cycle: 200, kind: FaultKind::NocDegrade { penalty: 1 } },
            FaultEvent { cycle: 400, kind: FaultKind::McStall { mc: 0, cycles: 500 } },
            FaultEvent { cycle: 900, kind: FaultKind::HalfSm { cluster: 1, half: 0 } },
            FaultEvent { cycle: 1_500, kind: FaultKind::Cluster { cluster: 0 } },
        ]);
        for scheme in [Scheme::Baseline, Scheme::WarpRegroup] {
            let dense =
                run_benchmark_faulted_dense(&cfg, &p, scheme, 11, true, &trace).unwrap();
            let skip =
                run_benchmark_faulted_dense(&cfg, &p, scheme, 11, false, &trace).unwrap();
            assert_eq!(dense, skip, "{scheme}: faulted skip must match dense");
        }
    }

    #[test]
    fn empty_fault_trace_is_identical_to_none() {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let p = small_profile("CP", 8);
        let plain = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 5).unwrap();
        let empty =
            run_benchmark_faulted(&cfg, &p, Scheme::Baseline, 5, &FaultTrace::default()).unwrap();
        assert_eq!(plain, empty, "empty trace must be a bit-identical no-op");
    }

    #[test]
    fn partition_scoped_drain_does_not_hold_other_tenants() {
        // Four tenants on four clusters. t0 (ScaleUp) finishes early and
        // frees a *fused* cluster; t1 (Baseline) adopts it at its second
        // launch and must reconfigure it private — a partition-scoped
        // drain + quiesce. t2 runs one long kernel across that whole
        // window. t3's single launch arrives *during* it: under the old
        // chip-global drain t3 (and t2's dispatch) would stall until the
        // whole chip went idle; partition-scoped draining starts t3 at
        // exactly its arrival cycle.
        let mut cfg = SystemConfig::tiny();
        cfg.num_sms = 8; // 4 clusters
        cfg.max_cycles = 1_500_000;
        let mut p0 = bench("CP").unwrap();
        p0.num_ctas = 4;
        p0.insns_per_thread = 40;
        let mut t0 = KernelStream::back_to_back("t0:CP", p0.clone(), Scheme::ScaleUp, 0xE01);
        t0.launches.truncate(1);
        let mut t1 = KernelStream::back_to_back("t1:CP", p0.clone(), Scheme::Baseline, 0xE02);
        t1.launches.truncate(2);
        t1.launches[1].arrival = 500_000;
        let mut p2 = bench("BFS").unwrap();
        p2.num_ctas = 12;
        p2.insns_per_thread = 800;
        let mut t2 = KernelStream::back_to_back("t2:BFS", p2, Scheme::Baseline, 0xE03);
        t2.launches.truncate(1);
        let mut t3 = KernelStream::back_to_back("t3:CP", p0, Scheme::Baseline, 0xE04);
        t3.launches.truncate(1);
        t3.launches[0].arrival = 500_040;
        let streams = vec![t0, t1, t2, t3];
        let r = serve_streams(&cfg, &streams, PartitionPolicy::Adaptive).unwrap();
        assert!(!r.deadline_hit);
        assert!(r.launches.iter().all(|l| l.finish != u64::MAX), "all launches served");
        // The adopted fused cluster forced a (partition-scoped) drain.
        assert!(
            r.tenants[1].chip.reconfig_events >= 1,
            "t1 never reconfigured its adopted cluster"
        );
        // t2's long kernel spans the drain window: the fabric stayed in
        // service for it while t1 drained and quiesced.
        let l2 = r.launches.iter().find(|l| l.tenant == 2).unwrap();
        assert!(l2.start < 10_000 && l2.finish > 500_100, "t2 must span the drain window");
        // t3 launched at exactly its arrival cycle: no chip-wide hold.
        let l3 = r.launches.iter().find(|l| l.tenant == 3).unwrap();
        assert_eq!(l3.start, 500_040, "partition-scoped drain must not delay t3's start");
        assert_eq!(l3.queue_delay, 0);
        // Launch-stat identities hold for every served launch.
        for l in &r.launches {
            assert_eq!(l.queue_delay, l.start - l.arrival);
            assert!(l.slowdown_milli >= 1000, "turnaround >= service");
        }
        let sum: u64 = r.tenants.iter().map(|t| t.sm.ctas_retired).sum();
        assert_eq!(sum, r.sm.ctas_retired);
    }

    #[test]
    fn high_priority_tenant_preempts_at_cta_boundary() {
        // Four clusters, three tenants -> partitions [0], [1], [2, 3].
        // t0 is High priority with a launch at cycle 5_000: below its
        // fair share (ceil(4/3) = 2), it steals the Low tenant's last
        // cluster mid-kernel. The victim's resident CTAs requeue and the
        // run still conserves every CTA, bit-identically in both modes.
        let mut cfg = SystemConfig::tiny();
        cfg.num_sms = 8; // 4 clusters
        cfg.max_cycles = 1_500_000;
        let mut p0 = bench("CP").unwrap();
        p0.num_ctas = 4;
        p0.insns_per_thread = 40;
        let mut t0 = KernelStream::back_to_back("t0:CP", p0.clone(), Scheme::Baseline, 0xF01);
        t0.launches.truncate(1);
        t0.launches[0].arrival = 5_000;
        t0.priority = Priority::High;
        // t1 must still be mid-kernel at cycle 5_000, or its freed
        // cluster would satisfy t0's fair share through the free pool
        // and no preemption would be needed.
        let mut p1 = p0.clone();
        p1.insns_per_thread = 300;
        let mut t1 = KernelStream::back_to_back("t1:CP", p1, Scheme::Baseline, 0xF02);
        t1.launches.truncate(1);
        let mut p2 = bench("BFS").unwrap();
        p2.num_ctas = 16;
        p2.insns_per_thread = 300;
        let mut t2 = KernelStream::back_to_back("t2:BFS", p2, Scheme::Baseline, 0xF03);
        t2.launches.truncate(1);
        t2.priority = Priority::Low;
        let streams = vec![t0, t1, t2];
        let dense = serve_streams_dense(&cfg, &streams, PartitionPolicy::Adaptive, true).unwrap();
        let skip = serve_streams_dense(&cfg, &streams, PartitionPolicy::Adaptive, false).unwrap();
        assert_eq!(dense, skip, "preemption must preserve the skip contract");
        let r = skip;
        assert!(!r.deadline_hit);
        assert!(r.launches.iter().all(|l| l.finish != u64::MAX), "all launches served");
        assert_eq!(r.chip.preemptions, 1, "t0 takes exactly one cluster to reach fair share");
        assert_eq!(r.tenants[0].chip.preemptions, 1, "attributed to the claimant");
        assert!(r.chip.ctas_preempted > 0, "the victim had resident CTAs mid-kernel");
        assert_eq!(r.tenants[2].chip.ctas_preempted, r.chip.ctas_preempted);
        assert!(r.chip.ctas_preempted <= r.chip.ctas_requeued);
        // The claimant actually ran work on the stolen cluster, and the
        // High tenant started at exactly its arrival.
        assert!(r.ctas_by_cluster[0][3] > 0, "stolen cluster never served the claimant");
        let l0 = r.launches.iter().find(|l| l.tenant == 0).unwrap();
        assert_eq!(l0.start, 5_000);
        // Conservation: every dispatch either retired or was requeued
        // (and a requeued CTA's re-dispatch counts again).
        assert_eq!(r.chip.ctas_dispatched, r.sm.ctas_retired + r.chip.ctas_requeued);
    }
}
