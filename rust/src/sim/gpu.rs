//! Top-level GPU: clusters + NoC + memory partitions + CTA dispatcher +
//! the per-kernel AMOEBA reconfiguration loop (Fig 7).
//!
//! The machine layout is a **per-cluster** fused/private vector
//! ([`ChipLayout`], §4.4): a private cluster keeps both of its NoC
//! routers, a fused cluster bypasses the second one, and the two kinds
//! can coexist in one fabric. The homogeneous special cases are the
//! paper's classic machines (all-private baseline: `num_sms + num_mcs`
//! nodes with cluster `i` at `2i`/`2i+1`; all-fused scale-up:
//! `num_sms/2 + num_mcs` nodes with cluster `i` at `i`).
//!
//! The NoC is rebuilt when the layout changes (kernel boundaries only;
//! dynamic split keeps the fused NoC interface, §4.3).
//!
//! ## Event-horizon cycle skipping
//!
//! Memory-divergent kernels spend most of their cycles with every warp
//! parked on a scoreboard or DRAM release. Instead of burning a full
//! `tick` through clusters, NoC and partitions for each of those idle
//! cycles, the kernel loop asks every component for its next event
//! ([`crate::sim::NextEvent`]) and, when the whole chip is quiescent (no
//! issuable warp, no movable packet, no dispatchable CTA), fast-forwards
//! `self.now` to the horizon while replaying the per-cycle accounting
//! (stall breakdowns, mode counters, LRU clocks) in O(1). The contract
//! is **bit-identical `SimReport`s** to the dense loop — enforced by
//! `tests/exec_determinism.rs` — and `AMOEBA_DENSE=1` (or
//! [`Gpu::set_dense`]) forces the dense loop for auditing. The skip mode
//! is deliberately *not* part of [`SystemConfig`], so sweep-cache
//! fingerprints ([`crate::harness::cfg_fingerprint`]) stay mode-agnostic.

use crate::amoeba::controller::{Controller, KernelDecision};
use crate::amoeba::dynsplit::DynSplit;
use crate::amoeba::metrics::MetricsSample;
use crate::config::{Scheme, SystemConfig};
use crate::isa::KernelLaunch;
use crate::sim::core::{ClusterMode, DivergenceMode, SmCluster};
use crate::sim::mem::{MemPartition, PartitionReply};
use crate::sim::noc::{ChipLayout, Noc, Packet, Payload, Subnet};
use crate::stats::{ChipStats, SmStats};
use crate::workload::{kernel_launches, BenchProfile, TraceGen};

/// Cached `AMOEBA_DENSE` escape hatch: any non-empty value other than
/// `0` forces the dense cycle loop (read once per process).
fn dense_env() -> bool {
    static DENSE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *DENSE.get_or_init(|| {
        std::env::var("AMOEBA_DENSE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
    })
}

/// One Fig 19 sample: cycle + per-cluster mode snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseSample {
    /// Sample cycle.
    pub cycle: u64,
    /// Mode of every cluster at that cycle.
    pub modes: Vec<ClusterMode>,
}

/// Result of simulating one application under one scheme.
///
/// `PartialEq` compares every counter, decision, phase sample, and
/// metric sample — the equality the skip-vs-dense and parallel-vs-serial
/// determinism tests assert (float fields compare by value; the tests
/// additionally pin their bit patterns).
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// Benchmark name.
    pub bench: String,
    /// Scheme simulated.
    pub scheme: Scheme,
    /// Total GPU cycles.
    pub cycles: u64,
    /// Aggregated SM statistics (all clusters).
    pub sm: SmStats,
    /// Chip-level statistics.
    pub chip: ChipStats,
    /// Fuse decisions taken: one per kernel for chip-global schemes, one
    /// per cluster per kernel for the heterogeneous scheme (§4.4).
    pub decisions: Vec<KernelDecision>,
    /// Periodic cluster-mode snapshots (Fig 19).
    pub phases: Vec<PhaseSample>,
    /// Metric samples collected during each kernel's profiling window
    /// (empty for schemes that do not profile; one per cluster per kernel
    /// under the heterogeneous scheme).
    pub samples: Vec<MetricsSample>,
}

impl SimReport {
    /// Thread-instructions per cycle — the paper's headline metric.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.sm.thread_insns as f64 / self.cycles as f64
        }
    }
}

/// Dispatch at most this many CTAs per cycle (kernel-launch engine rate).
const DISPATCH_PER_CYCLE: usize = 2;
/// Fig 19 phase-sampling period in cycles.
const PHASE_SAMPLE_PERIOD: u64 = 512;
/// Replies an MC can inject per cycle (the L2 slice has two reply ports,
/// matching GPGPU-Sim's icnt-to-shader interface width).
const MC_REPLY_BUDGET: usize = 2;

/// The machine under simulation.
pub struct Gpu {
    cfg: SystemConfig,
    scheme: Scheme,
    clusters: Vec<SmCluster>,
    partitions: Vec<MemPartition>,
    noc: Noc,
    /// Current per-cluster fused/private layout and its NoC node map.
    layout: ChipLayout,
    now: u64,
    chip: ChipStats,
    /// Per-MC replies awaiting injection (bounded by MC_REPLY_BUDGET).
    reply_retry: Vec<std::collections::VecDeque<PartitionReply>>,
    /// Per-MC requests ejected from the NoC but rejected by the partition
    /// (queue/MSHR full); retried before new ejections. Bounded so NoC
    /// backpressure is preserved.
    req_backlog: Vec<std::collections::VecDeque<Packet>>,
    controller: Controller,
    /// One split/fuse state machine per cluster ("watched independently",
    /// §4.3 — a single shared instance let one cluster's rebalance starve
    /// every other cluster's rebalance period).
    dynsplits: Vec<DynSplit>,
    phases: Vec<PhaseSample>,
    samples: Vec<MetricsSample>,
    decisions: Vec<KernelDecision>,
    /// Reusable per-cycle partition-reply buffer (hot-path alloc
    /// elimination: one buffer serves every MC every cycle).
    reply_scratch: Vec<PartitionReply>,
    /// Force the dense cycle loop (no event-horizon skipping). Defaults
    /// to the `AMOEBA_DENSE` env var; see [`Gpu::set_dense`].
    dense: bool,
}

impl Gpu {
    /// Build a machine for `scheme` under `cfg`.
    pub fn new(cfg: &SystemConfig, scheme: Scheme, controller: Controller) -> Self {
        cfg.validate().expect("invalid system config");
        let n_clusters = cfg.num_sms / 2;
        assert!(n_clusters > 0, "need at least 2 SMs (one cluster)");
        let initial_fused = scheme == Scheme::ScaleUp;
        let mode = if initial_fused { ClusterMode::Fused } else { ClusterMode::PrivatePair };
        let mut clusters: Vec<SmCluster> =
            (0..n_clusters).map(|i| SmCluster::new(i, cfg, mode)).collect();
        if scheme == Scheme::Dws {
            for c in &mut clusters {
                c.divergence_mode = DivergenceMode::Shadowed;
            }
        }
        let layout = ChipLayout::homogeneous(n_clusters, initial_fused, cfg.num_mcs);
        Gpu {
            cfg: cfg.clone(),
            scheme,
            clusters,
            partitions: (0..cfg.num_mcs).map(|_| MemPartition::new(cfg)).collect(),
            noc: Noc::new(cfg, &layout),
            layout,
            now: 0,
            chip: ChipStats::default(),
            reply_retry: (0..cfg.num_mcs).map(|_| std::collections::VecDeque::new()).collect(),
            req_backlog: (0..cfg.num_mcs).map(|_| std::collections::VecDeque::new()).collect(),
            controller,
            dynsplits: (0..n_clusters).map(|_| DynSplit::new(cfg)).collect(),
            phases: Vec::new(),
            samples: Vec::new(),
            decisions: Vec::new(),
            reply_scratch: Vec::with_capacity(MC_REPLY_BUDGET),
            dense: dense_env(),
        }
    }

    /// Select the execution mode: `true` runs the dense cycle-by-cycle
    /// loop, `false` (default unless `AMOEBA_DENSE=1`) enables
    /// event-horizon cycle skipping. Both produce bit-identical
    /// [`SimReport`]s; the dense loop is the auditing reference.
    pub fn set_dense(&mut self, dense: bool) {
        self.dense = dense;
    }

    /// NoC nodes for cluster `ci` in the current layout.
    fn nodes_of(&self, ci: usize) -> [usize; 2] {
        self.layout.nodes_of(ci)
    }

    /// Cluster owning NoC node `n` (inverse of `nodes_of`).
    fn cluster_of_node(&self, n: usize) -> usize {
        self.layout.cluster_of_node(n)
    }

    fn mc_node(&self, mc: usize) -> usize {
        self.layout.mc_node(mc)
    }

    /// Rebuild the NoC for a new per-cluster layout and flush cluster
    /// caches (the paper drains pipelines and pays a reconfiguration
    /// cost). `target[ci]` selects fused (true) or private (false) for
    /// cluster `ci`; mixed vectors build a heterogeneous fabric (§4.4).
    ///
    /// Only clusters whose mode actually changes are rewired (flush +
    /// freeze): a cluster that decided to stay as-is keeps its warm L1s
    /// and keeps issuing. Callers reconfigure on a drained machine, so
    /// the NoC rebuild never strands in-flight packets of skipped
    /// clusters. (On the chip-global paths every reconfigure crosses the
    /// fused/private boundary for every cluster, so the skip never fires
    /// there and their behaviour is unchanged.)
    fn reconfigure(&mut self, target: &[bool]) {
        debug_assert_eq!(target.len(), self.clusters.len());
        for (c, &fused) in self.clusters.iter_mut().zip(target) {
            let mode = if fused { ClusterMode::Fused } else { ClusterMode::PrivatePair };
            if c.mode() == mode {
                continue;
            }
            c.set_mode(mode);
            c.flush_caches();
            c.frozen_until = self.now + self.cfg.reconfig_cost;
        }
        self.layout = ChipLayout::new(target.to_vec(), self.cfg.num_mcs);
        self.noc = Noc::new(&self.cfg, &self.layout);
        self.chip.reconfig_events += 1;
        self.chip.reconfig_cycles += self.cfg.reconfig_cost;
    }

    /// Reconfigure every cluster to the same mode (chip-global schemes).
    fn reconfigure_all(&mut self, fused: bool) {
        let target = vec![fused; self.clusters.len()];
        self.reconfigure(&target);
    }

    /// Advance the whole machine one cycle; `gen` resolves traces of the
    /// kernel currently executing.
    fn tick(&mut self, gen: &TraceGen) {
        let now = self.now;
        self.chip.cycles += 1;

        // 1. SM clusters (issue + LSU + NoC injection).
        for ci in 0..self.clusters.len() {
            let nodes = self.nodes_of(ci);
            self.clusters[ci].tick(now, &mut self.noc, nodes, gen);
        }

        // 2. Interconnect.
        self.noc.tick(now);

        // 3. Memory side: requests into partitions. A rejected request
        // (queue/MSHR full) parks in a bounded per-MC backlog and is
        // retried before new ejections — its src (the reply address) is
        // preserved.
        const BACKLOG_CAP: usize = 16;
        for mc in 0..self.partitions.len() {
            let node = self.mc_node(mc);
            // Retry the backlog first (FIFO).
            while let Some(pkt) = self.req_backlog[mc].front().copied() {
                if self.offer_to_partition(mc, now, &pkt) {
                    self.req_backlog[mc].pop_front();
                } else {
                    break;
                }
            }
            // New ejections, bounded by backlog space.
            while self.req_backlog[mc].len() < BACKLOG_CAP {
                let Some(pkt) = self.noc.eject(Subnet::Request, node) else { break };
                if !self.offer_to_partition(mc, now, &pkt) {
                    self.req_backlog[mc].push_back(pkt);
                }
            }
        }

        // 4. Partitions tick; replies head for the reply subnet. The
        // emission buffer is owned by the Gpu and reused across MCs and
        // cycles (no per-cycle allocation).
        let mut out = std::mem::take(&mut self.reply_scratch);
        for mc in 0..self.partitions.len() {
            self.chip.mc_cycles += 1;
            let node = self.mc_node(mc);
            let mut stalled = false;
            // Retry previously blocked replies first (FIFO; preserve all).
            while let Some(r) = self.reply_retry[mc].front().copied() {
                if self.try_inject_reply(now, node, &r) {
                    self.reply_retry[mc].pop_front();
                } else {
                    stalled = true;
                    break;
                }
            }
            let budget = MC_REPLY_BUDGET.saturating_sub(self.reply_retry[mc].len());
            out.clear();
            let emit_stalled = self.partitions[mc].tick(now, &mut out, budget);
            for i in 0..out.len() {
                let r = out[i];
                if !self.try_inject_reply(now, node, &r) {
                    self.reply_retry[mc].push_back(r);
                    stalled = true;
                }
            }
            if stalled || emit_stalled {
                // Fig 17: a reply was ready but could not enter the NoC.
                self.chip.mc_inject_stall_cycles += 1;
            }
        }
        self.reply_scratch = out;

        // 5. SM side: reply delivery.
        let sm_nodes = self.layout.sm_nodes();
        for node in 0..sm_nodes {
            while let Some(pkt) = self.noc.eject(Subnet::Reply, node) {
                if let Payload::MemReply { line, is_write, .. } = pkt.payload {
                    let ci = self.cluster_of_node(node);
                    self.clusters[ci].on_reply(now, line, is_write);
                }
            }
        }

        self.now += 1;
    }

    /// Offer one ejected request packet to partition `mc`; false = retry.
    fn offer_to_partition(&mut self, mc: usize, now: u64, pkt: &Packet) -> bool {
        let Payload::MemRequest { line, requester, is_write } = pkt.payload else {
            return true; // stray reply payload: drop (cannot happen)
        };
        let tag = (pkt.src as u64) << 32 | requester as u64;
        self.partitions[mc].request(now, line, tag, is_write, self.cfg.l2_hit_latency as u64)
    }

    fn try_inject_reply(&mut self, now: u64, mc_node: usize, r: &PartitionReply) -> bool {
        let dst = (r.tag >> 32) as usize;
        let requester = (r.tag & 0xFFFF_FFFF) as u32;
        let flits = if r.is_write {
            1
        } else {
            self.cfg.flits_for(self.cfg.line_bytes + 16) as u32
        };
        let pkt = Packet {
            src: mc_node,
            dst,
            flits,
            born: now,
            payload: Payload::MemReply { line: r.line, requester, is_write: r.is_write },
        };
        self.noc.inject(Subnet::Reply, pkt)
    }

    /// Fast-forward `self.now` to the chip's event horizon if the machine
    /// is quiescent, replaying the skipped cycles' accounting in O(1).
    ///
    /// `cap` is the last cycle the caller allows to become the new `now`:
    /// the cycle *before* any loop-level trigger (profiling-window end,
    /// split check, Fig 19 sample boundary, deadline) so the triggering
    /// tick always runs live and fires at exactly the same `now` as the
    /// dense loop. Returns false — and skips nothing — when any component
    /// would make progress this cycle, when a retry/backlog queue holds
    /// work (those are retried every cycle), or in dense mode.
    ///
    /// The caller must have established that CTA dispatch made no
    /// progress this cycle (cluster state is frozen across the window, so
    /// dispatchability cannot appear mid-skip).
    fn try_skip(&mut self, gen: &TraceGen, cap: u64) -> bool {
        use crate::sim::NextEvent;
        if self.dense || cap <= self.now {
            return false;
        }
        if self.reply_retry.iter().any(|q| !q.is_empty())
            || self.req_backlog.iter().any(|q| !q.is_empty())
        {
            return false;
        }
        let now = self.now;
        let mut ev = NextEvent::Idle;
        for c in &self.clusters {
            ev = ev.min_with(c.next_event(now, gen));
            if ev == NextEvent::Progress {
                return false;
            }
        }
        ev = ev.min_with(self.noc.next_event(now));
        if ev == NextEvent::Progress {
            return false;
        }
        for p in &self.partitions {
            ev = ev.min_with(p.next_event(now));
            if ev == NextEvent::Progress {
                return false;
            }
        }
        let target = match ev {
            NextEvent::Progress => return false,
            NextEvent::At(t) => t.min(cap),
            // Fully event-free (e.g. a deadlock the deadline will catch):
            // accounting still advances, so skip to the cap.
            NextEvent::Idle => cap,
        };
        if target <= now {
            return false;
        }
        let k = target - now;
        self.chip.cycles += k;
        self.chip.mc_cycles += k * self.partitions.len() as u64;
        for c in &mut self.clusters {
            c.skip(now, k);
        }
        self.now = target;
        true
    }

    /// Is every cluster + partition + the NoC fully drained?
    fn drained(&self) -> bool {
        self.clusters.iter().all(|c| c.idle())
            && self.partitions.iter().all(|p| !p.busy())
            && !self.noc.busy()
            && self.reply_retry.iter().all(|r| r.is_empty())
            && self.req_backlog.iter().all(|b| b.is_empty())
    }

    /// Execute one kernel to completion, including the per-kernel AMOEBA
    /// controller loop: profile -> predict -> reconfigure -> run (Fig 7).
    fn run_kernel(&mut self, profile: &BenchProfile, kernel: &KernelLaunch) {
        let gen = TraceGen::new(profile, kernel);
        let mut next_cta: u32 = 0;
        let total_ctas = kernel.num_ctas;

        // -------- Phase 1: profiling window (predictor schemes only).
        let mut profiling = self.scheme.uses_predictor();
        let profile_start = self.now;
        let base_stats = self.aggregate_sm();
        // Per-cluster baselines for the heterogeneous decision path: each
        // cluster's window delta is taken against its own counters.
        let base_per: Vec<SmStats> = if self.scheme.per_cluster() {
            self.clusters.iter().map(|c| c.stats.clone()).collect()
        } else {
            Vec::new()
        };

        // Predictor schemes always profile in the scale-out layout.
        if profiling && self.layout.any_fused() {
            self.reconfigure_all(false);
        }

        let deadline = self.now + self.cfg.max_cycles.max(1);
        let mut split_check_at = self.now + self.cfg.split_check_period;

        // While profiling, only a probe wave of CTAs is dispatched (one per
        // cluster — §4.1.1: a CTA tracks its kernel's scaling behaviour);
        // the rest of the grid launches after the reconfiguration decision,
        // so the bulk of the kernel runs in the chosen configuration.
        let probe_cap = self.clusters.len() as u32;

        loop {
            // CTA dispatch.
            let cap = if profiling { probe_cap.min(total_ctas) } else { total_ctas };
            let mut dispatched = 0;
            if profiling && self.scheme.per_cluster() {
                // Heterogeneous probe wave: CTA `i` lands on cluster `i`,
                // so the per-cluster windows measure disjoint work. Grids
                // smaller than the cluster count leave the tail clusters
                // probeless: their all-zero window decides on the
                // intercept alone, i.e. "no evidence => stay private".
                while next_cta < cap && dispatched < DISPATCH_PER_CYCLE {
                    let ci = next_cta as usize % self.clusters.len();
                    if !self.clusters[ci].can_accept_cta(kernel) {
                        break;
                    }
                    self.clusters[ci].dispatch_cta(kernel, next_cta, &gen);
                    next_cta += 1;
                    dispatched += 1;
                }
            } else {
                'dispatch: for ci in 0..self.clusters.len() {
                    while next_cta < cap && self.clusters[ci].can_accept_cta(kernel) {
                        self.clusters[ci].dispatch_cta(kernel, next_cta, &gen);
                        next_cta += 1;
                        dispatched += 1;
                        if dispatched >= DISPATCH_PER_CYCLE {
                            break 'dispatch;
                        }
                    }
                }
            }

            // Quiescent chip: fast-forward to the next event instead of
            // ticking dead cycles one by one. The cap keeps every
            // loop-level trigger below on a live tick, so skip and dense
            // runs fire them at identical cycles. Dispatch progress this
            // cycle implies a live tick, so skipping is not considered;
            // neither is a loop about to terminate (a fully-drained grid
            // breaks after one more tick — skipping first could carry a
            // still-profiling kernel to its decision point, which the
            // dense loop never reaches).
            if dispatched == 0 && !(next_cta >= total_ctas && self.drained()) {
                let mut cap = deadline - 1;
                if profiling {
                    cap = cap.min((profile_start + self.cfg.profile_window).saturating_sub(1));
                }
                if self.scheme.splits().is_some() && self.layout.any_fused() {
                    cap = cap.min(split_check_at.saturating_sub(1));
                }
                let next_sample = (self.now / PHASE_SAMPLE_PERIOD + 1) * PHASE_SAMPLE_PERIOD;
                cap = cap.min(next_sample - 1);
                self.try_skip(&gen, cap);
            }

            self.tick(&gen);

            // Profiling window complete: predict and reconfigure.
            if profiling && self.now >= profile_start + self.cfg.profile_window {
                profiling = false;
                let target: Vec<bool> = if self.scheme.per_cluster() {
                    // §4.4: one decision per cluster from that cluster's
                    // own window — the chip can come out heterogeneous.
                    (0..self.clusters.len())
                        .map(|ci| {
                            let sample = MetricsSample::from_window_scaled(
                                &base_per[ci],
                                &self.clusters[ci].stats,
                                &self.cfg,
                                2,
                            );
                            let d = self.controller.decide_cluster(ci, &sample);
                            self.samples.push(sample);
                            self.decisions.push(d);
                            if d.scale_up {
                                self.chip.predictor_scale_up += 1;
                            } else {
                                self.chip.predictor_scale_out += 1;
                            }
                            d.scale_up
                        })
                        .collect()
                } else {
                    let cur = self.aggregate_sm();
                    let sample = MetricsSample::from_window(&base_stats, &cur, &self.cfg);
                    let fuse = self.controller.decide(&sample);
                    self.samples.push(sample);
                    self.decisions.push(fuse);
                    if fuse.scale_up {
                        self.chip.predictor_scale_up += 1;
                    } else {
                        self.chip.predictor_scale_out += 1;
                    }
                    vec![fuse.scale_up; self.clusters.len()]
                };
                if target.iter().any(|&f| f) {
                    // Drain resident work, then fuse. We stop dispatching
                    // during the drain by entering a drain loop here. The
                    // dense drain loop has no sampling or split checks, so
                    // the skip cap is the deadline alone.
                    while !self.drained() && self.now < deadline {
                        self.try_skip(&gen, deadline - 1);
                        self.tick(&gen);
                    }
                    for c in &mut self.clusters {
                        c.reap();
                    }
                    self.reconfigure(&target);
                    if let Some(policy) = self.scheme.splits() {
                        for (c, &fused) in self.clusters.iter_mut().zip(&target) {
                            c.split_policy = fused.then_some(policy);
                        }
                    }
                }
            }

            // Dynamic split/fuse checks (only meaningful on fused
            // clusters; each cluster's state machine runs independently).
            if self.scheme.splits().is_some()
                && self.layout.any_fused()
                && self.now >= split_check_at
            {
                split_check_at = self.now + self.cfg.split_check_period;
                for (ds, c) in self.dynsplits.iter_mut().zip(&mut self.clusters) {
                    ds.check(self.now, c);
                }
            }

            // Fig 19 phase sampling.
            if self.now % PHASE_SAMPLE_PERIOD == 0 {
                self.phases.push(PhaseSample {
                    cycle: self.now,
                    modes: self.clusters.iter().map(|c| c.mode()).collect(),
                });
            }

            if next_cta >= total_ctas && self.drained() {
                break;
            }
            if self.now >= deadline {
                // Safety net: dump state and bail (tests assert on IPC, so
                // a deadline hit is loudly visible).
                if std::env::var("AMOEBA_DEBUG").is_ok() {
                    eprintln!("[deadline] cycle {} kernel {}", self.now, kernel.id);
                    eprintln!("  noc busy: {} | {}", self.noc.busy(), self.noc.debug_state());
                    for (i, c) in self.clusters.iter().enumerate() {
                        eprintln!("  cluster {i}: {}", c.debug_state());
                    }
                    for (i, p) in self.partitions.iter().enumerate() {
                        eprintln!("  partition {i}: busy={}", p.busy());
                    }
                }
                break;
            }
        }

        for c in &mut self.clusters {
            c.reap();
            c.flush_caches();
        }
        for p in &mut self.partitions {
            p.flush();
        }
        self.chip.kernels_completed += 1;
    }

    fn aggregate_sm(&self) -> SmStats {
        let mut acc = SmStats::default();
        for c in &self.clusters {
            acc.absorb(&c.stats);
        }
        acc
    }

    /// Run a full application (all kernels) and report.
    pub fn run(&mut self, profile: &BenchProfile, seed: u64) -> SimReport {
        for kernel in kernel_launches(profile, seed) {
            self.run_kernel(profile, &kernel);
        }
        // Fold partition-side stats into the chip counters.
        for p in &self.partitions {
            self.chip.l2_accesses += p.accesses;
            self.chip.l2_misses += p.misses;
            self.chip.dram_reads += p.mc.reads;
            self.chip.dram_writes += p.mc.writes;
            self.chip.dram_row_hits += p.mc.row_hits;
            self.chip.dram_row_misses += p.mc.row_misses;
        }
        self.chip.noc_flits_routed = self.noc.flits_routed;
        // Surface predictor-backend fallbacks: nonzero means some logged
        // decisions were substituted defaults, not measured inferences.
        self.chip.predictor_fallbacks = self.controller.fallback_count();
        SimReport {
            bench: profile.name.to_string(),
            scheme: self.scheme,
            cycles: self.now,
            sm: self.aggregate_sm(),
            chip: self.chip.clone(),
            decisions: self.decisions.clone(),
            phases: self.phases.clone(),
            samples: self.samples.clone(),
        }
    }
}

/// Simulate `profile` under `scheme` with the default controller.
pub fn run_benchmark(cfg: &SystemConfig, profile: &BenchProfile, scheme: Scheme) -> SimReport {
    run_benchmark_seeded(cfg, profile, scheme, 0xAB0EBA)
}

/// Seeded variant (distinct workload instance per seed). Execution mode
/// (event-horizon skipping vs dense) follows `AMOEBA_DENSE`.
pub fn run_benchmark_seeded(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
) -> SimReport {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller);
    gpu.run(profile, seed)
}

/// [`run_benchmark_seeded`] with the execution mode pinned explicitly:
/// `dense = true` forces the cycle-by-cycle reference loop, `false` the
/// event-horizon skip engine. Both are bit-identical by contract — this
/// entry point exists so tests and benches can compare the two
/// in-process, independent of the `AMOEBA_DENSE` environment.
pub fn run_benchmark_seeded_dense(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    dense: bool,
) -> SimReport {
    let controller = Controller::native(cfg);
    let mut gpu = Gpu::new(cfg, scheme, controller);
    gpu.set_dense(dense);
    gpu.run(profile, seed)
}

/// Simulate with a caller-supplied controller (e.g. the PJRT-HLO-backed
/// predictor from [`crate::runtime`]).
pub fn run_benchmark_with_controller(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    controller: Controller,
    seed: u64,
) -> SimReport {
    let mut gpu = Gpu::new(cfg, scheme, controller);
    gpu.run(profile, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::bench;

    fn quick(profile: &str, scheme: Scheme) -> SimReport {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let mut p = bench(profile).unwrap();
        // Shrink for unit-test speed.
        p.num_ctas = 12;
        p.insns_per_thread = 120;
        p.num_kernels = 1;
        run_benchmark(&cfg, &p, scheme)
    }

    #[test]
    fn baseline_completes_and_counts() {
        let r = quick("CP", Scheme::Baseline);
        assert_eq!(r.chip.kernels_completed, 1);
        assert!(r.ipc() > 0.5, "ipc={}", r.ipc());
        assert!(r.sm.thread_insns >= 12 * 256 * 120);
        assert!(r.sm.l1d_accesses > 0);
        assert!(r.chip.dram_reads > 0 || r.chip.l2_accesses > 0 || r.sm.noc_packets > 0);
    }

    #[test]
    fn scale_up_completes() {
        let r = quick("CP", Scheme::ScaleUp);
        assert_eq!(r.chip.kernels_completed, 1);
        assert!(r.sm.fused_cycles > 0);
        assert!(r.ipc() > 0.1);
    }

    #[test]
    fn static_fuse_profiles_and_decides() {
        let r = quick("SM", Scheme::StaticFuse);
        assert_eq!(r.decisions.len(), 1);
        assert_eq!(r.samples.len(), 1);
        assert_eq!(r.chip.kernels_completed, 1);
    }

    #[test]
    fn dynamic_schemes_complete() {
        for s in [Scheme::DirectSplit, Scheme::WarpRegroup, Scheme::Dws] {
            let r = quick("RAY", s);
            assert_eq!(r.chip.kernels_completed, 1, "{s}");
            assert!(r.ipc() > 0.1, "{s}: ipc={}", r.ipc());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SystemConfig::tiny();
        let mut p = bench("BFS").unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        let a = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 9);
        let b = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 9);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.sm.thread_insns, b.sm.thread_insns);
        assert_eq!(a.sm.l1d_misses, b.sm.l1d_misses);
        let c = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 10);
        assert_ne!(a.cycles, c.cycles, "different seeds should differ");
    }

    #[test]
    fn phase_trace_is_sampled() {
        let r = quick("RAY", Scheme::WarpRegroup);
        assert!(!r.phases.is_empty());
        assert_eq!(r.phases[0].modes.len(), SystemConfig::tiny().num_sms / 2);
    }

    #[test]
    fn hetero_records_one_decision_per_cluster() {
        let r = quick("RAY", Scheme::Hetero);
        let n_clusters = SystemConfig::tiny().num_sms / 2;
        assert_eq!(r.chip.kernels_completed, 1);
        assert_eq!(r.decisions.len(), n_clusters, "one decision per cluster");
        assert_eq!(r.samples.len(), n_clusters, "one sample per cluster");
        for (ci, d) in r.decisions.iter().enumerate() {
            assert_eq!(d.cluster, Some(ci as u32));
        }
        assert!(r.ipc() > 0.1, "ipc={}", r.ipc());
        // Every decision came from a real (finite) sample.
        assert!(r.samples.iter().all(|s| s.features.iter().all(|f| f.is_finite())));
    }

    #[test]
    fn chip_global_schemes_still_record_one_decision_per_kernel() {
        let r = quick("SM", Scheme::StaticFuse);
        assert_eq!(r.decisions.len(), 1);
        assert_eq!(r.decisions[0].cluster, None);
    }

    #[test]
    fn cycle_skip_matches_dense_quick() {
        // The full scheme x bench matrix lives in tests/exec_determinism;
        // this is the in-crate smoke check for the core contract.
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let mut p = bench("BFS").unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        for scheme in [Scheme::Baseline, Scheme::WarpRegroup] {
            let dense = run_benchmark_seeded_dense(&cfg, &p, scheme, 11, true);
            let skip = run_benchmark_seeded_dense(&cfg, &p, scheme, 11, false);
            assert_eq!(dense, skip, "{scheme}: skip must be bit-identical to dense");
        }
    }

    #[test]
    fn cycle_skip_advances_past_dead_windows() {
        // A memory-bound run must still finish with identical cycle
        // counts; the skip engine only changes wall-clock, never `now`.
        let cfg = SystemConfig::tiny();
        let mut p = bench("BFS").unwrap();
        p.num_ctas = 4;
        p.insns_per_thread = 60;
        p.num_kernels = 1;
        let dense = run_benchmark_seeded_dense(&cfg, &p, Scheme::Baseline, 3, true);
        let skip = run_benchmark_seeded_dense(&cfg, &p, Scheme::Baseline, 3, false);
        assert_eq!(dense.cycles, skip.cycles);
        assert_eq!(dense.chip.cycles, skip.chip.cycles);
        assert_eq!(dense.sm.stall_memory, skip.sm.stall_memory);
    }
}
