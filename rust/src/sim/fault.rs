//! Deterministic fault injection: seeded traces of hardware faults that
//! the chip loop applies at cycle boundaries on live ticks.
//!
//! A [`FaultTrace`] is an ordered list of [`FaultEvent`]s — half-SM
//! failures, whole-cluster failures, permanent NoC link degradation, and
//! transient memory-controller stalls. The trace is a pure value: it
//! folds into the SweepExec cache fingerprint (via `Debug`, like the
//! config and profile), and injection follows the active-set contract —
//! the target component is woken *before* the fault mutates it, so fault
//! runs stay bit-identical between the dense and active-set loops.
//!
//! [`RunOutcome`] is the watchdog's structured triage record for runs
//! that hit the cycle deadline: a forward-progress dump built from each
//! component's `next_event` horizon and debug state, distinguishing true
//! deadlock (no component has a horizon) from slow progress.

use crate::errors::{err, Result};

/// One kind of hardware fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// One half of a cluster's SM pair dies. Schemes that can split route
    /// around it (the healthy half keeps serving under a forced split
    /// layout); rigid scale-up schemes lose the whole cluster.
    HalfSm { cluster: u32, half: u8 },
    /// The whole cluster dies: in-flight CTAs are requeued and the
    /// cluster leaves the dispatch/partition path permanently.
    Cluster { cluster: u32 },
    /// Permanent fabric degradation: every router hop gains `penalty`
    /// extra cycles from the injection cycle onward.
    NocDegrade { penalty: u32 },
    /// Transient stall of one memory controller: it services nothing for
    /// `cycles` cycles (requests queue; nothing is lost).
    McStall { mc: u32, cycles: u64 },
}

/// One fault at a specific injection cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Cycle boundary at which the fault is applied (before dispatch).
    pub cycle: u64,
    pub kind: FaultKind,
}

/// An ordered, deterministic fault schedule for one run.
///
/// Construction sorts events by cycle (stable, so same-cycle events keep
/// their given order); an empty trace is the no-fault default and is
/// bit-identical to not setting a trace at all.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultTrace {
    pub events: Vec<FaultEvent>,
}

impl FaultTrace {
    /// Build a trace, sorting events by injection cycle (stable).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| e.cycle);
        FaultTrace { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Check every event targets a component that exists on a machine
    /// with `n_clusters` clusters and `num_mcs` memory partitions.
    pub fn validate(&self, n_clusters: usize, num_mcs: usize) -> Result<()> {
        if self.events.windows(2).any(|w| w[0].cycle > w[1].cycle) {
            return Err(err("fault trace not sorted by cycle (use FaultTrace::new)"));
        }
        for e in &self.events {
            match e.kind {
                FaultKind::HalfSm { cluster, half } => {
                    if cluster as usize >= n_clusters {
                        return Err(err(format!(
                            "fault targets cluster {cluster} on a {n_clusters}-cluster chip"
                        )));
                    }
                    if half > 1 {
                        return Err(err(format!("half-SM fault half index {half} (must be 0/1)")));
                    }
                }
                FaultKind::Cluster { cluster } => {
                    if cluster as usize >= n_clusters {
                        return Err(err(format!(
                            "fault targets cluster {cluster} on a {n_clusters}-cluster chip"
                        )));
                    }
                }
                FaultKind::NocDegrade { penalty } => {
                    if penalty == 0 {
                        return Err(err("NoC degrade with zero penalty is a no-op"));
                    }
                }
                FaultKind::McStall { mc, cycles } => {
                    if mc as usize >= num_mcs {
                        return Err(err(format!(
                            "fault targets MC {mc} on a {num_mcs}-MC chip"
                        )));
                    }
                    if cycles == 0 {
                        return Err(err("MC stall with zero duration is a no-op"));
                    }
                }
            }
        }
        Ok(())
    }

    /// A seeded pseudo-random trace of `n_events` faults over the first
    /// `horizon` cycles of a `n_clusters`/`num_mcs` machine. Pure
    /// function of its arguments — the basis for deterministic fault
    /// sweeps and the ci.sh fault-mode determinism pass.
    pub fn seeded(seed: u64, n_events: usize, n_clusters: usize, num_mcs: usize, horizon: u64) -> Self {
        assert!(n_clusters > 0 && num_mcs > 0 && horizon > 0);
        let mut state = seed ^ 0xFA17_FA17_FA17_FA17;
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            let cycle = 1 + splitmix64(&mut state) % horizon;
            let kind = match splitmix64(&mut state) % 4 {
                0 => FaultKind::HalfSm {
                    cluster: (splitmix64(&mut state) % n_clusters as u64) as u32,
                    half: (splitmix64(&mut state) % 2) as u8,
                },
                1 => FaultKind::Cluster {
                    cluster: (splitmix64(&mut state) % n_clusters as u64) as u32,
                },
                2 => FaultKind::NocDegrade {
                    penalty: 1 + (splitmix64(&mut state) % 3) as u32,
                },
                _ => FaultKind::McStall {
                    mc: (splitmix64(&mut state) % num_mcs as u64) as u32,
                    cycles: 100 + splitmix64(&mut state) % 2_000,
                },
            };
            events.push(FaultEvent { cycle, kind });
        }
        FaultTrace::new(events)
    }
}

// ---------------------------------------------------------------------
// Checkpoint section layout for the fault schedule
// ---------------------------------------------------------------------
//
// The "faults" section of a [`crate::sim::snapshot::Checkpoint`] is
// `cursor, count, events...`. Both the GPU's state capture and
// `Checkpoint::strip_pending_faults` (which rewrites the section for
// tenant migration onto a healthy chip) go through this pair so the
// layout has exactly one definition.

/// Serialize one fault event.
fn write_event(w: &mut crate::sim::snapshot::ByteWriter, e: &FaultEvent) {
    w.u64(e.cycle);
    match e.kind {
        FaultKind::HalfSm { cluster, half } => {
            w.u8(0);
            w.u32(cluster);
            w.u8(half);
        }
        FaultKind::Cluster { cluster } => {
            w.u8(1);
            w.u32(cluster);
        }
        FaultKind::NocDegrade { penalty } => {
            w.u8(2);
            w.u32(penalty);
        }
        FaultKind::McStall { mc, cycles } => {
            w.u8(3);
            w.u32(mc);
            w.u64(cycles);
        }
    }
}

fn read_event(r: &mut crate::sim::snapshot::ByteReader<'_>) -> Result<FaultEvent> {
    let cycle = r.u64()?;
    let kind = match r.u8()? {
        0 => FaultKind::HalfSm { cluster: r.u32()?, half: r.u8()? },
        1 => FaultKind::Cluster { cluster: r.u32()? },
        2 => FaultKind::NocDegrade { penalty: r.u32()? },
        3 => FaultKind::McStall { mc: r.u32()?, cycles: r.u64()? },
        t => return Err(err(format!("unknown fault kind tag {t}"))),
    };
    Ok(FaultEvent { cycle, kind })
}

/// Write a checkpoint "faults" section: injection cursor + schedule.
pub fn write_fault_section(
    w: &mut crate::sim::snapshot::ByteWriter,
    events: &[FaultEvent],
    cursor: usize,
) {
    w.usize(cursor);
    w.usize(events.len());
    for e in events {
        write_event(w, e);
    }
}

/// Parse a checkpoint "faults" section back into (events, cursor).
pub fn read_fault_section(
    r: &mut crate::sim::snapshot::ByteReader<'_>,
) -> Result<(Vec<FaultEvent>, usize)> {
    let cursor = r.usize()?;
    let n = r.seq_len(9)?;
    let mut events = Vec::with_capacity(n);
    for _ in 0..n {
        events.push(read_event(r)?);
    }
    if cursor > events.len() {
        return Err(err(format!(
            "fault cursor {cursor} beyond {} scheduled events",
            events.len()
        )));
    }
    Ok((events, cursor))
}

/// splitmix64 step (local copy: `workload::rng` is module-private).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Structured watchdog triage for a run that hit its cycle deadline.
///
/// Replaces the old silent `eprintln!` + fabricated completion stats:
/// the run's report carries this outcome so callers (and the serving
/// layer's retry logic) can distinguish a true deadlock — every
/// component reports `NextEvent::Idle`, nothing can ever move — from
/// slow forward progress that merely ran out of budget.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RunOutcome {
    /// The run was truncated at `max_cycles`.
    pub deadline_hit: bool,
    /// No component had a forward horizon at truncation time.
    pub deadlock: bool,
    /// Human-readable forward-progress dump: per-component `next_event`
    /// horizons plus cluster/router debug state.
    pub dump: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_sorts_by_cycle_stably() {
        let t = FaultTrace::new(vec![
            FaultEvent { cycle: 50, kind: FaultKind::Cluster { cluster: 1 } },
            FaultEvent { cycle: 10, kind: FaultKind::NocDegrade { penalty: 2 } },
            FaultEvent { cycle: 50, kind: FaultKind::Cluster { cluster: 0 } },
        ]);
        assert_eq!(t.events[0].cycle, 10);
        // Stable: the two cycle-50 events keep their original order.
        assert_eq!(t.events[1].kind, FaultKind::Cluster { cluster: 1 });
        assert_eq!(t.events[2].kind, FaultKind::Cluster { cluster: 0 });
        t.validate(2, 1).unwrap();
    }

    #[test]
    fn validate_rejects_out_of_range_targets() {
        let t = FaultTrace::new(vec![FaultEvent { cycle: 1, kind: FaultKind::Cluster { cluster: 4 } }]);
        assert!(t.validate(4, 2).is_err());
        let t = FaultTrace::new(vec![FaultEvent {
            cycle: 1,
            kind: FaultKind::HalfSm { cluster: 0, half: 2 },
        }]);
        assert!(t.validate(4, 2).is_err());
        let t = FaultTrace::new(vec![FaultEvent {
            cycle: 1,
            kind: FaultKind::McStall { mc: 2, cycles: 10 },
        }]);
        assert!(t.validate(4, 2).is_err());
        let t = FaultTrace::new(vec![FaultEvent { cycle: 1, kind: FaultKind::NocDegrade { penalty: 0 } }]);
        assert!(t.validate(4, 2).is_err());
    }

    #[test]
    fn seeded_is_deterministic_and_valid() {
        let a = FaultTrace::seeded(0xFA11, 8, 4, 2, 100_000);
        let b = FaultTrace::seeded(0xFA11, 8, 4, 2, 100_000);
        assert_eq!(a, b, "same seed, same trace");
        assert_eq!(a.len(), 8);
        a.validate(4, 2).unwrap();
        assert!(a.events.windows(2).all(|w| w[0].cycle <= w[1].cycle));
        let c = FaultTrace::seeded(0xFA12, 8, 4, 2, 100_000);
        assert_ne!(a, c, "different seed, different trace");
    }

    #[test]
    fn empty_trace_is_default() {
        assert_eq!(FaultTrace::default(), FaultTrace::new(Vec::new()));
        assert!(FaultTrace::default().is_empty());
        FaultTrace::default().validate(1, 1).unwrap();
    }

    #[test]
    fn fault_section_round_trips() {
        let t = FaultTrace::seeded(0xFA11, 6, 4, 2, 100_000);
        let mut w = crate::sim::snapshot::ByteWriter::new();
        write_fault_section(&mut w, &t.events, 3);
        let bytes = w.into_bytes();
        let mut r = crate::sim::snapshot::ByteReader::new(&bytes);
        let (events, cursor) = read_fault_section(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(events, t.events);
        assert_eq!(cursor, 3);
        // Truncations error, never panic (count is in the header, so any
        // shorter prefix is missing event bytes).
        for cut in 0..bytes.len() {
            let mut r = crate::sim::snapshot::ByteReader::new(&bytes[..cut]);
            assert!(read_fault_section(&mut r).is_err(), "prefix {cut}");
        }
    }
}
