//! The event-horizon contract shared by every simulated component.
//!
//! Cycle skipping works because every stall source in the machine already
//! knows when it will wake: a DRAM bank knows its service-completion
//! cycle, a router knows when a blocked packet becomes movable, a frozen
//! cluster knows its thaw cycle. [`NextEvent`] is how a component reports
//! that knowledge to the top-level loop: either "ticking right now would
//! change state" ([`NextEvent::Progress`]), or "nothing I do changes
//! state before cycle `t`" ([`NextEvent::At`]), or "I will never act
//! again without external input" ([`NextEvent::Idle`]).
//!
//! The safety contract is one-sided: a component may report an event
//! *earlier* than its first real state change (the loop just skips less),
//! but never later — a late horizon silently diverges from the dense
//! cycle loop. `tests/prop_invariants.rs` checks the tightness direction
//! per component, and `tests/exec_determinism.rs` checks the composed
//! machine end to end (skip == dense, bit for bit).
//!
//! Multi-tenant stream runs (`Gpu::run_streams`) compose the same way
//! one level up: the chip is quiescent only when **every** tenant's
//! clusters are quiescent, and the machine horizon is the `min_with`
//! fold over all tenants' components plus their scheduler triggers
//! (kernel arrivals, profiling-window ends, split checks). No new
//! variant is needed — a tenant is just another source of [`NextEvent`]s
//! — which is exactly why the skip engine survived the jump from one
//! resident kernel to many.
//!
//! The per-component active-set scheduler ([`crate::sim::ActiveSet`])
//! consumes the same promises at finer grain: a component reporting
//! [`NextEvent::At`]/[`NextEvent::Idle`] is *parked* individually and
//! stops being ticked, instead of merely contributing to a whole-chip
//! skip decision. [`NextEvent::wake_cycle`] is the bridge between the
//! two vocabularies.

/// Earliest future activity of a simulated component, relative to the
/// cycle `now` it was queried at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NextEvent {
    /// Ticking at `now` would already change state: the cycle is live.
    Progress,
    /// Nothing changes before this cycle (always `> now`): the cycles in
    /// between are pure per-cycle accounting and may be fast-forwarded.
    At(u64),
    /// No internal event will ever fire without external input (e.g. an
    /// empty DRAM queue, a cluster whose warps all wait on replies).
    Idle,
}

impl NextEvent {
    /// Combine two components' horizons: the machine's next event is the
    /// earliest of its parts, and any live part makes the cycle live.
    pub fn min_with(self, other: NextEvent) -> NextEvent {
        use NextEvent::*;
        match (self, other) {
            (Progress, _) | (_, Progress) => Progress,
            (At(a), At(b)) => At(a.min(b)),
            (At(a), Idle) | (Idle, At(a)) => At(a),
            (Idle, Idle) => Idle,
        }
    }

    /// An event at cycle `t`: a future horizon if `t > now`, otherwise
    /// the component is ready to act this very cycle.
    pub fn at_or_progress(t: u64, now: u64) -> NextEvent {
        if t > now {
            NextEvent::At(t)
        } else {
            NextEvent::Progress
        }
    }

    /// The wake cycle a parked component would carry in the active-set
    /// scheduler: `None` means the cycle is live (the component must not
    /// be parked), `u64::MAX` encodes an event-free component that only
    /// an external message can revive.
    pub fn wake_cycle(self) -> Option<u64> {
        match self {
            NextEvent::Progress => None,
            NextEvent::At(t) => Some(t),
            NextEvent::Idle => Some(u64::MAX),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::NextEvent::{self, *};

    #[test]
    fn min_with_prefers_progress_then_earliest() {
        assert_eq!(Progress.min_with(At(5)), Progress);
        assert_eq!(At(9).min_with(Progress), Progress);
        assert_eq!(At(9).min_with(At(5)), At(5));
        assert_eq!(At(5).min_with(Idle), At(5));
        assert_eq!(Idle.min_with(Idle), Idle);
    }

    #[test]
    fn at_or_progress_boundary() {
        assert_eq!(NextEvent::at_or_progress(10, 9), At(10));
        assert_eq!(NextEvent::at_or_progress(10, 10), Progress);
        assert_eq!(NextEvent::at_or_progress(10, 11), Progress);
    }

    #[test]
    fn wake_cycle_maps_the_parking_vocabulary() {
        assert_eq!(Progress.wake_cycle(), None);
        assert_eq!(At(42).wake_cycle(), Some(42));
        assert_eq!(Idle.wake_cycle(), Some(u64::MAX));
    }
}
