//! The per-component active-set scheduler's parking structure.
//!
//! The event-horizon engine of PR 3 was all-or-nothing: the chip either
//! ticked every component densely or fast-forwarded past a window in
//! which *nothing* could act. [`ActiveSet`] generalises that to
//! per-component sleep/wake: each component (SM cluster, memory
//! partition, the router fabric) that promises a quiet window via its
//! `next_event` is **parked** here with its wake cycle, and the GPU loop
//! ticks only the components that remain active — so the cost of a cycle
//! scales with the amount of *live* work, not with the size of the chip.
//!
//! Parking is purely a wall-clock optimisation and carries three
//! obligations (and only these — the *policy* of when to park is free):
//!
//! 1. a component may only be parked when its `next_event` promises no
//!    state change before the wake cycle;
//! 2. any external event that could affect a parked component (packet
//!    arrival, DRAM fill, CTA dispatch, reconfiguration, a stats read)
//!    must [`ActiveSet::wake`] (or [`ActiveSet::sync`]) it first;
//! 3. the per-cycle accounting a parked component missed is replayed in
//!    O(1) over the parked window `[park, wake)` — the window the wake
//!    call reports back to the caller.
//!
//! Under those rules any parking policy produces bit-identical reports
//! to the dense loop, which is what `tests/exec_determinism.rs` and the
//! golden suite enforce end to end.
//!
//! Internally this is a binary heap of `(wake_cycle, component)` with
//! lazy invalidation: stale entries (the component was woken eagerly by
//! an event before its timer fired, or re-parked with a new wake) are
//! dropped when they surface at the top. Components parked as
//! [`crate::sim::NextEvent::Idle`] carry no timer at all — only an
//! external event can revive them.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Wake cycle of a component parked with no internal event pending.
const IDLE: u64 = u64::MAX;

/// Wake-ordered parking structure for the chip's components.
///
/// Components are dense indices `0..n` assigned by the owner (the GPU
/// maps clusters first, then memory partitions, then the NoC).
#[derive(Debug)]
pub struct ActiveSet {
    /// Scheduled wake cycle while parked (`IDLE` = event-free); unused
    /// while active.
    wake_at: Vec<u64>,
    /// First cycle the component was *not* ticked (valid while parked):
    /// the start of the accounting-replay window.
    park_from: Vec<u64>,
    active: Vec<bool>,
    active_count: usize,
    /// Min-heap of (wake cycle, component); may hold stale entries.
    heap: BinaryHeap<Reverse<(u64, u32)>>,
}

impl ActiveSet {
    /// Build with all `n` components active (the dense-equivalent state).
    pub fn new(n: usize) -> Self {
        ActiveSet {
            wake_at: vec![0; n],
            park_from: vec![0; n],
            active: vec![true; n],
            active_count: n,
            heap: BinaryHeap::new(),
        }
    }

    /// Is `c` being ticked every cycle?
    #[inline]
    pub fn is_active(&self, c: usize) -> bool {
        self.active[c]
    }

    /// Every component parked (the whole-chip fast-forward condition)?
    #[inline]
    pub fn all_parked(&self) -> bool {
        self.active_count == 0
    }

    /// Park `c`: it will not be ticked from cycle `from` (exclusive of
    /// any tick that already ran) until `wake` — or until an external
    /// event wakes it earlier. `wake == u64::MAX` parks without a timer
    /// (the component is event-free). Caller guarantees the component's
    /// `next_event` promised no state change before `wake`.
    pub fn park(&mut self, c: usize, from: u64, wake: u64) {
        debug_assert!(self.active[c], "parking an already-parked component");
        debug_assert!(wake > from, "park window must be non-empty");
        self.active[c] = false;
        self.active_count -= 1;
        self.park_from[c] = from;
        self.wake_at[c] = wake;
        if wake != IDLE {
            self.heap.push(Reverse((wake, c as u32)));
        }
    }

    /// Wake `c` so it ticks from cycle `upto` onward. Returns the parked
    /// window `[from, upto)` whose per-cycle accounting the caller must
    /// replay, or `None` if `c` was already active (wake is idempotent).
    pub fn wake(&mut self, c: usize, upto: u64) -> Option<(u64, u64)> {
        if self.active[c] {
            return None;
        }
        self.active[c] = true;
        self.active_count += 1;
        // A heap entry may remain; it is dropped lazily when it surfaces.
        Some((self.park_from[c], upto))
    }

    /// Replay-sync a parked component without waking it: returns the
    /// window `[from, upto)` to replay and restarts the parked window at
    /// `upto`. Used for pure reads (stats sampling) of parked components
    /// whose quiet-window promise still holds. `None` if `c` is active.
    pub fn sync(&mut self, c: usize, upto: u64) -> Option<(u64, u64)> {
        if self.active[c] {
            return None;
        }
        let from = self.park_from[c];
        debug_assert!(upto <= self.wake_at[c], "sync past the promised wake");
        self.park_from[c] = upto.max(from);
        Some((from, upto))
    }

    /// Wake every component whose timer is due at or before `now`,
    /// calling `f(component, replay_from, replay_upto)` for each.
    pub fn wake_due(&mut self, now: u64, mut f: impl FnMut(usize, u64, u64)) {
        while let Some(&Reverse((t, c))) = self.heap.peek() {
            if t > now {
                break;
            }
            self.heap.pop();
            let c = c as usize;
            // Stale if woken eagerly in the meantime or re-parked with a
            // different timer.
            if self.active[c] || self.wake_at[c] != t {
                continue;
            }
            if let Some((from, upto)) = self.wake(c, now) {
                f(c, from, upto);
            }
        }
    }

    /// Earliest scheduled wake among parked components, if any timer is
    /// pending (purges stale heap entries as a side effect).
    pub fn next_wake(&mut self) -> Option<u64> {
        while let Some(&Reverse((t, c))) = self.heap.peek() {
            let c = c as usize;
            if self.active[c] || self.wake_at[c] != t {
                self.heap.pop();
                continue;
            }
            return Some(t);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_fully_active() {
        let s = ActiveSet::new(3);
        assert!(!s.all_parked());
        assert!((0..3).all(|c| s.is_active(c)));
    }

    #[test]
    fn park_wake_reports_replay_window() {
        let mut s = ActiveSet::new(2);
        s.park(0, 10, 50);
        assert!(!s.is_active(0));
        assert!(s.is_active(1));
        assert!(!s.all_parked());
        // Eager wake at 30: replay [10, 30).
        assert_eq!(s.wake(0, 30), Some((10, 30)));
        assert!(s.is_active(0));
        // Idempotent.
        assert_eq!(s.wake(0, 31), None);
    }

    #[test]
    fn wake_due_fires_timers_in_order_and_drops_stale() {
        let mut s = ActiveSet::new(3);
        s.park(0, 5, 20);
        s.park(1, 5, 10);
        s.park(2, 5, u64::MAX); // idle: no timer
        assert!(s.all_parked());
        assert_eq!(s.next_wake(), Some(10));
        // Component 0 is woken eagerly, then re-parked later.
        assert_eq!(s.wake(0, 7), Some((5, 7)));
        s.park(0, 8, 15);
        let mut woken = Vec::new();
        s.wake_due(15, |c, from, upto| woken.push((c, from, upto)));
        // 1 fires at its timer, 0 at its re-parked timer; the stale
        // (20, 0) entry must not wake anything; 2 stays idle-parked.
        woken.sort_unstable();
        assert_eq!(woken, vec![(0, 8, 15), (1, 5, 15)]);
        assert!(!s.is_active(2));
        assert_eq!(s.next_wake(), None, "only the idle component remains");
    }

    #[test]
    fn sync_replays_without_waking() {
        let mut s = ActiveSet::new(1);
        s.park(0, 10, 100);
        assert_eq!(s.sync(0, 40), Some((10, 40)));
        assert!(!s.is_active(0));
        assert_eq!(s.sync(0, 60), Some((40, 60)), "window restarts at the sync point");
        assert_eq!(s.wake(0, 100), Some((60, 100)), "wake replays the tail only");
    }

    #[test]
    fn next_wake_skips_stale_entries() {
        let mut s = ActiveSet::new(2);
        s.park(0, 0, 8);
        s.park(1, 0, 12);
        s.wake(0, 3);
        assert_eq!(s.next_wake(), Some(12));
    }
}
