//! Memory-system substrates: coalescer, caches + MSHRs, L2 slices, DRAM.

pub mod cache;
pub mod coalesce;
pub mod dram;

pub use cache::{Access, Cache};
pub use coalesce::{coalesce, coalesce_fused, coalesce_fused_into, coalesce_into, CoalesceResult};
pub use dram::{DramReply, DramRequest, MemoryController};

use crate::config::SystemConfig;

/// An L2 slice + its memory controller: the memory partition that sits at
/// one NoC memory node (the paper couples the unified L2 with the MCs).
#[derive(Debug, Clone)]
pub struct MemPartition {
    /// The L2 tag array for this slice.
    pub l2: Cache,
    /// The DRAM controller behind it.
    pub mc: MemoryController,
    /// Requests that L2-missed and are waiting on DRAM: tag -> requester.
    /// (tag is the line address; value counts merged L2 misses.)
    pending_fills: Vec<(u64, u32)>,
    /// L2 latency pipeline: (ready_cycle, line, requester_tag, is_write).
    hit_pipe: Vec<(u64, u64, u64, bool)>,
    /// Stats.
    pub accesses: u64,
    pub misses: u64,
}

/// A reply leaving the partition toward an SM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionReply {
    /// Line address served.
    pub line: u64,
    /// Opaque requester tag (SM id etc.) carried through.
    pub tag: u64,
    /// Whether this answered a write (write-ack) or a read (data).
    pub is_write: bool,
}

impl MemPartition {
    /// Build one partition per the system config.
    pub fn new(cfg: &SystemConfig) -> Self {
        MemPartition {
            l2: Cache::new(
                cfg.l2_slice_bytes,
                cfg.l2_assoc,
                cfg.line_bytes,
                cfg.l2_hit_latency,
                cfg.mshr_per_sm, // generous L2 MSHR pool
            ),
            mc: MemoryController::new(
                cfg.dram_banks_per_mc,
                cfg.dram_row_bytes,
                cfg.dram_row_hit_latency,
                cfg.dram_row_miss_latency,
                cfg.mc_queue_depth,
            ),
            pending_fills: Vec::new(),
            hit_pipe: Vec::new(),
            accesses: 0,
            misses: 0,
        }
    }

    /// Present a request (read or write-through) to the slice. Returns
    /// false if it must be retried (MSHR/queue full — backpressure).
    /// `Cache::access` runs at most once per accepted request: a miss that
    /// cannot be queued at DRAM is rejected *before* touching the tags.
    pub fn request(&mut self, now: u64, line: u64, tag: u64, is_write: bool, l2_latency: u64) -> bool {
        if self.l2.probe(line) {
            let r = self.l2.access(line);
            debug_assert_eq!(r, Access::Hit);
            self.accesses += 1;
            self.hit_pipe.push((now + l2_latency, line, tag, is_write));
            return true;
        }
        // Miss path: require DRAM queue space up front so the access never
        // strands an MSHR without a fill request behind it.
        if !self.mc.can_accept() {
            return false;
        }
        match self.l2.access(line) {
            Access::MissMerged => {
                self.accesses += 1;
                self.misses += 1;
                // Park; woken when the original fill returns.
                self.hit_pipe.push((u64::MAX, line, tag, is_write));
                match self.pending_fills.iter_mut().find(|(l, _)| *l == line) {
                    Some((_, n)) => *n += 1,
                    None => self.pending_fills.push((line, 1)),
                }
                true
            }
            Access::MissNew => {
                self.accesses += 1;
                self.misses += 1;
                let ok = self.mc.push(DramRequest { addr: line, is_write, tag });
                debug_assert!(ok, "can_accept checked above");
                self.hit_pipe.push((u64::MAX, line, tag, is_write));
                true
            }
            Access::MshrFull => false,
            Access::Hit => {
                // Race between probe and access cannot happen single-
                // threaded, but keep the path total.
                self.accesses += 1;
                self.hit_pipe.push((now + l2_latency, line, tag, is_write));
                true
            }
        }
    }

    /// Advance one cycle; emit replies ready to leave toward the NoC.
    /// `out` is appended with at most `max_out` replies (injection limit).
    pub fn tick(&mut self, now: u64, out: &mut Vec<PartitionReply>, max_out: usize) -> bool {
        self.mc.tick(now);
        // DRAM fills: install in L2, release parked requesters.
        while let Some(fill) = self.mc.pop_reply() {
            let _merged = self.l2.fill(fill.addr);
            // Wake every parked entry for this line.
            for entry in self.hit_pipe.iter_mut() {
                if entry.0 == u64::MAX && entry.1 == fill.addr {
                    entry.0 = now; // ready now
                }
            }
        }
        // Emit ready replies, bounded by the injection budget.
        let mut emitted = 0;
        let mut stalled = false;
        let mut i = 0;
        while i < self.hit_pipe.len() {
            let (ready, line, tag, is_write) = self.hit_pipe[i];
            if ready <= now {
                if emitted >= max_out {
                    stalled = true; // reply ready but injection budget spent
                    break;
                }
                out.push(PartitionReply { line, tag, is_write });
                self.hit_pipe.swap_remove(i);
                emitted += 1;
            } else {
                i += 1;
            }
        }
        stalled
    }

    /// Any outstanding work?
    pub fn busy(&self) -> bool {
        !self.hit_pipe.is_empty() || self.mc.busy()
    }

    /// Earliest cycle at which [`MemPartition::tick`] could change state,
    /// assuming the caller supplies a positive emission budget and drains
    /// `out` (the GPU does both every cycle). Pipelined hits fire at
    /// their ready cycle; entries parked on DRAM (`ready == u64::MAX`)
    /// are woken by a fill, which the controller's own horizon covers.
    ///
    /// This is also the partition's parking horizon in the active-set
    /// scheduler: the GPU stops ticking a partition that reports a quiet
    /// window and wakes it at this cycle — or eagerly, the moment the
    /// NoC delivers a new request to its node (arrivals are external
    /// events this probe deliberately does not see).
    pub fn next_event(&self, now: u64) -> crate::sim::NextEvent {
        use crate::sim::NextEvent;
        let mut ev = self.mc.next_event(now);
        for &(ready, ..) in &self.hit_pipe {
            if ready == u64::MAX {
                continue;
            }
            ev = ev.min_with(NextEvent::at_or_progress(ready, now));
            if ev == NextEvent::Progress {
                break;
            }
        }
        ev
    }

    /// Kernel-boundary flush.
    pub fn flush(&mut self) {
        self.l2.flush();
        self.pending_fills.clear();
        self.hit_pipe.clear();
    }

    /// Serialize the partition's mutable state (checkpoint format): L2
    /// tags/MSHRs, DRAM controller, pending fills, hit pipeline, stats.
    pub fn save_state(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        self.l2.save_state(w);
        self.mc.save_state(w);
        w.usize(self.pending_fills.len());
        for &(line, n) in &self.pending_fills {
            w.u64(line);
            w.u32(n);
        }
        w.usize(self.hit_pipe.len());
        for &(ready, line, tag, is_write) in &self.hit_pipe {
            w.u64(ready);
            w.u64(line);
            w.u64(tag);
            w.bool(is_write);
        }
        w.u64(self.accesses);
        w.u64(self.misses);
    }

    /// Inverse of [`MemPartition::save_state`] into a partition built with
    /// the same configuration.
    pub fn load_state(
        &mut self,
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<()> {
        self.l2.load_state(r)?;
        self.mc.load_state(r)?;
        let nf = r.seq_len(12)?;
        self.pending_fills.clear();
        for _ in 0..nf {
            let line = r.u64()?;
            let n = r.u32()?;
            self.pending_fills.push((line, n));
        }
        let np = r.seq_len(25)?;
        self.hit_pipe.clear();
        for _ in 0..np {
            let ready = r.u64()?;
            let line = r.u64()?;
            let tag = r.u64()?;
            let is_write = r.bool()?;
            self.hit_pipe.push((ready, line, tag, is_write));
        }
        self.accesses = r.u64()?;
        self.misses = r.u64()?;
        Ok(())
    }
}

/// Which memory partition serves a line (low-order line-interleaving,
/// GPGPU-Sim style: spreads traffic across MCs).
pub fn partition_of(line: u64, line_bytes: usize, num_mcs: usize) -> usize {
    ((line / line_bytes as u64) % num_mcs as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part() -> MemPartition {
        MemPartition::new(&SystemConfig::tiny())
    }

    #[test]
    fn l2_hit_replies_after_latency() {
        let mut p = part();
        // Prime the line via DRAM.
        assert!(p.request(0, 0x1000, 5, false, 8));
        let mut out = Vec::new();
        let mut t = 0;
        while out.is_empty() && t < 500 {
            p.tick(t, &mut out, 4);
            t += 1;
        }
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 0x1000);
        let miss_t = t;
        // Now a hit: should reply in ~l2 latency cycles.
        out.clear();
        assert!(p.request(t, 0x1000, 6, false, 8));
        while out.is_empty() && t < miss_t + 50 {
            p.tick(t, &mut out, 4);
            t += 1;
        }
        assert_eq!(out.len(), 1, "l2 hit fast path");
        assert!(t - miss_t <= 10, "hit latency ~8: {}", t - miss_t);
    }

    #[test]
    fn injection_budget_reports_stall() {
        let mut p = part();
        // Two hits ready in the same cycle, budget 1 => stall flag.
        for (i, line) in [0x2000u64, 0x2080].iter().enumerate() {
            assert!(p.request(0, *line, i as u64, false, 1));
        }
        // Drain DRAM until both lines are L2-resident and replies emitted.
        let mut out = Vec::new();
        let mut stalled_any = false;
        for t in 0..600 {
            stalled_any |= p.tick(t, &mut out, 1);
        }
        assert_eq!(out.len(), 2);
        // Re-request both in the same cycle: now they are hits with the
        // same ready time; budget 1 must stall one of them.
        out.clear();
        assert!(p.request(600, 0x2000, 1, false, 1));
        assert!(p.request(600, 0x2080, 2, false, 1));
        let mut stalls = 0;
        for t in 601..650 {
            if p.tick(t, &mut out, 1) {
                stalls += 1;
            }
        }
        assert_eq!(out.len(), 2);
        assert!(stalls >= 1, "budget-1 must stall at least one cycle");
        let _ = stalled_any;
    }

    #[test]
    fn partition_interleaving_spreads_lines() {
        let mut counts = [0usize; 4];
        for i in 0..1024u64 {
            counts[partition_of(i * 128, 128, 4)] += 1;
        }
        for c in counts {
            assert_eq!(c, 256);
        }
    }

    #[test]
    fn write_through_acks() {
        let mut p = part();
        assert!(p.request(0, 0x3000, 9, true, 8));
        let mut out = Vec::new();
        for t in 0..500 {
            p.tick(t, &mut out, 4);
        }
        assert_eq!(out.len(), 1);
        assert!(out[0].is_write);
        assert!(!p.busy());
    }
}
