//! DRAM / memory-controller model with FR-FCFS scheduling.
//!
//! Each memory controller owns a request queue and a set of banks with one
//! open row each. The scheduler is First-Ready FR-FCFS: among queued
//! requests whose bank is free, row hits are served before older row
//! misses. Latencies are expressed in GPU core cycles (single clock
//! domain; see DESIGN.md "fidelity notes").

/// A memory request queued at a controller.
#[derive(Debug, Clone)]
pub struct DramRequest {
    /// Line address.
    pub addr: u64,
    /// True for writes (stores / L2 writebacks).
    pub is_write: bool,
    /// Opaque tag the owner uses to route the reply.
    pub tag: u64,
}

/// A completed request ready to be returned.
#[derive(Debug, Clone)]
pub struct DramReply {
    /// Line address.
    pub addr: u64,
    /// Whether the original request was a write.
    pub is_write: bool,
    /// Original tag.
    pub tag: u64,
}

#[derive(Debug, Clone)]
struct Bank {
    open_row: Option<u64>,
    busy_until: u64,
    /// Request currently being serviced (returned when `busy_until` hits).
    in_service: Option<(DramRequest, u64)>, // (req, finish_cycle)
}

/// One memory controller: FR-FCFS queue + banks.
#[derive(Debug, Clone)]
pub struct MemoryController {
    queue: Vec<DramRequest>,
    banks: Vec<Bank>,
    row_bytes: u64,
    row_hit_latency: u64,
    row_miss_latency: u64,
    queue_capacity: usize,
    /// Completed replies awaiting pickup (bounded by caller draining).
    /// FIFO: popped from the front every cycle, so a deque avoids the
    /// O(n) shift a `Vec::remove(0)` paid per reply.
    ready: std::collections::VecDeque<DramReply>,
    /// Stats: row hits / misses scheduled.
    pub row_hits: u64,
    pub row_misses: u64,
    pub reads: u64,
    pub writes: u64,
}

impl MemoryController {
    /// Build a controller with `banks` banks.
    pub fn new(banks: usize, row_bytes: usize, row_hit: u32, row_miss: u32, queue: usize) -> Self {
        MemoryController {
            queue: Vec::with_capacity(queue),
            banks: vec![
                Bank { open_row: None, busy_until: 0, in_service: None };
                banks.max(1)
            ],
            row_bytes: row_bytes as u64,
            row_hit_latency: row_hit as u64,
            row_miss_latency: row_miss as u64,
            queue_capacity: queue,
            ready: std::collections::VecDeque::new(),
            row_hits: 0,
            row_misses: 0,
            reads: 0,
            writes: 0,
        }
    }

    fn bank_of(&self, addr: u64) -> usize {
        ((addr / self.row_bytes) % self.banks.len() as u64) as usize
    }

    fn row_of(&self, addr: u64) -> u64 {
        addr / self.row_bytes / self.banks.len() as u64
    }

    /// Can another request be queued this cycle?
    pub fn can_accept(&self) -> bool {
        self.queue.len() < self.queue_capacity
    }

    /// Queue a request. Returns false (rejected) when the queue is full.
    pub fn push(&mut self, req: DramRequest) -> bool {
        if !self.can_accept() {
            return false;
        }
        self.queue.push(req);
        true
    }

    /// Outstanding work (queued + in service + ready)?
    pub fn busy(&self) -> bool {
        !self.queue.is_empty()
            || !self.ready.is_empty()
            || self.banks.iter().any(|b| b.in_service.is_some())
    }

    /// Advance one cycle: complete service, schedule FR-FCFS.
    pub fn tick(&mut self, now: u64) {
        // Completions.
        for bank in &mut self.banks {
            if let Some((_, finish)) = bank.in_service {
                if now >= finish {
                    let (req, _) = bank.in_service.take().unwrap();
                    self.ready.push_back(DramReply {
                        addr: req.addr,
                        is_write: req.is_write,
                        tag: req.tag,
                    });
                }
            }
        }
        // FR-FCFS issue: for each idle bank, prefer the oldest row-hit
        // request; otherwise the oldest request for that bank.
        for b in 0..self.banks.len() {
            if self.banks[b].in_service.is_some() || self.banks[b].busy_until > now {
                continue;
            }
            let open = self.banks[b].open_row;
            let mut pick: Option<usize> = None;
            for (i, r) in self.queue.iter().enumerate() {
                if self.bank_of(r.addr) != b {
                    continue;
                }
                let row = self.row_of(r.addr);
                if Some(row) == open {
                    pick = Some(i); // first-ready row hit (oldest first)
                    break;
                }
                if pick.is_none() {
                    pick = Some(i); // fallback: oldest for this bank
                }
            }
            if let Some(i) = pick {
                let req = self.queue.remove(i);
                let row = self.row_of(req.addr);
                let hit = Some(row) == open;
                let lat = if hit {
                    self.row_hits += 1;
                    self.row_hit_latency
                } else {
                    self.row_misses += 1;
                    self.row_miss_latency
                };
                if req.is_write {
                    self.writes += 1;
                } else {
                    self.reads += 1;
                }
                self.banks[b].open_row = Some(row);
                self.banks[b].busy_until = now + lat;
                self.banks[b].in_service = Some((req, now + lat));
            }
        }
    }

    /// Earliest cycle at which [`MemoryController::tick`] could change
    /// state, mirroring the tick's two phases exactly: completions fire
    /// when a bank's service finishes, and FR-FCFS issue fires as soon as
    /// any queued request's bank is free (a bank frees in the same tick
    /// its service completes, so in-service finish times bound both).
    /// Ready replies awaiting pickup are `Progress` — the owner drains
    /// them every cycle.
    pub fn next_event(&self, now: u64) -> crate::sim::NextEvent {
        use crate::sim::NextEvent;
        if !self.ready.is_empty() {
            return NextEvent::Progress;
        }
        let mut ev = NextEvent::Idle;
        for bank in &self.banks {
            if let Some((_, finish)) = bank.in_service {
                ev = ev.min_with(NextEvent::at_or_progress(finish, now));
                if ev == NextEvent::Progress {
                    return ev;
                }
            }
        }
        for r in &self.queue {
            let bank = &self.banks[self.bank_of(r.addr)];
            let free_at = match bank.in_service {
                Some((_, finish)) => finish.max(bank.busy_until),
                None => bank.busy_until,
            };
            ev = ev.min_with(NextEvent::at_or_progress(free_at, now));
            if ev == NextEvent::Progress {
                return ev;
            }
        }
        ev
    }

    /// Pop one completed reply, if any (FIFO).
    pub fn pop_reply(&mut self) -> Option<DramReply> {
        self.ready.pop_front()
    }

    /// Peek whether a reply is waiting (used to account injection stalls).
    pub fn has_reply(&self) -> bool {
        !self.ready.is_empty()
    }

    // ------------------------------------------------------------------
    // Checkpoint (sim::snapshot)
    // ------------------------------------------------------------------

    /// Serialize the mutable state: queue, banks, ready replies, and the
    /// four scheduling counters. Config-derived fields (row geometry,
    /// latencies, queue capacity) are rebuilt by the constructor.
    pub fn save_state(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        let wr_req = |w: &mut crate::sim::snapshot::ByteWriter, r: &DramRequest| {
            w.u64(r.addr);
            w.bool(r.is_write);
            w.u64(r.tag);
        };
        w.usize(self.queue.len());
        for r in &self.queue {
            wr_req(w, r);
        }
        w.usize(self.banks.len());
        for b in &self.banks {
            match b.open_row {
                Some(row) => {
                    w.bool(true);
                    w.u64(row);
                }
                None => w.bool(false),
            }
            w.u64(b.busy_until);
            match &b.in_service {
                Some((req, finish)) => {
                    w.bool(true);
                    wr_req(w, req);
                    w.u64(*finish);
                }
                None => w.bool(false),
            }
        }
        w.usize(self.ready.len());
        for r in &self.ready {
            w.u64(r.addr);
            w.bool(r.is_write);
            w.u64(r.tag);
        }
        w.u64(self.row_hits);
        w.u64(self.row_misses);
        w.u64(self.reads);
        w.u64(self.writes);
    }

    /// Restore state saved by [`MemoryController::save_state`] into a
    /// controller built with the same configuration.
    pub fn load_state(
        &mut self,
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<()> {
        use crate::errors::err;
        let rd_req = |r: &mut crate::sim::snapshot::ByteReader<'_>| -> crate::errors::Result<DramRequest> {
            Ok(DramRequest { addr: r.u64()?, is_write: r.bool()?, tag: r.u64()? })
        };
        let nq = r.seq_len(17)?;
        if nq > self.queue_capacity {
            return Err(err(format!(
                "checkpoint queues {nq} DRAM requests, machine capacity is {}",
                self.queue_capacity
            )));
        }
        self.queue.clear();
        for _ in 0..nq {
            self.queue.push(rd_req(r)?);
        }
        let nb = r.usize()?;
        if nb != self.banks.len() {
            return Err(err(format!(
                "checkpoint has {nb} DRAM banks, machine has {}",
                self.banks.len()
            )));
        }
        for b in &mut self.banks {
            b.open_row = if r.bool()? { Some(r.u64()?) } else { None };
            b.busy_until = r.u64()?;
            b.in_service = if r.bool()? {
                let req = rd_req(r)?;
                Some((req, r.u64()?))
            } else {
                None
            };
        }
        let nr = r.seq_len(17)?;
        self.ready.clear();
        for _ in 0..nr {
            self.ready.push_back(DramReply { addr: r.u64()?, is_write: r.bool()?, tag: r.u64()? });
        }
        self.row_hits = r.u64()?;
        self.row_misses = r.u64()?;
        self.reads = r.u64()?;
        self.writes = r.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> MemoryController {
        MemoryController::new(2, 2048, 40, 110, 8)
    }

    fn run_until_reply(m: &mut MemoryController, start: u64, limit: u64) -> (DramReply, u64) {
        for t in start..start + limit {
            m.tick(t);
            if let Some(r) = m.pop_reply() {
                return (r, t);
            }
        }
        panic!("no reply within {limit} cycles");
    }

    #[test]
    fn single_read_row_miss_latency() {
        let mut m = mc();
        assert!(m.push(DramRequest { addr: 0x1000, is_write: false, tag: 7 }));
        let (r, t) = run_until_reply(&mut m, 0, 200);
        assert_eq!(r.tag, 7);
        assert!(!r.is_write);
        assert!(t >= 110, "cold access is a row miss: t={t}");
        assert_eq!(m.row_misses, 1);
    }

    #[test]
    fn row_hit_is_faster() {
        let mut m = mc();
        m.push(DramRequest { addr: 0x0, is_write: false, tag: 1 });
        let (_, t1) = run_until_reply(&mut m, 0, 200);
        // Same row again.
        m.push(DramRequest { addr: 0x80, is_write: false, tag: 2 });
        let (_, t2) = run_until_reply(&mut m, t1 + 1, 200);
        assert_eq!(m.row_hits, 1);
        assert!(t2 - t1 < 110, "row hit should be fast: {}", t2 - t1);
    }

    #[test]
    fn frfcfs_prefers_row_hit_over_older_miss() {
        let mut m = mc();
        // Open row 0 on bank 0.
        m.push(DramRequest { addr: 0x0, is_write: false, tag: 0 });
        let (_, t) = run_until_reply(&mut m, 0, 200);
        // Queue: first an (older) row-miss to a different row on bank 0,
        // then a row-hit to the open row — the hit must be served first.
        let other_row = 2 * 2048 * 2; // bank 0, row 2
        m.push(DramRequest { addr: other_row, is_write: false, tag: 10 });
        m.push(DramRequest { addr: 0x100, is_write: false, tag: 11 });
        let (first, _) = run_until_reply(&mut m, t + 1, 400);
        assert_eq!(first.tag, 11, "row hit bypasses older miss");
    }

    #[test]
    fn queue_capacity_enforced() {
        let mut m = mc();
        for i in 0..8 {
            assert!(m.push(DramRequest { addr: i * 4096, is_write: false, tag: i }));
        }
        assert!(!m.push(DramRequest { addr: 99999, is_write: false, tag: 99 }));
        assert!(!m.can_accept());
    }

    #[test]
    fn banks_service_in_parallel() {
        let mut m = mc();
        // Two requests on different banks complete in ~one row-miss time.
        m.push(DramRequest { addr: 0, is_write: false, tag: 0 }); // bank 0
        m.push(DramRequest { addr: 2048, is_write: true, tag: 1 }); // bank 1
        let mut done = 0;
        for t in 0..130 {
            m.tick(t);
            while m.pop_reply().is_some() {
                done += 1;
            }
        }
        assert_eq!(done, 2, "parallel banks overlap latency");
        assert_eq!(m.writes, 1);
        assert_eq!(m.reads, 1);
    }

    #[test]
    fn busy_tracks_lifecycle() {
        let mut m = mc();
        assert!(!m.busy());
        m.push(DramRequest { addr: 0, is_write: false, tag: 0 });
        assert!(m.busy());
        let _ = run_until_reply(&mut m, 0, 200);
        assert!(!m.busy());
    }
}
