//! Memory-access coalescing unit.
//!
//! Folds the per-lane addresses of one warp memory instruction into the
//! minimal set of cache-line transactions (§3.1(2) of the paper). A fused
//! SM runs ONE coalescer over the full 64-lane access vector, which is
//! where the paper's cross-SM coalescing gains (Fig 4/16) come from:
//! broadcast and shared lines touched by both sub-warps merge into a
//! single transaction instead of two.

use crate::isa::{AccessPattern, ActiveMask};

/// Result of coalescing one warp access: unique line addresses, ordered.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoalesceResult {
    /// Unique cache-line base addresses the access touches.
    pub lines: Vec<u64>,
    /// Lane-level requests that went in (active lanes).
    pub requests: u32,
}

impl CoalesceResult {
    /// Number of memory transactions after coalescing.
    pub fn transactions(&self) -> usize {
        self.lines.len()
    }
}

/// Coalesce into a caller-owned line buffer (cleared first); returns the
/// number of lane-level requests. This is the hot-path entry: the SM
/// cluster owns one scratch buffer and reuses it for every memory
/// instruction instead of allocating a fresh `Vec` per access.
///
/// For fused warps the caller passes two patterns (one per 32-wide
/// sub-warp); see [`coalesce_fused_into`].
pub fn coalesce_into(
    pattern: &AccessPattern,
    mask: ActiveMask,
    width: usize,
    line_bytes: usize,
    lines: &mut Vec<u64>,
) -> u32 {
    debug_assert!(line_bytes.is_power_of_two());
    lines.clear();
    let shift = line_bytes.trailing_zeros();
    let mut requests = 0;
    for lane in mask.lanes().take_while(|&l| l < width) {
        requests += 1;
        let line = pattern.lane_addr(lane) >> shift << shift;
        // Linear dedup: transaction lists are tiny (<= warp width) and in
        // the common strided case almost always length 1-2.
        if !lines.contains(&line) {
            lines.push(line);
        }
    }
    requests
}

/// Coalesce a fused 64-wide access into a caller-owned buffer: the two
/// sub-warps' patterns are merged through ONE coalescing unit (paper
/// §4.2: "Each fused SM has one copy of the coalescing unit ... Since
/// the warp size is doubled, this leads to more chances for coalesced
/// memory accesses"). Returns the lane-level request count.
pub fn coalesce_fused_into(
    pat_lo: &AccessPattern,
    pat_hi: &AccessPattern,
    mask: ActiveMask,
    line_bytes: usize,
    lines: &mut Vec<u64>,
) -> u32 {
    lines.clear();
    let shift = line_bytes.trailing_zeros();
    let mut requests = 0;
    for lane in mask.lanes() {
        requests += 1;
        let addr = if lane < 32 {
            pat_lo.lane_addr(lane)
        } else {
            pat_hi.lane_addr(lane - 32)
        };
        let line = addr >> shift << shift;
        if !lines.contains(&line) {
            lines.push(line);
        }
    }
    requests
}

/// Allocating wrapper over [`coalesce_into`] (tests / one-shot callers).
pub fn coalesce(
    pattern: &AccessPattern,
    mask: ActiveMask,
    width: usize,
    line_bytes: usize,
) -> CoalesceResult {
    let mut lines: Vec<u64> = Vec::with_capacity(4);
    let requests = coalesce_into(pattern, mask, width, line_bytes, &mut lines);
    CoalesceResult { lines, requests }
}

/// Allocating wrapper over [`coalesce_fused_into`].
pub fn coalesce_fused(
    pat_lo: &AccessPattern,
    pat_hi: &AccessPattern,
    mask: ActiveMask,
    line_bytes: usize,
) -> CoalesceResult {
    let mut lines: Vec<u64> = Vec::with_capacity(4);
    let requests = coalesce_fused_into(pat_lo, pat_hi, mask, line_bytes, &mut lines);
    CoalesceResult { lines, requests }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::AccessPattern as P;

    const LINE: usize = 128;

    #[test]
    fn contiguous_stride_coalesces_to_one_line() {
        // 32 lanes x 4B = 128B = exactly one line when aligned.
        let r = coalesce(&P::Strided { base: 0x1000, stride: 4 }, ActiveMask::full(32), 32, LINE);
        assert_eq!(r.transactions(), 1);
        assert_eq!(r.requests, 32);
    }

    #[test]
    fn unaligned_stride_spans_two_lines() {
        let r = coalesce(&P::Strided { base: 0x1040, stride: 4 }, ActiveMask::full(32), 32, LINE);
        assert_eq!(r.transactions(), 2);
    }

    #[test]
    fn large_stride_one_line_per_lane() {
        let r = coalesce(&P::Strided { base: 0, stride: 256 }, ActiveMask::full(32), 32, LINE);
        assert_eq!(r.transactions(), 32);
    }

    #[test]
    fn broadcast_is_single_transaction() {
        let r = coalesce(&P::Broadcast { base: 0xABC0 }, ActiveMask::full(32), 32, LINE);
        assert_eq!(r.transactions(), 1);
    }

    #[test]
    fn masked_lanes_generate_no_requests() {
        let mut m = ActiveMask::empty();
        m.set(0);
        m.set(7);
        let r = coalesce(&P::Strided { base: 0, stride: 256 }, m, 32, LINE);
        assert_eq!(r.requests, 2);
        assert_eq!(r.transactions(), 2);
        let r = coalesce(&P::Broadcast { base: 0 }, ActiveMask::empty(), 32, LINE);
        assert_eq!(r.requests, 0);
        assert_eq!(r.transactions(), 0);
    }

    #[test]
    fn fused_broadcast_merges_across_subwarps() {
        // Both sub-warps broadcast the SAME line: fused coalescer emits 1
        // transaction where two separate SMs would emit 2. (Fig 4's gain.)
        let p = P::Broadcast { base: 0x5000 };
        let r = coalesce_fused(&p, &p, ActiveMask::full(64), LINE);
        assert_eq!(r.transactions(), 1);
        assert_eq!(r.requests, 64);
        // Two independent 32-wide coalesces => 2 transactions total.
        let a = coalesce(&p, ActiveMask::full(32), 32, LINE);
        let b = coalesce(&p, ActiveMask::full(32), 32, LINE);
        assert_eq!(a.transactions() + b.transactions(), 2);
    }

    #[test]
    fn fused_contiguous_subwarps_merge_shared_boundary() {
        // Sub-warp 1 continues exactly where sub-warp 0 ended: 256B = 2
        // lines fused (vs 2 lines separate — equal), but overlapping bases
        // dedup.
        let lo = P::Strided { base: 0x1000, stride: 4 };
        let hi = P::Strided { base: 0x1000, stride: 4 }; // same region => dedup
        let r = coalesce_fused(&lo, &hi, ActiveMask::full(64), LINE);
        assert_eq!(r.transactions(), 1);
    }

    #[test]
    fn scatter_is_deterministic_and_wide() {
        let p = P::Scatter { base: 0, seed: 99 };
        let a = coalesce(&p, ActiveMask::full(32), 32, LINE);
        let b = coalesce(&p, ActiveMask::full(32), 32, LINE);
        assert_eq!(a, b);
        assert!(a.transactions() > 24, "scatter should rarely coalesce: {}", a.transactions());
    }
}
