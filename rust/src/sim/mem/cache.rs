//! Set-associative tag-array cache model with MSHRs.
//!
//! Models timing-relevant behaviour only (tags, LRU, miss tracking) — no
//! data storage. Used for L1D/L1I/L1C/L1T (write-through, no write
//! allocate, GPU-style) and for the L2 slices at the memory controllers
//! (write-back approximated as write-through for timing).
//!
//! SM fusion merges two L1s by doubling associativity at +1 cycle hit
//! latency (paper §4.2); [`Cache::resize`] implements that reconfiguration
//! (tags are flushed — the paper drains the pipeline on reconfigure).

/// Outcome of a cache access attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    /// Tag present: hit with the cache's current latency.
    Hit,
    /// Miss; a new MSHR was allocated — caller must send a fill request.
    MissNew,
    /// Miss on a line already being fetched: merged into its MSHR, no new
    /// request leaves the cache (the paper's "MSHR rate" metric, §4.1.2(5)).
    MissMerged,
    /// Miss, but the MSHR table is full: the access must be retried later
    /// (upstream structural stall).
    MshrFull,
}

/// One MSHR entry: an in-flight line and how many warp-accesses merged.
#[derive(Debug, Clone)]
struct Mshr {
    line: u64,
    merged: u32,
}

/// Sentinel for an empty way. Real tags are line-aligned addresses
/// (`line_bytes` is a power of two >= 2), so the all-ones value can never
/// collide with one — which lets the tag array be a dense `Vec<u64>`
/// instead of `Vec<Option<u64>>` (half the bytes per way, no discriminant
/// branch in the hit loop that runs on every memory access).
const EMPTY_TAG: u64 = u64::MAX;

/// Set-associative tag cache + MSHR table.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    assoc: usize,
    line_bytes: usize,
    /// tags[set * assoc + way] = line address, or [`EMPTY_TAG`].
    tags: Vec<u64>,
    /// LRU stamps parallel to `tags` (higher = more recent).
    stamps: Vec<u64>,
    clock: u64,
    mshrs: Vec<Mshr>,
    mshr_capacity: usize,
    /// Hit latency in cycles (fusion adds 1).
    pub hit_latency: u32,
    /// log2(line_bytes): `line_of` is a shift, not a division.
    line_shift: u32,
    /// sets - 1: `set_of` is a mask, not a modulo.
    set_mask: u64,
}

/// Set count for a (bytes, assoc, line) geometry, rounded **down** to a
/// power of two so indexing is a mask. Every Table-1 geometry (and its
/// fused 2x variant) is already a power of two; only the Fig 3/4
/// resource-rescaled sweeps (25/36 SMs) hit the rounding, where the
/// paper's grid cannot split resources exactly either.
fn pow2_sets(bytes: usize, assoc: usize, line_bytes: usize) -> usize {
    assert!(
        line_bytes >= 2 && line_bytes.is_power_of_two(),
        "line_bytes {line_bytes} must be a power of two >= 2"
    );
    let sets = (bytes / line_bytes / assoc).max(1);
    if sets.is_power_of_two() {
        sets
    } else {
        1 << sets.ilog2()
    }
}

impl Cache {
    /// Build a cache of `bytes` capacity with `assoc` ways.
    pub fn new(bytes: usize, assoc: usize, line_bytes: usize, hit_latency: u32, mshrs: usize) -> Self {
        let sets = pow2_sets(bytes, assoc, line_bytes);
        Cache {
            sets,
            assoc,
            line_bytes,
            tags: vec![EMPTY_TAG; sets * assoc],
            stamps: vec![0; sets * assoc],
            clock: 0,
            mshrs: Vec::with_capacity(mshrs),
            mshr_capacity: mshrs,
            hit_latency,
            line_shift: line_bytes.trailing_zeros(),
            set_mask: sets as u64 - 1,
        }
    }

    /// Capacity in bytes.
    pub fn bytes(&self) -> usize {
        self.sets * self.assoc * self.line_bytes
    }

    /// Number of sets (exposed for tests / occupancy probes).
    pub fn sets(&self) -> usize {
        self.sets
    }

    /// Associativity.
    pub fn assoc(&self) -> usize {
        self.assoc
    }

    /// MSHR entries currently in flight.
    pub fn mshrs_in_flight(&self) -> usize {
        self.mshrs.len()
    }

    /// Reconfigure (fusion/unfusion): change geometry, flush tags & MSHRs.
    /// In-flight fills are dropped — the GPU drains SMs before reconfiguring
    /// so this never loses live requests in practice.
    pub fn resize(&mut self, bytes: usize, assoc: usize, hit_latency: u32, mshrs: usize) {
        let sets = pow2_sets(bytes, assoc, self.line_bytes);
        self.sets = sets;
        self.assoc = assoc;
        self.hit_latency = hit_latency;
        self.tags = vec![EMPTY_TAG; sets * assoc];
        self.stamps = vec![0; sets * assoc];
        self.mshrs.clear();
        self.mshr_capacity = mshrs;
        self.set_mask = sets as u64 - 1;
    }

    fn set_of(&self, line: u64) -> usize {
        // XOR-folded set hash (GPGPU-Sim-style "ipoly/hash" indexing):
        // large power-of-two-aligned structures (per-CTA regions, row
        // buffers) would otherwise pile into a handful of sets. The set
        // count is a power of two, so the reduction is a mask.
        let idx = line >> self.line_shift;
        let h = idx ^ (idx >> 7) ^ (idx >> 15) ^ (idx >> 23);
        (h & self.set_mask) as usize
    }

    /// Probe only (no state change): would `line` hit?
    pub fn probe(&self, addr: u64) -> bool {
        let line = self.line_of(addr);
        let set = self.set_of(line);
        self.tags[set * self.assoc..(set + 1) * self.assoc].contains(&line)
    }

    /// Line base address containing `addr`.
    pub fn line_of(&self, addr: u64) -> u64 {
        (addr >> self.line_shift) << self.line_shift
    }

    /// Is a fill for `addr`'s line already in flight? (An access now
    /// would merge: [`Access::MissMerged`].)
    pub fn has_pending(&self, addr: u64) -> bool {
        let line = self.line_of(addr);
        self.mshrs.iter().any(|m| m.line == line)
    }

    /// Is the MSHR table full? (An access to a new line now would be
    /// [`Access::MshrFull`].)
    pub fn mshr_full(&self) -> bool {
        self.mshrs.len() >= self.mshr_capacity
    }

    /// Replay `n` cycles of MSHR-full retries: each dense-loop retry
    /// calls [`Cache::access`], which advances the LRU clock once even
    /// when it returns [`Access::MshrFull`]. The event-horizon skip path
    /// must advance the clock identically or later LRU victims diverge
    /// from the dense loop.
    pub fn advance_clock(&mut self, n: u64) {
        self.clock += n;
    }

    /// Access `addr` (read or write-through). On `MissNew` the caller sends
    /// a fill to the next level and later calls [`Cache::fill`].
    pub fn access(&mut self, addr: u64) -> Access {
        self.clock += 1;
        let line = self.line_of(addr);
        let set = self.set_of(line);
        let base = set * self.assoc;
        // Hit path.
        for way in 0..self.assoc {
            if self.tags[base + way] == line {
                self.stamps[base + way] = self.clock;
                return Access::Hit;
            }
        }
        // Merge into an in-flight fetch of the same line.
        if let Some(m) = self.mshrs.iter_mut().find(|m| m.line == line) {
            m.merged += 1;
            return Access::MissMerged;
        }
        if self.mshrs.len() >= self.mshr_capacity {
            return Access::MshrFull;
        }
        self.mshrs.push(Mshr { line, merged: 0 });
        Access::MissNew
    }

    /// A fill returned for `line`: install the tag (LRU victim), release
    /// the MSHR, and return how many merged accesses it unblocks (>= 1).
    pub fn fill(&mut self, addr: u64) -> u32 {
        self.clock += 1;
        let line = self.line_of(addr);
        let set = self.set_of(line);
        let base = set * self.assoc;
        // Install into an empty or LRU way (unless already present).
        if !self.tags[base..base + self.assoc].contains(&line) {
            let mut victim = 0;
            let mut oldest = u64::MAX;
            for way in 0..self.assoc {
                if self.tags[base + way] == EMPTY_TAG {
                    victim = way;
                    break;
                }
                if self.stamps[base + way] < oldest {
                    oldest = self.stamps[base + way];
                    victim = way;
                }
            }
            self.tags[base + victim] = line;
            self.stamps[base + victim] = self.clock;
        }
        match self.mshrs.iter().position(|m| m.line == line) {
            Some(i) => self.mshrs.swap_remove(i).merged + 1,
            None => 1, // fill without MSHR (e.g. after a resize flush)
        }
    }

    /// Invalidate everything (kernel boundary, reconfiguration drain).
    pub fn flush(&mut self) {
        self.tags.fill(EMPTY_TAG);
        self.stamps.fill(0);
        self.mshrs.clear();
    }

    // ------------------------------------------------------------------
    // Checkpoint (sim::snapshot)
    // ------------------------------------------------------------------

    /// Serialize the mutable state: tags, LRU stamps, clock, MSHRs.
    /// Geometry (sets/assoc/latency/capacity) is config-derived and is
    /// rebuilt by the owning component's constructor, then validated on
    /// load.
    pub fn save_state(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        w.usize(self.tags.len());
        for &t in &self.tags {
            w.u64(t);
        }
        for &s in &self.stamps {
            w.u64(s);
        }
        w.u64(self.clock);
        w.usize(self.mshrs.len());
        for m in &self.mshrs {
            w.u64(m.line);
            w.u32(m.merged);
        }
    }

    /// Restore state saved by [`Cache::save_state`] into a cache of the
    /// same geometry. A way-count mismatch means the checkpoint was taken
    /// on a differently-configured machine: error, never a partial load.
    pub fn load_state(
        &mut self,
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<()> {
        use crate::errors::err;
        let n = r.usize()?;
        if n != self.tags.len() {
            return Err(err(format!(
                "cache geometry mismatch: checkpoint has {n} ways, machine has {}",
                self.tags.len()
            )));
        }
        for t in &mut self.tags {
            *t = r.u64()?;
        }
        for s in &mut self.stamps {
            *s = r.u64()?;
        }
        self.clock = r.u64()?;
        let m = r.seq_len(12)?;
        if m > self.mshr_capacity {
            return Err(err(format!(
                "checkpoint holds {m} MSHRs, machine capacity is {}",
                self.mshr_capacity
            )));
        }
        self.mshrs.clear();
        for _ in 0..m {
            self.mshrs.push(Mshr { line: r.u64()?, merged: r.u32()? });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cache {
        // 4 sets x 2 ways x 128B = 1 KiB.
        Cache::new(1024, 2, 128, 1, 4)
    }

    #[test]
    fn geometry() {
        let c = small();
        assert_eq!(c.sets(), 4);
        assert_eq!(c.assoc(), 2);
        assert_eq!(c.bytes(), 1024);
    }

    #[test]
    fn non_pow2_geometry_rounds_sets_down() {
        // 6 sets' worth of capacity (the Fig 3/4 25/36-SM rescales produce
        // such geometries) => 4 sets, so indexing stays a mask.
        let c = Cache::new(6 * 128 * 2, 2, 128, 1, 4);
        assert_eq!(c.sets(), 4);
        assert_eq!(c.bytes(), 4 * 2 * 128);
        let mut r = Cache::new(1024, 2, 128, 1, 4);
        r.resize(6 * 128 * 4, 4, 2, 8);
        assert_eq!(r.sets(), 4, "resize applies the same rounding");
    }

    #[test]
    fn pending_and_mshr_full_probes_match_access() {
        let mut c = small();
        assert!(!c.has_pending(0x2000));
        assert_eq!(c.access(0x2000), Access::MissNew);
        assert!(c.has_pending(0x2000));
        assert!(c.has_pending(0x2040), "same line");
        assert!(!c.mshr_full());
        for i in 1..4 {
            c.access(0x10_000 + i * 0x1000);
        }
        assert!(c.mshr_full());
        assert_eq!(c.access(0x50_000), Access::MshrFull);
        c.fill(0x2000);
        assert!(!c.has_pending(0x2000));
        assert!(!c.mshr_full());
    }

    #[test]
    fn advance_clock_matches_dense_mshr_full_retries() {
        // Two caches; one replays its blocked cycles via advance_clock,
        // the other retries densely. Subsequent LRU decisions must agree.
        let mk = || {
            let mut c = Cache::new(1024, 2, 128, 1, 1);
            // Same-set residents (set 0): 0x0 and 0x200.
            for addr in [0x0u64, 0x200] {
                c.access(addr);
                c.fill(addr);
            }
            c.access(0x0); // make 0x200 the LRU victim candidate
            assert_eq!(c.access(0x3000), Access::MissNew); // occupy the only MSHR
            c
        };
        let mut dense = mk();
        let mut skip = mk();
        for _ in 0..5 {
            assert_eq!(dense.access(0x5000), Access::MshrFull);
        }
        skip.advance_clock(5);
        // Unblock and keep going: both must pick identical victims.
        for c in [&mut dense, &mut skip] {
            c.fill(0x3000);
            c.access(0x400); // set 0 again: evicts the common LRU way
            c.fill(0x400);
        }
        for addr in [0x0u64, 0x200, 0x400] {
            assert_eq!(dense.probe(addr), skip.probe(addr), "addr {addr:#x}");
        }
    }

    #[test]
    fn miss_then_fill_then_hit() {
        let mut c = small();
        assert_eq!(c.access(0x1000), Access::MissNew);
        assert_eq!(c.fill(0x1000), 1);
        assert_eq!(c.access(0x1000), Access::Hit);
        assert_eq!(c.access(0x1004), Access::Hit, "same line");
    }

    #[test]
    fn mshr_merging_counts() {
        let mut c = small();
        assert_eq!(c.access(0x2000), Access::MissNew);
        assert_eq!(c.access(0x2000), Access::MissMerged);
        assert_eq!(c.access(0x2040), Access::MissMerged, "same 128B line");
        assert_eq!(c.fill(0x2000), 3, "fill releases 1 alloc + 2 merges");
        assert_eq!(c.mshrs_in_flight(), 0);
    }

    #[test]
    fn mshr_capacity_limits() {
        let mut c = small();
        for i in 0..4 {
            assert_eq!(c.access(0x10_000 + i * 0x1000), Access::MissNew);
        }
        assert_eq!(c.access(0x50_000), Access::MshrFull);
        c.fill(0x10_000);
        assert_eq!(c.access(0x50_000), Access::MissNew, "slot freed by fill");
    }

    #[test]
    fn lru_eviction_within_set() {
        let mut c = small();
        // Three lines mapping to the same set (set = line/128 % 4 == 0).
        let a = 0x0000; // set 0
        let b = 0x0200; // 512 -> set 0
        let d = 0x0400; // 1024 -> set 0 (wraps)
        for addr in [a, b] {
            c.access(addr);
            c.fill(addr);
        }
        assert_eq!(c.access(a), Access::Hit);
        assert_eq!(c.access(b), Access::Hit);
        // Touch a to make b the LRU victim, then install d.
        c.access(a);
        c.access(d);
        c.fill(d);
        assert_eq!(c.access(a), Access::Hit, "a kept (MRU)");
        assert_eq!(c.access(d), Access::Hit, "d installed");
        assert_ne!(c.access(b), Access::Hit, "b evicted (LRU)");
    }

    #[test]
    fn resize_doubles_assoc_and_flushes() {
        let mut c = small();
        c.access(0x1000);
        c.fill(0x1000);
        c.resize(2048, 4, 2, 8);
        assert_eq!(c.assoc(), 4);
        assert_eq!(c.bytes(), 2048);
        assert_eq!(c.hit_latency, 2);
        assert_ne!(c.access(0x1000), Access::Hit, "tags flushed on resize");
    }

    #[test]
    fn working_set_capacity_effect() {
        // The mechanism behind the paper's SM benchmark (Fig 15): a working
        // set that thrashes one L1 but fits the fused (2x) L1.
        let lines = 12u64;
        let mut small_c = Cache::new(1024, 2, 128, 1, 64); // 8 lines
        let mut big_c = Cache::new(2048, 4, 128, 1, 64); // 16 lines
        let mut misses = (0u32, 0u32);
        for round in 0..50 {
            for i in 0..lines {
                let addr = i * 128;
                for (c, m) in [(&mut small_c, &mut misses.0), (&mut big_c, &mut misses.1)] {
                    match c.access(addr) {
                        Access::Hit => {}
                        _ => {
                            if round > 0 {
                                *m += 1; // ignore cold-start misses
                            }
                            c.fill(addr);
                        }
                    }
                }
            }
        }
        assert_eq!(misses.1, 0, "fits the doubled cache");
        assert!(misses.0 > 100, "thrashes the small cache: {}", misses.0);
    }
}
