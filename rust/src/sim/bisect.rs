//! Divergence bisection: a time-travel debugger for simulator runs.
//!
//! Two runs of the same workload that *should* agree (dense vs skip, with
//! vs without an empty fault trace, two builds of the simulator) sometimes
//! don't — and the first symptom is usually a counter mismatch millions of
//! cycles after the actual divergence. [`bisect_benchmark`] binary-searches
//! the **first main-loop cycle whose machine state differs**, using
//! [`crate::sim::Checkpoint`]s as the comparison probe: a checkpoint is a
//! canonical, complete serialization of the machine (every warp, cache
//! line, router queue, and counter), so two checkpoints at the same cycle
//! are byte-equal iff the machines are in the same state.
//!
//! The probe relies on the capture contract of
//! [`crate::sim::gpu::run_benchmark_snapshot`]: both sides arm the same
//! cycle, both fast-forward engines clamp to it, and both capture at the
//! main-loop top *before* fault injection — so a fault trace injecting at
//! cycle `F` first shows up in the checkpoint at `F + 1`, and the bisector
//! reports exactly that cycle together with the differing sections
//! (`cluster.3`, `noc`, `mc.0`, ...). Capture granularity is the main
//! loop: nested drain loops run to completion inside one iteration, so a
//! probe armed inside one lands at the next loop top — identically on
//! both sides, which is all the bisection needs.

use crate::config::{Scheme, SystemConfig};
use crate::errors::Result;
use crate::sim::fault::FaultTrace;
use crate::sim::gpu::run_benchmark_snapshot;
use crate::sim::snapshot::Checkpoint;
use crate::workload::BenchProfile;

/// One side of a bisection: an execution mode plus an optional fault
/// schedule. The workload (config / profile / scheme / seed) is shared —
/// bisection localizes *where* two runs of the same work diverge, not why
/// two different workloads differ.
#[derive(Debug, Clone, Default)]
pub struct BisectSide {
    /// Pin the dense reference loop (`true`) or the event-horizon skip
    /// engine (`false`).
    pub dense: bool,
    /// Fault schedule injected on this side (`None` runs clean).
    pub faults: Option<FaultTrace>,
}

/// Where two runs first disagree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BisectOutcome {
    /// The two sides' final reports are byte-for-byte equal.
    Identical,
    /// First main-loop cycle whose machine state differs, plus the
    /// checkpoint sections that differ at that cycle (`report` when the
    /// divergence only manifests in the final report, `termination` when
    /// one side ends before the probe cycle and the other doesn't).
    Diverged { cycle: u64, sections: Vec<String> },
}

/// Probe both sides at `cycle` and diff the captured machine state.
/// `None` means the sides agree at that cycle (including "both already
/// finished"); `Some(sections)` names what differs.
#[allow(clippy::too_many_arguments)]
fn probe(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    a: &BisectSide,
    b: &BisectSide,
    cycle: u64,
) -> Result<Option<Vec<String>>> {
    let snap = |side: &BisectSide| -> Result<Option<Checkpoint>> {
        let (_, cp) = run_benchmark_snapshot(
            cfg,
            profile,
            scheme,
            seed,
            side.dense,
            cycle,
            side.faults.as_ref(),
        )?;
        Ok(cp)
    };
    match (snap(a)?, snap(b)?) {
        (None, None) => Ok(None),
        (Some(ca), Some(cb)) => {
            let d = ca.state_diff(&cb);
            Ok(if d.is_empty() { None } else { Some(d) })
        }
        // One side still running at `cycle`, the other already done:
        // identical machines finish at identical cycles, so this *is*
        // the divergence.
        _ => Ok(Some(vec!["termination".to_string()])),
    }
}

/// Binary-search the first main-loop cycle at which runs `a` and `b` of
/// the same workload hold different machine state.
///
/// Cost: two full runs up front (to compare reports and bound the search)
/// plus `2 * log2(cycles)` partial runs for the probes — each probe run
/// is re-executed from cycle 0, trading wall-clock for zero persistent
/// state (the simulator re-runs deterministically by contract).
pub fn bisect_benchmark(
    cfg: &SystemConfig,
    profile: &BenchProfile,
    scheme: Scheme,
    seed: u64,
    a: &BisectSide,
    b: &BisectSide,
) -> Result<BisectOutcome> {
    // Full runs, capture-free (`u64::MAX` is never reached): final
    // reports + end cycles.
    let (ra, _) =
        run_benchmark_snapshot(cfg, profile, scheme, seed, a.dense, u64::MAX, a.faults.as_ref())?;
    let (rb, _) =
        run_benchmark_snapshot(cfg, profile, scheme, seed, b.dense, u64::MAX, b.faults.as_ref())?;
    if ra == rb {
        return Ok(BisectOutcome::Identical);
    }

    // Upper probe bound: the last cycle both runs still exist. When the
    // end cycles agree, the final loop iteration may not reach another
    // capture point, so probe strictly before it.
    let hi_limit = ra.cycles.min(rb.cycles);
    let mut hi = if ra.cycles == rb.cycles { hi_limit.saturating_sub(1) } else { hi_limit };

    let mut sections_at_hi = match probe(cfg, profile, scheme, seed, a, b, hi)? {
        Some(d) => d,
        // State agrees as late as we can see, yet the reports differ:
        // the divergence is in the final iterations past the last
        // probe-able cycle.
        None => {
            return Ok(BisectOutcome::Diverged {
                cycle: hi.saturating_add(1),
                sections: vec!["report".to_string()],
            })
        }
    };
    if let Some(d) = probe(cfg, profile, scheme, seed, a, b, 0)? {
        return Ok(BisectOutcome::Diverged { cycle: 0, sections: d });
    }

    // Invariant: state equal at `lo`, different at `hi`.
    let mut lo = 0u64;
    while lo + 1 < hi {
        let mid = lo + (hi - lo) / 2;
        match probe(cfg, profile, scheme, seed, a, b, mid)? {
            Some(d) => {
                hi = mid;
                sections_at_hi = d;
            }
            None => lo = mid,
        }
    }
    Ok(BisectOutcome::Diverged { cycle: hi, sections: sections_at_hi })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fault::{FaultEvent, FaultKind};
    use crate::workload::bench;

    fn tiny() -> (SystemConfig, BenchProfile) {
        let mut cfg = SystemConfig::tiny();
        cfg.max_cycles = 1_500_000;
        let mut p = bench("CP").unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 60;
        p.num_kernels = 1;
        (cfg, p)
    }

    #[test]
    fn identical_sides_report_identical() {
        let (cfg, p) = tiny();
        let side = BisectSide { dense: false, faults: None };
        let out = bisect_benchmark(&cfg, &p, Scheme::Baseline, 7, &side, &side).unwrap();
        assert_eq!(out, BisectOutcome::Identical);
    }

    #[test]
    fn dense_vs_skip_is_identical() {
        let (cfg, p) = tiny();
        let a = BisectSide { dense: true, faults: None };
        let b = BisectSide { dense: false, faults: None };
        let out = bisect_benchmark(&cfg, &p, Scheme::Baseline, 7, &a, &b).unwrap();
        assert_eq!(out, BisectOutcome::Identical);
    }

    #[test]
    fn fault_divergence_localized_to_injection_cycle() {
        let (cfg, p) = tiny();
        let f = FaultTrace {
            events: vec![FaultEvent { cycle: 40, kind: FaultKind::Cluster { cluster: 0 } }],
        };
        let a = BisectSide { dense: false, faults: None };
        let b = BisectSide { dense: false, faults: Some(f) };
        let out = bisect_benchmark(&cfg, &p, Scheme::Baseline, 7, &a, &b).unwrap();
        match out {
            // Capture precedes injection: the fault at cycle 40 first
            // appears in state at the next main-loop top. Nested drains
            // can push the first differing *probe-able* cycle later, but
            // never earlier than 41.
            BisectOutcome::Diverged { cycle, ref sections } => {
                assert!(cycle >= 41, "diverged at {cycle}, before the fault fired");
                assert!(!sections.is_empty());
            }
            BisectOutcome::Identical => panic!("faulted run cannot match clean run"),
        }
    }
}
