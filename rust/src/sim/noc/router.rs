//! One mesh router: combined input queue, XY route computation, per-output
//! arbitration, 2-stage pipeline (paper Table 1).

use std::collections::VecDeque;

use super::Packet;

/// Output directions of a mesh router (Eject = local delivery).
const DIR_COUNT: usize = 5;
const DIR_EAST: usize = 0;
const DIR_WEST: usize = 1;
const DIR_NORTH: usize = 2;
const DIR_SOUTH: usize = 3;
const DIR_EJECT: usize = 4;

/// A mesh router with a bounded input queue.
#[derive(Debug)]
pub struct Router {
    /// Waiting packets with the cycle they become head-of-line eligible.
    queue: VecDeque<(u64, Packet)>,
    /// Transit capacity of the input queue.
    capacity: usize,
    /// Output-port busy-until times (serialization: one packet per output
    /// per cycle, wide packets hold the port for `flits` cycles).
    out_busy: [u64; DIR_COUNT],
    /// Pipeline depth in cycles (paper: 2).
    pub stages: u64,
}

impl Router {
    /// New router with `capacity` input-queue slots and `stages` pipeline.
    pub fn new(capacity: usize, stages: u64) -> Self {
        Router {
            queue: VecDeque::with_capacity(capacity),
            capacity,
            out_busy: [0; DIR_COUNT],
            stages,
        }
    }

    /// Local injection (from the attached SM/MC). `depth` bounds the share
    /// of the queue injection may use.
    pub fn inject(&mut self, pkt: Packet, depth: usize) -> bool {
        if self.queue.len() >= depth.min(self.capacity) {
            return false;
        }
        self.queue.push_back((pkt.born, pkt));
        true
    }

    /// Is there injection space?
    pub fn inject_space(&self, depth: usize) -> bool {
        self.queue.len() < depth.min(self.capacity)
    }

    /// Number of injections [`Router::inject`] would currently accept —
    /// the free-slot snapshot a [`super::ClusterOutbox`] reserves
    /// against, so buffered admission decisions match the live queue
    /// exactly.
    pub fn inject_free(&self, depth: usize) -> usize {
        depth.min(self.capacity).saturating_sub(self.queue.len())
    }

    /// Accept a packet arriving from a neighbouring router at `ready`.
    /// Transit traffic may overflow `capacity` by a small margin — real
    /// meshes use credits; we allow the in-flight hop to land to avoid
    /// dropping packets (conservation is asserted in tests).
    pub fn accept(&mut self, pkt: Packet, ready: u64) {
        self.queue.push_back((ready, pkt));
    }

    /// Any queued traffic?
    pub fn busy(&self) -> bool {
        !self.queue.is_empty()
    }

    /// Queue occupancy (diagnostics).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// XY route: next direction for a packet at node `here` heading to
    /// `dst` on a `width`x`height` mesh.
    fn route(here: usize, dst: usize, width: usize) -> usize {
        let (hx, hy) = (here % width, here / width);
        let (dx, dy) = (dst % width, dst / width);
        if dx > hx {
            DIR_EAST
        } else if dx < hx {
            DIR_WEST
        } else if dy > hy {
            DIR_SOUTH
        } else if dy < hy {
            DIR_NORTH
        } else {
            DIR_EJECT
        }
    }

    /// Neighbour node index in direction `dir` from `here`.
    fn neighbor(here: usize, dir: usize, width: usize, height: usize) -> usize {
        let (x, y) = (here % width, here / width);
        match dir {
            DIR_EAST => {
                debug_assert!(x + 1 < width);
                here + 1
            }
            DIR_WEST => {
                debug_assert!(x > 0);
                here - 1
            }
            DIR_SOUTH => {
                debug_assert!(y + 1 < height);
                here + width
            }
            DIR_NORTH => {
                debug_assert!(y > 0);
                here - width
            }
            _ => unreachable!("eject has no neighbour"),
        }
    }

    /// Select at most one packet per free output direction this cycle and
    /// dequeue them into `moves` (cleared first). Each entry is a
    /// (packet, next_node) pair; `usize::MAX` as next_node means "eject
    /// here". Taking the buffer from the caller keeps the per-cycle NoC
    /// sweep allocation-free (the [`super::Noc`] owns one reusable
    /// buffer for all routers).
    pub fn plan_moves_into(
        &mut self,
        now: u64,
        here: usize,
        width: usize,
        height: usize,
        moves: &mut Vec<(Packet, usize)>,
    ) {
        moves.clear();
        let mut claimed = [false; DIR_COUNT];
        let mut i = 0;
        while i < self.queue.len() {
            let (ready, pkt) = self.queue[i];
            if ready > now {
                i += 1;
                continue;
            }
            let dir = Self::route(here, pkt.dst, width);
            if claimed[dir] || self.out_busy[dir] > now {
                i += 1;
                continue;
            }
            claimed[dir] = true;
            // Port held for the packet's serialization time.
            self.out_busy[dir] = now + pkt.flits as u64;
            self.queue.remove(i);
            if dir == DIR_EJECT {
                moves.push((pkt, usize::MAX));
            } else {
                moves.push((pkt, Self::neighbor(here, dir, width, height)));
            }
        }
    }

    /// Earliest cycle at which this router could move a packet, mirroring
    /// [`Router::plan_moves_into`]'s eligibility rules exactly: a queued
    /// packet moves once it is head-of-line ready *and* its XY output
    /// port has finished serializing the previous packet. Arbitration
    /// (two ready packets on one port) only matters when at least one is
    /// already movable, which is `Progress` regardless.
    ///
    /// The [`super::Noc`] folds this over its busy routers only — an
    /// empty router is vacuously `Idle` and is neither swept nor probed,
    /// which is what lets interconnect cost track live traffic rather
    /// than fabric size (the active-set contract: a parked router is
    /// revived by the `accept`/`inject` that makes it busy again).
    pub fn next_event(&self, now: u64, here: usize, width: usize) -> crate::sim::NextEvent {
        use crate::sim::NextEvent;
        let mut ev = NextEvent::Idle;
        for &(ready, pkt) in &self.queue {
            let dir = Self::route(here, pkt.dst, width);
            let t = ready.max(self.out_busy[dir]);
            ev = ev.min_with(NextEvent::at_or_progress(t, now));
            if ev == NextEvent::Progress {
                break;
            }
        }
        ev
    }

    /// Serialize the mutable state: the input queue (with per-packet ready
    /// cycles) and output-port busy times. `capacity`/`stages` are config
    /// and rebuilt by the constructor.
    pub fn save_state(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        w.usize(self.queue.len());
        for (ready, pkt) in &self.queue {
            w.u64(*ready);
            super::write_packet(w, pkt);
        }
        for b in self.out_busy {
            w.u64(b);
        }
    }

    /// Inverse of [`Router::save_state`]. Transit traffic may legally
    /// exceed `capacity` (credits are not modelled), so queue length is
    /// only bounded by the reader's allocation guard.
    pub fn load_state(
        &mut self,
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<()> {
        let n = r.seq_len(42)?;
        self.queue.clear();
        for _ in 0..n {
            let ready = r.u64()?;
            let pkt = super::read_packet(r)?;
            self.queue.push_back((ready, pkt));
        }
        for b in self.out_busy.iter_mut() {
            *b = r.u64()?;
        }
        Ok(())
    }

    /// Allocating convenience wrapper over [`Router::plan_moves_into`]
    /// (unit tests and diagnostics; the simulation loop uses the `_into`
    /// form).
    pub fn plan_moves(&mut self, now: u64, here: usize, width: usize, height: usize) -> Vec<(Packet, usize)> {
        let mut moves = Vec::new();
        self.plan_moves_into(now, here, width, height, &mut moves);
        moves
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::noc::Payload;

    fn pkt(src: usize, dst: usize, flits: u32) -> Packet {
        Packet {
            src,
            dst,
            flits,
            born: 0,
            payload: Payload::MemReply { line: 0, requester: 0, is_write: false },
        }
    }

    #[test]
    fn xy_route_orders_x_first() {
        // 3x3 mesh; from center (4) to corner (0): west first, then north.
        assert_eq!(Router::route(4, 0, 3), DIR_WEST);
        assert_eq!(Router::route(3, 0, 3), DIR_NORTH);
        assert_eq!(Router::route(0, 0, 3), DIR_EJECT);
        assert_eq!(Router::route(0, 2, 3), DIR_EAST);
        assert_eq!(Router::route(0, 6, 3), DIR_SOUTH);
    }

    #[test]
    fn one_packet_per_output_per_cycle() {
        let mut r = Router::new(8, 2);
        assert!(r.inject(pkt(0, 2, 1), 8));
        assert!(r.inject(pkt(0, 2, 1), 8));
        let m = r.plan_moves(0, 0, 3, 3);
        assert_eq!(m.len(), 1, "east port arbitration");
        assert!(r.busy());
    }

    #[test]
    fn different_outputs_move_in_parallel() {
        let mut r = Router::new(8, 2);
        assert!(r.inject(pkt(4, 3, 1), 8)); // west
        assert!(r.inject(pkt(4, 5, 1), 8)); // east
        assert!(r.inject(pkt(4, 4, 1), 8)); // eject
        let m = r.plan_moves(0, 4, 3, 3);
        assert_eq!(m.len(), 3);
        assert!(m.iter().any(|(_, n)| *n == usize::MAX));
    }

    #[test]
    fn serialization_blocks_port() {
        let mut r = Router::new(8, 2);
        assert!(r.inject(pkt(0, 1, 4), 8));
        assert!(r.inject(pkt(0, 1, 1), 8));
        assert_eq!(r.plan_moves(0, 0, 3, 3).len(), 1);
        // Port busy until cycle 4 — nothing moves at t=1..3.
        assert_eq!(r.plan_moves(1, 0, 3, 3).len(), 0);
        assert_eq!(r.plan_moves(3, 0, 3, 3).len(), 0);
        assert_eq!(r.plan_moves(4, 0, 3, 3).len(), 1);
    }

    #[test]
    fn injection_respects_depth() {
        let mut r = Router::new(8, 2);
        for _ in 0..4 {
            assert!(r.inject(pkt(0, 1, 1), 4));
        }
        assert!(!r.inject(pkt(0, 1, 1), 4));
        assert!(!r.inject_space(4));
        assert!(r.inject_space(8));
    }

    #[test]
    fn not_ready_packets_wait() {
        let mut r = Router::new(8, 2);
        r.accept(pkt(0, 1, 1), 10);
        assert!(r.plan_moves(5, 0, 3, 3).is_empty());
        assert_eq!(r.plan_moves(10, 0, 3, 3).len(), 1);
    }
}
