//! Network-on-chip: 2D mesh with XY routing, 2-stage routers, bounded
//! queues and two subnets (request / reply) to avoid protocol deadlock —
//! the paper's Table 1 interconnect. A `Perfect` mode (zero latency,
//! infinite bandwidth) reproduces the Fig 3(b) methodology.
//!
//! Fusion interacts with the NoC by *shrinking* it: AMOEBA bypasses the
//! router of the second SM in each fused pair, so the fused machine builds
//! a smaller mesh (fewer nodes -> fewer hops, more bandwidth per SM —
//! Fig 17/18). Heterogeneous layouts (§4.4) mix both in one fabric: a
//! fused cluster occupies a single node while its private neighbours keep
//! two, so the node map is table-driven ([`ChipLayout`]). The GPU rebuilds
//! the NoC at reconfiguration boundaries.

mod router;

pub use router::Router;

use std::collections::VecDeque;

use crate::config::{NocMode, SystemConfig};

/// The per-cluster fused/private layout of the SM fabric and the derived
/// NoC endpoint map. Clusters are assigned nodes in index order: a
/// private cluster keeps both of its routers (two consecutive nodes), a
/// fused cluster bypasses the second router (one node). Memory
/// controllers occupy the nodes after every SM node.
///
/// The homogeneous special cases reproduce the historical maps exactly:
/// all-private puts cluster `i` at nodes `2i`/`2i+1`, all-fused at `i`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChipLayout {
    /// Fused flag per cluster.
    fused: Vec<bool>,
    /// Cluster -> its [first, second] NoC node (equal when fused).
    nodes_of: Vec<[usize; 2]>,
    /// SM node -> owning cluster (inverse of `nodes_of`).
    owner: Vec<usize>,
    /// Memory-controller count (MC nodes follow the SM nodes).
    num_mcs: usize,
}

impl ChipLayout {
    /// Build the node map for a per-cluster `fused` vector.
    pub fn new(fused: Vec<bool>, num_mcs: usize) -> Self {
        assert!(!fused.is_empty(), "layout needs at least one cluster");
        let mut nodes_of = Vec::with_capacity(fused.len());
        let mut owner = Vec::with_capacity(fused.len() * 2);
        for (ci, &f) in fused.iter().enumerate() {
            let n0 = owner.len();
            if f {
                nodes_of.push([n0, n0]);
                owner.push(ci);
            } else {
                nodes_of.push([n0, n0 + 1]);
                owner.push(ci);
                owner.push(ci);
            }
        }
        ChipLayout { fused, nodes_of, owner, num_mcs }
    }

    /// All clusters in the same mode (the pre-§4.4 special cases).
    pub fn homogeneous(n_clusters: usize, fused: bool, num_mcs: usize) -> Self {
        Self::new(vec![fused; n_clusters], num_mcs)
    }

    /// Number of SM clusters.
    pub fn n_clusters(&self) -> usize {
        self.fused.len()
    }

    /// Is cluster `ci` fused (single NoC interface)?
    pub fn is_fused(&self, ci: usize) -> bool {
        self.fused[ci]
    }

    /// The per-cluster fused flags.
    pub fn fused_flags(&self) -> &[bool] {
        &self.fused
    }

    /// Any cluster fused?
    pub fn any_fused(&self) -> bool {
        self.fused.iter().any(|&f| f)
    }

    /// Both fused and private clusters present (heterogeneous fabric)?
    pub fn is_mixed(&self) -> bool {
        self.any_fused() && self.fused.iter().any(|&f| !f)
    }

    /// SM endpoint count (fused clusters contribute one, private two).
    pub fn sm_nodes(&self) -> usize {
        self.owner.len()
    }

    /// Total endpoint count (SM nodes + MC nodes).
    pub fn nodes(&self) -> usize {
        self.owner.len() + self.num_mcs
    }

    /// NoC nodes of cluster `ci` ([half0, half1]; equal when fused).
    pub fn nodes_of(&self, ci: usize) -> [usize; 2] {
        self.nodes_of[ci]
    }

    /// Cluster owning SM node `n` (inverse of [`ChipLayout::nodes_of`]).
    pub fn cluster_of_node(&self, n: usize) -> usize {
        self.owner[n]
    }

    /// NoC node of memory controller `mc`.
    pub fn mc_node(&self, mc: usize) -> usize {
        debug_assert!(mc < self.num_mcs);
        self.owner.len() + mc
    }

    /// Serialize the layout (checkpoint format): the per-cluster fused
    /// flags and MC count. Everything else is derived by the constructor.
    pub fn save_state(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        w.usize(self.fused.len());
        for &f in &self.fused {
            w.bool(f);
        }
        w.usize(self.num_mcs);
    }

    /// Rebuild a layout saved by [`ChipLayout::save_state`].
    pub fn load(r: &mut crate::sim::snapshot::ByteReader<'_>) -> crate::errors::Result<ChipLayout> {
        let n = r.seq_len(1)?;
        if n == 0 {
            return Err(crate::errors::err("checkpoint layout has zero clusters"));
        }
        let mut fused = Vec::with_capacity(n);
        for _ in 0..n {
            fused.push(r.bool()?);
        }
        let num_mcs = r.usize()?;
        Ok(ChipLayout::new(fused, num_mcs))
    }
}

/// Serialize one packet (checkpoint format).
pub(crate) fn write_packet(w: &mut crate::sim::snapshot::ByteWriter, p: &Packet) {
    w.usize(p.src);
    w.usize(p.dst);
    w.u32(p.flits);
    w.u64(p.born);
    let (tag, line, requester, is_write) = match p.payload {
        Payload::MemRequest { line, requester, is_write } => (0u8, line, requester, is_write),
        Payload::MemReply { line, requester, is_write } => (1u8, line, requester, is_write),
    };
    w.u8(tag);
    w.u64(line);
    w.u32(requester);
    w.bool(is_write);
}

/// Inverse of [`write_packet`].
pub(crate) fn read_packet(
    r: &mut crate::sim::snapshot::ByteReader<'_>,
) -> crate::errors::Result<Packet> {
    let src = r.usize()?;
    let dst = r.usize()?;
    let flits = r.u32()?;
    let born = r.u64()?;
    let tag = r.u8()?;
    let line = r.u64()?;
    let requester = r.u32()?;
    let is_write = r.bool()?;
    let payload = match tag {
        0 => Payload::MemRequest { line, requester, is_write },
        1 => Payload::MemReply { line, requester, is_write },
        t => return Err(crate::errors::err(format!("unknown packet payload tag {t}"))),
    };
    Ok(Packet { src, dst, flits, born, payload })
}

/// What a packet carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload {
    /// SM -> memory partition: fetch or write-through of a line.
    MemRequest { line: u64, requester: u32, is_write: bool },
    /// Memory partition -> SM: data or write-ack for a line.
    MemReply { line: u64, requester: u32, is_write: bool },
}

/// One NoC packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Packet {
    /// Source node index.
    pub src: usize,
    /// Destination node index.
    pub dst: usize,
    /// Size in flits (header + payload on the 128-bit channel).
    pub flits: u32,
    /// Injection cycle (for latency accounting).
    pub born: u64,
    /// Payload.
    pub payload: Payload,
}

/// Subnet selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Subnet {
    /// SM -> MC traffic.
    Request = 0,
    /// MC -> SM traffic.
    Reply = 1,
}

/// A cluster's private injection buffer for one intra-parallel cycle.
///
/// When `Gpu::tick_active` fans the live clusters across worker threads,
/// each cluster injects into one of these instead of the shared [`Noc`].
/// Admission is decided *locally but exactly*: the free slots of the
/// cluster's own source routers are snapshotted at phase start
/// ([`Noc::begin_outbox`]) and reserved per accepted packet. Source
/// routers are disjoint across clusters and nothing else injects during
/// the cluster phase, so the snapshot cannot go stale — every
/// accept/refuse decision equals the serial loop's, and the reserved
/// capacity guarantees the deferred [`Noc::inject`] succeeds when
/// [`Noc::drain_outbox`] merges the buffers in cluster-index order
/// (reproducing the serial injection sequence bit-for-bit).
#[derive(Debug)]
pub struct ClusterOutbox {
    /// Endpoint count of the fabric (for [`NocPort::nodes`]).
    nodes: usize,
    /// Request-gate state at phase start (constant during the phase:
    /// the gate only moves at reconfiguration boundaries).
    req_gate: bool,
    /// Perfect fabric (admission is unconditional)?
    perfect: bool,
    /// This cluster's NoC endpoints ([half0, half1]; equal when fused).
    src_nodes: [usize; 2],
    /// Remaining injection slots per source router, snapshotted at
    /// phase start and decremented per accepted packet.
    free: [usize; 2],
    /// Accepted packets, in injection order.
    pkts: Vec<(Subnet, Packet)>,
    /// The cluster's post-tick horizon, carried back to the merge loop
    /// (scratch for the parallel phase; not interconnect state).
    pub ev: crate::sim::NextEvent,
}

impl Default for ClusterOutbox {
    fn default() -> Self {
        ClusterOutbox {
            nodes: 0,
            req_gate: false,
            perfect: false,
            src_nodes: [0; 2],
            free: [0; 2],
            pkts: Vec::new(),
            ev: crate::sim::NextEvent::Idle,
        }
    }
}

impl ClusterOutbox {
    /// Mirror of [`Noc::inject`]'s admission decision against the
    /// snapshotted state. Clusters only source Request-subnet traffic,
    /// which is what the free-slot snapshot covers.
    fn inject(&mut self, subnet: Subnet, pkt: Packet) -> bool {
        debug_assert!(pkt.src < self.nodes && pkt.dst < self.nodes);
        if self.req_gate && subnet == Subnet::Request {
            return false;
        }
        if self.perfect || pkt.src == pkt.dst {
            self.pkts.push((subnet, pkt));
            return true;
        }
        debug_assert_eq!(subnet, Subnet::Request, "outbox snapshot covers Request sources only");
        let slot = usize::from(pkt.src == self.src_nodes[1] && self.src_nodes[1] != self.src_nodes[0]);
        debug_assert_eq!(pkt.src, self.src_nodes[slot], "packet from a foreign source router");
        if self.free[slot] == 0 {
            return false;
        }
        self.free[slot] -= 1;
        self.pkts.push((subnet, pkt));
        true
    }
}

/// How a cluster reaches the interconnect during its tick: directly (the
/// serial loops) or through its private per-cycle [`ClusterOutbox`] (the
/// intra-parallel cluster phase). Both expose the identical
/// inject/nodes surface, and the buffered admission is exact by the
/// snapshot-and-reserve contract — so a cluster cannot observe which
/// port it was handed.
pub enum NocPort<'a> {
    /// Mutate the shared fabric immediately.
    Direct(&'a mut Noc),
    /// Buffer injections for an index-ordered merge after the join.
    Buffered(&'a mut ClusterOutbox),
}

impl NocPort<'_> {
    /// Endpoint count (see [`Noc::nodes`]).
    pub fn nodes(&self) -> usize {
        match self {
            NocPort::Direct(noc) => noc.nodes(),
            NocPort::Buffered(out) => out.nodes,
        }
    }

    /// Try to inject `pkt` at its source node (see [`Noc::inject`]).
    pub fn inject(&mut self, subnet: Subnet, pkt: Packet) -> bool {
        match self {
            NocPort::Direct(noc) => noc.inject(subnet, pkt),
            NocPort::Buffered(out) => out.inject(subnet, pkt),
        }
    }
}

/// The interconnect: a mesh (or ideal fabric) over `nodes` endpoints.
///
/// The router sweep is **active-set**: only routers with queued packets
/// are visited each cycle (`busy` lists below), so an idle fabric — or
/// the idle region of a partially busy one — costs nothing per cycle
/// instead of an O(routers) walk of empty queues. The sweep visits the
/// busy subset in exactly the dense loop's rotated order, and a router
/// that becomes busy mid-sweep holds only packets with a future ready
/// cycle (pipeline stages + serialization are >= 1), so skipping it
/// until the next cycle is behaviour-identical to the dense sweep.
#[derive(Debug)]
pub struct Noc {
    mode: NocMode,
    width: usize,
    height: usize,
    nodes: usize,
    /// Routers indexed [subnet][node].
    routers: [Vec<Router>; 2],
    /// Ejection queues per [subnet][node].
    eject: [Vec<VecDeque<Packet>>; 2],
    /// Perfect-mode delivery (bypasses routers entirely).
    /// Stats: total flit-hops routed.
    pub flits_routed: u64,
    /// Stats: packets delivered.
    pub packets_delivered: u64,
    inject_depth: usize,
    /// Reusable per-cycle move buffer (hot-path allocation elimination:
    /// one buffer serves every router sweep instead of a fresh `Vec` per
    /// router per cycle).
    moves_scratch: Vec<(Packet, usize)>,
    /// Routers with queued packets, per subnet (unordered; the sweep
    /// sorts a snapshot into the rotated visit order).
    busy: [Vec<u32>; 2],
    /// Membership flags mirroring `busy`.
    in_busy: [Vec<bool>; 2],
    /// Non-empty ejection-queue count per subnet: lets consumers skip
    /// their delivery scans in O(1) when nothing has arrived.
    eject_nonempty: [usize; 2],
    /// Monotone count of packets entering the router fabric. A parked
    /// NoC component compares it against the value it parked with: a
    /// difference means an injection happened and the fabric must tick
    /// again (the active-set wake condition for the interconnect).
    inject_epoch: u64,
    /// Reusable rotated-order snapshot of the busy set.
    order_scratch: Vec<u32>,
    /// Extra cycles added to every router hop (fault-injected link
    /// degradation; 0 on a healthy fabric). Applied when a hop's ready
    /// cycle is stamped, so raising it mid-run never reorders packets
    /// already accepted — horizons stay exact.
    hop_penalty: u64,
    /// When set, **new** Request-subnet injections are refused (both
    /// modes). Packets already in flight keep moving and the Reply
    /// subnet is untouched, so MC replies drain normally — this is the
    /// quiesce step of a partition-scoped reconfigure: stop feeding the
    /// fabric, let it empty, then swap the layout.
    req_gate: bool,
}

impl Noc {
    /// Build the interconnect for a chip layout: one endpoint per private
    /// SM, one per fused cluster (router bypass), one per MC.
    pub fn new(cfg: &SystemConfig, layout: &ChipLayout) -> Self {
        Self::with_nodes(cfg, layout.nodes())
    }

    /// Build an interconnect over a raw endpoint count (tests/benches and
    /// fabric studies that do not model clusters).
    pub fn with_nodes(cfg: &SystemConfig, nodes: usize) -> Self {
        let width = (nodes as f64).sqrt().ceil() as usize;
        let height = nodes.div_ceil(width);
        let mk = |n: usize| -> Vec<Router> {
            (0..n).map(|_| Router::new(cfg.noc_queue_depth, cfg.noc_router_stages as u64)).collect()
        };
        Noc {
            mode: cfg.noc_mode,
            width,
            height,
            nodes,
            routers: [mk(width * height), mk(width * height)],
            eject: [
                (0..nodes).map(|_| VecDeque::new()).collect(),
                (0..nodes).map(|_| VecDeque::new()).collect(),
            ],
            flits_routed: 0,
            packets_delivered: 0,
            inject_depth: cfg.noc_inject_depth,
            moves_scratch: Vec::with_capacity(8),
            busy: [Vec::new(), Vec::new()],
            in_busy: [vec![false; width * height], vec![false; width * height]],
            eject_nonempty: [0, 0],
            inject_epoch: 0,
            order_scratch: Vec::with_capacity(8),
            hop_penalty: 0,
            req_gate: false,
        }
    }

    /// Degrade every router hop by `penalty` extra cycles (fault
    /// injection). Monotone for the common single-event case, but any
    /// value is safe: only future hop stamps change.
    pub fn set_hop_penalty(&mut self, penalty: u64) {
        self.hop_penalty = penalty;
    }

    /// Current per-hop degradation penalty (0 = healthy fabric).
    pub fn hop_penalty(&self) -> u64 {
        self.hop_penalty
    }

    /// Gate (or un-gate) **new** Request-subnet injections. While gated,
    /// [`Noc::inject`]/[`Noc::can_inject`] refuse Request packets in both
    /// Perfect and Mesh modes; in-flight packets and the Reply subnet are
    /// unaffected, so outstanding loads complete and the fabric drains to
    /// empty — the precondition for a layout swap while *other* tenants'
    /// clusters stay live.
    pub fn set_request_gate(&mut self, gated: bool) {
        self.req_gate = gated;
    }

    /// Is the Request subnet currently refusing new injections?
    pub fn request_gate(&self) -> bool {
        self.req_gate
    }

    /// Record router `r` of `subnet` as holding queued packets.
    #[inline]
    fn mark_busy(&mut self, subnet: usize, r: usize) {
        if !self.in_busy[subnet][r] {
            self.in_busy[subnet][r] = true;
            self.busy[subnet].push(r as u32);
        }
    }

    /// Push a delivered packet into an ejection queue, tracking the
    /// non-empty count.
    #[inline]
    fn eject_push(&mut self, subnet: usize, node: usize, pkt: Packet) {
        if self.eject[subnet][node].is_empty() {
            self.eject_nonempty[subnet] += 1;
        }
        self.eject[subnet][node].push_back(pkt);
        self.packets_delivered += 1;
    }

    /// Mesh dimensions (width, height).
    pub fn dims(&self) -> (usize, usize) {
        (self.width, self.height)
    }

    /// Endpoint count.
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    fn coords(&self, node: usize) -> (usize, usize) {
        (node % self.width, node / self.width)
    }

    /// XY-routing hop count between two nodes.
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// Try to inject `pkt` at its source node. Returns false when the
    /// injection queue is full (the Fig 17 stall condition at MCs).
    pub fn inject(&mut self, subnet: Subnet, pkt: Packet) -> bool {
        debug_assert!(pkt.src < self.nodes && pkt.dst < self.nodes);
        if self.req_gate && subnet == Subnet::Request {
            return false;
        }
        match self.mode {
            NocMode::Perfect => {
                // Ideal fabric: instant delivery.
                self.eject_push(subnet as usize, pkt.dst, pkt);
                true
            }
            NocMode::Mesh => {
                if pkt.src == pkt.dst {
                    self.eject_push(subnet as usize, pkt.dst, pkt);
                    return true;
                }
                if self.routers[subnet as usize][pkt.src].inject(pkt, self.inject_depth) {
                    self.mark_busy(subnet as usize, pkt.src);
                    self.inject_epoch += 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Arm `out` as one cluster's injection buffer for this cycle's
    /// parallel cluster phase: snapshot the request gate, the fabric
    /// mode, and the free injection slots of the cluster's own source
    /// routers (`src_nodes`). Valid while nothing else injects at those
    /// routers — which the cluster phase guarantees, since source
    /// routers are cluster-private and MCs inject only in later phases.
    pub fn begin_outbox(&self, out: &mut ClusterOutbox, src_nodes: [usize; 2]) {
        out.nodes = self.nodes;
        out.req_gate = self.req_gate;
        out.perfect = self.mode == NocMode::Perfect;
        out.src_nodes = src_nodes;
        out.pkts.clear();
        out.ev = crate::sim::NextEvent::Idle;
        if out.perfect {
            out.free = [0; 2];
        } else {
            let req = &self.routers[Subnet::Request as usize];
            out.free = [
                req[src_nodes[0]].inject_free(self.inject_depth),
                req[src_nodes[1]].inject_free(self.inject_depth),
            ];
        }
    }

    /// Merge one armed outbox into the fabric: replay its accepted
    /// packets through [`Noc::inject`] in their original order. Called
    /// in cluster-index order after the join, this reproduces exactly
    /// the injection sequence the serial cluster loop would have
    /// produced; the reserved free slots make every replayed inject
    /// succeed.
    pub fn drain_outbox(&mut self, out: &mut ClusterOutbox) {
        for (subnet, pkt) in out.pkts.drain(..) {
            let _accepted = self.inject(subnet, pkt);
            debug_assert!(_accepted, "outbox reserved a slot the fabric then refused");
        }
    }

    /// Space available in the source injection queue?
    pub fn can_inject(&self, subnet: Subnet, node: usize) -> bool {
        if self.req_gate && subnet == Subnet::Request {
            return false;
        }
        match self.mode {
            NocMode::Perfect => true,
            NocMode::Mesh => self.routers[subnet as usize][node].inject_space(self.inject_depth),
        }
    }

    /// Advance both subnets one cycle.
    pub fn tick(&mut self, now: u64) {
        if self.mode == NocMode::Perfect {
            return;
        }
        for subnet in 0..2 {
            self.tick_subnet(subnet, now);
        }
    }

    fn tick_subnet(&mut self, subnet: usize, now: u64) {
        if self.busy[subnet].is_empty() {
            return;
        }
        let width = self.width;
        let height = self.height;
        let n_routers = self.routers[subnet].len();
        // Each router forwards at most one packet per output direction per
        // cycle. The dense loop swept *every* router in a rotating order
        // (based on cycle) to avoid systematic unfairness toward
        // low-indexed nodes; here we sweep only the busy subset, sorted
        // into that same rotated order, which is behaviour-identical:
        // empty routers move nothing and mutate nothing, and a router
        // that becomes busy mid-sweep (via `accept`) holds only packets
        // with `ready > now`, which the dense sweep could not move this
        // cycle either.
        let start = (now as usize) % n_routers;
        let mut order = std::mem::take(&mut self.order_scratch);
        order.clear();
        order.extend_from_slice(&self.busy[subnet]);
        order.sort_unstable_by_key(|&r| (r as usize + n_routers - start) % n_routers);
        // The scratch buffer is taken out of `self` for the sweep so the
        // borrow checker lets us touch other routers while draining it.
        let mut moves = std::mem::take(&mut self.moves_scratch);
        for &r in &order {
            let r = r as usize;
            // Decide moves out of router r.
            self.routers[subnet][r].plan_moves_into(now, r, width, height, &mut moves);
            for (pkt, next) in moves.drain(..) {
                if next == usize::MAX {
                    // Arrived: eject (bounded only by consumer draining).
                    self.eject_push(subnet, pkt.dst, pkt);
                    self.flits_routed += pkt.flits as u64;
                } else {
                    // Hop latency: pipeline stages + serialization, plus
                    // any fault-injected link degradation.
                    let ready =
                        now + self.routers[subnet][r].stages + pkt.flits as u64 + self.hop_penalty;
                    self.routers[subnet][next].accept(pkt, ready);
                    self.mark_busy(subnet, next);
                    self.flits_routed += pkt.flits as u64;
                }
            }
        }
        self.moves_scratch = moves;
        self.order_scratch = order;
        // Drop drained routers from the busy set.
        let mut busy = std::mem::take(&mut self.busy[subnet]);
        busy.retain(|&r| {
            let still = self.routers[subnet][r as usize].busy();
            if !still {
                self.in_busy[subnet][r as usize] = false;
            }
            still
        });
        self.busy[subnet] = busy;
    }

    /// Pop one delivered packet at `node`, if any.
    pub fn eject(&mut self, subnet: Subnet, node: usize) -> Option<Packet> {
        let q = &mut self.eject[subnet as usize][node];
        let pkt = q.pop_front();
        if pkt.is_some() && q.is_empty() {
            self.eject_nonempty[subnet as usize] -= 1;
        }
        pkt
    }

    /// Is a delivered packet waiting at `node`?
    pub fn has_ejectable(&self, subnet: Subnet, node: usize) -> bool {
        !self.eject[subnet as usize][node].is_empty()
    }

    /// Number of nodes with non-empty ejection queues on `subnet` (O(1);
    /// consumers use it to skip their delivery scans entirely).
    pub fn ejectable_nodes(&self, subnet: Subnet) -> usize {
        self.eject_nonempty[subnet as usize]
    }

    /// Monotone injection counter: a parked interconnect component is
    /// revived whenever this moved past the value it parked with.
    pub fn inject_epoch(&self) -> u64 {
        self.inject_epoch
    }

    /// Earliest cycle at which ticking the NoC (or draining its ejection
    /// queues) could change state. A non-empty ejection queue is always
    /// [`NextEvent::Progress`] because the GPU consumes ejections every
    /// cycle; otherwise the horizon is the earliest movable packet across
    /// both subnets' routers ([`Router::next_event`]). The rotating sweep
    /// start (`now % routers`) cannot affect a cycle in which nothing is
    /// movable, so it never invalidates a reported horizon.
    pub fn next_event(&self, now: u64) -> crate::sim::NextEvent {
        use crate::sim::NextEvent;
        if self.eject_nonempty.iter().any(|&c| c > 0) {
            return NextEvent::Progress;
        }
        self.router_next_event(now)
    }

    /// Earliest cycle at which the *router fabric* could move a packet,
    /// ignoring the ejection queues (those are the consumers' concern:
    /// the active-set GPU loop tracks them via [`Noc::ejectable_nodes`]
    /// and parks the fabric on this horizon alone).
    pub fn router_next_event(&self, now: u64) -> crate::sim::NextEvent {
        use crate::sim::NextEvent;
        if self.mode == NocMode::Perfect {
            // Perfect fabric: delivery happens at injection time; ticking
            // an empty network is a no-op.
            return NextEvent::Idle;
        }
        let mut ev = NextEvent::Idle;
        for (subnet, routers) in self.routers.iter().enumerate() {
            for &r in &self.busy[subnet] {
                ev = ev.min_with(routers[r as usize].next_event(now, r as usize, self.width));
                if ev == NextEvent::Progress {
                    return ev;
                }
            }
        }
        ev
    }

    /// Any packets still in flight anywhere? O(1) against the busy-router
    /// and non-empty-ejection bookkeeping.
    pub fn busy(&self) -> bool {
        self.eject_nonempty.iter().any(|&c| c > 0) || self.busy.iter().any(|b| !b.is_empty())
    }

    /// Serialize the interconnect's mutable state: router queues, ejection
    /// queues, stats, injection epoch, hop penalty and request gate.
    /// Geometry and the busy/scratch bookkeeping are rebuilt on load (the
    /// receiving NoC must have been constructed for the same layout).
    pub fn save_state(&self, w: &mut crate::sim::snapshot::ByteWriter) {
        for subnet in 0..2 {
            w.usize(self.routers[subnet].len());
            for rt in &self.routers[subnet] {
                rt.save_state(w);
            }
            w.usize(self.eject[subnet].len());
            for q in &self.eject[subnet] {
                w.usize(q.len());
                for p in q {
                    write_packet(w, p);
                }
            }
        }
        w.u64(self.flits_routed);
        w.u64(self.packets_delivered);
        w.u64(self.inject_epoch);
        w.u64(self.hop_penalty);
        w.bool(self.req_gate);
    }

    /// Inverse of [`Noc::save_state`] into a NoC built for the same layout
    /// and config. Rebuilds the busy sets and ejection counts from the
    /// restored queues (sweep order is derived by sorting, so index-order
    /// rebuild is behaviour-identical to the live insertion order).
    pub fn load_state(
        &mut self,
        r: &mut crate::sim::snapshot::ByteReader<'_>,
    ) -> crate::errors::Result<()> {
        use crate::errors::err;
        for subnet in 0..2 {
            let nr = r.usize()?;
            if nr != self.routers[subnet].len() {
                return Err(err(format!(
                    "checkpoint has {nr} routers on subnet {subnet}, machine has {}",
                    self.routers[subnet].len()
                )));
            }
            for rt in &mut self.routers[subnet] {
                rt.load_state(r)?;
            }
            let ne = r.usize()?;
            if ne != self.eject[subnet].len() {
                return Err(err(format!(
                    "checkpoint has {ne} eject queues on subnet {subnet}, machine has {}",
                    self.eject[subnet].len()
                )));
            }
            for qi in 0..ne {
                let n = r.seq_len(42)?;
                let q = &mut self.eject[subnet][qi];
                q.clear();
                for _ in 0..n {
                    q.push_back(read_packet(r)?);
                }
            }
        }
        self.flits_routed = r.u64()?;
        self.packets_delivered = r.u64()?;
        self.inject_epoch = r.u64()?;
        self.hop_penalty = r.u64()?;
        self.req_gate = r.bool()?;
        for subnet in 0..2 {
            self.busy[subnet].clear();
            for f in self.in_busy[subnet].iter_mut() {
                *f = false;
            }
            for ri in 0..self.routers[subnet].len() {
                if self.routers[subnet][ri].busy() {
                    self.in_busy[subnet][ri] = true;
                    self.busy[subnet].push(ri as u32);
                }
            }
            self.eject_nonempty[subnet] =
                self.eject[subnet].iter().filter(|q| !q.is_empty()).count();
        }
        Ok(())
    }

    /// Per-router queue occupancy summary (deadlock diagnostics).
    pub fn debug_state(&self) -> String {
        let mut out = String::new();
        for (s, label) in [(0usize, "req"), (1, "rep")] {
            let qs: Vec<usize> = self.routers[s].iter().map(|r| r.queue_len()).collect();
            let es: Vec<usize> = self.eject[s].iter().map(|q| q.len()).collect();
            out.push_str(&format!("{label}: routers={qs:?} eject={es:?}  "));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::tiny()
    }

    fn pkt(src: usize, dst: usize, flits: u32, born: u64) -> Packet {
        Packet {
            src,
            dst,
            flits,
            born,
            payload: Payload::MemRequest { line: 0, requester: 0, is_write: false },
        }
    }

    fn deliver(noc: &mut Noc, p: Packet, limit: u64) -> u64 {
        assert!(noc.inject(Subnet::Request, p));
        for t in p.born..p.born + limit {
            noc.tick(t);
            if noc.eject(Subnet::Request, p.dst).is_some() {
                return t - p.born;
            }
        }
        panic!("packet not delivered in {limit} cycles");
    }

    #[test]
    fn mesh_dims_cover_nodes() {
        let n = Noc::with_nodes(&cfg(), 6);
        let (w, h) = n.dims();
        assert!(w * h >= 6);
        assert_eq!(n.nodes(), 6);
    }

    #[test]
    fn delivery_latency_scales_with_hops() {
        let mut noc = Noc::with_nodes(&cfg(), 6); // 3x2 mesh
        let near = deliver(&mut noc, pkt(0, 1, 1, 0), 100);
        let far = deliver(&mut noc, pkt(0, 5, 1, 1000), 100);
        assert!(far > near, "far={far} near={near}");
        assert_eq!(noc.hops(0, 5), 3);
        assert_eq!(noc.hops(0, 1), 1);
    }

    #[test]
    fn bigger_packets_take_longer() {
        let mut noc = Noc::with_nodes(&cfg(), 6);
        let small = deliver(&mut noc, pkt(0, 5, 1, 0), 200);
        let big = deliver(&mut noc, pkt(0, 5, 9, 1000), 200);
        assert!(big > small, "big={big} small={small}");
    }

    #[test]
    fn perfect_mode_is_instant() {
        let mut c = cfg();
        c.noc_mode = NocMode::Perfect;
        let mut noc = Noc::with_nodes(&c, 6);
        assert!(noc.inject(Subnet::Reply, pkt(0, 5, 9, 0)));
        assert!(noc.eject(Subnet::Reply, 5).is_some(), "no tick needed");
    }

    #[test]
    fn injection_backpressure() {
        let mut noc = Noc::with_nodes(&cfg(), 6);
        let mut accepted = 0;
        for i in 0..100 {
            if noc.inject(Subnet::Request, pkt(0, 5, 4, i)) {
                accepted += 1;
            }
        }
        assert!(accepted < 100, "bounded queues must reject eventually");
        assert!(accepted >= cfg().noc_inject_depth as i32 as usize);
    }

    #[test]
    fn subnets_are_independent() {
        let mut noc = Noc::with_nodes(&cfg(), 6);
        assert!(noc.inject(Subnet::Request, pkt(0, 3, 1, 0)));
        assert!(noc.inject(Subnet::Reply, pkt(3, 0, 1, 0)));
        for t in 0..100 {
            noc.tick(t);
        }
        assert!(noc.eject(Subnet::Request, 3).is_some());
        assert!(noc.eject(Subnet::Reply, 0).is_some());
        assert!(noc.eject(Subnet::Request, 0).is_none());
    }

    #[test]
    fn all_packets_eventually_delivered_under_load() {
        let mut noc = Noc::with_nodes(&cfg(), 9);
        let mut sent = 0u32;
        let mut got = 0u32;
        let mut t = 0u64;
        // Saturate from every node toward node 4 (center) and drain.
        while t < 5_000 {
            for src in 0..9 {
                if src != 4 && sent < 300 && noc.inject(Subnet::Request, pkt(src, 4, 2, t)) {
                    sent += 1;
                }
            }
            noc.tick(t);
            while noc.eject(Subnet::Request, 4).is_some() {
                got += 1;
            }
            t += 1;
        }
        assert_eq!(got, sent, "conservation: every injected packet ejects");
        assert!(sent >= 290, "should accept most offered load: {sent}");
        assert!(!noc.busy());
    }

    #[test]
    fn layout_all_private_matches_historical_map() {
        let l = ChipLayout::homogeneous(3, false, 2);
        assert_eq!(l.sm_nodes(), 6);
        assert_eq!(l.nodes(), 8);
        for ci in 0..3 {
            assert_eq!(l.nodes_of(ci), [2 * ci, 2 * ci + 1]);
            assert_eq!(l.cluster_of_node(2 * ci), ci);
            assert_eq!(l.cluster_of_node(2 * ci + 1), ci);
        }
        assert_eq!(l.mc_node(0), 6);
        assert_eq!(l.mc_node(1), 7);
        assert!(!l.any_fused());
        assert!(!l.is_mixed());
    }

    #[test]
    fn layout_all_fused_matches_historical_map() {
        let l = ChipLayout::homogeneous(3, true, 2);
        assert_eq!(l.sm_nodes(), 3);
        assert_eq!(l.nodes(), 5);
        for ci in 0..3 {
            assert_eq!(l.nodes_of(ci), [ci, ci]);
            assert_eq!(l.cluster_of_node(ci), ci);
        }
        assert_eq!(l.mc_node(0), 3);
        assert!(l.any_fused());
        assert!(!l.is_mixed());
    }

    #[test]
    fn mixed_layout_interleaves_bypassed_routers() {
        // Clusters: private, fused, private, fused.
        let l = ChipLayout::new(vec![false, true, false, true], 2);
        assert_eq!(l.sm_nodes(), 6);
        assert_eq!(l.nodes_of(0), [0, 1]);
        assert_eq!(l.nodes_of(1), [2, 2]);
        assert_eq!(l.nodes_of(2), [3, 4]);
        assert_eq!(l.nodes_of(3), [5, 5]);
        assert!(l.is_mixed());
        // Inverse is consistent for every SM node.
        for ci in 0..l.n_clusters() {
            for n in l.nodes_of(ci) {
                assert_eq!(l.cluster_of_node(n), ci);
            }
        }
        // MCs sit after the last SM node.
        assert_eq!(l.mc_node(0), 6);
        assert_eq!(l.mc_node(1), 7);
        // The NoC built from the layout covers exactly these endpoints.
        let noc = Noc::new(&cfg(), &l);
        assert_eq!(noc.nodes(), 8);
    }

    #[test]
    fn busy_bookkeeping_tracks_queues_and_ejections() {
        let mut noc = Noc::with_nodes(&cfg(), 9);
        assert!(!noc.busy());
        assert_eq!(noc.ejectable_nodes(Subnet::Request), 0);
        let e0 = noc.inject_epoch();
        assert!(noc.inject(Subnet::Request, pkt(0, 5, 2, 0)));
        assert!(noc.inject_epoch() > e0, "router injection bumps the epoch");
        assert!(noc.busy(), "queued packet marks the fabric busy");
        let mut t = 0;
        while noc.ejectable_nodes(Subnet::Request) == 0 && t < 200 {
            noc.tick(t);
            t += 1;
        }
        assert_eq!(noc.ejectable_nodes(Subnet::Request), 1);
        assert!(noc.has_ejectable(Subnet::Request, 5));
        assert!(noc.eject(Subnet::Request, 5).is_some());
        assert_eq!(noc.ejectable_nodes(Subnet::Request), 0);
        assert!(!noc.busy(), "drained fabric is no longer busy");
        // Self-delivery and Perfect mode bypass the routers: no epoch bump,
        // but the ejectable count still tracks.
        let e1 = noc.inject_epoch();
        assert!(noc.inject(Subnet::Reply, pkt(3, 3, 1, t)));
        assert_eq!(noc.inject_epoch(), e1);
        assert_eq!(noc.ejectable_nodes(Subnet::Reply), 1);
        assert!(noc.eject(Subnet::Reply, 3).is_some());
    }

    #[test]
    fn active_sweep_matches_rotated_visit_order_under_contention() {
        // Two sources feed one sink; the busy-subset sweep must arbitrate
        // exactly like the dense rotated sweep: conservation plus a
        // deterministic delivery count per cycle.
        let mut noc = Noc::with_nodes(&cfg(), 9);
        let mut sent = 0u32;
        let mut got = 0u32;
        for t in 0..3_000u64 {
            for src in [0usize, 8] {
                if sent < 60 && noc.inject(Subnet::Request, pkt(src, 4, 3, t)) {
                    sent += 1;
                }
            }
            noc.tick(t);
            while noc.eject(Subnet::Request, 4).is_some() {
                got += 1;
            }
        }
        assert_eq!(sent, got, "active-set sweep must conserve packets");
        assert!(!noc.busy());
    }

    #[test]
    fn hop_penalty_slows_delivery() {
        let mut healthy = Noc::with_nodes(&cfg(), 6);
        let base = deliver(&mut healthy, pkt(0, 5, 1, 0), 200);
        let mut degraded = Noc::with_nodes(&cfg(), 6);
        degraded.set_hop_penalty(4);
        assert_eq!(degraded.hop_penalty(), 4);
        let slow = deliver(&mut degraded, pkt(0, 5, 1, 0), 400);
        assert!(slow > base, "degraded fabric must be slower: {slow} vs {base}");
        // Multi-hop paths pay the penalty per hop.
        assert!(slow >= base + 4 * (degraded.hops(0, 5) as u64 - 1), "slow={slow} base={base}");
    }

    #[test]
    fn request_gate_blocks_new_requests_but_drains_in_flight() {
        let mut noc = Noc::with_nodes(&cfg(), 6);
        assert!(noc.inject(Subnet::Request, pkt(0, 5, 2, 0)), "pre-gate inject");
        noc.set_request_gate(true);
        assert!(noc.request_gate());
        assert!(!noc.can_inject(Subnet::Request, 0), "gated request space");
        assert!(!noc.inject(Subnet::Request, pkt(1, 5, 1, 0)), "gated request inject");
        // The Reply subnet is untouched while gated.
        assert!(noc.can_inject(Subnet::Reply, 0));
        assert!(noc.inject(Subnet::Reply, pkt(5, 0, 1, 0)));
        // In-flight packets keep moving: the fabric drains to empty.
        for t in 0..200 {
            noc.tick(t);
        }
        assert!(noc.eject(Subnet::Request, 5).is_some(), "pre-gate packet delivered");
        assert!(noc.eject(Subnet::Reply, 0).is_some());
        assert!(!noc.busy(), "gated fabric drains");
        // Gated Perfect mode refuses too (same observable contract).
        let mut c = cfg();
        c.noc_mode = NocMode::Perfect;
        let mut ideal = Noc::with_nodes(&c, 6);
        ideal.set_request_gate(true);
        assert!(!ideal.can_inject(Subnet::Request, 0));
        assert!(!ideal.inject(Subnet::Request, pkt(0, 5, 1, 0)));
        assert!(ideal.inject(Subnet::Reply, pkt(0, 5, 1, 0)));
        ideal.set_request_gate(false);
        assert!(ideal.inject(Subnet::Request, pkt(0, 5, 1, 0)), "un-gated again");
    }

    #[test]
    fn noc_state_round_trip_is_byte_identical() {
        use crate::sim::snapshot::{ByteReader, ByteWriter};
        // Load the fabric mid-flight: queued hops, parked ejections, gate
        // and penalty all set.
        let mut noc = Noc::with_nodes(&cfg(), 9);
        for t in 0..20u64 {
            for src in [0usize, 8, 3] {
                let _ = noc.inject(Subnet::Request, pkt(src, 4, 2, t));
            }
            let _ = noc.inject(Subnet::Reply, pkt(4, 0, 1, t));
            noc.tick(t);
        }
        noc.set_hop_penalty(3);
        noc.set_request_gate(true);
        let mut w = ByteWriter::new();
        noc.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut fresh = Noc::with_nodes(&cfg(), 9);
        let mut r = ByteReader::new(&bytes);
        fresh.load_state(&mut r).unwrap();
        r.expect_end().unwrap();
        let mut w2 = ByteWriter::new();
        fresh.save_state(&mut w2);
        assert_eq!(w2.into_bytes(), bytes, "restore must re-save byte-identically");
        assert_eq!(fresh.debug_state(), noc.debug_state());
        assert_eq!(fresh.busy(), noc.busy());
        assert_eq!(fresh.inject_epoch(), noc.inject_epoch());
        // Every strict prefix must fail cleanly (the parse is prefix-
        // decodable, so a cut always lands inside some field).
        for cut in 0..bytes.len() {
            let mut m = Noc::with_nodes(&cfg(), 9);
            let mut r = ByteReader::new(&bytes[..cut]);
            assert!(m.load_state(&mut r).is_err(), "prefix {cut}");
        }
    }

    #[test]
    fn layout_round_trips_through_checkpoint() {
        use crate::sim::snapshot::{ByteReader, ByteWriter};
        let l = ChipLayout::new(vec![false, true, false, true], 2);
        let mut w = ByteWriter::new();
        l.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let l2 = ChipLayout::load(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(l2, l);
        // Zero-cluster input is a clean error, not an assert.
        let mut w = ByteWriter::new();
        w.usize(0);
        w.usize(2);
        let zero = w.into_bytes();
        assert!(ChipLayout::load(&mut ByteReader::new(&zero)).is_err());
    }

    #[test]
    fn smaller_mesh_has_shorter_paths() {
        // The fusion effect (Fig 17/18): halving nodes shrinks the mesh.
        let big = Noc::with_nodes(&cfg(), 56); // 48 SMs + 8 MCs
        let small = Noc::with_nodes(&cfg(), 32); // 24 fused + 8 MCs
        let max_hops_big = (0..56).map(|n| big.hops(0, n)).max().unwrap();
        let max_hops_small = (0..32).map(|n| small.hops(0, n)).max().unwrap();
        assert!(max_hops_small < max_hops_big);
    }
}
