//! Simulation substrates: SM cores, memory system, NoC, and the top-level
//! GPU cycle loop.

pub mod bisect;
pub mod core;
pub mod event;
pub mod fault;
pub mod gpu;
pub mod mem;
pub mod noc;
pub mod sched;
pub mod snapshot;

pub use event::NextEvent;
pub use sched::ActiveSet;
pub use snapshot::Checkpoint;
