//! Checkpoint/restore of a running [`crate::sim::gpu::Gpu`].
//!
//! A [`Checkpoint`] is a versioned, sectioned binary container written by
//! a hand-rolled little-endian byte writer — no serde, no external
//! dependencies. Each machine component serializes into its own named
//! section ("cluster.3", "noc", "mc.0", ...), which buys two things:
//!
//! * **Diffability.** Two checkpoints taken at the same cycle can be
//!   compared section-by-section ([`Checkpoint::diff`]), so a divergence
//!   names the component that diverged instead of a byte offset. The
//!   `amoeba bisect` time-travel debugger is built on this.
//! * **Forward evolution.** Unknown sections are carried opaquely;
//!   the format version gates structural changes (see README
//!   "Checkpoint & migration" for the version policy).
//!
//! The hard contract — enforced in `tests/exec_determinism.rs` — is that
//! restoring a checkpoint and continuing is **bit-identical** to the
//! uninterrupted run, in both the dense and the event-horizon execution
//! modes. To make that hold, the capture canonicalizes first: every
//! parked component is replayed to the capture cycle
//! (`wake_everything`), so dense and active checkpoints of the same run
//! at the same cycle are byte-comparable, and the restored machine
//! starts from the all-active state both modes agree on.
//!
//! What is *not* captured (rebuilt instead): cache/NoC geometry and every
//! config-derived constant (reconstructed from the caller's
//! `SystemConfig`), the `ActiveSet` parking heap (restore starts
//! all-active — the canonical state), scratch buffers, and derived
//! indices (pending-table hash index, ready-warp counts). The workload
//! (trace generators) is pure and is rebuilt from the same
//! profile/stream inputs the original run was given.

use crate::errors::{err, Result};

/// Magic bytes opening every serialized checkpoint.
pub const MAGIC: [u8; 4] = *b"AMBS";
/// Current checkpoint format version. Bump on any incompatible change to
/// a section layout; loaders reject other versions (never panic).
pub const VERSION: u32 = 1;

/// Hard caps the loader enforces before trusting length fields from the
/// wire — corrupt input must fail fast, not allocate unbounded memory.
const MAX_SECTIONS: usize = 65_536;
const MAX_NAME_LEN: usize = 256;

// ---------------------------------------------------------------------
// Byte writer / reader
// ---------------------------------------------------------------------

/// Append-only little-endian byte sink.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        ByteWriter { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as u64 (the format is architecture-independent).
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// `f64` travels as its IEEE bit pattern — exact round trip, NaNs
    /// included.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed UTF-8.
    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Raw bytes, no length prefix (caller frames them).
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }
}

/// Checked little-endian byte source. Every read returns
/// [`crate::errors::Result`] — truncated or corrupt input is an error,
/// never a panic (fuzzed over all prefixes in `tests/prop_invariants.rs`).
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(err(format!(
                "checkpoint truncated: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| err(format!("checkpoint length {v} overflows usize")))
    }

    /// Strict bool: anything but 0/1 is corruption.
    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(err(format!("checkpoint bool field holds {v}"))),
        }
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.usize()?;
        if n > self.remaining() {
            return Err(err(format!("checkpoint string length {n} exceeds remaining bytes")));
        }
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| err(format!("checkpoint string: {e}")))
    }

    /// A length read from the wire that will drive a `Vec` reservation:
    /// bounded by what the remaining bytes could possibly encode
    /// (`min_elem_bytes` per element) so corrupt lengths cannot trigger
    /// huge allocations.
    pub fn seq_len(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.usize()?;
        let cap = self.remaining() / min_elem_bytes.max(1);
        if n > cap {
            return Err(err(format!(
                "checkpoint sequence length {n} exceeds what {} remaining bytes can hold",
                self.remaining()
            )));
        }
        Ok(n)
    }

    /// All input consumed? Section decoders check this so trailing
    /// garbage (a symptom of a layout mismatch) is caught loudly.
    pub fn expect_end(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(err(format!(
                "checkpoint section has {} trailing bytes",
                self.remaining()
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Checkpoint container
// ---------------------------------------------------------------------

/// One named section of machine state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Section {
    pub name: String,
    pub bytes: Vec<u8>,
}

/// A versioned, sectioned snapshot of a running simulator.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Checkpoint {
    /// Sections in serialization order (order is part of the byte
    /// format: `save(load(bytes)) == bytes`).
    pub sections: Vec<Section>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Checkpoint { sections: Vec::new() }
    }

    /// Append a section (names must be unique; the writer controls them).
    pub fn push(&mut self, name: impl Into<String>, bytes: Vec<u8>) {
        self.sections.push(Section { name: name.into(), bytes });
    }

    /// Look up a section's bytes by name.
    pub fn section(&self, name: &str) -> Option<&[u8]> {
        self.sections.iter().find(|s| s.name == name).map(|s| s.bytes.as_slice())
    }

    /// Replace a section's bytes in place (e.g. the fault strip below).
    fn section_mut(&mut self, name: &str) -> Option<&mut Vec<u8>> {
        self.sections.iter_mut().find(|s| s.name == name).map(|s| &mut s.bytes)
    }

    /// Serialize: magic, version, section count, then each section as
    /// (name, byte length, bytes).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.raw(&MAGIC);
        w.u32(VERSION);
        w.usize(self.sections.len());
        for s in &self.sections {
            w.str(&s.name);
            w.usize(s.bytes.len());
            w.raw(&s.bytes);
        }
        w.into_bytes()
    }

    /// Parse a serialized checkpoint. Truncated, corrupt, or
    /// wrong-version input returns an error — never panics, for any
    /// byte prefix (fuzzed in `tests/prop_invariants.rs`).
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut r = ByteReader::new(bytes);
        let magic = r.take(4)?;
        if magic != MAGIC {
            return Err(err("not a checkpoint: bad magic"));
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(err(format!(
                "checkpoint format version {version} unsupported (this build reads {VERSION})"
            )));
        }
        let n = r.usize()?;
        if n > MAX_SECTIONS {
            return Err(err(format!("checkpoint claims {n} sections (cap {MAX_SECTIONS})")));
        }
        let mut sections = Vec::with_capacity(n.min(64));
        for _ in 0..n {
            let name = r.str()?;
            if name.len() > MAX_NAME_LEN {
                return Err(err("checkpoint section name too long"));
            }
            let len = r.usize()?;
            if len > r.remaining() {
                return Err(err(format!(
                    "checkpoint section '{name}' claims {len} bytes, {} remain",
                    r.remaining()
                )));
            }
            let bytes = r.take(len)?.to_vec();
            sections.push(Section { name, bytes });
        }
        r.expect_end()?;
        Ok(Checkpoint { sections })
    }

    /// Write the checkpoint to a file.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_bytes())
            .map_err(|e| err(format!("write checkpoint {}: {e}", path.as_ref().display())))
    }

    /// Read a checkpoint from a file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Checkpoint> {
        let bytes = std::fs::read(path.as_ref())
            .map_err(|e| err(format!("read checkpoint {}: {e}", path.as_ref().display())))?;
        Checkpoint::from_bytes(&bytes)
    }

    /// Names of sections whose bytes differ between two checkpoints
    /// (including sections present on only one side).
    pub fn diff(&self, other: &Checkpoint) -> Vec<String> {
        let mut out = Vec::new();
        for s in &self.sections {
            match other.section(&s.name) {
                Some(b) if b == s.bytes.as_slice() => {}
                _ => out.push(s.name.clone()),
            }
        }
        for s in &other.sections {
            if self.section(&s.name).is_none() {
                out.push(s.name.clone());
            }
        }
        out
    }

    /// [`Checkpoint::diff`] restricted to *machine state*: the "meta"
    /// section (identity of the run) and the "faults" section (the
    /// injected schedule) are excluded. Bisecting a faulted run against
    /// a clean one must report the cycle the machines diverge, not the
    /// cycle-0 difference in their fault schedules.
    pub fn state_diff(&self, other: &Checkpoint) -> Vec<String> {
        self.diff(other)
            .into_iter()
            .filter(|n| n != "meta" && n != "faults")
            .collect()
    }

    /// Total serialized size in bytes.
    pub fn byte_len(&self) -> usize {
        self.to_bytes().len()
    }

    /// Drop every fault event the captured machine had not yet injected
    /// (events at or after the capture cursor). Used by live tenant
    /// migration: the capture happens *before* the failing cycle's
    /// injection, so stripping the pending tail yields the same machine
    /// on a chip that will never fault.
    pub fn strip_pending_faults(&mut self) -> Result<()> {
        let bytes = self
            .section("faults")
            .ok_or_else(|| err("checkpoint has no faults section"))?;
        let mut r = ByteReader::new(bytes);
        let (events, cursor) = crate::sim::fault::read_fault_section(&mut r)?;
        r.expect_end()?;
        let kept: Vec<_> = events.into_iter().take(cursor).collect();
        let mut w = ByteWriter::new();
        crate::sim::fault::write_fault_section(&mut w, &kept, cursor);
        *self.section_mut("faults").expect("section existed above") = w.into_bytes();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_round_trip() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.usize(42);
        w.bool(true);
        w.bool(false);
        w.f64(-1.5e300);
        w.str("hello §nap");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.usize().unwrap(), 42);
        assert!(r.bool().unwrap());
        assert!(!r.bool().unwrap());
        assert_eq!(r.f64().unwrap(), -1.5e300);
        assert_eq!(r.str().unwrap(), "hello §nap");
        r.expect_end().unwrap();
    }

    #[test]
    fn truncated_reads_error() {
        let mut w = ByteWriter::new();
        w.u64(5);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = ByteReader::new(&bytes[..cut]);
            assert!(r.u64().is_err(), "prefix {cut} must not parse");
        }
    }

    #[test]
    fn bad_bool_is_corruption() {
        let mut r = ByteReader::new(&[2]);
        assert!(r.bool().is_err());
    }

    fn sample() -> Checkpoint {
        let mut c = Checkpoint::new();
        c.push("meta", vec![1, 2, 3]);
        c.push("cluster.0", vec![4, 5]);
        c.push("noc", vec![]);
        c
    }

    #[test]
    fn container_round_trip_is_byte_identical() {
        let c = sample();
        let bytes = c.to_bytes();
        let c2 = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(c, c2);
        assert_eq!(c2.to_bytes(), bytes, "save(load(bytes)) == bytes");
    }

    #[test]
    fn any_truncation_fails_cleanly() {
        let bytes = sample().to_bytes();
        for cut in 0..bytes.len() {
            assert!(Checkpoint::from_bytes(&bytes[..cut]).is_err(), "prefix {cut}");
        }
        // Full input parses.
        assert!(Checkpoint::from_bytes(&bytes).is_ok());
    }

    #[test]
    fn wrong_version_and_magic_rejected() {
        let mut bytes = sample().to_bytes();
        bytes[4] ^= 0xFF; // version field
        assert!(Checkpoint::from_bytes(&bytes).is_err());
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn diff_names_changed_sections() {
        let a = sample();
        let mut b = sample();
        assert!(a.diff(&b).is_empty());
        b.section_mut("cluster.0").unwrap().push(9);
        b.push("extra", vec![1]);
        let d = a.diff(&b);
        assert!(d.contains(&"cluster.0".to_string()));
        assert!(d.contains(&"extra".to_string()));
        assert!(!d.contains(&"meta".to_string()));
    }

    #[test]
    fn state_diff_ignores_meta_and_faults() {
        let mut a = sample();
        a.push("faults", vec![1]);
        let mut b = sample();
        b.push("faults", vec![2]);
        b.section_mut("meta").unwrap().push(0);
        assert!(a.state_diff(&b).is_empty());
        assert_eq!(a.diff(&b).len(), 2);
    }
}
