//! Runtime integration: the PJRT-compiled HLO predictor must be
//! numerically equivalent to the native rust logistic, and the compiled
//! train step must learn. Skips (with a loud message) when `artifacts/`
//! has not been built — run `make artifacts` first.

use amoeba_gpu::amoeba::{
    sigmoid, Coefficients, MetricsSample, NativePredictor, ScalePredictor, NUM_FEATURES,
};
use amoeba_gpu::runtime::{HloPredictor, HloTrainer, Runtime};
use amoeba_gpu::workload::Pcg32;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::new() {
        Ok(rt) => {
            if rt.load("predictor_infer").is_ok() {
                Some(rt)
            } else {
                eprintln!("SKIP: artifacts/ missing (run `make artifacts`)");
                None
            }
        }
        Err(e) => {
            eprintln!("SKIP: PJRT unavailable: {e}");
            None
        }
    }
}

/// HLO inference == native logistic across random coefficient/feature
/// draws (the L1 Pallas kernel's numerics survive AOT + PJRT round trip).
#[test]
fn hlo_matches_native_predictor() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg32::new(0x9A71, 1);
    for case in 0..25 {
        let mut weights = [0f32; NUM_FEATURES];
        for w in &mut weights {
            *w = (rng.next_f64() * 8.0 - 4.0) as f32;
        }
        let intercept = (rng.next_f64() * 4.0 - 2.0) as f32;
        let hlo = HloPredictor::new(&rt, weights, intercept).unwrap();
        let mut weights64 = [0f64; NUM_FEATURES];
        for (o, w) in weights64.iter_mut().zip(weights) {
            *o = w as f64;
        }
        let mut native = NativePredictor::with_coeffs(Coefficients {
            weights: weights64,
            intercept: intercept as f64,
        });
        for _ in 0..8 {
            let mut f = [0f64; NUM_FEATURES];
            for v in &mut f {
                // f32-representable values so both paths see identical inputs.
                *v = (rng.next_f64() as f32) as f64;
            }
            let s = MetricsSample { features: f };
            let got = hlo.infer(&s.as_f32()).unwrap();
            let want = native.probability(&s);
            assert!(
                (got - want).abs() < 1e-5,
                "case {case}: hlo {got} vs native {want}"
            );
            assert_eq!(
                got > 0.5,
                native.scale_up(&s),
                "case {case}: decision divergence"
            );
        }
    }
}

/// The compiled train step fits a separable rule and the learned model
/// agrees with a from-scratch rust SGD on the same data.
#[test]
fn hlo_training_matches_rust_sgd() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut trainer = HloTrainer::new(&rt).unwrap();
    let n = trainer.batch;
    let mut rng = Pcg32::new(0x7EA1, 2);
    let mut x = vec![0f32; n * NUM_FEATURES];
    let mut y = vec![0f32; n];
    let mut true_w = [0f32; NUM_FEATURES];
    for w in &mut true_w {
        *w = (rng.next_f64() * 2.0 - 1.0) as f32;
    }
    for i in 0..n {
        let mut dot = 0f32;
        for j in 0..NUM_FEATURES {
            let v = (rng.next_f64() * 2.0 - 1.0) as f32;
            x[i * NUM_FEATURES + j] = v;
            dot += v * true_w[j];
        }
        y[i] = (dot > 0.0) as u8 as f32;
    }

    // Rust-side reference SGD (same math as ref.py).
    let mut rw = vec![0f64; NUM_FEATURES];
    let mut rb = 0f64;
    let lr = 0.9f64;
    for _ in 0..300 {
        let mut gw = vec![0f64; NUM_FEATURES];
        let mut gb = 0f64;
        for i in 0..n {
            let mut z = rb;
            for j in 0..NUM_FEATURES {
                z += rw[j] * x[i * NUM_FEATURES + j] as f64;
            }
            let dz = (sigmoid(z) - y[i] as f64) / n as f64;
            for j in 0..NUM_FEATURES {
                gw[j] += dz * x[i * NUM_FEATURES + j] as f64;
            }
            gb += dz;
        }
        for j in 0..NUM_FEATURES {
            rw[j] -= lr * gw[j];
        }
        rb -= lr * gb;
    }

    let mut loss = f32::MAX;
    for _ in 0..300 {
        loss = trainer.step(&x, &y, lr as f32).unwrap();
    }
    assert!(loss < 0.35, "HLO training failed to fit: loss {loss}");
    // Weight agreement (same trajectory in f32 vs f64; allow slack).
    for j in 0..NUM_FEATURES {
        assert!(
            (trainer.weights[j] as f64 - rw[j]).abs() < 0.15,
            "weight {j}: hlo {} vs rust {}",
            trainer.weights[j],
            rw[j]
        );
    }
    // Both models classify the training set nearly identically.
    let mut agree = 0;
    for i in 0..n {
        let mut zh = trainer.intercept as f64;
        let mut zr = rb;
        for j in 0..NUM_FEATURES {
            zh += trainer.weights[j] as f64 * x[i * NUM_FEATURES + j] as f64;
            zr += rw[j] * x[i * NUM_FEATURES + j] as f64;
        }
        agree += ((zh > 0.0) == (zr > 0.0)) as usize;
    }
    assert!(agree as f64 / n as f64 > 0.97, "agreement {agree}/{n}");
}

/// The batch artifact evaluates many rows at once and matches row-by-row
/// single inference.
#[test]
fn hlo_batch_matches_single() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("predictor_batch").unwrap();
    let mut rng = Pcg32::new(0xBA7C, 3);
    let batch = 64usize;
    let mut x = vec![0f32; batch * NUM_FEATURES];
    for v in &mut x {
        *v = rng.next_f64() as f32;
    }
    let mut weights = [0.3f32; NUM_FEATURES];
    weights[2] = -1.2;
    let b = -0.4f32;
    let xl = xla::Literal::vec1(&x[..])
        .reshape(&[batch as i64, NUM_FEATURES as i64])
        .unwrap();
    let wl = xla::Literal::vec1(&weights[..]);
    let bl = xla::Literal::scalar(b);
    let out = exe.run(&[xl, wl, bl]).unwrap();
    let probs: Vec<f32> = out[0].to_vec().unwrap();
    assert_eq!(probs.len(), batch);
    let single = HloPredictor::new(&rt, weights, b).unwrap();
    for i in (0..batch).step_by(7) {
        let mut row = [0f32; NUM_FEATURES];
        row.copy_from_slice(&x[i * NUM_FEATURES..(i + 1) * NUM_FEATURES]);
        let p = single.infer(&row).unwrap();
        assert!(
            (p - probs[i] as f64).abs() < 1e-5,
            "row {i}: batch {} vs single {p}",
            probs[i]
        );
    }
}
